//! End-to-end driver (the DESIGN.md validation run): SFT-pretrain a base
//! model on the synthetic corpus, then run GRPO with and without SPEC-RL
//! on SynthMath-A, logging reward curves, rollout-token counts, per-stage
//! times and the final benchmark battery. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example e2e_train            # scaled run
//! SPEC_RL_FULL=1 cargo run --release --example e2e_train
//! ```

use anyhow::Result;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::Table;
use spec_rl::runtime::Engine;
use spec_rl::spec::ReuseVariant;
use spec_rl::trainer::eval::summarize;
use spec_rl::util::logging;

fn main() -> Result<()> {
    logging::init();
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts")?;
    let bundle = "tiny_b32";

    // --- stage 1: supervised pretraining (base model) -----------------------
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps.max(3000))?;
    println!("base model ready ({bundle})");

    // --- stage 2: RL with and without speculative rollouts ------------------
    let mut rows = Vec::new();
    for (label, variant) in [("GRPO", ReuseVariant::Off), ("GRPO+SPEC-RL", ReuseVariant::Spec)] {
        let cfg = exp::with_spec(exp::base_config(scale, bundle), variant, None);
        println!("\n=== {label}: {} steps on {} ===", cfg.steps, cfg.dataset);
        let summary = exp::run_one(&eng, cfg, &base, label)?;
        println!(
            "{label}: tokens={} rollout={:.1}s verify={:.1}s total={:.1}s reward={:.3}",
            summary.total_new_tokens,
            summary.rollout_secs,
            summary.verify_secs,
            summary.total_secs,
            summary.final_reward
        );
        rows.push(summary);
    }

    // --- stage 3: report -------------------------------------------------------
    let mut t = Table::new("e2e: GRPO vs GRPO+SPEC-RL (tiny backbone)", &exp::table1_header());
    let base_tokens = rows[0].total_new_tokens;
    let base_rollout = rows[0].rollout_secs;
    exp::table1_row(&mut t, &rows[0], None, None);
    exp::table1_row(&mut t, &rows[1], Some(base_tokens), Some(base_rollout));
    println!("\n{}", t.render());

    let tok_speedup = base_tokens as f64 / rows[1].total_new_tokens.max(1) as f64;
    let time_speedup = base_rollout / rows[1].rollout_secs.max(1e-9);
    let (_, _, avg_off) = summarize(&rows[0].final_eval);
    let (_, _, avg_spec) = summarize(&rows[1].final_eval);
    println!(
        "HEADLINE: token-speedup {tok_speedup:.2}x | rollout-time speedup {time_speedup:.2}x | \
         avg accuracy {:.1} -> {:.1}",
        avg_off * 100.0,
        avg_spec * 100.0
    );
    println!("per-step series: out/grpo_off_{bundle}.csv, out/grpo_spec_{bundle}.csv");
    Ok(())
}
