//! Case studies (paper Figures 12-15): print cached drafts next to the new
//! rollouts with the verified prefix marked, showing where verification
//! rejected and regeneration took over.
//!
//! ```text
//! cargo run --release --example case_study
//! ```

use anyhow::Result;
use spec_rl::config::RunConfig;
use spec_rl::exp;
use spec_rl::metrics::overlap::common_prefix_len;
use spec_rl::runtime::Engine;
use spec_rl::spec::ReuseVariant;
use spec_rl::trainer::Trainer;
use spec_rl::util::logging;

fn main() -> Result<()> {
    logging::init();
    let eng = Engine::load("artifacts")?;
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, 1500)?;

    let cfg = RunConfig {
        bundle: bundle.into(),
        n_prompts: 16,
        prompts_per_step: 8,
        group: 4,
        steps: 0, // driven manually below
        variant: ReuseVariant::Spec,
        ..RunConfig::default()
    };
    let steps_per_epoch = cfg.steps_per_epoch();
    let mut tr = Trainer::new(&eng, cfg, base)?;

    // Epoch 1 fills the cache; a couple of updates shift the policy a bit so
    // verification has something to reject.
    for s in 0..steps_per_epoch {
        tr.step(s)?;
    }
    let tok = tr.tok.clone();
    // snapshot drafts for the prompts the next step will revisit
    let mut drafts = Vec::new();
    for pi in 0..4 {
        let id = pi * tr.cfg.group;
        if let Some(e) = tr.spec.cache.latest(id) {
            drafts.push((pi, id, e.response.clone()));
        }
    }
    let rec = tr.step(steps_per_epoch)?;

    println!("=== SPEC-RL case studies (cf. paper Figures 12-15) ===\n");
    for (pi, id, draft) in drafts {
        let prompt = &tr.train_set[pi].prompt;
        let answer = &tr.train_set[pi].answer;
        let Some(cur) = tr.spec.cache.latest(id) else { continue };
        let shared = common_prefix_len(&draft, &cur.response);
        println!("prompt       : {prompt}   (answer: {answer})");
        println!("old rollout  : {}", tok.decode(&draft));
        println!("new rollout  : {}", tok.decode(&cur.response));
        let marker = "^".repeat(shared);
        println!("verified     : {marker}  ({shared} tokens reused)");
        println!();
    }
    println!(
        "step stats: mean verified prefix {:.1} tokens | full-reuse {:.0}% | {} new tokens",
        rec["prefix_len"],
        rec["full_reuse"] * 100.0,
        rec["tokens_new"] as u64
    );
    Ok(())
}
