//! Quickstart: load the engine, generate from a base policy, and watch one
//! speculative draft-and-verify round do its thing.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use spec_rl::exp;
use spec_rl::rollout::{RolloutEngine, SampleCfg};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
use spec_rl::tokenizer::Tokenizer;
use spec_rl::util::{logging, Rng, StageTimer};

fn main() -> Result<()> {
    logging::init();
    // 1. Load the AOT artifacts into the PJRT runtime (compile-once).
    let eng = Engine::load("artifacts")?;
    println!(
        "loaded manifest: vocab={} prompt_len={} total_len={}",
        eng.manifest.vocab, eng.manifest.prompt_len, eng.manifest.total_len
    );

    // 2. Get a base policy (cached SFT checkpoint, trains one if missing).
    let policy = exp::ensure_base(&eng, "tiny_b32", 1500)?;
    let tok = Tokenizer::new(&eng.manifest.charset);

    // 3. Batched generation through the rollout engine.
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32")?;
    let mut rng = Rng::new(42);
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
    let prompts = ["17+25=", "9*7=", "3+4*2=", "80-35="];
    let reqs: Vec<RolloutRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| RolloutRequest { id: i, prompt: tok.encode_prompt(p) })
        .collect();

    let mut timer = StageTimer::new();
    let (first, s0) =
        spec.collect(&mut rollout, &policy.blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!("\n-- epoch 1 (cold cache: everything decoded) --");
    for r in &first {
        println!("  {:10} -> {}", prompts[r.id], tok.decode(&r.response));
    }
    println!("  new tokens: {}  reused: {}", s0.new_tokens, s0.reused_tokens);

    // 4. Same prompts again: cached rollouts become speculative drafts,
    //    verified inside the decode slot pool (no blocking verify wave).
    let (second, s1) =
        spec.collect(&mut rollout, &policy.blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!("\n-- epoch 2 (drafts verified under the current policy) --");
    for r in &second {
        println!(
            "  {:10} -> {}   (reused {} of {} tokens)",
            prompts[r.id],
            tok.decode(&r.response),
            r.reused,
            r.response.len()
        );
    }
    println!(
        "  drafts={} mean verified prefix={:.1} full-reuse={:.0}% new tokens={}",
        s1.drafts,
        s1.mean_prefix_len,
        s1.full_reuse_ratio * 100.0,
        s1.new_tokens
    );
    println!(
        "\nstage seconds: rollout={:.3} verification={:.3} assembly={:.4}",
        timer.get("rollout"),
        timer.get("verification"),
        timer.get("assembly")
    );
    Ok(())
}
