//! Quickstart: one speculative draft-and-verify round, first sharded
//! across two mock engines (no artifacts needed), then over remote
//! shards on the loopback transport — including surviving one shard
//! dying mid-step — then against the real PJRT runtime when
//! `artifacts/` exists.
//!
//! ```text
//! cargo run --release --example quickstart          # mock shard demo
//! make artifacts && cargo run --release --example quickstart   # + PJRT
//! ```

use anyhow::Result;
use spec_rl::exp;
use spec_rl::rollout::{EnginePool, SampleCfg};
use spec_rl::runtime::{Backend, Engine, Loopback, RemoteBackend, TransportFaults};
use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::tokenizer::{Tokenizer, BOS};
use spec_rl::util::{logging, Rng, StageTimer};

/// Part 1 — `rollout.shards = 2` on mock replicas: the `EnginePool`
/// drives one step's work across two slot pools pulling from one shared
/// steal-queue (LPT-first, mid-step included; see ARCHITECTURE.md §7,
/// "Placement, stealing, and the pinning invariant") and, because
/// sampling and verification use per-task RNG streams (ARCHITECTURE.md
/// §6), the outputs are byte-identical to a single-engine run.
fn sharded_mock_demo() -> Result<()> {
    println!("== part 1: rollout.shards = 2 over mock replicas ==");
    // Two identically-provisioned engines — in production each would be
    // its own device/process; the mock replicas are content-hashed pure
    // functions, so they agree on every distribution by construction.
    // Sharing a virtual clock arms the overlap accounting below
    // (ARCHITECTURE.md §11).
    let shards = MockEngine::clocked_replicas(2, 8, 8, 24, 24);
    let blobs: Vec<_> = shards.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(shards.iter(), "mock")?;

    // 20 sequences over 2x8 slots — 5 prompts x 4 GRPO samples, the
    // trainer's grouped id layout (id = prompt * group + sample); the
    // 4-task tail beyond the initial seats drains through the shared
    // steal-queue mid-step.
    let group = 4usize;
    let reqs: Vec<RolloutRequest> = (0..20)
        .map(|i| RolloutRequest {
            id: i,
            prompt: vec![BOS, 3 + (i as i32 / group as i32), 5],
        })
        .collect();
    // `spec.cache_budget` (config) / `with_cache_budget` (API) caps the
    // rollout cache in *resident tokens* — the prefix trie counts each
    // run shared by a group's samples or by consecutive generations only
    // once (ARCHITECTURE.md §8); past the cap, oldest-version leaves are
    // evicted before any latest entry. Deliberately tight here so the
    // budget can bind on a 20-sequence demo — size a real run from the
    // `cache_tokens` CSV column (ARCHITECTURE.md §10). `with_group` keys
    // the trie by prompt so the group's samples intern one shared spine.
    // `rollout.predict_len` (config) / `with_predict` (API) turns on
    // predicted-length LPT seating: epoch 1 teaches the per-task EWMA
    // each row's realized length, so epoch 2's queue seats the predicted
    // stragglers first (ARCHITECTURE.md §14). Prediction only reorders
    // work — outputs are byte-identical either way.
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5))
        .with_cache_budget(Some(48))
        .with_group(group)
        .with_predict(true);
    let mut rng = Rng::new(42);
    let mut timer = StageTimer::new();

    // epoch 1: cold cache, everything decodes (across both shards)
    let (_, s0) = spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    // epoch 2: cached rollouts become drafts, verified inside each
    // shard's slot pool (lifecycle pinned per engine: KV never migrates —
    // ARCHITECTURE.md, "Sequence lifecycle")
    let (results, s1) = spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;

    println!("epoch 1: new tokens={} (cold cache)", s0.new_tokens);
    println!(
        "epoch 2: drafts={} mean verified prefix={:.1} new tokens={} sequences={}",
        s1.drafts,
        s1.mean_prefix_len,
        s1.new_tokens,
        results.len()
    );
    // Per-shard PipelineStats: device_calls() per engine — on real
    // hardware the shards run concurrently, so the busiest engine is the
    // step's critical path. `steal_count` is how much of the step's tail
    // drained through the shared steal-queue to whichever engine had free
    // slots (ARCHITECTURE.md §7) instead of queueing behind one shard.
    for (shard, calls) in s1.shard_device_calls.iter().enumerate() {
        println!("  shard {shard}: {calls} device calls (verify_seat + decode + refill)");
    }
    println!("  work stolen mid-step: {} items", s1.steal_count);
    // Overlap accounting on the shared virtual clock: the pool submits
    // both shards' forward chains before blocking on either readback, so
    // the realized makespan lands below what a host-serialized driver
    // would pay (the summed device-busy time). On real hardware the same
    // two columns come from the wall clock; here the mock's latency model
    // makes the win visible without devices (ARCHITECTURE.md §11).
    println!(
        "  makespan: {:.1} virtual-s overlapped vs {:.1} serialized ({:.2}x)",
        s1.overlap_makespan,
        s1.serial_makespan,
        s1.serial_makespan / s1.overlap_makespan.max(1e-9)
    );
    // Traffic accounting (the `readback_bytes` / `upload_bytes` CSV
    // columns): sampling runs on-device (ARCHITECTURE.md §12), so each
    // decode round reads back only [B tok | B ptok | B aux] instead of
    // the O(B*V) probs payload the host-sampling path would ship.
    println!(
        "  traffic: {} bytes read back, {} bytes uploaded (device sampling)",
        s1.readback_bytes, s1.upload_bytes
    );
    for (shard, m) in shards.iter().enumerate() {
        println!(
            "  shard {shard} counters: {} total entry calls, {} uploads",
            m.counters().calls.len(),
            m.counters().uploads.len()
        );
    }
    // Cache telemetry from the same merged report: the token budget binds
    // globally across shards (one cache, one budget), and every eviction
    // it forces is surfaced per step. `cache_nodes` / `cache_shared_tokens`
    // are the trie's dedup gauges — shared tokens is what flat
    // per-trajectory storage would hold *on top of* the resident total
    // (ARCHITECTURE.md §8).
    println!(
        "  cache: {} tokens held, {} leaves evicted this step ({} tokens freed)",
        spec.cache.total_tokens(),
        s1.cache_evictions,
        s1.cache_evicted_tokens
    );
    println!(
        "  trie: {} interned runs, {} tokens deduplicated by prefix sharing",
        s1.cache_nodes, s1.cache_shared_tokens
    );
    // §14 telemetry (the `predict_err` / `draft_len_mean` / `draft_len_max`
    // / `draft_trunc` CSV columns): the error gauge is the mean
    // |predicted − realized| length over rows that had an estimate when
    // the step was scheduled, measured *before* this step's lengths fold
    // into the EWMA; the draft-length columns summarize what the (here
    // uncapped) draft clamp actually offered for verification.
    println!(
        "  predictor: mean |err|={:.2} tokens over {} scored rows",
        s1.mean_predict_err, s1.predict_rows
    );
    println!(
        "  drafts offered: mean len={:.1} max={} truncated-by-cap={}",
        s1.mean_draft_len, s1.draft_len_hi, s1.draft_trunc
    );
    // Trie-aware fallback gauges (`spec.sibling_drafts`, ARCHITECTURE.md
    // §8): rows whose own leaf was gone but drafted from a surviving
    // sibling spine anyway, the tokens those fallbacks offered, and how
    // deep the drafted prompt groups agreed before diverging.
    println!(
        "  sibling fallbacks: {} rows, {} tokens offered, mean branch depth={:.1}",
        s1.sibling_draft_hits, s1.sibling_draft_tokens, s1.branch_depth_mean
    );
    Ok(())
}

/// Part 2 — the same two-shard pool driven over the wire: each shard is
/// a `RemoteBackend` whose `Loopback` transport wraps a mock engine
/// in-process (ARCHITECTURE.md §13). Entry calls ship u64 handles across
/// the transport; generation blobs never round-trip. Mid-demo one
/// shard's peer dies and the pool finishes the step on the survivor with
/// every task completed exactly once.
fn remote_pool_demo() -> Result<()> {
    println!("\n== part 2: the same pool over the loopback remote transport ==");
    // `EnginePool` is generic over a single `Backend` type, so every
    // shard wraps its engine in `RemoteBackend<Loopback<..>>`; in
    // production each transport would dial a different host or device
    // instead of looping back into this process.
    let shards = MockEngine::clocked_replicas(2, 8, 8, 24, 24);
    let remotes: Vec<_> = shards.iter().map(|m| RemoteBackend::new(Loopback::new(m))).collect();
    // Weights cross the wire once at setup; afterwards only handles do.
    let blobs = remotes
        .iter()
        .map(|r| r.upload_f32(&[0.0], &[1]))
        .collect::<Result<Vec<_>>>()?;
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(remotes.iter(), "mock")?;

    let reqs: Vec<RolloutRequest> = (0..12)
        .map(|i| RolloutRequest { id: i, prompt: vec![BOS, 3 + (i as i32 % 9), 5] })
        .collect();
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
    let mut rng = Rng::new(42);
    let mut timer = StageTimer::new();

    // epoch 1: both remote peers healthy. The overlapped submit/complete
    // driver works through the wire unchanged — the makespan win from
    // part 1 survives because submits return tickets without blocking
    // (tests/remote_loopback.rs pins both properties byte-for-byte).
    let (_, s0) =
        spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!(
        "epoch 1 (healthy): new tokens={} makespan {:.1} virtual-s overlapped vs {:.1} serialized",
        s0.new_tokens, s0.overlap_makespan, s0.serial_makespan
    );

    // Kill shard 1's peer: every data-plane op it sees from now on is
    // refused. The pool retries (`rollout.max_retries`), declares the
    // shard dead, rebuilds its seated rows as drafts from the rollout
    // cache, and completes the step on shard 0 — outputs stay
    // byte-identical to the no-failure run (ARCHITECTURE.md §13,
    // "Dead-shard recovery").
    let faults = TransportFaults { dead_from_op: Some(0), ..Default::default() };
    remotes[1].transport().set_faults(faults);
    let (results, s1) =
        spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!(
        "epoch 2 (shard 1 dead): {} sequences finished, shard failures={}, rows requeued={}",
        results.len(),
        s1.shard_failures,
        s1.requeued_tasks
    );
    Ok(())
}

/// Part 3 — the same flow against the real PJRT runtime (requires
/// `make artifacts`; skipped when missing).
fn pjrt_demo() -> Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n== part 3 skipped: no artifacts/ (run `make artifacts`) ==");
        return Ok(());
    }
    println!("\n== part 3: PJRT engine ==");
    let eng = Engine::load("artifacts")?;
    println!(
        "loaded manifest: vocab={} prompt_len={} total_len={}",
        eng.manifest.vocab, eng.manifest.prompt_len, eng.manifest.total_len
    );

    // A base policy (cached SFT checkpoint; trains one if missing) and a
    // one-shard pool — the single-engine pipeline, unchanged. With N
    // devices you would load N engines and pass N blobs instead.
    let policy = exp::ensure_base(&eng, "tiny_b32", 1500)?;
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32")?;
    let mut rng = Rng::new(42);
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
    let prompts = ["17+25=", "9*7=", "3+4*2=", "80-35="];
    let reqs: Vec<RolloutRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| RolloutRequest { id: i, prompt: tok.encode_prompt(p) })
        .collect();

    let mut timer = StageTimer::new();
    let blobs = [&policy.blob];
    let (first, s0) =
        spec.collect(&mut pool, &blobs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!("\n-- epoch 1 (cold cache: everything decoded) --");
    for r in &first {
        println!("  {:10} -> {}", prompts[r.id], tok.decode(&r.response));
    }
    println!("  new tokens: {}  reused: {}", s0.new_tokens, s0.reused_tokens);

    // Same prompts again: cached rollouts become speculative drafts,
    // verified inside the decode slot pool (no blocking verify wave).
    let (second, s1) =
        spec.collect(&mut pool, &blobs, &reqs, SampleCfg::default(), &mut rng, &mut timer)?;
    println!("\n-- epoch 2 (drafts verified under the current policy) --");
    for r in &second {
        println!(
            "  {:10} -> {}   (reused {} of {} tokens)",
            prompts[r.id],
            tok.decode(&r.response),
            r.reused,
            r.response.len()
        );
    }
    println!(
        "  drafts={} mean verified prefix={:.1} full-reuse={:.0}% new tokens={}",
        s1.drafts,
        s1.mean_prefix_len,
        s1.full_reuse_ratio * 100.0,
        s1.new_tokens
    );
    println!(
        "\nstage seconds: rollout={:.3} verification={:.3} assembly={:.4}",
        timer.get("rollout"),
        timer.get("verification"),
        timer.get("assembly")
    );
    Ok(())
}

fn main() -> Result<()> {
    logging::init();
    sharded_mock_demo()?;
    remote_pool_demo()?;
    pjrt_demo()
}
