//! Reproduce Table 1: {models} × {GRPO, PPO, DAPO} × {vanilla, +SPEC-RL}.
//!
//! Paper-shape expectations (not absolute numbers): SPEC-RL cuts generated
//! tokens 2-3× per algorithm with accuracy preserved, and the per-algorithm
//! lenience defaults (e^0.5 / e^0.3 / e^0.15) apply automatically.
//!
//! ```text
//! cargo run --release --example repro_table1        # nano+tiny backbones
//! SPEC_RL_FULL=1 cargo run ... --example repro_table1   # + small backbone
//! ```

use anyhow::Result;
use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::eval::summarize;
use spec_rl::util::logging;

fn main() -> Result<()> {
    logging::init();
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts")?;
    let bundles: &[&str] =
        if scale.full { &["nano_b32", "tiny_b32", "small_b32"] } else { &["nano_b32", "tiny_b32"] };

    let mut csv = Report::new(
        "out/table1.csv",
        &["model", "algo", "spec", "tokens", "rollout_s", "verify_s", "math", "ood", "avg"],
    );
    for bundle in bundles {
        let base = exp::ensure_base(&eng, bundle, scale.sft_steps)?;
        let mut t = Table::new(&format!("Table 1 — {bundle}"), &exp::table1_header());
        for algo in [Algo::Grpo, Algo::Ppo, Algo::Dapo] {
            let mut baseline_tokens = None;
            let mut baseline_rollout = None;
            for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
                let mut cfg = exp::base_config(scale, bundle);
                cfg.algo = algo;
                cfg.params = algo.default_params();
                cfg.variant = variant;
                cfg.lenience = Lenience::Fixed(cfg.params.default_log_lenience);
                let label = match variant {
                    ReuseVariant::Off => algo.name().to_uppercase(),
                    _ => format!("{}+SPEC-RL", algo.name().to_uppercase()),
                };
                let s = exp::run_one(&eng, cfg, &base, &label)?;
                exp::table1_row(&mut t, &s, baseline_tokens, baseline_rollout);
                let (math, ood, avg) = summarize(&s.final_eval);
                csv.push(&[
                    bundle_index(bundle) as f64,
                    algo_index(algo) as f64,
                    (variant == ReuseVariant::Spec) as u8 as f64,
                    s.total_new_tokens as f64,
                    s.rollout_secs,
                    s.verify_secs,
                    math,
                    ood,
                    avg,
                ]);
                if variant == ReuseVariant::Off {
                    baseline_tokens = Some(s.total_new_tokens);
                    baseline_rollout = Some(s.rollout_secs);
                }
            }
        }
        println!("\n{}", t.render());
    }
    csv.save()?;
    println!("raw rows: out/table1.csv; per-step series: out/<algo>_<variant>_<bundle>.csv");
    Ok(())
}

fn bundle_index(b: &str) -> usize {
    ["nano_b32", "tiny_b32", "small_b32"].iter().position(|x| *x == b).unwrap_or(99)
}

fn algo_index(a: Algo) -> usize {
    match a {
        Algo::Grpo => 0,
        Algo::Ppo => 1,
        Algo::Dapo => 2,
    }
}
