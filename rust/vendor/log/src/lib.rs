//! Offline stand-in for the `log` facade crate.
//!
//! The build image has no crates.io mirror, so this vendored crate provides
//! the (small) subset of the `log` 0.4 API the workspace uses: the five
//! leveled macros, `Log`/`set_logger`/`set_max_level`, and the
//! `Level`/`LevelFilter`/`Record`/`Metadata` types. Swapping in the real
//! crate is a one-line Cargo.toml change; no source edits needed.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record (Error is most severe / lowest value).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (Off disables everything).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a record: level + target module path.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record (level, target, preformatted arguments).
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        if let Some(logger) = LOGGER.get() {
            let record = Record { metadata: Metadata { level, target }, args };
            if logger.enabled(&record.metadata) {
                logger.log(&record);
            }
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
