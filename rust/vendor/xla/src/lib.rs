//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build image carries no XLA shared library, so this crate provides a
//! host-only, API-compatible subset of xla-rs: buffers and literals are
//! real (host `Vec`-backed, uploads/readbacks work, `.npy` reading works),
//! while `PjRtClient::compile` — the only entry that actually needs the
//! PJRT runtime — returns a clear error. Everything gated on built
//! `artifacts/` therefore skips cleanly, and the pure-host coordinator
//! logic (plus the [`crate::Literal`] plumbing it relies on) stays fully
//! buildable and testable. Swapping in the real bindings is a Cargo.toml
//! path change; no source edits are required.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors xla-rs's `Error` shape loosely).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Element types the coordinator moves across the boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Typed storage behind literals/buffers (implementation detail, public
/// only because the [`NativeType`] plumbing names it).
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        }
    }
}

/// Host tensor: typed flat storage + dims.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<usize>,
}

/// Sealed-ish marker for the native types the stub supports.
pub trait NativeType: Sized + Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap_ref(data: &Data) -> Result<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }

    fn unwrap_ref(data: &Data) -> Result<&[f32]> {
        match data {
            Data::F32(v) => Ok(v),
            _ => Err(err("literal element type is not f32")),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }

    fn unwrap_ref(data: &Data) -> Result<&[i32]> {
        match data {
            Data::I32(v) => Ok(v),
            _ => Err(err("literal element type is not i32")),
        }
    }
}

impl Literal {
    pub fn from_slice<T: NativeType>(data: &[T], dims: &[usize]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: dims.to_vec() }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn element_type(&self) -> ElementType {
        self.data.ty()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_ref(&self.data).map(|s| s.to_vec())
    }

    /// Borrow the typed storage without copying (the stub's analog of the
    /// bindings' raw literal view): callers that own reusable scratch can
    /// `extend_from_slice` out of this instead of paying `to_vec`'s fresh
    /// allocation on every readback.
    pub fn as_slice<T: NativeType>(&self) -> Result<&[T]> {
        T::unwrap_ref(&self.data)
    }
}

/// Loading literals from raw on-disk formats (the subset used: `.npy`).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npy<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Self>;
}

impl FromRawBytes for Literal {
    type Context = ();

    /// Minimal NumPy `.npy` reader: v1/v2 headers, little-endian `<f4` and
    /// `<i4`, C order.
    fn read_npy<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Literal> {
        let bytes = std::fs::read(path.as_ref())
            .map_err(|e| err(format!("reading {:?}: {e}", path.as_ref())))?;
        if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
            return Err(err("not an npy file (bad magic)"));
        }
        let major = bytes[6];
        let (hlen, hstart) = match major {
            1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10usize),
            2 | 3 => {
                if bytes.len() < 12 {
                    return Err(err("truncated npy v2 header"));
                }
                (
                    u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
                    12usize,
                )
            }
            _ => return Err(err(format!("unsupported npy version {major}"))),
        };
        if bytes.len() < hstart + hlen {
            return Err(err("truncated npy header"));
        }
        let header = std::str::from_utf8(&bytes[hstart..hstart + hlen])
            .map_err(|_| err("npy header is not utf-8"))?;
        let descr = if header.contains("'<f4'") || header.contains("\"<f4\"") {
            ElementType::F32
        } else if header.contains("'<i4'") || header.contains("\"<i4\"") {
            ElementType::S32
        } else {
            return Err(err(format!("unsupported npy dtype in header: {header}")));
        };
        if header.contains("'fortran_order': True") {
            return Err(err("fortran-order npy not supported"));
        }
        let dims = parse_shape(header)?;
        let n: usize = dims.iter().product();
        let body = &bytes[hstart + hlen..];
        if body.len() < n * 4 {
            return Err(err(format!("npy body too short: {} < {}", body.len(), n * 4)));
        }
        let data = match descr {
            ElementType::F32 => Data::F32(
                body[..n * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            ElementType::S32 => Data::I32(
                body[..n * 4]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
        };
        Ok(Literal { data, dims })
    }
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let open = header.find("'shape':").ok_or_else(|| err("npy header missing shape"))?;
    let rest = &header[open..];
    let lp = rest.find('(').ok_or_else(|| err("npy shape missing '('"))?;
    let rp = rest.find(')').ok_or_else(|| err("npy shape missing ')'"))?;
    let inner = &rest[lp + 1..rp];
    let mut dims = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        dims.push(p.parse::<usize>().map_err(|_| err(format!("bad npy dim '{p}'")))?);
    }
    if dims.is_empty() {
        dims.push(1); // 0-d scalar => one element
    }
    Ok(dims)
}

/// Shape of a device buffer (stub: dims + element type).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    pub dims: Vec<usize>,
    pub ty: ElementType,
}

/// "Device" buffer — host-resident in the stub.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }

    pub fn on_device_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.lit.dims.clone(), ty: self.lit.data.ty() })
    }

    pub fn element_count(&self) -> usize {
        self.lit.element_count()
    }
}

/// Parsed HLO module (stub: retains nothing but the source path).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        // Validate existence so callers get errors at the same point the
        // real bindings would.
        let p = path.as_ref();
        if !p.exists() {
            return Err(err(format!("HLO text file not found: {p:?}")));
        }
        Ok(HloModuleProto { path: p.display().to_string() })
    }
}

/// Computation handle (stub).
#[derive(Clone, Debug)]
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// Compiled executable. Unconstructable in the stub (compile always
/// errors), so `execute_b` can never actually run.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(err("offline xla stub: executables cannot run without the PJRT runtime"))
    }
}

/// PJRT client (stub: uploads work, compilation does not).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(err(format!(
                "buffer_from_host_buffer: dims {dims:?} product {n} != data len {}",
                data.len()
            )));
        }
        Ok(PjRtBuffer { lit: Literal::from_slice(data, dims) })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(err(format!(
            "offline xla stub: cannot compile {} (PJRT runtime unavailable in this image)",
            comp.path
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_and_read_back() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1.0f32, 2.0, 3.0, 4.0], &[2, 2], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[2, 2]);
        let shape = b.on_device_shape().unwrap();
        assert_eq!(shape.dims, vec![2, 2]);
    }

    #[test]
    fn dims_mismatch_is_error() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[3], None).is_err());
    }

    #[test]
    fn npy_roundtrip_v1_header() {
        // hand-rolled v1 npy with two f32s
        let mut header = String::from("{'descr': '<f4', 'fortran_order': False, 'shape': (2,), }");
        let unpadded = 10 + header.len() + 1;
        let pad = (64 - unpadded % 64) % 64;
        header.extend(std::iter::repeat(' ').take(pad));
        header.push('\n');
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f32).to_le_bytes());
        let path = std::env::temp_dir().join("xla_stub_npy_test.npy");
        std::fs::write(&path, &bytes).unwrap();
        let lit = Literal::read_npy(&path, &()).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.5, -2.0]);
        assert_eq!(lit.element_count(), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn compile_is_a_clear_error() {
        let c = PjRtClient::cpu().unwrap();
        let path = std::env::temp_dir().join("xla_stub_fake.hlo.txt");
        std::fs::write(&path, "HloModule m\n").unwrap();
        let proto = HloModuleProto::from_text_file(&path).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(format!("{e}").contains("offline xla stub"));
        let _ = std::fs::remove_file(path);
    }
}
