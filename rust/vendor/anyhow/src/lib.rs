//! Offline stand-in for the `anyhow` crate.
//!
//! Provides the subset of the anyhow 1.x API this workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on `Result` and
//! `Option`), and the `anyhow!` / `bail!` / `ensure!` macros. Error chains
//! are kept as rendered strings (no downcasting), which is all the
//! coordinator needs; `{:#}` prints the full context chain like anyhow.

use std::fmt;

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: `chain[0]` is the outermost context, the last
/// element the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first, `: `-joined.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

/// `Debug` mirrors anyhow's multi-line "Caused by" rendering.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Preserve the source chain as rendered strings.
        let mut chain = vec![err.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = err.source();
        while let Some(src) = cur {
            chain.push(src.to_string());
            cur = src.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_layers_render_in_order() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
    }

    #[test]
    fn with_context_on_option() {
        let v: Result<i32> = None.with_context(|| format!("no {}", "value"));
        assert_eq!(format!("{:#}", v.unwrap_err()), "no value");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).is_err());
        assert!(f(101).is_err());
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let e: Error = Err::<(), Error>(anyhow!("root")).context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }
}
