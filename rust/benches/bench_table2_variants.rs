//! Table 2 regeneration: SPEC-RL vs Random Reuse vs Delayed Reuse
//! (tiny backbone, GRPO). Paper shape: Random is fast but loses accuracy;
//! Delayed keeps accuracy but reuses much less (stale drafts).

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::Table;
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table2_variants: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let mut table = Table::new(
        "Table 2 — reuse variants (tiny, GRPO)",
        &exp::table1_header(),
    );
    let mut base_tokens = None;
    let mut base_secs = None;
    for (label, variant) in [
        ("GRPO", ReuseVariant::Off),
        ("SPEC-RL", ReuseVariant::Spec),
        ("Random Reuse", ReuseVariant::Random),
        ("Delayed Reuse", ReuseVariant::Delayed),
    ] {
        let mut cfg = exp::base_config(scale, bundle);
        cfg.algo = Algo::Grpo;
        cfg.params = Algo::Grpo.default_params();
        cfg.variant = variant;
        cfg.lenience = Lenience::Fixed(0.5);
        let s = exp::run_one(&eng, cfg, &base, label).unwrap();
        exp::table1_row(&mut table, &s, base_tokens, base_secs);
        if variant == ReuseVariant::Off {
            base_tokens = Some(s.total_new_tokens);
            base_secs = Some(s.rollout_secs);
        }
    }
    println!("\n{}", table.render());
    println!("expected shape: Random ~ fastest but lowest AVG; Delayed ~ baseline AVG, least reuse.");
}
