//! Table 1 / Figure 1 regeneration: {backbones} × {GRPO, PPO, DAPO} ×
//! {vanilla, +SPEC-RL}: rollout tokens, speedup, benchmark battery.
//!
//! Scaled defaults run nano+tiny; `SPEC_RL_FULL=1` adds the small backbone
//! and full step counts. Per-step series land in `out/` (Tables 16-27,
//! Figures 8-11); the Figure 1 summary block prints at the end.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::eval::summarize;
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table1: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundles: &[&str] =
        if scale.full { &["nano_b32", "tiny_b32", "small_b32"] } else { &["nano_b32", "tiny_b32"] };

    let mut fig1 = Vec::new(); // (label, tok_speedup, time_speedup, avg_off, avg_spec)
    let mut csv = Report::new(
        "out/table1_bench.csv",
        &["bundle", "algo", "spec", "tokens", "rollout_s", "verify_s", "avg"],
    );
    for (bi, bundle) in bundles.iter().enumerate() {
        let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();
        let mut table = Table::new(&format!("Table 1 — {bundle}"), &exp::table1_header());
        for (ai, algo) in [Algo::Grpo, Algo::Ppo, Algo::Dapo].into_iter().enumerate() {
            let mut base_tokens = None;
            let mut base_secs = None;
            let mut avg_off = 0.0;
            for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
                let mut cfg = exp::base_config(scale, bundle);
                cfg.algo = algo;
                cfg.params = algo.default_params();
                cfg.variant = variant;
                cfg.lenience = Lenience::Fixed(cfg.params.default_log_lenience);
                let label = if variant == ReuseVariant::Off {
                    algo.name().to_uppercase()
                } else {
                    format!("+SPEC-RL")
                };
                let s = exp::run_one(&eng, cfg, &base, &label).unwrap();
                exp::table1_row(&mut table, &s, base_tokens, base_secs);
                let (_, _, avg) = summarize(&s.final_eval);
                csv.push(&[
                    bi as f64,
                    ai as f64,
                    (variant == ReuseVariant::Spec) as u8 as f64,
                    s.total_new_tokens as f64,
                    s.rollout_secs,
                    s.verify_secs,
                    avg,
                ]);
                match variant {
                    ReuseVariant::Off => {
                        base_tokens = Some(s.total_new_tokens);
                        base_secs = Some(s.rollout_secs);
                        avg_off = avg;
                    }
                    _ => {
                        let tok_sp = base_tokens.unwrap() as f64
                            / s.total_new_tokens.max(1) as f64;
                        let time_sp = base_secs.unwrap() / s.rollout_secs.max(1e-9);
                        fig1.push((
                            format!("{bundle}/{}", algo.name()),
                            tok_sp,
                            time_sp,
                            avg_off,
                            avg,
                        ));
                    }
                }
            }
        }
        println!("\n{}", table.render());
    }
    csv.save().unwrap();

    // Figure 1 block: speedup vs average performance
    let mut f1 = Table::new(
        "Figure 1 — speedup vs avg performance (SPEC-RL vs vanilla)",
        &["setting", "tok-speedup", "time-speedup", "avg(vanilla)", "avg(+spec)"],
    );
    for (label, ts, ws, a0, a1) in &fig1 {
        f1.row(vec![
            label.clone(),
            format!("{ts:.2}x"),
            format!("{ws:.2}x"),
            format!("{:.1}", a0 * 100.0),
            format!("{:.1}", a1 * 100.0),
        ]);
    }
    println!("{}", f1.render());
    let mean_ts: f64 = fig1.iter().map(|x| x.1).sum::<f64>() / fig1.len().max(1) as f64;
    println!("mean token-speedup across settings: {mean_ts:.2}x (paper: 2.31x)");
}
