//! Sibling-fallback bench: trie-aware draft selection under eviction
//! pressure (`spec.sibling_drafts`, ARCHITECTURE.md §8).
//!
//! The workload is the grouped pressure scenario from `benchkit`: a full
//! warm epoch, a partial refresh that skips one rotating member per
//! group, and a budget tightening that strands exactly those members
//! while their siblings keep the shared spine. With the knob **off** the
//! stranded rows re-decode their whole response from scratch; with it
//! **on** they ride the longest surviving sibling spine through
//! verification (fully accepted here — the crafted log-probs claim a
//! tiny `p_prev`), so the first measured step pins, at shards {2, 4}:
//!
//! - **strictly fewer device calls** (verify + decode + refill forwards),
//! - **strictly more accepted draft tokens per verify forward**,
//!
//! while outputs stay byte-identical to the two-phase oracle for either
//! knob setting at shards {1, 2, 4} — the §6 per-id streams make the
//! borrowed tokens verify under the requesting row's randomness, so
//! shard count never leaks into results. Writes `BENCH_sibling.json`.

use spec_rl::benchkit::grouped::{self, GroupedCfg};
use spec_rl::benchkit::{Bench, JsonReport};
use spec_rl::rollout::{EnginePool, PipelineStats, RolloutEngine, SampleCfg};
use spec_rl::spec::{Lenience, ReuseVariant, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Mock geometry: enough slots that every grouped row seats in the first
/// wave (24 rows over 2 shards = 32 slots), so the on/off comparison is
/// structural — same seating waves, different draft availability.
const B: usize = 16;
const P: usize = 16;
const T: usize = 64;
const V: usize = 51;
/// Live epochs per run: epoch 0 is the analyzable pressure step the perf
/// pins read; epoch 1 keeps churning the budgeted trie for the identity
/// sweep.
const EPOCHS: usize = 2;
const LOG_LENIENCE: f32 = -0.4;
const SEED: u64 = 21;

/// `eos_bias = 0` replicas: every row decodes exactly to the cap, so the
/// work saved by an accepted sibling prefix is deterministic.
fn mocks_for(n: usize) -> Vec<MockEngine> {
    let mut ms = MockEngine::replicas(n, B, P, T, V);
    for m in &mut ms {
        m.eos_bias = 0.0;
    }
    ms
}

/// The pre-stranded spec state: warm epoch 0, partial refresh at epoch 1,
/// then tighten to the pressure budget (one stranded id per group,
/// siblings intact — see `benchkit::grouped::pressure_budget`).
fn stranded_spec(sibling: bool, cfg: &GroupedCfg) -> SpecRollout {
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(LOG_LENIENCE))
        .with_group(cfg.group)
        .with_sibling_drafts(sibling);
    spec.cache.insert_batch(grouped::pressure_entries(cfg, 0));
    spec.cache.insert_batch(grouped::pressure_refresh(cfg, 1));
    spec.cache.set_token_budget(Some(grouped::pressure_budget(cfg)));
    spec.step = 2;
    spec
}

struct Run {
    /// Per-epoch id-sorted `(id, response, logps)`.
    outs: Vec<Vec<(usize, Vec<i32>, Vec<f32>)>>,
    /// Per-epoch merged pipeline stats.
    stats: Vec<PipelineStats>,
}

impl Run {
    fn accepted(&self) -> usize {
        self.stats.iter().map(|s| s.prefix_tokens).sum()
    }
    fn sibling_hits(&self) -> usize {
        self.stats.iter().map(|s| s.sibling_draft_hits).sum()
    }
}

/// One pressured run. `shards == 0` uses the two-phase oracle on a
/// single engine; `shards > 0` the interleaved pipeline over a pool.
fn drive(sibling: bool, shards: usize) -> Run {
    let cfg = GroupedCfg::default();
    let reqs = grouped::requests(&cfg);
    let scfg = SampleCfg::default();
    let mut spec = stranded_spec(sibling, &cfg);
    let mut rng = Rng::new(SEED);
    let mut timer = StageTimer::new();
    let mocks = mocks_for(shards.max(1));
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let mut run = Run { outs: Vec::new(), stats: Vec::new() };
    if shards == 0 {
        let mut eng = RolloutEngine::new(&mocks[0], "mock").unwrap();
        for _ in 0..EPOCHS {
            let (res, stats) =
                spec.run_two_phase(&mut eng, &blobs[0], &reqs, scfg, &mut rng, &mut timer).unwrap();
            run.outs.push(res.into_iter().map(|r| (r.id, r.response, r.logps)).collect());
            run.stats.push(stats);
        }
    } else {
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        for _ in 0..EPOCHS {
            let (res, stats) =
                spec.collect(&mut pool, &blob_refs, &reqs, scfg, &mut rng, &mut timer).unwrap();
            run.outs.push(res.into_iter().map(|r| (r.id, r.response, r.logps)).collect());
            run.stats.push(stats);
        }
    }
    spec.cache.check_invariants().expect("trie invariants after the pressured run");
    run
}

fn main() {
    let bench = Bench::new(2, 8);
    let cfg = GroupedCfg::default();
    let mut j = JsonReport::new();
    j.int("epochs", EPOCHS)
        .num("log_lenience", LOG_LENIENCE as f64)
        .int("pressure_budget", grouped::pressure_budget(&cfg))
        .int("batch", cfg.batch());
    println!(
        "== sibling bench ({} prompts x {} samples, budget {}, {} epochs) ==",
        cfg.prompts,
        cfg.group,
        grouped::pressure_budget(&cfg),
        EPOCHS
    );

    // -- identity sweep: knob {off, on} x shards {1, 2, 4} vs the oracle --
    for sibling in [false, true] {
        let oracle = drive(sibling, 0);
        if sibling {
            assert!(
                oracle.sibling_hits() > 0,
                "stranded ids must actually take sibling fallbacks"
            );
        } else {
            assert_eq!(oracle.sibling_hits(), 0, "knob off must never take a fallback");
        }
        for shards in [1usize, 2, 4] {
            let live = drive(sibling, shards);
            assert_eq!(
                oracle.outs, live.outs,
                "sibling={sibling} shards={shards}: outputs must be byte-identical to the oracle"
            );
            assert_eq!(
                oracle.accepted(),
                live.accepted(),
                "sibling={sibling} shards={shards}: accepted draft tokens drifted"
            );
            assert_eq!(
                oracle.sibling_hits(),
                live.sibling_hits(),
                "sibling={sibling} shards={shards}: fallback count must be shard-invariant"
            );
        }
    }
    println!("identity sweep: sibling {{off,on}} x shards {{1,2,4}} byte-identical to the oracle");

    // -- perf pins on the pressure step (epoch 0) at shards {2, 4} --------
    for shards in [2usize, 4] {
        let off = drive(false, shards);
        let on = drive(true, shards);
        let (s_off, s_on) = (&off.stats[0], &on.stats[0]);
        assert!(
            s_on.device_calls() < s_off.device_calls(),
            "shards {shards}: sibling drafts must save device calls ({} vs {})",
            s_on.device_calls(),
            s_off.device_calls()
        );
        let per_off = s_off.prefix_tokens as f64 / s_off.verify_calls.max(1) as f64;
        let per_on = s_on.prefix_tokens as f64 / s_on.verify_calls.max(1) as f64;
        assert!(
            per_on > per_off,
            "shards {shards}: accepted tokens per verify forward must grow ({per_on:.1} vs {per_off:.1})"
        );
        println!(
            "shards {shards}: device calls {} -> {} | accepted/verify {per_off:.1} -> {per_on:.1} \
             | {} fallbacks, {} tokens offered, mean branch depth {:.1}",
            s_off.device_calls(),
            s_on.device_calls(),
            s_on.sibling_draft_hits,
            s_on.sibling_draft_tokens,
            s_on.branch_depth_mean
        );
        j.int(&format!("device_calls_off_s{shards}"), s_off.device_calls())
            .int(&format!("device_calls_on_s{shards}"), s_on.device_calls())
            .num(&format!("accepted_per_verify_off_s{shards}"), per_off)
            .num(&format!("accepted_per_verify_on_s{shards}"), per_on)
            .int(&format!("sibling_hits_s{shards}"), s_on.sibling_draft_hits)
            .int(&format!("sibling_tokens_s{shards}"), s_on.sibling_draft_tokens)
            .num(&format!("branch_depth_mean_s{shards}"), s_on.branch_depth_mean);
    }

    // -- timings ----------------------------------------------------------
    for sibling in [false, true] {
        let label = if sibling { "on" } else { "off" };
        let r = bench.run(&format!("pressured step sibling={label} (2 shards, {EPOCHS} epochs)"), || {
            drive(sibling, 2).stats[0].device_calls()
        });
        j.bench(&format!("drive_sibling_{label}"), &r);
    }

    println!("{}", j.render());
    if let Err(e) = j.save("BENCH_sibling.json") {
        eprintln!("could not write BENCH_sibling.json: {e}");
    }
}
