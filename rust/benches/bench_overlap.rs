//! Overlap bench: the adversarial stale-draft step on replicas sharing a
//! virtual clock, measuring the overlapped steal driver's realized
//! makespan against the serialized baseline at equal outputs.
//!
//! Since PR 5 the pool drives each round in two passes — submit every
//! live shard's device chain, then complete the readbacks — so engine
//! forwards on distinct devices run concurrently instead of
//! host-serialized. On the mock's virtual clock
//! (`MockEngine::clocked_replicas`) that shows up as
//! `PipelineStats::overlap_makespan` (realized host-clock delta)
//! dropping strictly below `serial_makespan` (summed device-busy time —
//! exactly what the old round-robin driver realized, since it never let
//! two forwards overlap). Asserts, for `shards ∈ {2, 4}`: byte-identical
//! outputs across placements and shard counts, a strictly lower
//! overlapped makespan, and agreement of the two columns on the
//! serialized disciplines (1 shard, and `Placement::Static`). Writes
//! `BENCH_overlap.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::drafted::{B, LOG_LENIENCE, P, SEED, T, V};
use spec_rl::benchkit::{fmt_secs, stale, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, Placement, SampleCfg, SeqResult};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Draft length: identical for every task, so the placement estimate
/// carries no information about realized work (same as `bench_steal`).
const DRAFT_LEN: usize = 30;

fn main() {
    println!(
        "== overlap bench (clocked mock replicas: B={B}/shard T={T}, {} stale-mod-{} drafts) ==",
        stale::N_TASKS,
        stale::STALE_MOD,
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", B)
        .int("tasks", stale::N_TASKS)
        .int("draft_len", DRAFT_LEN)
        .num("log_lenience", LOG_LENIENCE as f64);

    let mut baseline: Option<Vec<SeqResult>> = None;
    println!("\nshards  overlap makespan  serial makespan  speedup  wall-clock (median)");
    for shards in [1usize, 2, 4] {
        let mut mocks = MockEngine::clocked_replicas(shards, B, P, T, V);
        for m in &mut mocks {
            // Deterministic full-length tails: every rejected row decodes
            // exactly to the cap, so the imbalance is structural.
            m.eos_bias = 0.0;
        }
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let cfg = SampleCfg::default();
        let mut timer = StageTimer::new();

        let mut run = |placement: Placement| {
            let mut spec = stale::warmed(stale::N_TASKS, DRAFT_LEN, V, LOG_LENIENCE)
                .with_placement(placement);
            let mut rng = Rng::new(SEED);
            let reqs = stale::requests(stale::N_TASKS, V);
            spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap()
        };

        let (steal_res, steal_stats) = run(Placement::Steal);
        let (static_res, static_stats) = run(Placement::Static);

        // outputs must be byte-identical across placements AND shard
        // counts (length first: zip alone would pass on truncation)
        assert_eq!(steal_res.len(), stale::N_TASKS, "steal run dropped results");
        assert_eq!(static_res.len(), stale::N_TASKS, "static run dropped results");
        for (a, b) in steal_res.iter().zip(&static_res) {
            assert_eq!((a.id, &a.response), (b.id, &b.response), "placement changed outputs");
            assert_eq!(a.logps, b.logps, "placement changed logps");
        }
        match &baseline {
            None => baseline = Some(steal_res),
            Some(base) => {
                assert_eq!(base.len(), steal_res.len(), "shard count changed result count");
                for (a, b) in base.iter().zip(&steal_res) {
                    assert_eq!((a.id, &a.response), (b.id, &b.response), "shard count leaked");
                    assert_eq!(a.logps, b.logps, "shard count leaked into logps");
                }
            }
        }

        let (ov, se) = (steal_stats.overlap_makespan, steal_stats.serial_makespan);
        assert!(se > 0.0, "{shards} shards: the virtual clock never moved");
        if shards > 1 {
            assert!(
                ov < se,
                "{shards} shards: overlapped makespan {ov} must come out strictly below \
                 the serialized {se}"
            );
            // Static completes every chain inline — a serialized
            // discipline realizes exactly its serial column.
            assert!(
                (static_stats.overlap_makespan - static_stats.serial_makespan).abs() < 1e-6,
                "static realized {} != serialized {}",
                static_stats.overlap_makespan,
                static_stats.serial_makespan
            );
        } else {
            assert!(
                (ov - se).abs() < 1e-6,
                "one shard has nothing to overlap with: {ov} vs {se}"
            );
        }

        let r_time = bench.run(&format!("overlapped steal over {shards} shard(s)"), || {
            run(Placement::Steal)
        });

        let speedup = se / ov.max(1e-12);
        println!(
            "{shards:>6}  {ov:>16.1}  {se:>15.1}  {speedup:>6.2}x  {:>19}",
            fmt_secs(r_time.median_secs)
        );
        j.num(&format!("s{shards}_overlap_makespan"), ov)
            .num(&format!("s{shards}_serial_makespan"), se)
            .num(&format!("s{shards}_overlap_speedup"), speedup)
            .num(&format!("s{shards}_static_overlap_makespan"), static_stats.overlap_makespan)
            .num(&format!("s{shards}_static_serial_makespan"), static_stats.serial_makespan)
            .bench(&format!("s{shards}"), &r_time);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_overlap.json") {
        eprintln!("could not write BENCH_overlap.json: {e}");
    }
}
