//! Sharding bench: the phase-aware pipeline over an `EnginePool` of 1, 2,
//! and 4 engines, at equal outputs (per-task sampling and verification RNG
//! streams make results shard-count-invariant).
//!
//! Runs against mock replicas, so it needs no artifacts and measures pure
//! placement efficiency on the skewed 40-draft workload: per-engine
//! device-call totals (the critical path when shards run on their own
//! devices — the busiest engine must strictly shrink as the pool grows),
//! the cross-shard balance, and host-side wall-clock. Writes
//! `BENCH_shards.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::drafted::{
    epoch1_rng, requests, warmed, B, LOG_LENIENCE, N_TASKS, P, SEED, T, V,
};
use spec_rl::benchkit::{fmt_secs, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, SampleCfg, SeqResult};
use spec_rl::spec::{Lenience, ReuseVariant, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

fn main() {
    println!(
        "== shards bench (mock replicas: B={B}/shard T={T}, {N_TASKS} drafted tasks, log l={LOG_LENIENCE}) =="
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", B).int("tasks", N_TASKS).num("log_lenience", LOG_LENIENCE as f64);

    let mut baseline: Option<Vec<SeqResult>> = None;
    let mut prev_max = usize::MAX;
    println!("\nshards  device calls (total)  busiest engine  idlest engine  wall-clock (median)");
    for shards in [1usize, 2, 4] {
        let mocks = MockEngine::replicas(shards, B, P, T, V);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let cfg = SampleCfg::default();
        let mut timer = StageTimer::new();

        // epoch 0 (cold cache) once: its results template the drafts
        let mut spec0 = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(LOG_LENIENCE));
        let mut rng = Rng::new(SEED);
        let (template, _) =
            spec0.collect(&mut pool, &blob_refs, &requests(), cfg, &mut rng, &mut timer).unwrap();

        let r_time = bench.run(&format!("pipeline over {shards} shard(s)"), || {
            let mut spec = warmed(&template);
            let mut rng = epoch1_rng();
            spec.collect(&mut pool, &blob_refs, &requests(), cfg, &mut rng, &mut timer).unwrap()
        });

        // one counted pass for per-engine device traffic + equivalence
        for m in &mocks {
            m.reset_counters();
        }
        let mut spec = warmed(&template);
        let mut rng = epoch1_rng();
        let (res, stats) = spec
            .collect(&mut pool, &blob_refs, &requests(), cfg, &mut rng, &mut timer)
            .unwrap();
        let per_engine: Vec<usize> = mocks.iter().map(|m| m.device_calls()).collect();
        assert_eq!(stats.shard_device_calls, per_engine, "telemetry must match counters");

        match &baseline {
            None => baseline = Some(res),
            Some(base) => {
                for (a, b) in base.iter().zip(&res) {
                    assert_eq!((a.id, &a.response), (b.id, &b.response), "outputs must be equal");
                    assert_eq!(a.logps, b.logps, "logps must be equal");
                }
            }
        }
        let max = *per_engine.iter().max().unwrap();
        let min = *per_engine.iter().min().unwrap();
        assert!(
            max < prev_max,
            "busiest engine must strictly shrink as shards grow ({max} !< {prev_max})"
        );
        prev_max = max;

        println!(
            "{shards:>6}  {:>20}  {:>14}  {:>13}  {:>19}",
            stats.device_calls(),
            max,
            min,
            fmt_secs(r_time.median_secs)
        );
        j.int(&format!("s{shards}_device_calls_total"), stats.device_calls())
            .int(&format!("s{shards}_device_calls_max_per_engine"), max)
            .int(&format!("s{shards}_device_calls_min_per_engine"), min)
            .int(&format!("s{shards}_new_tokens"), stats.new_tokens)
            .int(&format!("s{shards}_reused_tokens"), stats.reused_tokens)
            .bench(&format!("s{shards}"), &r_time);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_shards.json") {
        eprintln!("could not write BENCH_shards.json: {e}");
    }
}
