//! Table 6 regeneration: dataset generality — GRPO ± SPEC-RL on
//! SynthMath-A (DeepMath analog) and SynthMath-B (SimpleRL analog).
//!
//! Paper shape: efficiency and accuracy improvements hold on both
//! training distributions.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::Table;
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table6_datasets: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let mut table = Table::new("Table 6 — dataset generality (tiny, GRPO)", &exp::table1_header());
    for dataset in ["SynthMath-A", "SynthMath-B"] {
        let mut base_tokens = None;
        let mut base_secs = None;
        for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
            let mut cfg = exp::base_config(scale, bundle);
            cfg.dataset = dataset.into();
            cfg.algo = Algo::Grpo;
            cfg.params = Algo::Grpo.default_params();
            cfg.variant = variant;
            cfg.lenience = Lenience::Fixed(0.5);
            let label = if variant == ReuseVariant::Off {
                format!("GRPO [{dataset}]")
            } else {
                "+SPEC-RL".to_string()
            };
            let s = exp::run_one(&eng, cfg, &base, &label).unwrap();
            exp::table1_row(&mut table, &s, base_tokens, base_secs);
            if variant == ReuseVariant::Off {
                base_tokens = Some(s.total_new_tokens);
                base_secs = Some(s.rollout_secs);
            }
        }
    }
    println!("\n{}", table.render());
}
