//! Table 5 regeneration: the larger backbone (small_b32, the
//! Qwen3-14B-analog) across the three algorithms, vanilla vs +SPEC-RL.
//!
//! Paper shape: efficiency gains persist (or grow) at larger scale with
//! accuracy preserved.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::Table;
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table5_scale: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "small_b32";
    if eng.bundle(bundle).is_err() {
        eprintln!("bundle {bundle} missing; re-run `make artifacts MODELS=nano,tiny,small,critic`");
        return;
    }
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let algos: &[Algo] =
        if scale.full { &[Algo::Grpo, Algo::Ppo, Algo::Dapo] } else { &[Algo::Grpo] };
    let mut table = Table::new("Table 5 — larger backbone (small)", &exp::table1_header());
    for &algo in algos {
        let mut base_tokens = None;
        let mut base_secs = None;
        for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
            let mut cfg = exp::base_config(scale, bundle);
            cfg.algo = algo;
            cfg.params = algo.default_params();
            cfg.variant = variant;
            cfg.lenience = Lenience::Fixed(cfg.params.default_log_lenience);
            let label = if variant == ReuseVariant::Off {
                algo.name().to_uppercase()
            } else {
                "+SPEC-RL".to_string()
            };
            let s = exp::run_one(&eng, cfg, &base, &label).unwrap();
            exp::table1_row(&mut table, &s, base_tokens, base_secs);
            if variant == ReuseVariant::Off {
                base_tokens = Some(s.total_new_tokens);
                base_secs = Some(s.rollout_secs);
            }
        }
    }
    println!("\n{}", table.render());
}
