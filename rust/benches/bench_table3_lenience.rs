//! Table 3 / Figures 4-5 regeneration: the lenience sweep
//! ℓ ∈ {0 (vanilla), 1, e^0.2, e^0.5, e^0.8, e^2, ∞} on tiny+GRPO.
//!
//! Paper shape: speedup grows monotonically with ℓ (1.22x -> 14.9x);
//! accuracy peaks at moderate ℓ (e^0.5) and collapses at ℓ=∞. The
//! Figure 5 block reports entropy/KL/clip-fraction means per ℓ, which
//! should rise with ℓ.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::eval::summarize;
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table3_lenience: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let sweep: Vec<(&str, ReuseVariant, Lenience)> = vec![
        ("GRPO (l=0)", ReuseVariant::Off, Lenience::Zero),
        ("l=1", ReuseVariant::Spec, Lenience::Fixed(0.0)),
        ("l=e^0.2", ReuseVariant::Spec, Lenience::Fixed(0.2)),
        ("l=e^0.5", ReuseVariant::Spec, Lenience::Fixed(0.5)),
        ("l=e^0.8", ReuseVariant::Spec, Lenience::Fixed(0.8)),
        ("l=e^2.0", ReuseVariant::Spec, Lenience::Fixed(2.0)),
        ("l=inf", ReuseVariant::Full, Lenience::Infinite),
    ];

    let mut table = Table::new("Table 3 — lenience sweep (tiny, GRPO)", &exp::table1_header());
    let mut fig5 = Table::new(
        "Figure 5 — training dynamics vs lenience",
        &["lenience", "entropy", "kl", "clip_frac", "prefix_len", "full_reuse"],
    );
    let mut csv = Report::new(
        "out/table3_lenience.csv",
        &["loglen", "tokens", "rollout_s", "avg", "entropy", "kl", "clip_frac", "prefix_len"],
    );
    let mut base_tokens = None;
    let mut base_secs = None;
    for (label, variant, len) in sweep {
        let mut cfg = exp::base_config(scale, bundle);
        cfg.algo = Algo::Grpo;
        cfg.params = Algo::Grpo.default_params();
        cfg.variant = variant;
        cfg.lenience = len;
        let mut trainer =
            spec_rl::trainer::Trainer::new(&eng, cfg, base.duplicate(&eng).unwrap()).unwrap();
        let summary = trainer.run(label).unwrap();
        exp::table1_row(&mut table, &summary, base_tokens, base_secs);
        if variant == ReuseVariant::Off {
            base_tokens = Some(summary.total_new_tokens);
            base_secs = Some(summary.rollout_secs);
        }
        // series means for Figure 5
        let mean = |col: &str| {
            let v = trainer.report.column(col).unwrap_or_default();
            let vals: Vec<f64> = v.into_iter().filter(|x| !x.is_nan()).collect();
            vals.iter().sum::<f64>() / vals.len().max(1) as f64
        };
        let (ent, kl, cf, pl, fr) = (
            mean("entropy"),
            mean("kl"),
            mean("clip_frac"),
            mean("prefix_len"),
            mean("full_reuse"),
        );
        fig5.row(vec![
            label.to_string(),
            format!("{ent:.3}"),
            format!("{kl:.5}"),
            format!("{cf:.5}"),
            format!("{pl:.1}"),
            format!("{fr:.2}"),
        ]);
        let (_, _, avg) = summarize(&summary.final_eval);
        let loglen = match len {
            Lenience::Zero => -9.0,
            Lenience::Infinite => 9.0,
            Lenience::Fixed(x) => x as f64,
            _ => f64::NAN,
        };
        csv.push(&[
            loglen,
            summary.total_new_tokens as f64,
            summary.rollout_secs,
            avg,
            ent,
            kl,
            cf,
            pl,
        ]);
    }
    println!("\n{}", table.render());
    println!("{}", fig5.render());
    csv.save().unwrap();
    println!("expected shape: tokens fall monotonically with l; AVG peaks at moderate l; entropy/KL/clip rise with l.");
}
