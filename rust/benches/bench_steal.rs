//! Work-stealing bench: the same adversarial stale-draft step under PR 3's
//! one-pass static placement vs the PR 4 shared steal-queue, at equal
//! outputs (per-task sampling and verification RNG streams make results
//! placement-invariant).
//!
//! The workload (`benchkit::stale`) is the static-placement worst case:
//! 40 same-length drafts (the LPT estimate is uninformative, so one-pass
//! placement degenerates to round-robin by id) where every 4th draft is
//! stale — rejected at ~offset 0, re-decoding its whole response — and
//! staleness is id-correlated, so static placement pins *every* expensive
//! draft to shard 0. `eos_bias = 0` makes realized lengths deterministic.
//! Asserts, for `shards ∈ {2, 4}`: byte-identical outputs, a strictly
//! lower busiest-engine device-call total under stealing (`shard_calls_max`
//! is the step's critical path when shards run on their own devices), and
//! `steal_count > 0`. Writes `BENCH_steal.json` for machine diffing / the
//! CI smoke run.

use spec_rl::benchkit::drafted::{B, LOG_LENIENCE, P, SEED, T, V};
use spec_rl::benchkit::{fmt_secs, stale, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, Placement, SampleCfg, SeqResult};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Draft length: identical for every task, so the placement estimate
/// carries no information about realized work.
const DRAFT_LEN: usize = 30;

fn main() {
    println!(
        "== steal bench (mock replicas: B={B}/shard T={T}, {} stale-mod-{} drafts, log l={LOG_LENIENCE}) ==",
        stale::N_TASKS,
        stale::STALE_MOD,
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", B)
        .int("tasks", stale::N_TASKS)
        .int("draft_len", DRAFT_LEN)
        .num("log_lenience", LOG_LENIENCE as f64);

    let mut baseline: Option<Vec<SeqResult>> = None;
    println!(
        "\nshards  static max/engine  steal max/engine  steals  steal wall-clock (median)"
    );
    for shards in [1usize, 2, 4] {
        let mut mocks = MockEngine::replicas(shards, B, P, T, V);
        for m in &mut mocks {
            // Deterministic full-length tails: every rejected row decodes
            // exactly to the cap, so the imbalance is structural, not
            // sampled.
            m.eos_bias = 0.0;
        }
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let cfg = SampleCfg::default();
        let mut timer = StageTimer::new();

        let mut run = |placement: Placement| {
            for m in &mocks {
                m.reset_counters();
            }
            let mut spec = stale::warmed(stale::N_TASKS, DRAFT_LEN, V, LOG_LENIENCE)
                .with_placement(placement);
            let mut rng = Rng::new(SEED);
            let reqs = stale::requests(stale::N_TASKS, V);
            let (res, stats) =
                spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap();
            let per_engine: Vec<usize> = mocks.iter().map(|m| m.device_calls()).collect();
            assert_eq!(stats.shard_device_calls, per_engine, "telemetry must match counters");
            (res, stats, per_engine)
        };

        let (static_res, _static_stats, static_calls) = run(Placement::Static);
        let (steal_res, steal_stats, steal_calls) = run(Placement::Steal);

        // outputs must be byte-identical across placements AND shard counts
        // (length first: zip alone would pass on a truncated result set)
        assert_eq!(static_res.len(), stale::N_TASKS, "static run dropped results");
        assert_eq!(steal_res.len(), stale::N_TASKS, "steal run dropped results");
        for (a, b) in static_res.iter().zip(&steal_res) {
            assert_eq!((a.id, &a.response), (b.id, &b.response), "placement changed outputs");
            assert_eq!(a.logps, b.logps, "placement changed logps");
        }
        match &baseline {
            None => baseline = Some(steal_res),
            Some(base) => {
                assert_eq!(base.len(), steal_res.len(), "shard count changed result count");
                for (a, b) in base.iter().zip(&steal_res) {
                    assert_eq!((a.id, &a.response), (b.id, &b.response), "shard count leaked");
                    assert_eq!(a.logps, b.logps, "shard count leaked into logps");
                }
            }
        }

        let static_max = *static_calls.iter().max().unwrap();
        let steal_max = *steal_calls.iter().max().unwrap();
        if shards > 1 {
            assert!(
                steal_max < static_max,
                "{shards} shards: stealing must strictly tighten the critical path \
                 ({steal_max} !< {static_max})"
            );
            assert!(steal_stats.steal_count > 0, "no steals on the adversarial tail");
        } else {
            assert_eq!(steal_max, static_max, "one shard: the disciplines coincide");
            assert_eq!(steal_stats.steal_count, 0, "a lone engine cannot steal");
        }

        let r_time = bench.run(&format!("steal pipeline over {shards} shard(s)"), || {
            let mut spec = stale::warmed(stale::N_TASKS, DRAFT_LEN, V, LOG_LENIENCE);
            let mut rng = Rng::new(SEED);
            let reqs = stale::requests(stale::N_TASKS, V);
            spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap()
        });

        println!(
            "{shards:>6}  {static_max:>17}  {steal_max:>16}  {:>6}  {:>25}",
            steal_stats.steal_count,
            fmt_secs(r_time.median_secs)
        );
        j.int(&format!("s{shards}_static_calls_max_per_engine"), static_max)
            .int(&format!("s{shards}_steal_calls_max_per_engine"), steal_max)
            .int(&format!("s{shards}_static_calls_total"), static_calls.iter().sum())
            .int(&format!("s{shards}_steal_calls_total"), steal_calls.iter().sum())
            .int(&format!("s{shards}_steal_count"), steal_stats.steal_count)
            .bench(&format!("s{shards}"), &r_time);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_steal.json") {
        eprintln!("could not write BENCH_steal.json: {e}");
    }
}
