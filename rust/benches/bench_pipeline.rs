//! Pipeline bench: two-phase verify-then-decode vs the interleaved
//! phase-aware pipeline, at equal outputs (per-task sampling and
//! verification RNG streams make the two paths byte-identical).
//!
//! Runs against the in-tree mock backend, so it needs no artifacts and
//! measures pure scheduling efficiency on a skewed draft workload: total
//! device-call count (verify + decode + refill — the acceptance metric),
//! per-entry breakdown, and host-side wall-clock. Writes
//! `BENCH_pipeline.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::drafted::{epoch1_rng, requests, warmed, B, LOG_LENIENCE, N_TASKS, T};
use spec_rl::benchkit::{fmt_secs, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, PipelineStats, RolloutEngine, SampleCfg, SeqResult};
use spec_rl::spec::{Lenience, ReuseVariant, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

const P: usize = spec_rl::benchkit::drafted::P;
const V: usize = spec_rl::benchkit::drafted::V;
const SEED: u64 = spec_rl::benchkit::drafted::SEED;

fn main() {
    let m = MockEngine::new(B, P, T, V);
    let blob = m.blob();
    let mut pool = EnginePool::single(&m, "mock").unwrap();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let cfg = SampleCfg::default();
    let mut timer = StageTimer::new();

    // epoch 0 (cold cache) once: its results template the drafts
    let mut spec0 = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(LOG_LENIENCE));
    let mut rng = Rng::new(SEED);
    let (template, _) =
        spec0.collect(&mut pool, &[&blob], &requests(), cfg, &mut rng, &mut timer).unwrap();

    println!(
        "== pipeline bench (mock backend: B={B} T={T}, {N_TASKS} drafted tasks, log l={LOG_LENIENCE}) =="
    );
    let bench = Bench::new(2, 10);

    let r_pipe = bench.run("interleaved pipeline (verify_seat)", || {
        let mut spec = warmed(&template);
        let mut rng = epoch1_rng();
        spec.collect(&mut pool, &[&blob], &requests(), cfg, &mut rng, &mut timer).unwrap()
    });
    let r_two = bench.run("two-phase (verify wave, then decode)", || {
        let mut spec = warmed(&template);
        let mut rng = epoch1_rng();
        spec.run_two_phase(&mut eng, &blob, &requests(), cfg, &mut rng, &mut timer).unwrap()
    });

    // one measured pass each for call counts + output equivalence
    let mut run_counted = |two_phase: bool| -> (Vec<SeqResult>, PipelineStats, usize) {
        let mut spec = warmed(&template);
        let mut rng = epoch1_rng();
        let mut pass_timer = StageTimer::new();
        m.reset_counters();
        let (res, stats) = if two_phase {
            spec.run_two_phase(&mut eng, &blob, &requests(), cfg, &mut rng, &mut pass_timer)
                .unwrap()
        } else {
            spec.collect(&mut pool, &[&blob], &requests(), cfg, &mut rng, &mut pass_timer)
                .unwrap()
        };
        let calls = ["verify", "verify_seat", "decode", "refill"]
            .iter()
            .map(|e| m.calls_of(e))
            .sum();
        (res, stats, calls)
    };
    let (pipe_res, pipe, pipe_calls) = run_counted(false);
    let (two_res, two, two_calls) = run_counted(true);

    assert_eq!(pipe_res.len(), two_res.len());
    for (a, b) in pipe_res.iter().zip(&two_res) {
        assert_eq!((a.id, &a.response), (b.id, &b.response), "outputs must be equal");
        assert_eq!(a.logps, b.logps, "logps must be equal");
    }
    assert_eq!(pipe_calls, pipe.device_calls());
    assert_eq!(two_calls, two.device_calls());
    assert!(
        pipe.device_calls() < two.device_calls(),
        "pipeline must strictly reduce device calls ({} vs {})",
        pipe.device_calls(),
        two.device_calls()
    );

    println!("\n                        pipeline    two-phase");
    println!("verify calls          {:>10}  {:>10}", pipe.verify_calls, two.verify_calls);
    println!("decode steps          {:>10}  {:>10}", pipe.decode_steps, two.decode_steps);
    println!("refills               {:>10}  {:>10}", pipe.refills, two.refills);
    println!("total device calls    {:>10}  {:>10}", pipe.device_calls(), two.device_calls());
    println!("reused tokens         {:>10}  {:>10}", pipe.reused_tokens, two.reused_tokens);
    println!("new tokens            {:>10}  {:>10}", pipe.new_tokens, two.new_tokens);
    println!("mean accepted prefix  {:>10.2}  {:>10.2}", pipe.mean_prefix_len, two.mean_prefix_len);
    println!(
        "wall-clock (median)   {:>10}  {:>10}",
        fmt_secs(r_pipe.median_secs),
        fmt_secs(r_two.median_secs)
    );
    println!(
        "\nspeedup: {:.2}x fewer device calls, {:.2}x wall-clock",
        two.device_calls() as f64 / pipe.device_calls() as f64,
        r_two.median_secs / r_pipe.median_secs.max(1e-12)
    );

    let mut j = JsonReport::new();
    j.int("batch", B)
        .int("tasks", N_TASKS)
        .num("log_lenience", LOG_LENIENCE as f64)
        .int("pipeline_device_calls", pipe.device_calls())
        .int("two_phase_device_calls", two.device_calls())
        .int("pipeline_verify_calls", pipe.verify_calls)
        .int("two_phase_verify_calls", two.verify_calls)
        .int("pipeline_decode_steps", pipe.decode_steps)
        .int("two_phase_decode_steps", two.decode_steps)
        .int("pipeline_refills", pipe.refills)
        .int("two_phase_refills", two.refills)
        .int("new_tokens", pipe.new_tokens)
        .int("reused_tokens", pipe.reused_tokens)
        .bench("pipeline", &r_pipe)
        .bench("two_phase", &r_two);
    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_pipeline.json") {
        eprintln!("could not write BENCH_pipeline.json: {e}");
    }
}
