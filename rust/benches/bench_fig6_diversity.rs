//! Figure 6 regeneration: rollout diversity (Distinct-1, Self-BLEU) of
//! GRPO vs GRPO+SPEC-RL at identical training steps.
//!
//! Paper shape: SPEC-RL matches or slightly improves diversity — reuse
//! does not collapse the batch distribution.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::Trainer;
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_fig6_diversity: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let mut table = Table::new(
        "Figure 6 — diversity (mean over steps, epochs >= 2)",
        &["run", "distinct-1", "self-BLEU"],
    );
    let mut csv = Report::new("out/fig6_diversity.csv", &["spec", "step", "distinct1", "self_bleu"]);
    for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
        let mut cfg = exp::base_config(scale, bundle);
        cfg.algo = Algo::Grpo;
        cfg.params = Algo::Grpo.default_params();
        cfg.variant = variant;
        cfg.lenience = Lenience::Fixed(0.5);
        cfg.eval_n = 4;
        cfg.eval_samples_hard = 1;
        let spe = cfg.steps_per_epoch();
        let mut tr = Trainer::new(&eng, cfg.clone(), base.duplicate(&eng).unwrap()).unwrap();
        let mut d1s = Vec::new();
        let mut sbs = Vec::new();
        for s in 0..cfg.steps {
            let rec = tr.step(s).unwrap();
            csv.push(&[
                (variant == ReuseVariant::Spec) as u8 as f64,
                s as f64,
                rec["distinct1"],
                rec["self_bleu"],
            ]);
            if s >= spe {
                d1s.push(rec["distinct1"]);
                sbs.push(rec["self_bleu"]);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        table.row(vec![
            if variant == ReuseVariant::Off { "GRPO" } else { "GRPO+SPEC-RL" }.into(),
            format!("{:.4}", mean(&d1s)),
            format!("{:.4}", mean(&sbs)),
        ]);
    }
    csv.save().unwrap();
    println!("\n{}", table.render());
    println!("expected shape: +SPEC-RL distinct-1 >= GRPO's; self-BLEU <= GRPO's (equal or more diverse).");
}
