//! Scheduler bench: lockstep waves vs continuous batching on a
//! skewed-length workload, at equal outputs (per-task RNG streams make the
//! two paths produce identical tokens).
//!
//! Runs against the in-tree mock backend, so it needs no artifacts and
//! measures pure scheduling efficiency: decode-executable invocations,
//! slot-idle fraction, and host-side wall-clock. Writes
//! `bench_sched.json` next to the working directory for machine diffing.

use spec_rl::benchkit::{fmt_secs, Bench, JsonReport};
use spec_rl::rollout::{RolloutEngine, SampleCfg, SeqTask};
use spec_rl::testing::mock::MockEngine;
use spec_rl::tokenizer::BOS;
use spec_rl::util::{Rng, StageTimer};

const B: usize = 8;
const P: usize = 16;
const T: usize = 64;
const V: usize = 51;
const N_TASKS: usize = 40;

/// Skewed workload: remaining lengths spread from 1 token to the full
/// generation region (reuse-heavy rows next to fresh rows, the shape
/// SPEC-RL produces after its first epoch).
fn skewed_tasks() -> Vec<SeqTask> {
    let gen_len = T - P;
    (0..N_TASKS)
        .map(|i| {
            let prefix_len = (i * (gen_len - 1) / N_TASKS).min(gen_len - 1);
            SeqTask {
                id: i,
                prompt: vec![BOS, 3 + (i as i32 % 40), 5],
                prefix: (0..prefix_len).map(|j| 3 + ((i + j) as i32 % 40)).collect(),
                prefix_logps: vec![-1.0; prefix_len],
            }
        })
        .collect()
}

fn main() {
    let mut mock = MockEngine::new(B, P, T, V);
    mock.eos_bias = 0.0; // length skew comes from the prefixes: deterministic
    let blob = mock.blob();
    let mut eng = RolloutEngine::new(&mock, "mock").unwrap();
    let cfg = SampleCfg::default();

    println!("== scheduler bench (mock backend: B={B} T={T}, {N_TASKS} skewed tasks) ==");
    let bench = Bench::new(2, 10);

    let mut timer = StageTimer::new();
    let r_cont = bench.run("continuous batching (slot refill)", || {
        let mut rng = Rng::new(7);
        eng.run(&blob, skewed_tasks(), cfg, &mut rng, &mut timer).unwrap()
    });
    let r_lock = bench.run("lockstep waves (baseline)", || {
        let mut rng = Rng::new(7);
        eng.run_lockstep(&blob, skewed_tasks(), cfg, &mut rng, &mut timer).unwrap()
    });

    // one measured pass each for the step/idle stats + output equivalence
    let mut rng = Rng::new(7);
    let (cont_res, cont) = eng.run(&blob, skewed_tasks(), cfg, &mut rng, &mut timer).unwrap();
    let mut rng = Rng::new(7);
    let (lock_res, lock) =
        eng.run_lockstep(&blob, skewed_tasks(), cfg, &mut rng, &mut timer).unwrap();
    assert_eq!(cont_res.len(), lock_res.len());
    for (c, l) in cont_res.iter().zip(&lock_res) {
        assert_eq!((c.id, &c.response), (l.id, &l.response), "outputs must be equal");
    }
    assert!(
        cont.decode_steps < lock.decode_steps,
        "continuous must strictly reduce decode steps ({} vs {})",
        cont.decode_steps,
        lock.decode_steps
    );

    println!("\n                      continuous    lockstep");
    println!("decode_steps        {:>10}  {:>10}", cont.decode_steps, lock.decode_steps);
    println!(
        "slot idle fraction  {:>10.3}  {:>10.3}",
        cont.slot_idle_fraction(B),
        lock.slot_idle_fraction(B)
    );
    println!("prefills (waves)    {:>10}  {:>10}", cont.waves, lock.waves);
    println!("refills             {:>10}  {:>10}", cont.refills, lock.refills);
    println!(
        "wall-clock (median) {:>10}  {:>10}",
        fmt_secs(r_cont.median_secs),
        fmt_secs(r_lock.median_secs)
    );
    println!(
        "\nspeedup: {:.2}x fewer decode steps, {:.2}x wall-clock",
        lock.decode_steps as f64 / cont.decode_steps as f64,
        r_lock.median_secs / r_cont.median_secs.max(1e-12)
    );

    let mut j = JsonReport::new();
    j.int("batch", B)
        .int("tasks", N_TASKS)
        .int("continuous_decode_steps", cont.decode_steps)
        .int("lockstep_decode_steps", lock.decode_steps)
        .num("continuous_slot_idle_fraction", cont.slot_idle_fraction(B))
        .num("lockstep_slot_idle_fraction", lock.slot_idle_fraction(B))
        .int("continuous_refills", cont.refills)
        .int("continuous_new_tokens", cont.new_tokens)
        .int("lockstep_new_tokens", lock.new_tokens)
        .bench("continuous", &r_cont)
        .bench("lockstep", &r_lock);
    println!("\n{}", j.render());
    if let Err(e) = j.save("bench_sched.json") {
        eprintln!("could not write bench_sched.json: {e}");
    }
}
