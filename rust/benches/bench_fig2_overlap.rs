//! Figure 2 regeneration: cross-epoch rollout overlap (ROUGE-1) under
//! *vanilla* GRPO / PPO / DAPO — the redundancy observation that motivates
//! SPEC-RL. The trainer's shadow cache measures overlap without reusing.
//!
//! Paper shape: substantial overlap (~0.5-0.8) that persists across
//! training, similar for all three algorithms.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::model::Policy;
use spec_rl::runtime::Engine;
use spec_rl::spec::ReuseVariant;
use spec_rl::trainer::Trainer;
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_fig2_overlap: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let mut table = Table::new(
        "Figure 2 — mean cross-epoch ROUGE-1 per epoch (vanilla)",
        &["algo", "epoch2", "epoch3", "overall"],
    );
    let mut csv = Report::new("out/fig2_overlap.csv", &["algo", "step", "rouge1"]);
    for (ai, algo) in [Algo::Grpo, Algo::Ppo, Algo::Dapo].into_iter().enumerate() {
        let mut cfg = exp::base_config(scale, bundle);
        cfg.algo = algo;
        cfg.params = algo.default_params();
        cfg.variant = ReuseVariant::Off;
        cfg.eval_n = 4;
        cfg.eval_samples_hard = 1;
        let spe = cfg.steps_per_epoch();
        cfg.steps = (3 * spe).min(scale.steps); // 3 epochs if budget allows
        let base_copy = base.duplicate(&eng).unwrap();
        let mut tr = Trainer::new(&eng, cfg.clone(), base_copy).unwrap();
        let mut per_step: Vec<(usize, f64)> = Vec::new();
        for s in 0..cfg.steps {
            let rec = tr.step(s).unwrap();
            let r = rec["rouge1_prev_epoch"];
            if !r.is_nan() {
                per_step.push((s, r));
                csv.push(&[ai as f64, s as f64, r]);
            }
        }
        let epoch_mean = |e: usize| {
            let vals: Vec<f64> = per_step
                .iter()
                .filter(|(s, _)| s / spe == e)
                .map(|(_, r)| *r)
                .collect();
            vals.iter().sum::<f64>() / vals.len().max(1) as f64
        };
        let overall = per_step.iter().map(|(_, r)| r).sum::<f64>() / per_step.len().max(1) as f64;
        table.row(vec![
            algo.name().to_uppercase(),
            format!("{:.3}", epoch_mean(1)),
            format!("{:.3}", epoch_mean(2)),
            format!("{overall:.3}"),
        ]);
        let _ = Policy::from_init(&eng, bundle); // keep engine warm ordering stable
    }
    csv.save().unwrap();
    println!("\n{}", table.render());
    println!("expected shape: overlap well above 0 (paper reports ~0.6-0.8 ROUGE-1).");
}
