//! Figure 7 regeneration: effect of training-set size on when SPEC-RL's
//! acceleration activates (first reuse point = start of epoch 2).
//!
//! Paper shape: smaller prompt sets reach epoch 2 sooner, so rollout time
//! drops earlier; all sizes converge to reduced rollout time once reuse is
//! active.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::{Report, Table};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::Trainer;
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_fig7_trainsize: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let sizes = [32usize, 64, 96, 128];
    let mut table = Table::new(
        "Figure 7 — rollout time vs train-set size (tiny, GRPO+SPEC-RL)",
        &["n_prompts", "first_reuse_step", "rollout_s/step (epoch1)", "rollout_s/step (after)", "tokens"],
    );
    let mut csv = Report::new("out/fig7_trainsize.csv", &["n_prompts", "step", "rollout_s", "tokens_new"]);
    for &n in &sizes {
        let mut cfg = exp::base_config(scale, bundle);
        cfg.algo = Algo::Grpo;
        cfg.params = Algo::Grpo.default_params();
        cfg.variant = ReuseVariant::Spec;
        cfg.lenience = Lenience::Fixed(0.5);
        cfg.n_prompts = n;
        cfg.eval_n = 4;
        cfg.eval_samples_hard = 1;
        let spe = cfg.steps_per_epoch();
        cfg.steps = (2 * spe + spe / 2).min(48);
        let mut tr = Trainer::new(&eng, cfg.clone(), base.duplicate(&eng).unwrap()).unwrap();
        let mut tokens = 0usize;
        let mut early = Vec::new();
        let mut late = Vec::new();
        for s in 0..cfg.steps {
            let rec = tr.step(s).unwrap();
            csv.push(&[n as f64, s as f64, rec["rollout_s"], rec["tokens_new"]]);
            tokens += rec["tokens_new"] as usize;
            if s < spe {
                early.push(rec["rollout_s"]);
            } else {
                late.push(rec["rollout_s"]);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        table.row(vec![
            n.to_string(),
            spe.to_string(),
            format!("{:.3}", mean(&early)),
            format!("{:.3}", mean(&late)),
            tokens.to_string(),
        ]);
    }
    csv.save().unwrap();
    println!("\n{}", table.render());
    println!("expected shape: smaller sets hit the first-reuse point earlier; post-reuse rollout time drops for all sizes.");
}
