//! Readback bench (PR 6): device-resident sampling + the fused O(B) step
//! readback against the host-sampling baseline, at equal outputs.
//!
//! The hot pipeline path ends each decode round with the `sample` entry
//! (per-task RNG streams replayed device-side, ARCHITECTURE.md §12) and a
//! `read_step` readback of just `[B tok | B ptok | B aux]`, where the
//! baseline reads the full `[B*V probs | B aux]` payload and samples on
//! the host. On clocked mock replicas (readback latency scales with
//! payload size) that shows up twice in `PipelineStats`: per-step
//! `readback_bytes` drops from O(B·V) to O(B), and the overlapped
//! makespan drops with it. Asserts, for `shards ∈ {2, 4}`: byte-identical
//! outputs across the two sampling paths, strictly lower readback bytes,
//! and a strictly lower overlapped makespan than the host baseline.
//! Writes `BENCH_readback.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::drafted::{B, LOG_LENIENCE, P, SEED, T, V};
use spec_rl::benchkit::{fmt_secs, stale, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, Placement, SampleCfg};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Draft length: identical for every task (same workload as
/// `bench_overlap`, so the two JSON reports compare directly).
const DRAFT_LEN: usize = 30;

fn main() {
    println!(
        "== readback bench (clocked mock replicas: B={B}/shard V={V}, {} stale-mod-{} drafts) ==",
        stale::N_TASKS,
        stale::STALE_MOD,
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", B)
        .int("vocab", V)
        .int("tasks", stale::N_TASKS)
        .int("draft_len", DRAFT_LEN)
        .num("log_lenience", LOG_LENIENCE as f64);

    println!("\nshards  path    readback bytes  overlap makespan  wall-clock (median)");
    for shards in [2usize, 4] {
        let mut mocks = MockEngine::clocked_replicas(shards, B, P, T, V);
        for m in &mut mocks {
            // Deterministic full-length tails: every rejected row decodes
            // exactly to the cap, so the traffic totals are structural.
            m.eos_bias = 0.0;
        }
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let cfg = SampleCfg::default();
        let mut timer = StageTimer::new();

        let mut run = |host: bool| {
            pool.set_host_sampling(host);
            let mut spec = stale::warmed(stale::N_TASKS, DRAFT_LEN, V, LOG_LENIENCE)
                .with_placement(Placement::Steal);
            let mut rng = Rng::new(SEED);
            let reqs = stale::requests(stale::N_TASKS, V);
            spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap()
        };

        let (dev_res, dev_stats) = run(false);
        let (host_res, host_stats) = run(true);

        // The two sampling paths must agree byte-for-byte (length first:
        // zip alone would pass on truncation).
        assert_eq!(dev_res.len(), stale::N_TASKS, "device run dropped results");
        assert_eq!(host_res.len(), stale::N_TASKS, "host run dropped results");
        for (a, b) in dev_res.iter().zip(&host_res) {
            assert_eq!((a.id, &a.response), (b.id, &b.response), "sampling path changed outputs");
            assert_eq!(a.logps, b.logps, "sampling path changed logps");
        }

        // The fused path must read strictly less per step...
        assert!(
            dev_stats.readback_bytes < host_stats.readback_bytes,
            "{shards} shards: device readback {} must come out strictly below host {}",
            dev_stats.readback_bytes,
            host_stats.readback_bytes
        );
        // ...and win the clock even after paying for the extra `sample`
        // launch each round.
        let (dev_ov, host_ov) = (dev_stats.overlap_makespan, host_stats.overlap_makespan);
        assert!(host_ov > 0.0, "{shards} shards: the virtual clock never moved");
        assert!(
            dev_ov < host_ov,
            "{shards} shards: device-sampling makespan {dev_ov} must come out strictly \
             below the host-sampling {host_ov}"
        );

        let r_dev = bench.run(&format!("device sampling over {shards} shard(s)"), || run(false));
        let r_host = bench.run(&format!("host sampling over {shards} shard(s)"), || run(true));

        let ratio = host_stats.readback_bytes as f64 / dev_stats.readback_bytes.max(1) as f64;
        println!(
            "{shards:>6}  device  {:>14}  {dev_ov:>16.1}  {:>19}",
            dev_stats.readback_bytes,
            fmt_secs(r_dev.median_secs)
        );
        println!(
            "{shards:>6}  host    {:>14}  {host_ov:>16.1}  {:>19}  ({ratio:.1}x more readback)",
            host_stats.readback_bytes,
            fmt_secs(r_host.median_secs)
        );
        j.int(&format!("s{shards}_device_readback_bytes"), dev_stats.readback_bytes)
            .int(&format!("s{shards}_host_readback_bytes"), host_stats.readback_bytes)
            .int(&format!("s{shards}_device_upload_bytes"), dev_stats.upload_bytes)
            .int(&format!("s{shards}_host_upload_bytes"), host_stats.upload_bytes)
            .num(&format!("s{shards}_readback_ratio"), ratio)
            .num(&format!("s{shards}_device_overlap_makespan"), dev_ov)
            .num(&format!("s{shards}_host_overlap_makespan"), host_ov)
            .num(&format!("s{shards}_device_serial_makespan"), dev_stats.serial_makespan)
            .num(&format!("s{shards}_host_serial_makespan"), host_stats.serial_makespan)
            .bench(&format!("s{shards}_device"), &r_dev)
            .bench(&format!("s{shards}_host"), &r_host);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_readback.json") {
        eprintln!("could not write BENCH_readback.json: {e}");
    }
}
