//! Table 4 regeneration: end-to-end per-stage time breakdown for the three
//! algorithms with and without SPEC-RL.
//!
//! Paper shape: rollout dominates vanilla step time; with SPEC-RL a small
//! verification stage + negligible assembly replace most of the rollout
//! cost while every other stage is unchanged.

use spec_rl::algo::Algo;
use spec_rl::exp::{self, Scale};
use spec_rl::metrics::Table;
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::util::logging;

fn main() {
    logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_table4_breakdown: run `make artifacts` first");
        return;
    }
    let scale = Scale::from_env();
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let base = exp::ensure_base(&eng, bundle, scale.sft_steps).unwrap();

    let mut table = Table::new(
        "Table 4 — mean per-step stage breakdown (tiny; seconds)",
        &exp::breakdown_header(),
    );
    for algo in [Algo::Grpo, Algo::Ppo, Algo::Dapo] {
        for variant in [ReuseVariant::Off, ReuseVariant::Spec] {
            let mut cfg = exp::base_config(scale, bundle);
            cfg.steps = scale.steps.min(24); // breakdown needs fewer steps
            cfg.eval_n = 4; // final eval is irrelevant here
            cfg.eval_samples_hard = 1;
            cfg.algo = algo;
            cfg.params = algo.default_params();
            cfg.variant = variant;
            cfg.lenience = Lenience::Fixed(cfg.params.default_log_lenience);
            let label = if variant == ReuseVariant::Off {
                algo.name().to_uppercase()
            } else {
                format!("{}+SPEC", algo.name().to_uppercase())
            };
            let s = exp::run_one(&eng, cfg, &base, &label).unwrap();
            exp::breakdown_row(&mut table, &s);
        }
    }
    println!("\n{}", table.render());
    println!("expected shape: rollout >> other stages in vanilla rows; +SPEC rows trade most rollout time for a small verify stage.");
}
