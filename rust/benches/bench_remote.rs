//! Remote-transport bench: the adversarial stale-draft step driven
//! through `RemoteBackend<Loopback<MockEngine>>` vs the in-process pool
//! (`ARCHITECTURE.md` §13).
//!
//! Pins two things the chaos/conformance tests check functionally, as
//! numbers CI can diff:
//!
//! - **Loopback-transport overhead** at 1 shard: the handle-table
//!   indirection (every upload/submit/complete/read crosses the
//!   `Transport` boundary) against driving the mock directly, at
//!   byte-identical outputs.
//! - **Overlapped makespan through the wire** at 2/4 shards on the
//!   shared virtual clock, with and without one injected dead peer:
//!   remote submits must stay cheap (overlap strictly below serialized),
//!   and a dead shard's recovery must complete every task exactly once —
//!   the makespan and requeue columns price that recovery.
//!
//! Writes `BENCH_remote.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::drafted::{B, LOG_LENIENCE, P, SEED, T, V};
use spec_rl::benchkit::{fmt_secs, stale, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, PipelineStats, Placement, SampleCfg, SeqResult};
use spec_rl::runtime::{Backend, Loopback, RemoteBackend, TransportFaults};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Same draft length as `bench_steal`: uninformative for placement, long
/// enough that stale rows re-decode a real tail.
const DRAFT_LEN: usize = 30;

/// One adversarial drafted step on an existing pool (fresh warmed cache
/// and RNG per call, so repeated timing iterations are identical work).
fn step<Bk: Backend>(
    pool: &mut EnginePool<'_, Bk>,
    blob_refs: &[&Bk::Buf],
) -> (Vec<SeqResult>, PipelineStats) {
    let mut spec = stale::warmed(stale::N_TASKS, DRAFT_LEN, V, LOG_LENIENCE)
        .with_placement(Placement::Steal);
    let mut rng = Rng::new(SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(stale::N_TASKS, V);
    spec.collect(pool, blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer).unwrap()
}

/// Full remote step on shared-clock replicas, optionally killing the last
/// shard's transport mid-step (everything rebuilt per call: a dead
/// transport stays dead, so timing iterations must not share state).
fn clocked_remote(
    shards: usize,
    faults: Option<TransportFaults>,
) -> (Vec<SeqResult>, PipelineStats) {
    let mut mocks = MockEngine::clocked_replicas(shards, B, P, T, V);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    let remotes: Vec<_> = mocks.iter().map(|m| RemoteBackend::new(Loopback::new(m))).collect();
    let blobs: Vec<_> = remotes.iter().map(|r| r.upload_f32(&[0.0], &[1]).unwrap()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    if let Some(f) = faults {
        remotes[shards - 1].transport().set_faults(f);
    }
    let mut pool = EnginePool::new(remotes.iter(), "mock").unwrap();
    step(&mut pool, &blob_refs)
}

fn main() {
    println!(
        "== remote/loopback bench (mock replicas: B={B}/shard T={T}, {} drafts, log l={LOG_LENIENCE}) ==",
        stale::N_TASKS,
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", B).int("tasks", stale::N_TASKS).int("draft_len", DRAFT_LEN);

    // -- loopback-transport overhead, 1 shard ------------------------------
    let mut mocks = MockEngine::replicas(1, B, P, T, V);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let (direct_res, _) = step(&mut pool, &blob_refs);

    let remotes: Vec<_> = mocks.iter().map(|m| RemoteBackend::new(Loopback::new(m))).collect();
    let rblobs: Vec<_> = remotes.iter().map(|r| r.upload_f32(&[0.0], &[1]).unwrap()).collect();
    let rblob_refs: Vec<_> = rblobs.iter().collect();
    let mut rpool = EnginePool::new(remotes.iter(), "mock").unwrap();
    let (remote_res, _) = step(&mut rpool, &rblob_refs);

    assert_eq!(direct_res.len(), stale::N_TASKS, "direct run dropped results");
    assert_eq!(remote_res.len(), stale::N_TASKS, "remote run dropped results");
    for (a, b) in direct_res.iter().zip(&remote_res) {
        assert_eq!((a.id, &a.response), (b.id, &b.response), "the wire changed outputs");
        assert_eq!(a.logps, b.logps, "the wire changed logps");
    }

    let t_direct = bench.run("in-process pipeline, 1 shard", || step(&mut pool, &blob_refs));
    let t_remote =
        bench.run("loopback-remote pipeline, 1 shard", || step(&mut rpool, &rblob_refs));
    let overhead = t_remote.median_secs / t_direct.median_secs.max(1e-12);
    println!(
        "\n1 shard: direct {}  remote {}  (x{overhead:.2} loopback overhead)",
        fmt_secs(t_direct.median_secs),
        fmt_secs(t_remote.median_secs),
    );
    j.bench("direct_s1", &t_direct)
        .bench("remote_s1", &t_remote)
        .num("loopback_overhead_x", overhead);

    // -- overlapped makespan through the wire, with/without a dead peer ----
    println!("\nshards  overlap/serial (healthy)   overlap (one dead)  requeued  wall (one dead)");
    for shards in [2usize, 4] {
        let dead = TransportFaults { dead_from_op: Some(40), ..Default::default() };
        let (healthy_res, healthy) = clocked_remote(shards, None);
        let (faulted_res, faulted) = clocked_remote(shards, Some(dead.clone()));

        // recovery is invisible in the outputs: byte-identical, complete
        assert_eq!(healthy_res.len(), stale::N_TASKS, "healthy run dropped results");
        assert_eq!(faulted_res.len(), stale::N_TASKS, "recovery dropped results");
        for (a, b) in healthy_res.iter().zip(&faulted_res) {
            assert_eq!((a.id, &a.response), (b.id, &b.response), "recovery changed outputs");
            assert_eq!(a.logps, b.logps, "recovery changed logps");
        }
        assert_eq!(healthy.shard_failures, 0, "healthy run reported a failure");
        assert_eq!(faulted.shard_failures, 1, "the dead peer must surface as one failure");
        // remote submits stay cheap: the overlap survives the wire
        assert!(
            healthy.overlap_makespan > 0.0
                && healthy.overlap_makespan < healthy.serial_makespan,
            "{shards} shards: the wire serialized the pool ({healthy:?})"
        );

        let healthy_label = format!("remote pipeline, {shards} shards (incl. setup)");
        let t_healthy = bench.run(&healthy_label, || clocked_remote(shards, None));
        let faulted_label = format!("remote pipeline, {shards} shards, one dead (incl. setup)");
        let t_faulted = bench.run(&faulted_label, || clocked_remote(shards, Some(dead.clone())));

        println!(
            "{shards:>6}  {:>10.2} / {:<10.2}   {:>18.2}  {:>8}  {:>15}",
            healthy.overlap_makespan,
            healthy.serial_makespan,
            faulted.overlap_makespan,
            faulted.requeued_tasks,
            fmt_secs(t_faulted.median_secs),
        );
        j.num(&format!("s{shards}_overlap_makespan"), healthy.overlap_makespan)
            .num(&format!("s{shards}_serial_makespan"), healthy.serial_makespan)
            .num(&format!("s{shards}_overlap_makespan_one_dead"), faulted.overlap_makespan)
            .int(&format!("s{shards}_requeued_one_dead"), faulted.requeued_tasks)
            .bench(&format!("s{shards}_healthy"), &t_healthy)
            .bench(&format!("s{shards}_one_dead"), &t_faulted);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_remote.json") {
        eprintln!("could not write BENCH_remote.json: {e}");
    }
}
