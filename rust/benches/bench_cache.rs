//! Cache bench: the prefix-trie rollout cache vs the flat
//! per-trajectory baseline on the grouped workload (n samples per prompt
//! across epochs).
//!
//! Pins the tentpole claim: at group sizes 4 and 8 the trie holds
//! **strictly fewer resident cached tokens** than flat storage while the
//! drafts it materializes are **byte-identical** field-by-field —
//! identical drafts feed identical acceptance decisions, so accepted
//! draft tokens cannot degrade. A live identity sweep re-runs the
//! grouped batch through the trie-backed pipeline across every
//! `ReuseVariant` × shards {1, 2, 4} against the two-phase oracle.
//! Writes `BENCH_cache.json` for machine diffing / the CI smoke run.

use spec_rl::benchkit::grouped::{self, GroupedCfg};
use spec_rl::benchkit::{Bench, JsonReport};
use spec_rl::rollout::{EnginePool, RolloutEngine, SampleCfg};
use spec_rl::spec::{FlatCache, Lenience, ReuseVariant, RolloutCache, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

/// Mock geometry (same envelope as the drafted workload benches).
const B: usize = 8;
const P: usize = 16;
const T: usize = 64;
const V: usize = 51;
/// Crafted and live epochs per measurement.
const EPOCHS: u64 = 3;
const LOG_LENIENCE: f32 = -0.4;
const SEED: u64 = 21;

fn cfg_for(group: usize) -> GroupedCfg {
    GroupedCfg { group, ..GroupedCfg::default() }
}

/// Stream [`EPOCHS`] of crafted grouped rollouts into both cache
/// flavors, asserting after every epoch that the trie materializes
/// byte-identical entries (latest *and* previous) to the flat baseline.
/// Returns `(trie_tokens, flat_tokens, shared_tokens, cache_nodes)`.
fn footprints(cfg: &GroupedCfg) -> (usize, usize, usize, usize) {
    let mut trie = RolloutCache::new().with_group(cfg.group);
    let mut flat = FlatCache::new();
    for epoch in 0..EPOCHS {
        let batch = grouped::entries(cfg, epoch);
        trie.insert_batch(batch.clone());
        flat.insert_batch(batch);
        for id in 0..cfg.batch() {
            let a = trie.latest(id).expect("trie entry");
            let b = flat.latest(id).expect("flat entry");
            assert_eq!(a.response, b.response, "id {id} epoch {epoch}: tokens must match");
            assert_eq!(a.logps, b.logps, "id {id} epoch {epoch}: logps must match");
            assert_eq!((a.version, a.finished), (b.version, b.finished));
            match (trie.previous(id), flat.previous(id)) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.response, y.response, "id {id}: previous tokens");
                    assert_eq!(x.logps, y.logps, "id {id}: previous logps");
                }
                (None, None) => {}
                (x, y) => panic!("previous presence diverged: {x:?} vs {y:?}"),
            }
        }
    }
    trie.check_invariants().expect("trie invariants");
    (trie.total_tokens(), flat.total_tokens(), trie.shared_tokens(), trie.cache_nodes())
}

/// One live grouped run: [`EPOCHS`] steps of the grouped request batch
/// through the trie-backed rollout path. `shards == 0` uses the
/// two-phase oracle on a single engine; `shards > 0` the interleaved
/// pipeline over an [`EnginePool`]. Returns per-epoch id-sorted
/// `(id, response, logps)` plus the total accepted draft tokens.
#[allow(clippy::type_complexity)]
fn drive(
    variant: ReuseVariant,
    shards: usize,
    group: usize,
) -> (Vec<Vec<(usize, Vec<i32>, Vec<f32>)>>, usize) {
    let cfg = cfg_for(group);
    let reqs = grouped::requests(&cfg);
    let scfg = SampleCfg::default();
    let mut spec =
        SpecRollout::new(variant, Lenience::Fixed(LOG_LENIENCE)).with_group(group);
    let mut rng = Rng::new(SEED);
    let mut timer = StageTimer::new();
    let mut outs = Vec::new();
    let mut accepted = 0usize;
    if shards == 0 {
        let m = MockEngine::new(B, P, T, V);
        let blob = m.blob();
        let mut eng = RolloutEngine::new(&m, "mock").unwrap();
        for _ in 0..EPOCHS {
            let (res, stats) =
                spec.run_two_phase(&mut eng, &blob, &reqs, scfg, &mut rng, &mut timer).unwrap();
            accepted += stats.prefix_tokens;
            outs.push(res.into_iter().map(|r| (r.id, r.response, r.logps)).collect());
        }
    } else {
        let mocks = MockEngine::replicas(shards, B, P, T, V);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        for _ in 0..EPOCHS {
            let (res, stats) =
                spec.collect(&mut pool, &blob_refs, &reqs, scfg, &mut rng, &mut timer).unwrap();
            accepted += stats.prefix_tokens;
            outs.push(res.into_iter().map(|r| (r.id, r.response, r.logps)).collect());
        }
    }
    (outs, accepted)
}

fn main() {
    let bench = Bench::new(2, 10);
    let mut j = JsonReport::new();
    j.int("epochs", EPOCHS as usize).num("log_lenience", LOG_LENIENCE as f64);

    for group in [4usize, 8] {
        let cfg = cfg_for(group);
        println!(
            "== cache bench (group={group}: {} prompts x {} samples, depth={}, overlap={}, {} epochs) ==",
            cfg.prompts, cfg.group, cfg.divergence_depth, cfg.epoch_overlap, EPOCHS
        );

        // -- footprint: trie vs flat on identical insert streams -----------
        let (trie_tokens, flat_tokens, shared, nodes) = footprints(&cfg);
        assert!(
            trie_tokens < flat_tokens,
            "group {group}: trie must hold strictly fewer resident tokens ({trie_tokens} vs {flat_tokens})"
        );
        println!(
            "resident tokens: trie {trie_tokens} vs flat {flat_tokens} ({:.2}x, {shared} shared over {nodes} runs)",
            flat_tokens as f64 / trie_tokens as f64
        );

        // -- live identity sweep: variants x shards vs the oracle ----------
        // Per-task RNG streams + the trie's byte-exact materialization
        // keep outputs AND accepted draft tokens invariant across shard
        // counts and disciplines.
        for variant in [
            ReuseVariant::Off,
            ReuseVariant::Spec,
            ReuseVariant::Random,
            ReuseVariant::Delayed,
            ReuseVariant::Full,
        ] {
            let (oracle, oracle_accepted) = drive(variant, 0, group);
            for shards in [1usize, 2, 4] {
                let (live, live_accepted) = drive(variant, shards, group);
                assert_eq!(
                    oracle, live,
                    "group {group} {} shards={shards}: outputs must be byte-identical",
                    variant.name()
                );
                assert_eq!(
                    oracle_accepted, live_accepted,
                    "group {group} {}: accepted draft tokens drifted",
                    variant.name()
                );
            }
            if variant == ReuseVariant::Spec {
                j.int(&format!("accepted_tokens_g{group}"), oracle_accepted);
            }
        }
        println!("identity sweep: 5 variants x shards {{1,2,4}} byte-identical to the oracle");

        // -- timings -------------------------------------------------------
        let r_trie = bench.run(&format!("trie insert g{group} ({EPOCHS} epochs)"), || {
            let mut c = RolloutCache::new().with_group(group);
            for e in 0..EPOCHS {
                c.insert_batch(grouped::entries(&cfg, e));
            }
            c.total_tokens()
        });
        let r_flat = bench.run(&format!("flat insert g{group} ({EPOCHS} epochs)"), || {
            let mut c = FlatCache::new();
            for e in 0..EPOCHS {
                c.insert_batch(grouped::entries(&cfg, e));
            }
            c.total_tokens()
        });
        let walk_cache = {
            let mut c = RolloutCache::new().with_group(group);
            for e in 0..EPOCHS {
                c.insert_batch(grouped::entries(&cfg, e));
            }
            c
        };
        let r_walk = bench.run(&format!("trie draft walk g{group} (all ids)"), || {
            (0..cfg.batch())
                .map(|id| walk_cache.latest(id).map(|e| e.response.len()).unwrap_or(0))
                .sum::<usize>()
        });

        j.int(&format!("trie_tokens_g{group}"), trie_tokens)
            .int(&format!("flat_tokens_g{group}"), flat_tokens)
            .int(&format!("shared_tokens_g{group}"), shared)
            .int(&format!("cache_nodes_g{group}"), nodes)
            .bench(&format!("trie_insert_g{group}"), &r_trie)
            .bench(&format!("flat_insert_g{group}"), &r_flat)
            .bench(&format!("trie_walk_g{group}"), &r_walk);
        println!();
    }

    println!("{}", j.render());
    if let Err(e) = j.save("BENCH_cache.json") {
        eprintln!("could not write BENCH_cache.json: {e}");
    }
}
