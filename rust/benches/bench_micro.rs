//! Micro-benchmarks of the hot path (benchkit harness; criterion is
//! unavailable offline). The numbers feed EXPERIMENTS.md §Perf.
//!
//! Covers: engine entry latencies (prefill / decode / read_gen / score /
//! verify / train), host-side samplers and packing, cache ops.

use spec_rl::benchkit::Bench;
use spec_rl::model::Policy;
use spec_rl::rollout::{BatchLayout, SeqTask};
use spec_rl::runtime::Engine;
use spec_rl::spec::{CacheEntry, RolloutCache};
use spec_rl::tokenizer::BOS;
use spec_rl::util::{Rng, TopPSampler};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("bench_micro: run `make artifacts` first");
        return;
    }
    let eng = Engine::load("artifacts").unwrap();
    let bundle = "tiny_b32";
    let info = eng.bundle(bundle).unwrap().clone();
    let (b, t) = (info.batch, eng.manifest.total_len);
    let g = eng.manifest.gen_len();
    let v = info.model.vocab;
    let policy = Policy::from_init(&eng, bundle).unwrap();
    let mut rng = Rng::new(1);

    println!("== micro benches ({bundle}: B={b} T={t} V={v}) ==");
    let bench = Bench::new(3, 20);

    // ---- engine entries -----------------------------------------------------
    let tokens: Vec<i32> = (0..b * t).map(|i| 3 + (i as i32 % 40)).collect();
    let valid: Vec<f32> = vec![1.0; b * t];
    let tok_buf = eng.upload_i32(&tokens, &[b, t]).unwrap();
    let val_buf = eng.upload_f32(&valid, &[b, t]).unwrap();
    let temp = eng.upload_f32(&[1.0], &[1]).unwrap();
    let last = eng.upload_i32(&vec![(t - 1) as i32; b], &[b]).unwrap();

    let gen_blob = eng
        .call(bundle, "prefill", &[&policy.blob, &tok_buf, &val_buf, &last, &temp])
        .unwrap();
    bench.run("prefill (full seq fwd + cache)", || {
        eng.call(bundle, "prefill", &[&policy.blob, &tok_buf, &val_buf, &last, &temp]).unwrap()
    });

    let token_in = eng.upload_i32(&vec![5i32; b], &[b]).unwrap();
    let slot_in = eng.upload_i32(&vec![(t - 1) as i32; b], &[b]).unwrap();
    let lpos_in = eng.upload_i32(&vec![(t - 1) as i32; b], &[b]).unwrap();
    // the decode entry carries no [B,T] valid arg: mask lives device-side
    bench.run("decode step (one token, all rows)", || {
        eng.call(
            bundle,
            "decode",
            &[&policy.blob, &gen_blob, &token_in, &slot_in, &lpos_in, &temp],
        )
        .unwrap()
    });
    let rowmask = eng.upload_f32(&vec![1.0f32; b], &[b]).unwrap();
    bench.run("refill (masked per-row prefill)", || {
        eng.call(
            bundle,
            "refill",
            &[&policy.blob, &gen_blob, &tok_buf, &val_buf, &rowmask, &last, &temp],
        )
        .unwrap()
    });
    bench.run("read_gen (probs extraction)", || {
        let out = eng.call(bundle, "read_gen", &[&gen_blob]).unwrap();
        eng.read_f32(&out).unwrap()
    });
    bench.run("score (teacher-forced logp+ent)", || {
        eng.call(bundle, "score", &[&policy.blob, &tok_buf, &val_buf, &temp]).unwrap()
    });

    let logp_prev = eng.upload_f32(&vec![-1.0f32; b * g], &[b, g]).unwrap();
    let uniforms = eng.upload_f32(&vec![0.5f32; b * g], &[b, g]).unwrap();
    let dv = eng.upload_f32(&vec![1.0f32; b * g], &[b, g]).unwrap();
    let ll = eng.upload_f32(&[0.5], &[1]).unwrap();
    bench.run("verify (score + acceptance scan)", || {
        eng.call(
            bundle,
            "verify",
            &[&policy.blob, &tok_buf, &val_buf, &logp_prev, &uniforms, &dv, &ll, &temp],
        )
        .unwrap()
    });

    let rm = eng.upload_f32(&vec![1.0f32; b * g], &[b, g]).unwrap();
    let adv = eng.upload_f32(&vec![0.1f32; b * g], &[b, g]).unwrap();
    let hp = eng.upload_f32(&[3e-4, 0.2, 0.2, 1e-4, 0.0, 0.0, 0.01, 1.0], &[8]).unwrap();
    bench.run("train_policy (fwd+bwd+AdamW)", || {
        eng.call(
            bundle,
            "train_policy",
            &[&policy.blob, &tok_buf, &val_buf, &rm, &adv, &logp_prev, &logp_prev, &hp],
        )
        .unwrap()
    });
    bench.run("read_metrics", || {
        let out = eng.call(bundle, "read_metrics", &[&policy.blob]).unwrap();
        eng.read_f32(&out).unwrap()
    });
    bench.run("upload tokens+valid (B*T)", || {
        let a = eng.upload_i32(&tokens, &[b, t]).unwrap();
        let c = eng.upload_f32(&valid, &[b, t]).unwrap();
        (a, c)
    });

    // ---- host-side hot paths ---------------------------------------------------
    let probs: Vec<f32> = {
        let mut p: Vec<f32> = (0..v).map(|i| ((i * 37) % 97) as f32 + 1.0).collect();
        let s: f32 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        p
    };
    let mut sampler = TopPSampler::new(v);
    bench.run("top-p sample (1 row, p=0.95)", || sampler.sample(&probs, 0.95, &mut rng));
    bench.run("categorical sample (1 row, p=1.0)", || sampler.sample(&probs, 1.0, &mut rng));

    let tasks: Vec<SeqTask> = (0..b)
        .map(|i| SeqTask {
            id: i,
            prompt: vec![BOS, 5, 6, 7, 8],
            prefix: vec![9; 20],
            prefix_logps: vec![-1.0; 20],
        })
        .collect();
    bench.run("BatchLayout::pack (32 rows)", || BatchLayout::pack(&tasks, b, 16, t));

    let mut cache = RolloutCache::new();
    let entry = CacheEntry {
        response: vec![7; 40],
        logps: vec![-1.0; 40],
        version: 0,
        finished: true,
    };
    bench.run("cache insert+lookup (1k ids)", || {
        for id in 0..1000 {
            cache.insert(id, entry.clone());
        }
        (0..1000).map(|id| cache.latest(id).map(|e| e.response.len()).unwrap_or(0)).sum::<usize>()
    });

    println!("\nper-entry engine stats:");
    for (k, s) in eng.stats() {
        println!(
            "  {k:<28} calls={:<6} total={:.3}s mean={:.3}ms",
            s.calls,
            s.total_secs,
            1e3 * s.total_secs / s.calls.max(1) as f64
        );
    }
}
