//! Long-tail scheduling bench: predicted-length LPT vs raw LPT on the
//! heavy-tail workload (`benchkit::longtail`, ARCHITECTURE.md §14).
//!
//! Every draft spans the full generation region, so the raw LPT key
//! (`draft_len`) is uninformative and the queue degenerates to id order —
//! which on this workload seats the cheap suite blocks first and leaves
//! the expensive last block straggling (a shortest-first schedule). The
//! predicted run seeds a [`spec_rl::rollout::LenPredictor`] from the raw
//! run's realized lengths/acceptances — exactly the prior-epoch signal
//! the trainer has — which recovers true longest-remaining-first order.
//!
//! Asserts, on shared-virtual-clock replicas at `shards ∈ {2, 4}`:
//! byte-identical outputs between the two runs (prediction only reorders
//! seating; §6 RNG streams never see it) and a strictly lower
//! `overlap_makespan` for the predicted run. One shard still asserts
//! identity; its makespan win is not required (with few slots the drain
//! order barely moves the critical path). Writes `BENCH_longtail.json`
//! for machine diffing / the CI smoke run.

use spec_rl::benchkit::{fmt_secs, longtail, Bench, JsonReport};
use spec_rl::rollout::{EnginePool, SampleCfg, SeqResult};
use spec_rl::testing::mock::MockEngine;
use spec_rl::util::{Rng, StageTimer};

fn main() {
    let gen_len = longtail::T - longtail::P;
    println!(
        "== longtail bench (mock replicas: B={}/shard T={}, {} drafts, alpha={}, log l={}) ==",
        longtail::B,
        longtail::T,
        longtail::N_TASKS,
        longtail::ALPHA,
        longtail::LOG_LENIENCE,
    );
    let bench = Bench::new(1, 8);
    let mut j = JsonReport::new();
    j.int("batch_per_shard", longtail::B)
        .int("tasks", longtail::N_TASKS)
        .int("gen_len", gen_len)
        .num("alpha", longtail::ALPHA)
        .num("log_lenience", longtail::LOG_LENIENCE as f64);

    let mut baseline: Option<Vec<SeqResult>> = None;
    println!("\nshards  raw makespan  predicted makespan  predict_err  wall-clock (median)");
    for shards in [1usize, 2, 4] {
        let mut mocks = MockEngine::clocked_replicas(
            shards,
            longtail::B,
            longtail::P,
            longtail::T,
            longtail::V,
        );
        for m in &mut mocks {
            // Deterministic full-length tails: every cut row decodes
            // exactly to the cap, so remaining work is the crafted r_i.
            m.eos_bias = 0.0;
        }
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let cfg = SampleCfg::default();
        let mut timer = StageTimer::new();

        let mut run = |predict: bool, seed_from: Option<&[SeqResult]>| {
            let mut spec = longtail::warmed(longtail::ALPHA, longtail::SEED, gen_len, longtail::V)
                .with_predict(predict);
            if let Some(prior) = seed_from {
                // The prior-epoch feedback the trainer would have folded
                // in: realized totals + per-draft acceptance.
                for r in prior {
                    spec.predictor.observe_len(r.id, r.response.len());
                    spec.predictor.observe_acceptance(r.id, r.reused, gen_len);
                }
            }
            let mut rng = Rng::new(longtail::SEED);
            let reqs = longtail::requests(longtail::V);
            spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap()
        };

        let (raw_res, raw_stats) = run(false, None);
        let (pred_res, pred_stats) = run(true, Some(&raw_res));

        // outputs must be byte-identical across predictor settings AND
        // shard counts (length first: zip alone would pass on a
        // truncated result set)
        assert_eq!(raw_res.len(), longtail::N_TASKS, "raw run dropped results");
        assert_eq!(pred_res.len(), longtail::N_TASKS, "predicted run dropped results");
        for (a, b) in raw_res.iter().zip(&pred_res) {
            assert_eq!((a.id, &a.response), (b.id, &b.response), "prediction changed outputs");
            assert_eq!(a.logps, b.logps, "prediction changed logps");
        }
        match &baseline {
            None => baseline = Some(pred_res),
            Some(base) => {
                assert_eq!(base.len(), pred_res.len(), "shard count changed result count");
                for (a, b) in base.iter().zip(&pred_res) {
                    assert_eq!((a.id, &a.response), (b.id, &b.response), "shard count leaked");
                    assert_eq!(a.logps, b.logps, "shard count leaked into logps");
                }
            }
        }

        // the seeded estimates are exact on this workload (every row
        // realizes the cap), so the predictor-error gauge must read 0
        assert_eq!(pred_stats.predict_rows, longtail::N_TASKS, "every row must be scored");
        assert!(
            pred_stats.mean_predict_err.abs() < 1e-9,
            "seeded estimates should be exact, err={}",
            pred_stats.mean_predict_err
        );

        let raw_mk = raw_stats.overlap_makespan;
        let pred_mk = pred_stats.overlap_makespan;
        assert!(raw_mk > 0.0 && pred_mk > 0.0, "clocked replicas must report makespans");
        if shards > 1 {
            assert!(
                pred_mk < raw_mk,
                "{shards} shards: predicted LPT must strictly tighten the makespan \
                 ({pred_mk} !< {raw_mk})"
            );
        }

        let r_time = bench.run(&format!("predicted pipeline over {shards} shard(s)"), || {
            let mut spec = longtail::warmed(longtail::ALPHA, longtail::SEED, gen_len, longtail::V)
                .with_predict(true);
            for r in &raw_res {
                spec.predictor.observe_len(r.id, r.response.len());
                spec.predictor.observe_acceptance(r.id, r.reused, gen_len);
            }
            let mut rng = Rng::new(longtail::SEED);
            let reqs = longtail::requests(longtail::V);
            spec.collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer).unwrap()
        });

        println!(
            "{shards:>6}  {raw_mk:>12.3}  {pred_mk:>18.3}  {:>11.3}  {:>19}",
            pred_stats.mean_predict_err,
            fmt_secs(r_time.median_secs)
        );
        j.num(&format!("s{shards}_raw_makespan"), raw_mk)
            .num(&format!("s{shards}_predicted_makespan"), pred_mk)
            .num(&format!("s{shards}_makespan_ratio"), pred_mk / raw_mk)
            .num(&format!("s{shards}_predict_err"), pred_stats.mean_predict_err)
            .num(&format!("s{shards}_mean_draft_len"), pred_stats.mean_draft_len)
            .bench(&format!("s{shards}"), &r_time);
    }

    println!("\n{}", j.render());
    if let Err(e) = j.save("BENCH_longtail.json") {
        eprintln!("could not write BENCH_longtail.json: {e}");
    }
}
