//! Shared experiment drivers for the benches and examples.
//!
//! Every `benches/bench_*.rs` regenerates one paper table/figure; they all
//! need the same plumbing: a cached SFT base checkpoint per bundle, a
//! configured [`Trainer`] run, and paper-shaped table rows (tokens /
//! speedup / per-suite accuracy). That plumbing lives here so the benches
//! stay readable.

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::Table;
use crate::model::Policy;
use crate::runtime::Engine;
use crate::spec::{Lenience, ReuseVariant};
use crate::trainer::eval::summarize;
use crate::trainer::sft::{run_sft, SftConfig};
use crate::trainer::{RunSummary, Trainer};

/// Scale knobs for experiment drivers. `SPEC_RL_FULL=1` selects the larger
/// configuration (more steps, bigger evals, extra model sizes).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub steps: usize,
    pub eval_n: usize,
    pub samples_hard: usize,
    pub sft_steps: usize,
    pub full: bool,
}

impl Scale {
    pub fn from_env() -> Scale {
        let full = std::env::var("SPEC_RL_FULL").map(|v| v == "1").unwrap_or(false);
        if full {
            Scale { steps: 45, eval_n: 32, samples_hard: 4, sft_steps: 3000, full }
        } else {
            Scale { steps: 36, eval_n: 16, samples_hard: 2, sft_steps: 1500, full }
        }
    }
}

/// Load `out/base_<bundle>.npy`, SFT-ing it first if missing.
pub fn ensure_base(eng: &Engine, bundle: &str, sft_steps: usize) -> Result<Policy> {
    let path = format!("out/base_{bundle}.npy");
    if std::path::Path::new(&path).exists() {
        if let Ok(p) = Policy::load(eng, bundle, &path) {
            return Ok(p);
        }
        log::warn!("stale checkpoint {path}; re-running SFT");
    }
    std::fs::create_dir_all("out").ok();
    log::info!("SFT base model for {bundle} ({sft_steps} steps)...");
    let (policy, _) = run_sft(
        eng,
        &SftConfig {
            bundle: bundle.to_string(),
            steps: sft_steps,
            lr: 1e-3,
            examples: 8192,
            seed: 7,
            init_from: None,
        },
    )?;
    policy.save(eng, &path)?;
    Ok(policy)
}

/// A preconfigured run: (label, algo+variant config).
pub fn base_config(scale: Scale, bundle: &str) -> RunConfig {
    RunConfig {
        bundle: bundle.to_string(),
        steps: scale.steps,
        eval_n: scale.eval_n,
        eval_samples_hard: scale.samples_hard,
        ..RunConfig::default()
    }
}

/// Run one configuration from a shared base checkpoint.
pub fn run_one(eng: &Engine, cfg: RunConfig, base: &Policy, label: &str) -> Result<RunSummary> {
    let base_copy = base.duplicate(eng)?;
    let mut trainer = Trainer::new(eng, cfg, base_copy)?;
    trainer.run(label)
}

/// Configure variant/lenience on a config (builder-ish helper).
pub fn with_spec(mut cfg: RunConfig, variant: ReuseVariant, log_len: Option<f32>) -> RunConfig {
    cfg.variant = variant;
    if let Some(l) = log_len {
        cfg.lenience = Lenience::Fixed(l);
    }
    cfg
}

/// The paper's Table-1-shaped row: tokens (M…here K), speedup vs a
/// baseline, per-suite accuracy, AVG.
pub fn table1_row(
    table: &mut Table,
    summary: &RunSummary,
    baseline_tokens: Option<usize>,
    baseline_rollout_secs: Option<f64>,
) {
    let speedup_tok = baseline_tokens
        .map(|b| format!("{:.2}x", b as f64 / summary.total_new_tokens.max(1) as f64))
        .unwrap_or_else(|| "1.00x".into());
    let speedup_time = baseline_rollout_secs
        .map(|b| format!("{:.2}x", b / summary.rollout_secs.max(1e-9)))
        .unwrap_or_else(|| "1.00x".into());
    let mut cells = vec![
        summary.label.clone(),
        format!("{:.1}K", summary.total_new_tokens as f64 / 1e3),
        speedup_tok,
        speedup_time,
    ];
    for (_, acc) in &summary.final_eval {
        cells.push(format!("{:.1}", acc * 100.0));
    }
    let (math, ood, avg) = summarize(&summary.final_eval);
    cells.push(format!("{:.1}", math * 100.0));
    cells.push(format!("{:.1}", ood * 100.0));
    cells.push(format!("{:.1}", avg * 100.0));
    table.row(cells);
}

/// Standard Table-1 header (suite columns from the battery).
pub fn table1_header() -> Vec<&'static str> {
    vec![
        "algorithm", "tokens", "tok-speedup", "time-speedup",
        "add-easy", "add-hard", "sub", "mul", "chain", "compare", "format",
        "MATH", "OOD", "AVG",
    ]
}

/// Write a summary's stage means as a Table-4-shaped row.
pub fn breakdown_row(table: &mut Table, s: &RunSummary) {
    let m = |k: &str| s.stage_means.get(k).copied().unwrap_or(0.0);
    table.row(vec![
        s.label.clone(),
        format!("{:.2}", s.total_secs),
        format!("{:.3}", m("verification")),
        format!("{:.3}", m("rollout")),
        format!("{:.4}", m("assembly")),
        format!("{:.3}", m("reward")),
        format!("{:.3}", m("old_logp")),
        format!("{:.3}", m("ref")),
        format!("{:.3}", m("values")),
        format!("{:.4}", m("adv")),
        format!("{:.3}", m("update_critic")),
        format!("{:.3}", m("update_actor")),
        format!("{:.3}", m("others")),
    ])
}

/// Table-4 header.
pub fn breakdown_header() -> Vec<&'static str> {
    vec![
        "algorithm", "total(s)", "verify", "rollout", "assembly", "reward",
        "old-logp", "ref", "values", "adv", "upd-critic", "upd-actor", "others",
    ]
}
