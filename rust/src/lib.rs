//! # SPEC-RL — Accelerating On-Policy RL with Speculative Rollouts
//!
//! Reproduction of *SPEC-RL: Accelerating On-Policy Reinforcement Learning
//! with Speculative Rollouts* (Liu, Wang, Min et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: RL training loop, batched
//!   rollout engine, the speculative rollout cache + verifier (the paper's
//!   contribution), GRPO / PPO / DAPO, the verifiable-reward task
//!   environment, metrics, benches.
//! - **L2** (`python/compile/model.py`) — the policy/value transformer,
//!   AOT-lowered once to HLO text.
//! - **L1** (`python/compile/kernels/`) — Pallas kernels for attention,
//!   lenient speculative acceptance, and fused log-prob/entropy.
//!
//! Python never runs at training time: [`runtime::Engine`] loads
//! `artifacts/*.hlo.txt` into a PJRT CPU client and all large tensors
//! (parameters, optimizer state, KV cache) stay device-resident between
//! calls.
//!
//! Quick tour: [`trainer::Trainer`] drives steps; [`rollout::EnginePool`]
//! drives each step's work across one or more [`rollout::RolloutEngine`]s
//! pulling from one shared [`rollout::WorkQueue`] (the mid-step
//! steal-queue over sharded slot pools); [`spec::SpecRollout`] wraps
//! generation with draft-and-verify reuse; [`algo`] turns rewards into
//! updates; [`tasks`] provides the synthetic verifiable-math environment
//! standing in for DeepMath (see DESIGN.md for the substitution table).
//!
//! The load-bearing invariants — the gen-blob layout, the
//! `Draft -> Verify -> Decode -> Done` lifecycle, the inert-slot and
//! packing-invariance (per-task RNG stream) contracts, and the
//! placement/stealing rules (lifecycle pinning) — are specified in
//! `ARCHITECTURE.md` at the repository root; every backend and every
//! scheduler change must preserve them (`rust/tests/sched_continuous.rs`
//! pins them down, and `rust/tests/doc_links.rs` keeps the book's `§`
//! anchors honest).

pub mod algo;
pub mod benchkit;
pub mod exp;
pub mod cli;
pub mod config;
pub mod metrics;
pub mod model;
pub mod rollout;
pub mod runtime;
pub mod spec;
pub mod tasks;
pub mod testing;
pub mod tokenizer;
pub mod trainer;
pub mod util;
