//! RL algorithms: GRPO, PPO, DAPO on the shared clipped-surrogate update.
//!
//! The L2 `train_policy` graph implements the token-level PPO-clip
//! objective with a k3 KL term and entropy bonus; the three algorithms
//! differ only in (a) how advantages are computed host-side, (b) the
//! hyperparameter vector, and (c) batch curation (DAPO's dynamic
//! sampling). This mirrors the paper: "SPEC-RL modifies only the rollout
//! stage" — the algorithms are untouched and shared.

pub mod advantage;

pub use advantage::{gae, grpo_advantages, whiten};

/// Which RLVR algorithm drives the update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Grpo,
    Ppo,
    Dapo,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "grpo" => Some(Algo::Grpo),
            "ppo" => Some(Algo::Ppo),
            "dapo" => Some(Algo::Dapo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Grpo => "grpo",
            Algo::Ppo => "ppo",
            Algo::Dapo => "dapo",
        }
    }

    /// Paper defaults (Appendix A.1), scaled where noted in DESIGN.md.
    pub fn default_params(&self) -> AlgoParams {
        match self {
            // GRPO: KL regularization on (coef 1e-4), clip 0.2, seq-mean.
            Algo::Grpo => AlgoParams {
                clip_low: 0.2,
                clip_high: 0.2,
                kl_coef: 1e-4,
                token_mean: false,
                dynamic_sampling: false,
                use_critic: false,
                default_log_lenience: 0.5, // e^0.5
                ..AlgoParams::base()
            },
            // PPO: critic + GAE, no KL.
            Algo::Ppo => AlgoParams {
                clip_low: 0.2,
                clip_high: 0.2,
                kl_coef: 0.0,
                token_mean: false,
                dynamic_sampling: false,
                use_critic: true,
                default_log_lenience: 0.3, // e^0.3
                ..AlgoParams::base()
            },
            // DAPO: clip-higher (0.28), token-mean loss, dynamic sampling,
            // no KL.
            Algo::Dapo => AlgoParams {
                clip_low: 0.2,
                clip_high: 0.28,
                kl_coef: 0.0,
                token_mean: true,
                dynamic_sampling: true,
                use_critic: false,
                default_log_lenience: 0.15, // e^0.15
                ..AlgoParams::base()
            },
        }
    }
}

/// Flattened algorithm hyperparameters (host side of the L2 `hp` vector).
#[derive(Clone, Copy, Debug)]
pub struct AlgoParams {
    pub lr: f32,
    pub critic_lr: f32,
    pub clip_low: f32,
    pub clip_high: f32,
    pub kl_coef: f32,
    pub ent_coef: f32,
    /// true => token-mean loss aggregation (DAPO), false => seq-mean.
    pub token_mean: bool,
    pub weight_decay: f32,
    pub max_grad_norm: f32,
    /// GAE parameters (PPO).
    pub gamma: f32,
    pub lam: f32,
    pub dynamic_sampling: bool,
    pub use_critic: bool,
    /// Paper's per-algorithm grid-searched lenience (log ℓ).
    pub default_log_lenience: f32,
}

impl AlgoParams {
    fn base() -> AlgoParams {
        AlgoParams {
            // paper: actor lr 5e-7 for billion-param models; scaled for the
            // ~1e5..1e6-param substitutes (see DESIGN.md).
            lr: 3e-4,
            critic_lr: 1e-3,
            clip_low: 0.2,
            clip_high: 0.2,
            kl_coef: 0.0,
            ent_coef: 0.0,
            token_mean: false,
            weight_decay: 0.01,
            max_grad_norm: 1.0,
            gamma: 1.0,
            lam: 0.95,
            dynamic_sampling: false,
            use_critic: false,
            default_log_lenience: 0.5,
        }
    }

    /// Serialize into the L2 `hp` vector layout
    /// (`manifest.hp_names` order: lr, clip_low, clip_high, kl_coef,
    /// ent_coef, loss_agg_mode, weight_decay, max_grad_norm).
    pub fn hp_vector(&self, lr: f32) -> [f32; 8] {
        [
            lr,
            self.clip_low,
            self.clip_high,
            self.kl_coef,
            self.ent_coef,
            if self.token_mean { 1.0 } else { 0.0 },
            self.weight_decay,
            self.max_grad_norm,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for a in [Algo::Grpo, Algo::Ppo, Algo::Dapo] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("GRPO"), Some(Algo::Grpo));
        assert_eq!(Algo::parse("sac"), None);
    }

    #[test]
    fn defaults_match_paper_structure() {
        let g = Algo::Grpo.default_params();
        assert!(g.kl_coef > 0.0 && !g.dynamic_sampling && !g.use_critic);
        let p = Algo::Ppo.default_params();
        assert!(p.kl_coef == 0.0 && p.use_critic);
        let d = Algo::Dapo.default_params();
        assert!(d.clip_high > d.clip_low && d.dynamic_sampling && d.token_mean);
        // paper's lenience ordering: GRPO e^0.5 > PPO e^0.3 > DAPO e^0.15
        assert!(g.default_log_lenience > p.default_log_lenience);
        assert!(p.default_log_lenience > d.default_log_lenience);
    }

    #[test]
    fn hp_vector_layout() {
        let g = Algo::Dapo.default_params();
        let hp = g.hp_vector(1e-3);
        assert_eq!(hp[0], 1e-3);
        assert_eq!(hp[2], 0.28);
        assert_eq!(hp[5], 1.0); // token-mean
    }
}
