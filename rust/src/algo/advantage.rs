//! Advantage estimation: group-relative (GRPO/DAPO) and GAE (PPO).

/// Group-relative advantages: for each group of `group` consecutive
/// rewards, `A = (r - mean) / (std + eps)`. Returns one advantage per
/// sequence (broadcast over its tokens by the caller).
///
/// Degenerate groups clamp to zero advantage instead of poisoning the
/// update: a zero-variance group (every sample got the same reward — no
/// learning signal), a group with a non-finite reward, and a short tail
/// when `rewards.len()` is not a multiple of `group` (a partial final
/// batch; a singleton has no group baseline at all) all yield zeros. The
/// tail's statistics use its actual length, never padding.
pub fn grpo_advantages(rewards: &[f32], group: usize) -> Vec<f32> {
    assert!(group > 0, "group must be positive");
    let mut adv = vec![0f32; rewards.len()];
    for (gi, rs) in rewards.chunks(group).enumerate() {
        if rs.len() < 2 || rs.iter().any(|r| !r.is_finite()) {
            continue;
        }
        let n = rs.len() as f32;
        let mean = rs.iter().sum::<f32>() / n;
        let var = rs.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>() / n;
        if var <= 1e-8 {
            continue;
        }
        let std = var.sqrt();
        for (k, &r) in rs.iter().enumerate() {
            adv[gi * group + k] = (r - mean) / (std + 1e-6);
        }
    }
    adv
}

/// Generalized Advantage Estimation for a sparse terminal reward.
///
/// `values` holds `V(s_0..s_L)` (L+1 entries, `s_j` = state before
/// response token j; `V(s_L)` is the post-terminal bootstrap, ignored for
/// finished episodes). Reward `r` lands on the final token. Returns
/// `(advantages[L], value_targets[L])`.
pub fn gae(values: &[f32], reward: f32, gamma: f32, lam: f32) -> (Vec<f32>, Vec<f32>) {
    let l = values.len() - 1;
    let mut adv = vec![0f32; l];
    let mut gae_acc = 0f32;
    for j in (0..l).rev() {
        let next_v = if j == l - 1 { 0.0 } else { values[j + 1] };
        let r = if j == l - 1 { reward } else { 0.0 };
        let delta = r + gamma * next_v - values[j];
        gae_acc = delta + gamma * lam * gae_acc;
        adv[j] = gae_acc;
    }
    let targets: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, targets)
}

/// Whiten advantages to zero mean / unit variance over the masked entries.
pub fn whiten(adv: &mut [f32], mask: &[f32]) {
    assert_eq!(adv.len(), mask.len());
    let n: f32 = mask.iter().sum();
    if n < 2.0 {
        return;
    }
    let mean = adv.iter().zip(mask).map(|(a, m)| a * m).sum::<f32>() / n;
    let var = adv
        .iter()
        .zip(mask)
        .map(|(a, m)| m * (a - mean) * (a - mean))
        .sum::<f32>()
        / n;
    let std = var.sqrt() + 1e-6;
    for (a, m) in adv.iter_mut().zip(mask) {
        if *m > 0.5 {
            *a = (*a - mean) / std;
        } else {
            *a = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grpo_zero_for_uniform_group() {
        let adv = grpo_advantages(&[1.0, 1.0, 1.0, 1.0], 4);
        assert!(adv.iter().all(|&a| a.abs() < 1e-3));
    }

    #[test]
    fn grpo_sums_to_zero_per_group() {
        let adv = grpo_advantages(&[1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0], 4);
        for g in adv.chunks(4) {
            let s: f32 = g.iter().sum();
            assert!(s.abs() < 1e-4, "{s}");
        }
    }

    #[test]
    fn grpo_correct_reward_gets_positive_advantage() {
        let adv = grpo_advantages(&[1.0, 0.0, 0.0, 0.0], 4);
        assert!(adv[0] > 0.0);
        assert!(adv[1] < 0.0);
    }

    #[test]
    fn grpo_zero_variance_group_yields_exact_zeros() {
        // all-correct (DAPO's degenerate case) and all-wrong groups carry
        // no signal: exact zeros, not 0/eps noise or NaN
        for r in [0.0f32, 1.0] {
            let adv = grpo_advantages(&[r; 4], 4);
            assert!(adv.iter().all(|&a| a == 0.0), "{adv:?}");
        }
    }

    #[test]
    fn grpo_partial_tail_group_uses_actual_length() {
        // 6 rewards with group 4: the 2-long tail normalizes over its own
        // statistics instead of panicking or dividing by `group`
        let adv = grpo_advantages(&[1.0, 0.0, 0.0, 1.0, 1.0, 0.0], 4);
        assert_eq!(adv.len(), 6);
        let tail: f32 = adv[4..].iter().sum();
        assert!(tail.abs() < 1e-4, "tail sums to zero: {tail}");
        assert!(adv[4] > 0.0 && adv[5] < 0.0);
    }

    #[test]
    fn grpo_singleton_tail_gets_zero_advantage() {
        // a 1-long tail has no group baseline: its advantage clamps to 0
        let adv = grpo_advantages(&[1.0, 0.0, 1.0], 2);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
        assert_eq!(adv[2], 0.0);
    }

    #[test]
    fn grpo_non_finite_rewards_clamp_their_group_to_zero() {
        let adv = grpo_advantages(&[f32::NAN, 1.0, 1.0, 0.0], 2);
        assert_eq!(&adv[..2], &[0.0, 0.0], "poisoned group zeroed");
        assert!(adv[2] > 0.0 && adv[3] < 0.0, "healthy group unaffected");
        assert!(adv.iter().all(|a| a.is_finite()));
    }

    #[test]
    fn gae_terminal_only_reward_gamma1_lam1_is_reward_minus_value() {
        // with gamma=lam=1 advantages telescope: A_j = r - V(s_j)
        let values = vec![0.2, 0.4, 0.1, 0.0];
        let (adv, tgt) = gae(&values, 1.0, 1.0, 1.0);
        for j in 0..3 {
            assert!((adv[j] - (1.0 - values[j])).abs() < 1e-5, "{j}");
            assert!((tgt[j] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gae_lambda_zero_is_one_step_td() {
        let values = vec![0.5, 0.25, 0.0];
        let (adv, _) = gae(&values, 1.0, 1.0, 0.0);
        assert!((adv[0] - (0.25 - 0.5)).abs() < 1e-5);
        assert!((adv[1] - (1.0 - 0.25)).abs() < 1e-5);
    }

    #[test]
    fn whiten_normalizes_masked() {
        let mut adv = vec![1.0, 2.0, 3.0, 99.0];
        let mask = vec![1.0, 1.0, 1.0, 0.0];
        whiten(&mut adv, &mask);
        assert_eq!(adv[3], 0.0);
        let mean: f32 = adv[..3].iter().sum::<f32>() / 3.0;
        assert!(mean.abs() < 1e-5);
    }

    #[test]
    fn whiten_single_entry_noop() {
        let mut adv = vec![5.0];
        whiten(&mut adv, &[1.0]);
        assert_eq!(adv[0], 5.0);
    }
}
