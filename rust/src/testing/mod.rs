//! Property-based testing kit (proptest substitute for the offline image).
//!
//! Seeded generators + a `forall` runner with minimal shrinking (halving
//! retries on sizes). Used by `rust/tests/prop_*.rs` to check the
//! coordinator invariants listed in DESIGN.md.

pub mod mock;

use crate::util::Rng;

/// A value generator.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run `prop` over `cases` generated inputs; panics with the seed and a
/// debug dump of the failing case.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    generator: impl Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generator.gen(&mut rng);
        if !prop(&input) {
            panic!("property failed (seed={seed}, case={case}):\n{input:#?}");
        }
    }
}

/// Like [`forall`] but the property returns `Result` with a reason.
pub fn forall_ok<T: std::fmt::Debug, E: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    generator: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), E>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generator.gen(&mut rng);
        if let Err(e) = prop(&input) {
            panic!("property failed (seed={seed}, case={case}): {e:?}\n{input:#?}");
        }
    }
}

// -- common generators ---------------------------------------------------
/// usize in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    move |rng: &mut Rng| lo + rng.below(hi - lo + 1)
}

/// f32 in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> impl Gen<f32> {
    move |rng: &mut Rng| lo + rng.f32() * (hi - lo)
}

/// Vec of token ids (1-`max_len` long, ids in [3, vocab)).
pub fn tokens(max_len: usize, vocab: usize) -> impl Gen<Vec<i32>> {
    move |rng: &mut Rng| {
        let len = 1 + rng.below(max_len);
        (0..len).map(|_| (3 + rng.below(vocab - 3)) as i32).collect()
    }
}

/// Vec of log-probs (negative reals).
pub fn logps(len: usize) -> impl Gen<Vec<f32>> {
    move |rng: &mut Rng| (0..len).map(|_| -(rng.f32() * 5.0 + 1e-3)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall(1, 200, usize_in(0, 10), |&x| x <= 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 200, usize_in(0, 10), |&x| x < 10);
    }

    #[test]
    fn token_gen_in_range() {
        forall(3, 100, tokens(12, 52), |ts| {
            !ts.is_empty() && ts.len() <= 12 && ts.iter().all(|&t| (3..52).contains(&t))
        });
    }

    #[test]
    fn forall_ok_variant() {
        forall_ok(4, 50, f32_in(0.0, 1.0), |&x| {
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
