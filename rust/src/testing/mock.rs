//! In-process mock of the decode-entry contract.
//!
//! [`MockEngine`] implements [`Backend`] with a tiny deterministic "model":
//! each row's next-token distribution is a pure function of that row's
//! logical token content (a hash seeds an [`Rng`]), with EOS mass growing
//! with row length so sequences terminate at varied, content-dependent
//! points. Because probs depend only on row content — exactly the
//! independence property the real per-row-masked transformer has — the
//! mock lets the scheduler invariants run as plain unit tests with no
//! built `artifacts/`:
//!
//! - lockstep vs continuous byte-equivalence,
//! - per-decode-step upload accounting (no `[B, T]` mask traffic),
//! - refill ordering determinism and slot-idle stats.
//!
//! It also *enforces* the contract: argument counts and shapes are checked
//! on every call (a decode carrying a stale `[B, T]` valid arg fails
//! loudly), and the generation state carries its valid mask device-side,
//! updated incrementally from `slot` writes like the real lowered entry.
//! Every row seated on an engine (`prefill` / `refill` / `verify_seat`)
//! is logged by prompt signature ([`MockCounters::seated`]), so the
//! steal tests can assert the lifecycle-pinning invariant directly: a
//! task's row appears on exactly one engine per step, however the shared
//! queue drained.
//!
//! The `verify` / `verify_seat` entries implement the lenient acceptance
//! rule `u <= min(1, l * p_curr/p_prev)` against the same content-hashed
//! model, with `p_curr` scored token-by-token exactly as a teacher-forced
//! forward would: both entries share one scoring routine, so the blocking
//! two-phase wave and the interleaved pipeline accept identical prefixes
//! by construction. `verify_seat` additionally seats the accepted prefix
//! into the row (the mock analog of reusing the verify forward's KV) and
//! reports its length in the gen state's `aux` lane; `read_gen` returns
//! `[probs | aux]` per the contract in `rollout/sched.rs`.
//!
//! The device-resident sampling lanes (`ARCHITECTURE.md` §12) are
//! mirrored too: `verify_seat` raises the `live` lane for seated rows
//! whose accepted prefix is not terminal, the `sample` entry replays the
//! crate's own per-task RNG streams (`task_rng` + [`TopPSampler`] — the
//! literal host sampler, so tokens match bit-for-bit by construction),
//! and `read_step` returns the fused `[tok | ptok | aux]` O(B) readback
//! that replaces `read_gen` on the pipeline hot path.
//!
//! ## The virtual clock (overlap accounting)
//!
//! A [`VirtualClock`] attached via [`MockEngine::attach_clock`] (or
//! [`MockEngine::clocked_replicas`]) gives the mock a latency model for
//! the submit/complete protocol (`ARCHITECTURE.md` §11): every entry
//! call costs a fixed per-entry latency on *this engine's* device
//! timeline, while the shared clock tracks the host. A synchronous
//! [`Backend::call_entry`] blocks the host for the whole forward; a
//! [`Backend::submit_entry`] only reserves device time, and the host
//! does not advance until [`Backend::complete`]. Replicas sharing one
//! clock therefore realize a shorter makespan when a driver submits all
//! their chains before completing any — exactly the quantity
//! `PipelineStats::overlap_makespan` reports against the serialized
//! `serial_makespan` baseline (`bench_overlap`). Without an attached
//! clock every latency is zero and the accounting stays dark.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use anyhow::{bail, ensure, Result};

use crate::rollout::engine::task_rng;
use crate::runtime::{Backend, BatchShape};
use crate::tokenizer::{BOS, EOS, PAD};
use crate::util::{Rng, TopPSampler};

/// The host timeline one replica group shares for overlap accounting.
/// Engine-local device timelines live in each [`MockEngine`]'s busy
/// counter; this cell is the host's position, advanced by synchronous
/// calls and by completes.
#[derive(Debug, Default)]
pub struct VirtualClock {
    host: Cell<f64>,
}

impl VirtualClock {
    pub fn new() -> Rc<VirtualClock> {
        Rc::new(VirtualClock::default())
    }

    /// Current host time (virtual seconds since construction).
    pub fn now(&self) -> f64 {
        self.host.get()
    }
}

/// An in-flight mock forward: the eagerly-computed result plus the
/// virtual time at which the device finishes it.
pub struct MockPending {
    buf: MockBuf,
    ready: f64,
}

/// One row of mock generation state.
#[derive(Clone, Debug, Default)]
struct RowState {
    /// Logical token sequence (prompt + response, valid slots in order) —
    /// the mock's stand-in for KV cache + device-side valid mask.
    toks: Vec<i32>,
    /// Next-token distribution for this row.
    probs: Vec<f32>,
}

/// Mock generation blob (the `gen` buffer chained through decode calls).
#[derive(Clone, Debug, Default)]
pub struct GenState {
    rows: Vec<RowState>,
    /// Per-row f32 side channel: `verify_seat` writes accepted-prefix
    /// lengths here; prefill zeroes it, decode/refill pass it through.
    aux: Vec<f32>,
    /// Device-side liveness lane (§12): `verify_seat` sets 1.0 for seated
    /// rows whose accepted prefix is not yet terminal — the `sample`
    /// entry's mode-2 arming predicate.
    live: Vec<f32>,
    /// Sampled token ids written by the `sample` entry (-1.0 = unarmed).
    tok: Vec<f32>,
    /// Raw probability of each sampled token (the host takes the log).
    ptok: Vec<f32>,
}

/// A mock device buffer.
#[derive(Clone, Debug)]
pub enum MockBuf {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    Gen(GenState),
}

impl MockBuf {
    fn f32s(&self) -> Result<&[f32]> {
        match self {
            MockBuf::F32(v, _) => Ok(v),
            _ => bail!("expected f32 buffer"),
        }
    }

    fn i32s(&self) -> Result<&[i32]> {
        match self {
            MockBuf::I32(v, _) => Ok(v),
            _ => bail!("expected i32 buffer"),
        }
    }

    fn gen(&self) -> Result<&GenState> {
        match self {
            MockBuf::Gen(g) => Ok(g),
            _ => bail!("expected gen-state buffer"),
        }
    }

    fn dims(&self) -> &[usize] {
        match self {
            MockBuf::F32(_, d) | MockBuf::I32(_, d) => d,
            MockBuf::Gen(_) => &[],
        }
    }
}

/// Per-engine call/upload telemetry.
#[derive(Clone, Debug, Default)]
pub struct MockCounters {
    /// Dims of every host→device upload, in order.
    pub uploads: Vec<Vec<usize>>,
    /// Entry names of every call, in order. Only *executed* forwards are
    /// logged: a call killed by an armed [`FaultPlan`] fails before it
    /// runs and leaves no trace here.
    pub calls: Vec<String>,
    /// Prompt-region signature of every row seated on this engine (via
    /// `prefill`, `refill`, or `verify_seat`), in seating order. With
    /// per-task-unique prompts this is a row→engine attribution trace:
    /// the steal tests assert no signature ever appears on two engines —
    /// the lifecycle-pinning invariant made observable.
    pub seated: Vec<Vec<i32>>,
    /// [`MockCounters::seated`] with the seating entry attached:
    /// `(entry name, prompt signature)` per seated row, in order. The
    /// chaos property tests use this to tell a row seated on a live
    /// engine from one stranded on a dead engine when a requeued task
    /// legitimately appears on two engines across a recovery
    /// (`ARCHITECTURE.md` §13).
    pub seats: Vec<(String, Vec<i32>)>,
}

/// One injected backend failure, armed on a [`MockEngine`] via
/// [`MockEngine::arm_faults`] (`ARCHITECTURE.md` §13). A tripped plan
/// makes `execute` bail *before* the forward runs — the mock analog of a
/// transport error killing an RPC before the remote applies it — so the
/// engine's functional state (the last completed gen blob held by the
/// caller) is unchanged, exactly like a real idempotent backend.
///
/// Triggers are OR-ed: the plan trips at the `at_call`-th executed
/// device call (0-based, this engine's whole lifetime as counted by
/// `MockCounters::calls`) and/or at the first call of entry `at_entry`
/// (the lifecycle-phase knob: `prefill`/`verify_seat`/`decode`/`refill`
/// /`read_gen`/`read_step` pin the Draft/Verify/Decode/Done boundaries).
/// A non-sticky plan disarms after tripping once — later calls succeed,
/// modeling a transient blip; a `sticky` plan keeps failing every
/// subsequent call, modeling a dead host.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Fail the call whose 0-based executed-call index equals this.
    pub at_call: Option<usize>,
    /// Fail the first call of this entry name.
    pub at_entry: Option<String>,
    /// Keep failing every call after the first trip.
    pub sticky: bool,
    /// Set once the plan has tripped (drives sticky persistence).
    tripped: bool,
}

impl FaultPlan {
    /// Trip at the `n`-th executed device call (0-based).
    pub fn at_call(n: usize) -> FaultPlan {
        FaultPlan { at_call: Some(n), ..FaultPlan::default() }
    }

    /// Trip at the first call of `entry`.
    pub fn at_entry(entry: &str) -> FaultPlan {
        FaultPlan { at_entry: Some(entry.to_string()), ..FaultPlan::default() }
    }

    /// Same plan, sticky: every call after the trip fails too.
    pub fn sticky(mut self) -> FaultPlan {
        self.sticky = true;
        self
    }
}

/// Deterministic mock rollout backend.
pub struct MockEngine {
    pub shape: BatchShape,
    /// EOS mass per unit of row length: 0.0 = rows always run to the cap,
    /// larger = shorter, more length-skewed rollouts.
    pub eos_bias: f32,
    counters: RefCell<MockCounters>,
    /// Armed fault injection (None = healthy engine).
    faults: RefCell<Option<FaultPlan>>,
    /// Shared host timeline (None = no latency model, all costs zero).
    clock: Option<Rc<VirtualClock>>,
    /// This engine's device timeline: virtual time its last forward ends.
    busy: Cell<f64>,
    /// Cumulative forward latency on this engine (idle gaps excluded).
    busy_secs: Cell<f64>,
}

impl MockEngine {
    pub fn new(batch: usize, prompt_len: usize, total_len: usize, vocab: usize) -> Self {
        MockEngine {
            shape: BatchShape { batch, prompt_len, total_len, vocab },
            eos_bias: 0.6,
            counters: RefCell::new(MockCounters::default()),
            faults: RefCell::new(None),
            clock: None,
            busy: Cell::new(0.0),
            busy_secs: Cell::new(0.0),
        }
    }

    /// `n` identically-configured replicas — the cheap stand-in for a
    /// pool of per-device engines. Each instance keeps its own call and
    /// upload counters, so per-shard device traffic is directly
    /// observable (the content-hashed model is a pure function, so all
    /// replicas agree on every distribution by construction).
    pub fn replicas(
        n: usize,
        batch: usize,
        prompt_len: usize,
        total_len: usize,
        vocab: usize,
    ) -> Vec<MockEngine> {
        (0..n).map(|_| MockEngine::new(batch, prompt_len, total_len, vocab)).collect()
    }

    /// [`MockEngine::replicas`] sharing one [`VirtualClock`]: each
    /// replica keeps its own device timeline but the host timeline is
    /// common, so the pool's overlap accounting
    /// (`PipelineStats::overlap_makespan` / `serial_makespan`) measures
    /// how much of the replicas' device time a driver actually ran
    /// concurrently.
    pub fn clocked_replicas(
        n: usize,
        batch: usize,
        prompt_len: usize,
        total_len: usize,
        vocab: usize,
    ) -> Vec<MockEngine> {
        let clock = VirtualClock::new();
        let mut out = MockEngine::replicas(n, batch, prompt_len, total_len, vocab);
        for m in &mut out {
            m.attach_clock(clock.clone());
        }
        out
    }

    /// Attach a (shared) host timeline, arming the latency model. The
    /// engine's device timeline starts at the clock's current reading.
    pub fn attach_clock(&mut self, clock: Rc<VirtualClock>) {
        self.busy.set(clock.now());
        self.clock = Some(clock);
    }

    /// Per-entry latency (virtual seconds) of the clock model. Values
    /// are arbitrary but ordered like the real entries: full `[B, T]`
    /// forwards (prefill / refill / verify) dominate, the one-token
    /// decode step is cheaper, and the readback entries (`read_gen`,
    /// `read_step`) cost a fixed issue overhead plus a per-float transfer
    /// term — so `overlap_makespan` reflects bytes actually moved, not
    /// call counts alone (the O(B·V)→O(B) readback shrink is measurable,
    /// `bench_readback`). The `sample` entry is a trivial elementwise op
    /// next to a forward. Zero without an attached clock.
    fn entry_latency(&self, entry: &str) -> f64 {
        if self.clock.is_none() {
            return 0.0;
        }
        let (b, v) = (self.shape.batch, self.shape.vocab);
        match entry {
            "prefill" => 2.0,
            "refill" => 1.5,
            "verify" | "verify_seat" => 1.6,
            "decode" => 1.0,
            "sample" => 0.05,
            "read_gen" => 0.05 + 5.0e-4 * (b * v + b) as f64,
            "read_step" => 0.05 + 5.0e-4 * (3 * b) as f64,
            _ => 0.0,
        }
    }

    /// Reserve device time for one forward submitted now; returns the
    /// virtual time the forward finishes. Host time is not advanced —
    /// that is the caller's choice (sync call vs complete).
    fn reserve(&self, entry: &str) -> f64 {
        let Some(clock) = &self.clock else { return 0.0 };
        let lat = self.entry_latency(entry);
        let start = clock.now().max(self.busy.get());
        let end = start + lat;
        self.busy.set(end);
        self.busy_secs.set(self.busy_secs.get() + lat);
        end
    }

    /// Total executable invocations over the contract's device-call
    /// entries (`verify` + `verify_seat` + `decode` + `refill`) — the
    /// per-engine critical-path metric `bench_shards` tracks, matching
    /// [`crate::rollout::PipelineStats::device_calls`].
    pub fn device_calls(&self) -> usize {
        ["verify", "verify_seat", "decode", "refill"]
            .iter()
            .map(|e| self.calls_of(e))
            .sum()
    }

    /// Policy blob stand-in (contents irrelevant to the mock model).
    pub fn blob(&self) -> MockBuf {
        MockBuf::F32(vec![0.0], vec![1])
    }

    pub fn counters(&self) -> MockCounters {
        self.counters.borrow().clone()
    }

    pub fn reset_counters(&self) {
        *self.counters.borrow_mut() = MockCounters::default();
    }

    /// Uploads whose dims match exactly.
    pub fn uploads_with_dims(&self, dims: &[usize]) -> usize {
        self.counters.borrow().uploads.iter().filter(|d| d.as_slice() == dims).count()
    }

    /// Calls of one entry.
    pub fn calls_of(&self, entry: &str) -> usize {
        self.counters.borrow().calls.iter().filter(|c| c.as_str() == entry).count()
    }

    /// Prompt signatures of every row seated on this engine, in order
    /// (see [`MockCounters::seated`]).
    pub fn seated_rows(&self) -> Vec<Vec<i32>> {
        self.counters.borrow().seated.clone()
    }

    /// Arm (or, with `None`-equivalent semantics via a fresh default
    /// plan, effectively disarm) fault injection on this engine. The
    /// plan applies to all subsequent entry calls; see [`FaultPlan`].
    pub fn arm_faults(&self, plan: FaultPlan) {
        *self.faults.borrow_mut() = Some(plan);
    }

    /// Remove any armed [`FaultPlan`].
    pub fn clear_faults(&self) {
        *self.faults.borrow_mut() = None;
    }

    /// Bail if the armed [`FaultPlan`] says this call must die. Runs
    /// before the forward executes or is logged, so a killed call leaves
    /// no trace in [`MockCounters::calls`] and no state change anywhere —
    /// retrying it (or requeueing its work) can never double-apply.
    fn fault_check(&self, entry: &str) -> Result<()> {
        let mut slot = self.faults.borrow_mut();
        let Some(plan) = slot.as_mut() else { return Ok(()) };
        let n_exec = self.counters.borrow().calls.len();
        let hit = plan.tripped
            || plan.at_call == Some(n_exec)
            || plan.at_entry.as_deref() == Some(entry);
        if !hit {
            return Ok(());
        }
        if plan.sticky {
            plan.tripped = true;
        } else {
            *slot = None;
        }
        bail!("injected fault: entry '{entry}' killed at executed-call index {n_exec}")
    }

    /// Record the prompt signature of a row being seated by `entry`.
    fn trace_seat(&self, entry: &str, tokens: &[i32], valid: &[f32], r: usize) {
        let sig = self.prompt_of(tokens, valid, r);
        if !sig.is_empty() {
            let mut c = self.counters.borrow_mut();
            c.seated.push(sig.clone());
            c.seats.push((entry.to_string(), sig));
        }
    }

    /// Next-token distribution as a pure function of row content.
    fn row_probs(&self, toks: &[i32]) -> Vec<f32> {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &t in toks {
            h = (h ^ t as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        let mut r = Rng::new(h);
        let v = self.shape.vocab;
        let mut p: Vec<f32> = (0..v).map(|_| 0.05 + r.f32()).collect();
        p[PAD as usize] = 0.0;
        p[BOS as usize] = 0.0;
        p[EOS as usize] = self.eos_bias * toks.len() as f32;
        let s: f32 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        p
    }

    /// Rebuild one row from an uploaded [B,T] tokens/valid pair.
    fn row_from_layout(&self, tokens: &[i32], valid: &[f32], r: usize) -> RowState {
        let t = self.shape.total_len;
        let toks: Vec<i32> = (0..t)
            .filter(|&j| valid[r * t + j] > 0.5)
            .map(|j| tokens[r * t + j])
            .collect();
        let probs = self.row_probs(&toks);
        RowState { toks, probs }
    }

    /// Valid prompt-region tokens of one packed row.
    fn prompt_of(&self, tokens: &[i32], valid: &[f32], r: usize) -> Vec<i32> {
        let (p, t) = (self.shape.prompt_len, self.shape.total_len);
        (0..p).filter(|&j| valid[r * t + j] > 0.5).map(|j| tokens[r * t + j]).collect()
    }

    /// Teacher-forced acceptance scan for one packed row: score each draft
    /// token under the content-hashed "current policy" and apply the
    /// lenient rule `u <= exp(min(0, loglen + ln p_curr - logp_prev))`.
    /// Shared by `verify` and `verify_seat` so the two entries accept
    /// identical prefixes by construction. Returns (accepted count,
    /// per-draft-token current logps).
    #[allow(clippy::too_many_arguments)]
    fn accept_row(
        &self,
        tokens: &[i32],
        valid: &[f32],
        r: usize,
        logp_prev: &[f32],
        uniforms: &[f32],
        draft_valid: &[f32],
        loglen: f32,
    ) -> (usize, Vec<f32>) {
        let (p, t) = (self.shape.prompt_len, self.shape.total_len);
        let g = t - p;
        let row = r * t;
        let mut ctx = self.prompt_of(tokens, valid, r);
        let mut lps = vec![0f32; g];
        let mut n_acc = 0usize;
        let mut rejected = false;
        for j in 0..g {
            if draft_valid[r * g + j] < 0.5 {
                break;
            }
            let tok = tokens[row + p + j];
            let probs = self.row_probs(&ctx);
            let lc = probs[tok as usize].max(1e-30).ln();
            lps[j] = lc;
            if !rejected {
                let log_alpha = (loglen + lc - logp_prev[r * g + j]).min(0.0);
                if uniforms[r * g + j] > log_alpha.exp() {
                    rejected = true;
                } else {
                    n_acc += 1;
                }
            }
            ctx.push(tok);
        }
        (n_acc, lps)
    }
}

impl Backend for MockEngine {
    type Buf = MockBuf;
    type Entry = String;
    type Pending = MockPending;

    fn resolve(&self, _bundle: &str, entry: &str) -> Result<String> {
        match entry {
            "prefill" | "decode" | "read_gen" | "refill" | "verify" | "verify_seat"
            | "sample" | "read_step" => Ok(entry.to_string()),
            other => bail!("mock backend has no entry '{other}'"),
        }
    }

    fn call_entry(&self, entry: &String, args: &[&MockBuf]) -> Result<MockBuf> {
        // Submit + complete in one blocking step: the host timeline
        // advances past the whole forward, which is what makes the
        // serialized driver's makespan the sum of its calls' latencies.
        let pending = self.submit_entry(entry, args)?;
        self.complete(pending)
    }

    fn submit_entry(&self, entry: &String, args: &[&MockBuf]) -> Result<MockPending> {
        let buf = self.execute(entry, args)?;
        let ready = self.reserve(entry);
        Ok(MockPending { buf, ready })
    }

    fn complete(&self, pending: MockPending) -> Result<MockBuf> {
        if let Some(clock) = &self.clock {
            clock.host.set(clock.now().max(pending.ready));
        }
        Ok(pending.buf)
    }

    fn pending_buf<'a>(&self, pending: &'a MockPending) -> &'a MockBuf {
        &pending.buf
    }

    fn virtual_now(&self) -> Option<f64> {
        self.clock.as_ref().map(|c| c.now())
    }

    fn device_busy_secs(&self) -> f64 {
        self.busy_secs.get()
    }

    fn read_f32_into(&self, buf: &MockBuf, out: &mut Vec<f32>) -> Result<()> {
        // Straight out of the host-resident storage into the caller's
        // scratch — the trait default's intermediate Vec is the
        // documented fallback, not this backend's path.
        out.clear();
        out.extend_from_slice(buf.f32s()?);
        Ok(())
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<MockBuf> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload_f32 dims mismatch");
        self.counters.borrow_mut().uploads.push(dims.to_vec());
        Ok(MockBuf::F32(data.to_vec(), dims.to_vec()))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<MockBuf> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload_i32 dims mismatch");
        self.counters.borrow_mut().uploads.push(dims.to_vec());
        Ok(MockBuf::I32(data.to_vec(), dims.to_vec()))
    }

    fn read_f32(&self, buf: &MockBuf) -> Result<Vec<f32>> {
        Ok(buf.f32s()?.to_vec())
    }

    fn shape(&self, _bundle: &str) -> Result<BatchShape> {
        Ok(self.shape)
    }
}

impl MockEngine {
    /// Execute one entry against the contract — argument counts, shapes,
    /// and the content-hashed model. Clock accounting ([`VirtualClock`])
    /// is layered on top by the [`Backend`] impl: the synchronous
    /// `call_entry` is submit + complete in one blocking step, while
    /// `submit_entry` only reserves time on this engine's device
    /// timeline and leaves the host free to submit elsewhere.
    fn execute(&self, entry: &str, args: &[&MockBuf]) -> Result<MockBuf> {
        self.fault_check(entry)?;
        self.counters.borrow_mut().calls.push(entry.to_string());
        let (b, t) = (self.shape.batch, self.shape.total_len);
        match entry {
            "prefill" => {
                // (blob, tokens[B,T], valid[B,T], last[B], temp[1])
                ensure!(args.len() == 5, "prefill: expected 5 args, got {}", args.len());
                let tokens = args[1].i32s()?;
                let valid = args[2].f32s()?;
                ensure!(args[1].dims() == [b, t], "prefill: tokens dims {:?}", args[1].dims());
                ensure!(args[2].dims() == [b, t], "prefill: valid dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [b], "prefill: last dims {:?}", args[3].dims());
                for r in 0..b {
                    self.trace_seat("prefill", tokens, valid, r);
                }
                let rows = (0..b).map(|r| self.row_from_layout(tokens, valid, r)).collect();
                Ok(MockBuf::Gen(GenState {
                    rows,
                    aux: vec![0.0; b],
                    live: vec![0.0; b],
                    tok: vec![0.0; b],
                    ptok: vec![0.0; b],
                }))
            }
            "decode" => {
                // (blob, gen, token[B], slot[B], lpos[B], temp[1]) — a 7th
                // [B,T] valid arg is a contract violation.
                ensure!(args.len() == 6, "decode: expected 6 args, got {}", args.len());
                let mut gen = args[1].gen()?.clone();
                let token = args[2].i32s()?;
                let slot = args[3].i32s()?;
                ensure!(args[2].dims() == [b], "decode: token dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [b], "decode: slot dims {:?}", args[3].dims());
                ensure!(args[4].dims() == [b], "decode: lpos dims {:?}", args[4].dims());
                for r in 0..b {
                    ensure!(
                        (0..=t as i32).contains(&slot[r]),
                        "decode: slot {} out of range for row {r}",
                        slot[r]
                    );
                    if (slot[r] as usize) < t {
                        // in-range slot: cache + device-side valid write
                        gen.rows[r].toks.push(token[r]);
                        gen.rows[r].probs = self.row_probs(&gen.rows[r].toks);
                    }
                }
                Ok(MockBuf::Gen(gen))
            }
            "refill" => {
                // (blob, gen, tokens[B,T], valid[B,T], rowmask[B], last[B], temp[1])
                ensure!(args.len() == 7, "refill: expected 7 args, got {}", args.len());
                let mut gen = args[1].gen()?.clone();
                let tokens = args[2].i32s()?;
                let valid = args[3].f32s()?;
                let rowmask = args[4].f32s()?;
                ensure!(args[2].dims() == [b, t], "refill: tokens dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [b, t], "refill: valid dims {:?}", args[3].dims());
                ensure!(args[4].dims() == [b], "refill: rowmask dims {:?}", args[4].dims());
                ensure!(args[5].dims() == [b], "refill: last dims {:?}", args[5].dims());
                for r in 0..b {
                    if rowmask[r] > 0.5 {
                        self.trace_seat("refill", tokens, valid, r);
                        gen.rows[r] = self.row_from_layout(tokens, valid, r);
                    }
                }
                Ok(MockBuf::Gen(gen))
            }
            "read_gen" => {
                ensure!(args.len() == 1, "read_gen: expected 1 arg, got {}", args.len());
                let gen = args[0].gen()?;
                let v = self.shape.vocab;
                let mut out = Vec::with_capacity(b * v + b);
                for r in 0..b {
                    if gen.rows[r].probs.is_empty() {
                        out.extend(std::iter::repeat(1.0 / v as f32).take(v));
                    } else {
                        out.extend_from_slice(&gen.rows[r].probs);
                    }
                }
                // [probs | aux] — the aux tail carries verify_seat results;
                // a gen state without the lane is a contract violation, not
                // a zeros-for-free situation (it would silently read as
                // "every draft rejected at offset 0")
                ensure!(gen.aux.len() == b, "read_gen: gen state has no aux lane");
                out.extend_from_slice(&gen.aux);
                Ok(MockBuf::F32(out, vec![b * v + b]))
            }
            "verify" => {
                // (blob, tokens[B,T], valid[B,T], logp_prev[B,G],
                //  uniforms[B,G], draft_valid[B,G], loglen[1], temp[1])
                ensure!(args.len() == 8, "verify: expected 8 args, got {}", args.len());
                let g = t - self.shape.prompt_len;
                let tokens = args[1].i32s()?;
                let valid = args[2].f32s()?;
                let lp_prev = args[3].f32s()?;
                let un = args[4].f32s()?;
                let dv = args[5].f32s()?;
                ensure!(args[1].dims() == [b, t], "verify: tokens dims {:?}", args[1].dims());
                ensure!(args[2].dims() == [b, t], "verify: valid dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [b, g], "verify: logp_prev dims {:?}", args[3].dims());
                ensure!(args[4].dims() == [b, g], "verify: uniforms dims {:?}", args[4].dims());
                ensure!(args[5].dims() == [b, g], "verify: draft_valid dims {:?}", args[5].dims());
                ensure!(args[6].dims() == [1], "verify: loglen dims {:?}", args[6].dims());
                let ll = args[6].f32s()?[0];
                // [rej | logp | entropy] like the lowered entry
                let mut out = vec![0f32; b + 2 * b * g];
                for r in 0..b {
                    let (n_acc, lps) = self.accept_row(tokens, valid, r, lp_prev, un, dv, ll);
                    out[r] = n_acc as f32;
                    out[b + r * g..b + (r + 1) * g].copy_from_slice(&lps);
                }
                Ok(MockBuf::F32(out, vec![b + 2 * b * g]))
            }
            "verify_seat" => {
                // (blob, gen, tokens[B,T], valid[B,T], logp_prev[B,G],
                //  uniforms[B,G], draft_valid[B,G], rowmask[B], loglen[1], temp[1])
                ensure!(args.len() == 10, "verify_seat: expected 10 args, got {}", args.len());
                let g = t - self.shape.prompt_len;
                let mut gen = args[1].gen()?.clone();
                let tokens = args[2].i32s()?;
                let valid = args[3].f32s()?;
                let lp_prev = args[4].f32s()?;
                let un = args[5].f32s()?;
                let dv = args[6].f32s()?;
                let rowmask = args[7].f32s()?;
                ensure!(args[2].dims() == [b, t], "verify_seat: tokens dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [b, t], "verify_seat: valid dims {:?}", args[3].dims());
                ensure!(
                    args[4].dims() == [b, g],
                    "verify_seat: logp_prev dims {:?}",
                    args[4].dims()
                );
                ensure!(
                    args[5].dims() == [b, g],
                    "verify_seat: uniforms dims {:?}",
                    args[5].dims()
                );
                ensure!(
                    args[6].dims() == [b, g],
                    "verify_seat: draft_valid dims {:?}",
                    args[6].dims()
                );
                ensure!(args[7].dims() == [b], "verify_seat: rowmask dims {:?}", args[7].dims());
                ensure!(args[8].dims() == [1], "verify_seat: loglen dims {:?}", args[8].dims());
                let ll = args[8].f32s()?[0];
                ensure!(gen.aux.len() == b, "verify_seat: gen state has no aux lane");
                ensure!(gen.live.len() == b, "verify_seat: gen state has no live lane");
                for r in 0..b {
                    if rowmask[r] <= 0.5 {
                        continue;
                    }
                    self.trace_seat("verify_seat", tokens, valid, r);
                    let (n_acc, _) = self.accept_row(tokens, valid, r, lp_prev, un, dv, ll);
                    // seat the accepted prefix: the mock analog of reusing
                    // the verify forward's KV under a truncated valid mask
                    let mut toks = self.prompt_of(tokens, valid, r);
                    let row = r * t;
                    let p = self.shape.prompt_len;
                    toks.extend((0..n_acc).map(|j| tokens[row + p + j]));
                    let probs = self.row_probs(&toks);
                    gen.rows[r] = RowState { toks, probs };
                    gen.aux[r] = n_acc as f32;
                    // §12 liveness: terminal iff the accepted prefix hit the
                    // generation cap or ended in EOS — the same predicate
                    // the host's resolve_verified applies
                    let ends_eos = n_acc > 0 && tokens[row + p + n_acc - 1] == EOS;
                    gen.live[r] = if n_acc >= g || ends_eos { 0.0 } else { 1.0 };
                }
                Ok(MockBuf::Gen(gen))
            }
            "sample" => {
                // (gen, ctrl[B,3], nonce[2], top_p[1]) — ctrl rows are
                // (task id, draws consumed so far, arm mode)
                ensure!(args.len() == 4, "sample: expected 4 args, got {}", args.len());
                let mut gen = args[0].gen()?.clone();
                let ctrl = args[1].i32s()?;
                let nonce_w = args[2].i32s()?;
                let top_p = args[3].f32s()?[0];
                ensure!(args[1].dims() == [b, 3], "sample: ctrl dims {:?}", args[1].dims());
                ensure!(args[2].dims() == [2], "sample: nonce dims {:?}", args[2].dims());
                ensure!(args[3].dims() == [1], "sample: top_p dims {:?}", args[3].dims());
                ensure!(gen.live.len() == b, "sample: gen state has no live lane");
                ensure!(
                    gen.tok.len() == b && gen.ptok.len() == b,
                    "sample: gen state is missing the tok/ptok out-lanes"
                );
                let nonce =
                    ((nonce_w[0] as u32 as u64) << 32) | (nonce_w[1] as u32 as u64);
                let v = self.shape.vocab;
                let mut sampler = TopPSampler::new(v);
                for r in 0..b {
                    let (id, draws, mode) = (ctrl[r * 3], ctrl[r * 3 + 1], ctrl[r * 3 + 2]);
                    let armed = mode == 1 || (mode == 2 && gen.live[r] > 0.5);
                    if !armed {
                        gen.tok[r] = -1.0;
                        gen.ptok[r] = 0.0;
                        continue;
                    }
                    let probs = &gen.rows[r].probs;
                    ensure!(probs.len() == v, "sample: armed row {r} has no probs");
                    // replay the host's per-task stream (§6): skip the draws
                    // already consumed, then draw this token's uniform — the
                    // literal host sampler, so tokens match bit-for-bit
                    let mut rng = task_rng(nonce, id as usize);
                    for _ in 0..draws {
                        rng.f32();
                    }
                    let tok = sampler.sample(probs, top_p, &mut rng);
                    gen.tok[r] = tok as f32;
                    gen.ptok[r] = probs[tok];
                }
                Ok(MockBuf::Gen(gen))
            }
            "read_step" => {
                ensure!(args.len() == 1, "read_step: expected 1 arg, got {}", args.len());
                let gen = args[0].gen()?;
                ensure!(
                    gen.tok.len() == b && gen.ptok.len() == b && gen.aux.len() == b,
                    "read_step: gen state is missing sampling lanes"
                );
                // the fused O(B) readback: [tok | ptok | aux]
                let mut out = Vec::with_capacity(3 * b);
                out.extend_from_slice(&gen.tok);
                out.extend_from_slice(&gen.ptok);
                out.extend_from_slice(&gen.aux);
                Ok(MockBuf::F32(out, vec![3 * b]))
            }
            other => bail!("mock backend cannot execute '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probs_depend_only_on_row_content() {
        let m = MockEngine::new(2, 4, 12, 10);
        let a = m.row_probs(&[BOS, 5, 6]);
        let b = m.row_probs(&[BOS, 5, 6]);
        let c = m.row_probs(&[BOS, 5, 7]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!((a.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert_eq!(a[PAD as usize], 0.0);
        assert_eq!(a[BOS as usize], 0.0);
    }

    #[test]
    fn decode_appends_only_in_range_slots() {
        let m = MockEngine::new(2, 2, 6, 8);
        let blob = m.blob();
        let tokens = m.upload_i32(&[0, 1, 3, 0, 1, 4, 0, 0, 1, 5, 6, 7], &[2, 6]).unwrap();
        let valid = m
            .upload_f32(&[0., 1., 1., 0., 1., 1., 0., 0., 1., 1., 1., 1.], &[2, 6])
            .unwrap();
        let last = m.upload_i32(&[2, 5], &[2]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let pre = m.resolve("x", "prefill").unwrap();
        let dec = m.resolve("x", "decode").unwrap();
        let gen = m.call_entry(&pre, &[&blob, &tokens, &valid, &last, &temp]).unwrap();
        let tok = m.upload_i32(&[5, 0], &[2]).unwrap();
        let slot = m.upload_i32(&[3, 6], &[2]).unwrap(); // row 1 inert
        let lpos = m.upload_i32(&[3, 0], &[2]).unwrap();
        let gen2 = m.call_entry(&dec, &[&blob, &gen, &tok, &slot, &lpos, &temp]).unwrap();
        let g2 = gen2.gen().unwrap();
        assert_eq!(g2.rows[0].toks, vec![1, 3, 1, 4, 5]);
        assert_eq!(g2.rows[1].toks, vec![1, 5, 6, 7]);
    }

    #[test]
    fn decode_with_valid_mask_arg_is_rejected() {
        let m = MockEngine::new(1, 2, 4, 8);
        let blob = m.blob();
        let dec = m.resolve("x", "decode").unwrap();
        let g = MockBuf::Gen(GenState {
            rows: vec![RowState::default()],
            aux: vec![0.0],
            ..GenState::default()
        });
        let tok = m.upload_i32(&[5], &[1]).unwrap();
        let slot = m.upload_i32(&[2], &[1]).unwrap();
        let lpos = m.upload_i32(&[2], &[1]).unwrap();
        let stale_valid = m.upload_f32(&[1.0; 4], &[1, 4]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let err = m
            .call_entry(&dec, &[&blob, &g, &tok, &slot, &lpos, &stale_valid, &temp])
            .unwrap_err();
        assert!(format!("{err:#}").contains("expected 6 args"));
    }

    #[test]
    fn unknown_entry_is_error() {
        let m = MockEngine::new(1, 2, 4, 8);
        assert!(m.resolve("x", "train_policy").is_err());
    }

    #[test]
    fn verify_and_verify_seat_accept_identically() {
        let (b, p, t, v) = (2usize, 3usize, 9usize, 10usize);
        let g = t - p;
        let m = MockEngine::new(b, p, t, v);
        let blob = m.blob();
        // two drafts of length 4 and 2, prompts right-aligned
        let mut tokens = vec![PAD; b * t];
        let mut valid = vec![0f32; b * t];
        let mut dv = vec![0f32; b * g];
        for (r, dlen) in [(0usize, 4usize), (1, 2)] {
            tokens[r * t + p - 2] = BOS;
            tokens[r * t + p - 1] = 4 + r as i32;
            valid[r * t + p - 2] = 1.0;
            valid[r * t + p - 1] = 1.0;
            for j in 0..dlen {
                tokens[r * t + p + j] = 3 + ((r + j) as i32 % 5);
                valid[r * t + p + j] = 1.0;
                dv[r * g + j] = 1.0;
            }
        }
        let lp_prev = vec![-1.2f32; b * g];
        let mut rng = Rng::new(5);
        let mut un = vec![0f32; b * g];
        rng.fill_uniform(&mut un);

        let tok_b = m.upload_i32(&tokens, &[b, t]).unwrap();
        let val_b = m.upload_f32(&valid, &[b, t]).unwrap();
        let lp_b = m.upload_f32(&lp_prev, &[b, g]).unwrap();
        let un_b = m.upload_f32(&un, &[b, g]).unwrap();
        let dv_b = m.upload_f32(&dv, &[b, g]).unwrap();
        let ll = m.upload_f32(&[0.3], &[1]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();

        let hv = m.resolve("x", "verify").unwrap();
        let out = m
            .call_entry(&hv, &[&blob, &tok_b, &val_b, &lp_b, &un_b, &dv_b, &ll, &temp])
            .unwrap();
        let host = m.read_f32(&out).unwrap();
        assert_eq!(host.len(), b + 2 * b * g);
        let rej: Vec<usize> = (0..b).map(|r| host[r] as usize).collect();
        assert!(rej[0] <= 4 && rej[1] <= 2);

        // seat through verify_seat and cross-check via read_gen's aux tail
        let hp = m.resolve("x", "prefill").unwrap();
        let last = m.upload_i32(&[(p - 1) as i32; 2], &[b]).unwrap();
        let gen = m.call_entry(&hp, &[&blob, &tok_b, &val_b, &last, &temp]).unwrap();
        let hs = m.resolve("x", "verify_seat").unwrap();
        let rm = m.upload_f32(&[1.0, 1.0], &[b]).unwrap();
        let gen2 = m
            .call_entry(
                &hs,
                &[&blob, &gen, &tok_b, &val_b, &lp_b, &un_b, &dv_b, &rm, &ll, &temp],
            )
            .unwrap();
        let hr = m.resolve("x", "read_gen").unwrap();
        let read = m.read_f32(&m.call_entry(&hr, &[&gen2]).unwrap()).unwrap();
        assert_eq!(read.len(), b * v + b);
        for r in 0..b {
            assert_eq!(read[b * v + r] as usize, rej[r], "row {r} acceptance must match");
        }
        // seated row content == prompt + accepted prefix
        let g2 = gen2.gen().unwrap();
        assert_eq!(g2.rows[0].toks.len(), 2 + rej[0]);
        assert_eq!(g2.rows[1].toks.len(), 2 + rej[1]);
    }

    #[test]
    fn virtual_clock_serializes_sync_calls_and_overlaps_submits() {
        // Two replicas on one clock. Synchronous calls advance the shared
        // host past each forward (serialized: 2 x prefill = 4.0s); a
        // submit/submit/complete/complete round runs the same two
        // forwards concurrently (+2.0s only).
        let mocks = MockEngine::clocked_replicas(2, 1, 2, 4, 8);
        let (a, b) = (&mocks[0], &mocks[1]);
        let run_prefill = |m: &MockEngine, sync: bool| -> MockBuf {
            let blob = m.blob();
            let tok = m.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
            let val = m.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
            let last = m.upload_i32(&[1], &[1]).unwrap();
            let temp = m.upload_f32(&[1.0], &[1]).unwrap();
            let h = m.resolve("x", "prefill").unwrap();
            let args = [&blob, &tok, &val, &last, &temp];
            if sync {
                m.call_entry(&h, &args).unwrap()
            } else {
                let p = m.submit_entry(&h, &args).unwrap();
                m.complete(p).unwrap()
            }
        };

        let t0 = Backend::virtual_now(a).unwrap();
        run_prefill(a, true);
        run_prefill(b, true);
        let t1 = Backend::virtual_now(a).unwrap();
        assert!((t1 - t0 - 4.0).abs() < 1e-9, "sync calls must serialize: {}", t1 - t0);

        // submit both, then complete both: the forwards overlap
        let blob_a = a.blob();
        let blob_b = b.blob();
        let mk = |m: &MockEngine| {
            (
                m.upload_i32(&[BOS, 6, 0, 0], &[1, 4]).unwrap(),
                m.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap(),
                m.upload_i32(&[1], &[1]).unwrap(),
                m.upload_f32(&[1.0], &[1]).unwrap(),
            )
        };
        let (ta, va, la, pa) = mk(a);
        let (tb, vb, lb, pb) = mk(b);
        let h = a.resolve("x", "prefill").unwrap();
        let pend_a = a.submit_entry(&h, &[&blob_a, &ta, &va, &la, &pa]).unwrap();
        let pend_b = b.submit_entry(&h, &[&blob_b, &tb, &vb, &lb, &pb]).unwrap();
        assert_eq!(Backend::virtual_now(a).unwrap(), t1, "submits leave the host free");
        a.complete(pend_a).unwrap();
        b.complete(pend_b).unwrap();
        let t2 = Backend::virtual_now(a).unwrap();
        assert!((t2 - t1 - 2.0).abs() < 1e-9, "submitted forwards overlap: {}", t2 - t1);

        // busy accounting: each engine executed two 2.0s prefills
        assert!((Backend::device_busy_secs(a) - 4.0).abs() < 1e-9);
        assert!((Backend::device_busy_secs(b) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn pending_buf_chains_without_advancing_the_host() {
        // decode(submit) chained onto prefill(submit) through pending_buf:
        // the host stays put until complete, and the chain's end time is
        // the sum of the two latencies on one device timeline.
        let mocks = MockEngine::clocked_replicas(1, 1, 2, 4, 8);
        let m = &mocks[0];
        let blob = m.blob();
        let tok = m.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = m.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = m.upload_i32(&[1], &[1]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let hp = m.resolve("x", "prefill").unwrap();
        let hd = m.resolve("x", "decode").unwrap();
        let t0 = Backend::virtual_now(m).unwrap();
        let p_gen = m.submit_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        let tok1 = m.upload_i32(&[7], &[1]).unwrap();
        let slot = m.upload_i32(&[2], &[1]).unwrap();
        let lpos = m.upload_i32(&[2], &[1]).unwrap();
        let p_dec = {
            let gen = m.pending_buf(&p_gen);
            m.submit_entry(&hd, &[&blob, gen, &tok1, &slot, &lpos, &temp]).unwrap()
        };
        assert_eq!(Backend::virtual_now(m).unwrap(), t0, "chain submits are free");
        let gen2 = m.complete(p_dec).unwrap();
        let t1 = Backend::virtual_now(m).unwrap();
        assert!((t1 - t0 - 3.0).abs() < 1e-9, "prefill 2.0 + decode 1.0: {}", t1 - t0);
        assert_eq!(gen2.gen().unwrap().rows[0].toks, vec![BOS, 5, 7]);
    }

    #[test]
    fn fault_plan_kills_the_indexed_call_without_logging_it() {
        let m = MockEngine::new(1, 2, 4, 8);
        let blob = m.blob();
        let tok = m.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = m.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = m.upload_i32(&[1], &[1]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let h = m.resolve("x", "prefill").unwrap();
        let args = [&blob, &tok, &val, &last, &temp];
        m.arm_faults(FaultPlan::at_call(1));
        m.call_entry(&h, &args).unwrap(); // call 0 executes
        let err = m.call_entry(&h, &args).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        // the killed call is not in the executed log; a non-sticky plan
        // disarms after one trip, so the retry goes through
        assert_eq!(m.calls_of("prefill"), 1);
        m.call_entry(&h, &args).unwrap();
        assert_eq!(m.calls_of("prefill"), 2);
    }

    #[test]
    fn fault_plan_entry_trigger_and_sticky_persistence() {
        let m = MockEngine::new(1, 2, 4, 8);
        let blob = m.blob();
        let tok = m.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = m.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = m.upload_i32(&[1], &[1]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let hp = m.resolve("x", "prefill").unwrap();
        let hr = m.resolve("x", "read_gen").unwrap();
        m.arm_faults(FaultPlan::at_entry("read_gen").sticky());
        // other entries are untouched until the trigger entry is called
        let gen = m.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        assert!(m.call_entry(&hr, &[&gen]).is_err(), "trigger entry dies");
        // sticky: every later call fails too, whatever the entry
        assert!(m.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).is_err());
        assert_eq!(m.calls_of("prefill"), 1);
        assert_eq!(m.calls_of("read_gen"), 0);
        m.clear_faults();
        m.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        assert_eq!(m.calls_of("prefill"), 2);
    }

    #[test]
    fn seats_attribute_rows_to_their_seating_entry() {
        let m = MockEngine::new(2, 2, 6, 8);
        let blob = m.blob();
        let tokens = m.upload_i32(&[0, 1, 3, 0, 0, 0, 0, 1, 4, 0, 0, 0], &[2, 6]).unwrap();
        let valid = m
            .upload_f32(&[0., 1., 1., 0., 0., 0., 0., 1., 1., 0., 0., 0.], &[2, 6])
            .unwrap();
        let last = m.upload_i32(&[2, 2], &[2]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let hp = m.resolve("x", "prefill").unwrap();
        let gen = m.call_entry(&hp, &[&blob, &tokens, &valid, &last, &temp]).unwrap();
        // refill only row 1
        let rm = m.upload_f32(&[0.0, 1.0], &[2]).unwrap();
        let hf = m.resolve("x", "refill").unwrap();
        m.call_entry(&hf, &[&blob, &gen, &tokens, &valid, &rm, &last, &temp]).unwrap();
        let seats = m.counters().seats;
        assert_eq!(seats.len(), 3, "2 prefill rows + 1 refilled row");
        assert_eq!(seats[0], ("prefill".to_string(), vec![1, 3]));
        assert_eq!(seats[1], ("prefill".to_string(), vec![1, 4]));
        assert_eq!(seats[2], ("refill".to_string(), vec![1, 4]));
        // seated stays the entry-less view of the same trace
        assert_eq!(
            m.seated_rows(),
            seats.iter().map(|(_, s)| s.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn decode_preserves_aux_lane() {
        let m = MockEngine::new(1, 2, 6, 8);
        let blob = m.blob();
        let mut g = GenState {
            rows: vec![RowState::default()],
            aux: vec![3.0],
            ..GenState::default()
        };
        g.rows[0].toks = vec![1, 4];
        g.rows[0].probs = m.row_probs(&g.rows[0].toks);
        let gen = MockBuf::Gen(g);
        let dec = m.resolve("x", "decode").unwrap();
        let tok = m.upload_i32(&[5], &[1]).unwrap();
        let slot = m.upload_i32(&[2], &[1]).unwrap();
        let lpos = m.upload_i32(&[2], &[1]).unwrap();
        let temp = m.upload_f32(&[1.0], &[1]).unwrap();
        let gen2 = m.call_entry(&dec, &[&blob, &gen, &tok, &slot, &lpos, &temp]).unwrap();
        assert_eq!(gen2.gen().unwrap().aux, vec![3.0]);
    }
}
