//! The RL training loop: rollout (speculative or vanilla) → reward →
//! old-log-probs → ref/values → advantages → update(s), with the same
//! per-stage accounting as the paper's Table 4.
//!
//! - [`sft`] — supervised pretraining (the "base model" producer).
//! - [`eval`] — benchmark-suite evaluation.
//! - [`Trainer`] — the per-step pipeline + per-step CSV series.

pub mod eval;
pub mod sft;

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::algo;
use crate::config::RunConfig;
use crate::metrics::{self, Report};
use crate::model::Policy;
use crate::rollout::{EnginePool, SampleCfg, SeqResult};
use crate::runtime::Engine;
use crate::spec::{RolloutRequest, SpecRollout};
use crate::tasks::{self, TaskInstance};
use crate::tokenizer::Tokenizer;
use crate::util::{Rng, StageTimer};

/// Per-run aggregate summary (feeds Tables 1/2/3/5/6 rows).
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub label: String,
    pub steps: usize,
    pub total_new_tokens: usize,
    pub total_reused_tokens: usize,
    pub rollout_secs: f64,
    pub verify_secs: f64,
    pub assembly_secs: f64,
    pub total_secs: f64,
    pub final_reward: f64,
    /// (suite name, accuracy) at the final eval.
    pub final_eval: Vec<(String, f64)>,
    /// Per-stage time means (Table 4 row).
    pub stage_means: BTreeMap<&'static str, f64>,
}

impl RunSummary {
    /// Average over math suites + OOD suites (the paper's AVG column).
    pub fn avg_accuracy(&self) -> f64 {
        if self.final_eval.is_empty() {
            return 0.0;
        }
        self.final_eval.iter().map(|(_, a)| a).sum::<f64>() / self.final_eval.len() as f64
    }
}

/// Per-step record used by the CSV series (Tables 7-27, Figures 4-11).
pub const STEP_COLUMNS: &[&str] = &[
    "step", "epoch", "reward", "tokens_new", "tokens_reused", "tokens_cum",
    "prefix_len", "full_reuse", "drafts", "gen_rounds", "verify_calls",
    "shards", "device_calls", "shard_calls_max", "shard_calls_min", "steal_count",
    "shard_failures", "requeued_tasks",
    "overlap_makespan", "serial_makespan", "readback_bytes", "upload_bytes",
    "predict_err", "draft_len_mean", "draft_len_max", "draft_trunc",
    "sibling_hits", "sibling_tokens", "branch_depth_mean",
    "cache_tokens", "cache_nodes", "cache_shared_tokens",
    "cache_evictions", "cache_evicted_tokens",
    "rollout_s", "verification_s", "assembly_s", "reward_s", "old_logp_s",
    "ref_s", "values_s", "adv_s", "update_critic_s", "update_actor_s",
    "others_s", "total_s",
    "loss", "pg_loss", "kl", "entropy", "clip_frac", "grad_norm",
    "distinct1", "self_bleu", "rouge1_prev_epoch",
];

/// The RL trainer.
pub struct Trainer<'e> {
    pub eng: &'e Engine,
    pub cfg: RunConfig,
    pub policy: Policy,
    /// Frozen reference policy for the GRPO KL term.
    pub ref_policy: Option<Policy>,
    /// PPO critic.
    pub critic: Option<Policy>,
    pub spec: SpecRollout,
    /// `cfg.rollout_shards` engines over one slot-pool placement layer
    /// (one shard == the plain single-engine pipeline).
    pub pool: EnginePool<'e>,
    pub tok: Tokenizer,
    pub train_set: Vec<TaskInstance>,
    pub rng: Rng,
    pub report: Report,
    /// Cursor into the (cyclic) prompt order.
    cursor: usize,
    cum_new_tokens: usize,
    cum_reused_tokens: usize,
    stage_totals: StageTimer,
}

impl<'e> Trainer<'e> {
    /// Build a trainer around an SFT'd base policy.
    pub fn new(eng: &'e Engine, cfg: RunConfig, base: Policy) -> Result<Trainer<'e>> {
        cfg.validate()?;
        let info = eng.bundle(&cfg.bundle)?;
        anyhow::ensure!(
            cfg.rollout_batch() == info.batch,
            "rollout batch {} must equal bundle batch {} (prompts_per_step * group)",
            cfg.rollout_batch(),
            info.batch
        );
        let tok = Tokenizer::new(&eng.manifest.charset);
        let spec_variant = cfg.variant;
        let ref_policy = if cfg.params.kl_coef > 0.0 {
            Some(base.duplicate(eng)?)
        } else {
            None
        };
        let critic = if cfg.params.use_critic {
            Some(Policy::from_init(eng, &cfg.critic_bundle)?)
        } else {
            None
        };
        let dataset = tasks::DatasetSpec::by_name(&cfg.dataset)
            .with_context(|| format!("unknown dataset '{}'", cfg.dataset))?;
        let train_set = tasks::train_set(&dataset, cfg.n_prompts);
        // All shards bind to the same PJRT engine here (one device, one
        // blob); distinct per-device backends plug into the same pool.
        let pool = EnginePool::new((0..cfg.rollout_shards).map(|_| eng), &cfg.bundle)?;
        let cache_budget =
            if cfg.cache_budget_tokens > 0 { Some(cfg.cache_budget_tokens) } else { None };
        let report_path = format!(
            "{}/{}_{}_{}.csv",
            cfg.out_dir,
            cfg.algo.name(),
            spec_variant.name(),
            cfg.bundle
        );
        let mut spec = SpecRollout::new(spec_variant, cfg.lenience)
            .with_cache_budget(cache_budget)
            .with_group(cfg.group)
            .with_predict(cfg.predict_len)
            .with_draft_control(cfg.draft_len_min, cfg.draft_len_max, cfg.draft_len_adapt)
            .with_sibling_drafts(cfg.sibling_drafts);
        if cfg.predict_len {
            // Zero-history prompts schedule by their family's typical
            // canonical length (ARCHITECTURE.md §14) until the first
            // observed rollout replaces the prior with a per-task EWMA.
            let priors = tasks::family_length_priors(cfg.eval_n.max(8));
            for (pi, t) in train_set.iter().enumerate() {
                if let Some((_, prior)) = priors.iter().find(|(f, _)| *f == t.family) {
                    for k in 0..cfg.group {
                        spec.set_len_prior(pi * cfg.group + k, *prior);
                    }
                }
            }
        }
        Ok(Trainer {
            eng,
            rng: Rng::new(cfg.seed),
            spec,
            pool,
            tok,
            train_set,
            policy: base,
            ref_policy,
            critic,
            report: Report::new(report_path, STEP_COLUMNS),
            cursor: 0,
            cum_new_tokens: 0,
            cum_reused_tokens: 0,
            stage_totals: StageTimer::new(),
            cfg,
        })
    }

    fn sample_cfg(&self) -> SampleCfg {
        SampleCfg {
            temperature: self.cfg.temperature,
            top_p: self.cfg.top_p,
            verify_seat_min: self.cfg.verify_seat_min,
        }
    }

    /// Next `prompts_per_step` prompt indices (cyclic epoch order).
    fn next_prompt_ids(&mut self) -> Vec<usize> {
        let n = self.cfg.prompts_per_step;
        let ids: Vec<usize> =
            (0..n).map(|k| (self.cursor + k) % self.train_set.len()).collect();
        self.cursor = (self.cursor + n) % self.train_set.len();
        ids
    }

    fn requests_for(&self, prompt_ids: &[usize]) -> Vec<RolloutRequest> {
        let mut reqs = Vec::with_capacity(prompt_ids.len() * self.cfg.group);
        for &pi in prompt_ids {
            let prompt = self.tok.encode_prompt(&self.train_set[pi].prompt);
            for k in 0..self.cfg.group {
                reqs.push(RolloutRequest { id: pi * self.cfg.group + k, prompt: prompt.clone() });
            }
        }
        reqs
    }

    fn reward_of(&self, prompt_idx: usize, result: &SeqResult) -> f32 {
        let text = self.tok.decode_clean(&result.response);
        tasks::reward(&text, &self.train_set[prompt_idx].answer, false)
    }

    /// One full training step. Returns the per-step record.
    pub fn step(&mut self, step_idx: usize) -> Result<BTreeMap<&'static str, f64>> {
        let t_step = std::time::Instant::now();
        let mut timer = StageTimer::new();
        let group = self.cfg.group;
        let b = self.cfg.rollout_batch();

        // ---- rollout (+verification) with optional DAPO dynamic sampling ----
        let mut kept: Vec<(usize, SeqResult, f32)> = Vec::with_capacity(b);
        let mut gen_rounds = 0usize;
        let mut spec_stats_acc = crate::spec::SpecStepStats::default();
        let max_rounds = if self.cfg.params.dynamic_sampling { 3 } else { 1 };
        let mut rouge_acc: Vec<f64> = Vec::new();
        let scfg = self.sample_cfg();
        while kept.len() < b && gen_rounds < max_rounds {
            let prompt_ids = self.next_prompt_ids();
            let requests = self.requests_for(&prompt_ids);
            // Snapshot previous-epoch rollouts before the cache refreshes so
            // the ROUGE-1 overlap series (Figure 2) can be computed below.
            let prev_drafts: BTreeMap<usize, Vec<i32>> = requests
                .iter()
                .filter_map(|r| self.spec.cache.latest(r.id).map(|e| (r.id, e.response)))
                .collect();

            // Interleaved phase-aware pipeline over the engine pool (the
            // default since PR 2 / sharded since PR 3;
            // `SpecRollout::run_two_phase` is the retained oracle).
            let shard_blobs: Vec<_> =
                (0..self.pool.shards()).map(|_| &self.policy.blob).collect();
            let (results, sstats) = self.spec.collect(
                &mut self.pool,
                &shard_blobs,
                &requests,
                scfg,
                &mut self.rng,
                &mut timer,
            )?;
            spec_stats_acc.absorb(&sstats);
            gen_rounds += 1;

            for (id, prev) in &prev_drafts {
                if let Some(r) = results.iter().find(|r| r.id == *id) {
                    rouge_acc.push(metrics::rouge1_f1(prev, &r.response));
                }
            }

            // ---- reward ------------------------------------------------------
            let span = std::time::Instant::now();
            let mut groups: BTreeMap<usize, Vec<(usize, SeqResult, f32)>> = BTreeMap::new();
            for r in results {
                let prompt_idx = r.id / group;
                let rew = self.reward_of(prompt_idx, &r);
                groups.entry(prompt_idx).or_default().push((prompt_idx, r, rew));
            }
            timer.add("reward", span.elapsed().as_secs_f64());

            for (_, g) in groups {
                if kept.len() >= b {
                    break;
                }
                let degenerate = {
                    let first = g[0].2;
                    g.iter().all(|(_, _, r)| *r == first)
                };
                // DAPO dynamic sampling: drop zero-variance groups unless
                // this is the last permitted round (then keep to fill).
                if self.cfg.params.dynamic_sampling
                    && degenerate
                    && gen_rounds < max_rounds
                {
                    continue;
                }
                kept.extend(g);
            }
        }
        kept.truncate(b);
        anyhow::ensure!(kept.len() == b, "could not fill batch: {} < {b}", kept.len());

        // ---- batch tensors ----------------------------------------------------
        let (p, t) = (self.eng.manifest.prompt_len, self.eng.manifest.total_len);
        let g_len = t - p;
        let mut tokens = vec![crate::tokenizer::PAD; b * t];
        let mut valid = vec![0f32; b * t];
        let mut resp_mask = vec![0f32; b * g_len];
        let rewards: Vec<f32> = kept.iter().map(|(_, _, r)| *r).collect();
        for (row, (pi, res, _)) in kept.iter().enumerate() {
            let prompt = self.tok.encode_prompt(&self.train_set[*pi].prompt);
            let start = p - prompt.len();
            for (i, &tk) in prompt.iter().enumerate() {
                tokens[row * t + start + i] = tk;
                valid[row * t + start + i] = 1.0;
            }
            for (j, &tk) in res.response.iter().enumerate() {
                tokens[row * t + p + j] = tk;
                valid[row * t + p + j] = 1.0;
                resp_mask[row * g_len + j] = 1.0;
            }
        }
        let tok_buf = self.eng.upload_i32(&tokens, &[b, t])?;
        let val_buf = self.eng.upload_f32(&valid, &[b, t])?;
        let temp1 = self.eng.upload_f32(&[1.0], &[1])?;

        // ---- old log-probs (recomputed, veRL-style) -----------------------------
        let old_logp = timer.time("old_logp", || -> Result<Vec<f32>> {
            let out = self.eng.call(
                &self.cfg.bundle,
                "score",
                &[&self.policy.blob, &tok_buf, &val_buf, &temp1],
            )?;
            Ok(self.eng.read_f32(&out)?[..b * g_len].to_vec())
        })?;

        // ---- reference log-probs (GRPO KL) --------------------------------------
        let ref_logp = if let Some(ref refp) = self.ref_policy {
            timer.time("ref", || -> Result<Vec<f32>> {
                let out = self.eng.call(
                    &self.cfg.bundle,
                    "score",
                    &[&refp.blob, &tok_buf, &val_buf, &temp1],
                )?;
                Ok(self.eng.read_f32(&out)?[..b * g_len].to_vec())
            })?
        } else {
            old_logp.clone()
        };

        // ---- values + advantages -------------------------------------------------
        let mut adv = vec![0f32; b * g_len];
        let mut value_targets = vec![0f32; b * g_len];
        if let Some(ref critic) = self.critic {
            let values = timer.time("values", || -> Result<Vec<f32>> {
                let out = self.eng.call(
                    &self.cfg.critic_bundle,
                    "value_fwd",
                    &[&critic.blob, &tok_buf, &val_buf],
                )?;
                self.eng.read_f32(&out)
            })?;
            let span = std::time::Instant::now();
            for (row, (_, res, rew)) in kept.iter().enumerate() {
                let l = res.response.len();
                if l == 0 {
                    continue;
                }
                let vrow = &values[row * (g_len + 1)..(row + 1) * (g_len + 1)];
                let (a, tg) = algo::gae(&vrow[..=l], *rew, self.cfg.params.gamma, self.cfg.params.lam);
                adv[row * g_len..row * g_len + l].copy_from_slice(&a);
                value_targets[row * g_len..row * g_len + l].copy_from_slice(&tg);
            }
            algo::whiten(&mut adv, &resp_mask);
            timer.add("adv", span.elapsed().as_secs_f64());
        } else {
            let span = std::time::Instant::now();
            let seq_adv = algo::grpo_advantages(&rewards, group);
            for (row, a) in seq_adv.iter().enumerate() {
                for j in 0..g_len {
                    adv[row * g_len + j] = a * resp_mask[row * g_len + j];
                }
            }
            timer.add("adv", span.elapsed().as_secs_f64());
        }

        // ---- critic update ----------------------------------------------------------
        let mut critic_metrics = None;
        if let Some(critic) = self.critic.as_mut() {
            let rm_buf = self.eng.upload_f32(&resp_mask, &[b, g_len])?;
            let tg_buf = self.eng.upload_f32(&value_targets, &[b, g_len])?;
            let hp = self.cfg.params.hp_vector(self.cfg.params.critic_lr);
            let hp_buf = self.eng.upload_f32(&hp, &[8])?;
            let new_blob = timer.time("update_critic", || {
                self.eng.call(
                    &self.cfg.critic_bundle,
                    "train_value",
                    &[&critic.blob, &tok_buf, &val_buf, &rm_buf, &tg_buf, &hp_buf],
                )
            })?;
            critic.swap(new_blob);
            critic_metrics = Some(critic.metrics(self.eng)?);
        }
        let _ = critic_metrics;

        // ---- actor update --------------------------------------------------------------
        let rm_buf = self.eng.upload_f32(&resp_mask, &[b, g_len])?;
        let adv_buf = self.eng.upload_f32(&adv, &[b, g_len])?;
        let ol_buf = self.eng.upload_f32(&old_logp, &[b, g_len])?;
        let rl_buf = self.eng.upload_f32(&ref_logp, &[b, g_len])?;
        let hp = self.cfg.params.hp_vector(self.cfg.params.lr);
        let hp_buf = self.eng.upload_f32(&hp, &[8])?;
        let new_blob = timer.time("update_actor", || {
            self.eng.call(
                &self.cfg.bundle,
                "train_policy",
                &[
                    &self.policy.blob,
                    &tok_buf,
                    &val_buf,
                    &rm_buf,
                    &adv_buf,
                    &ol_buf,
                    &rl_buf,
                    &hp_buf,
                ],
            )
        })?;
        self.policy.swap(new_blob);
        let tm = self.policy.metrics(self.eng)?;

        // ---- diversity metrics (cheap; every step) -----------------------------------
        let responses: Vec<Vec<i32>> = kept.iter().map(|(_, r, _)| r.response.clone()).collect();
        let d1 = metrics::distinct_1(&responses);
        let sbleu = metrics::self_bleu(&responses);
        let rouge = if rouge_acc.is_empty() {
            f64::NAN
        } else {
            rouge_acc.iter().sum::<f64>() / rouge_acc.len() as f64
        };

        // ---- record -----------------------------------------------------------------
        self.cum_new_tokens += spec_stats_acc.new_tokens;
        self.cum_reused_tokens += spec_stats_acc.reused_tokens;
        // Re-derive the per-draft means from the raw counters summed over
        // the step's gen rounds (absorb never merges derived fields).
        spec_stats_acc.finalize_draft_means();
        let total_s = t_step.elapsed().as_secs_f64();
        let known: f64 = timer.total();
        let mut rec: BTreeMap<&'static str, f64> = BTreeMap::new();
        let reward_mean = rewards.iter().map(|&r| r as f64).sum::<f64>() / b as f64;
        rec.insert("step", step_idx as f64);
        rec.insert("epoch", (step_idx / self.cfg.steps_per_epoch()) as f64);
        rec.insert("reward", reward_mean);
        rec.insert("tokens_new", spec_stats_acc.new_tokens as f64);
        rec.insert("tokens_reused", spec_stats_acc.reused_tokens as f64);
        rec.insert("tokens_cum", self.cum_new_tokens as f64);
        rec.insert("prefix_len", spec_stats_acc.mean_prefix_len);
        rec.insert("full_reuse", spec_stats_acc.full_reuse_ratio);
        rec.insert("drafts", spec_stats_acc.drafts as f64);
        rec.insert("gen_rounds", gen_rounds as f64);
        rec.insert("verify_calls", spec_stats_acc.verify_calls as f64);
        let shard_calls = &spec_stats_acc.shard_device_calls;
        rec.insert("shards", self.pool.shards() as f64);
        rec.insert("device_calls", shard_calls.iter().sum::<usize>() as f64);
        rec.insert("shard_calls_max", shard_calls.iter().copied().max().unwrap_or(0) as f64);
        rec.insert("shard_calls_min", shard_calls.iter().copied().min().unwrap_or(0) as f64);
        rec.insert("steal_count", spec_stats_acc.steal_count as f64);
        // Shard failure recovery (ARCHITECTURE.md §13): dead shards this
        // step and the once-seated rows requeued onto survivors. Both
        // stay 0 on healthy pools.
        rec.insert("shard_failures", spec_stats_acc.shard_failures as f64);
        rec.insert("requeued_tasks", spec_stats_acc.requeued_tasks as f64);
        // Virtual-clock overlap accounting (ARCHITECTURE.md §11): zero on
        // real devices, populated when the pool runs on clocked mocks.
        rec.insert("overlap_makespan", spec_stats_acc.overlap_makespan);
        rec.insert("serial_makespan", spec_stats_acc.serial_makespan);
        // Host<->device traffic (ARCHITECTURE.md §12): the fused O(B)
        // readback should hold readback_bytes far below the O(B*V) probs
        // payload the host-sampling oracle reads each decode round.
        rec.insert("readback_bytes", spec_stats_acc.readback_bytes as f64);
        rec.insert("upload_bytes", spec_stats_acc.upload_bytes as f64);
        // Predicted-length scheduling gauges (ARCHITECTURE.md §14): mean
        // |predicted - actual| response length over rows the predictor
        // scored, plus the offered-draft-length summary from the adaptive
        // controller. All stay 0/NaN-free when the features are off.
        rec.insert("predict_err", spec_stats_acc.mean_predict_err);
        rec.insert("draft_len_mean", spec_stats_acc.mean_draft_len);
        rec.insert("draft_len_max", spec_stats_acc.draft_len_hi as f64);
        rec.insert("draft_trunc", spec_stats_acc.draft_trunc as f64);
        // Trie-aware fallback gauges (ARCHITECTURE.md §8): rows drafted
        // from a sibling spine, the tokens those fallbacks offered, and
        // the mean branch-point depth of drafted prompt groups. All 0
        // with spec.sibling_drafts off.
        rec.insert("sibling_hits", spec_stats_acc.sibling_draft_hits as f64);
        rec.insert("sibling_tokens", spec_stats_acc.sibling_draft_tokens as f64);
        rec.insert("branch_depth_mean", spec_stats_acc.branch_depth_mean);
        rec.insert("cache_tokens", self.spec.cache.total_tokens() as f64);
        // Trie gauges after the step's last refresh: live interned runs
        // and the tokens prefix sharing saves over flat storage.
        rec.insert("cache_nodes", spec_stats_acc.cache_nodes as f64);
        rec.insert("cache_shared_tokens", spec_stats_acc.cache_shared_tokens as f64);
        rec.insert("cache_evictions", spec_stats_acc.cache_evictions as f64);
        rec.insert("cache_evicted_tokens", spec_stats_acc.cache_evicted_tokens as f64);
        rec.insert("rollout_s", timer.get("rollout"));
        rec.insert("verification_s", timer.get("verification"));
        rec.insert("assembly_s", timer.get("assembly"));
        rec.insert("reward_s", timer.get("reward"));
        rec.insert("old_logp_s", timer.get("old_logp"));
        rec.insert("ref_s", timer.get("ref"));
        rec.insert("values_s", timer.get("values"));
        rec.insert("adv_s", timer.get("adv"));
        rec.insert("update_critic_s", timer.get("update_critic"));
        rec.insert("update_actor_s", timer.get("update_actor"));
        rec.insert("others_s", (total_s - known).max(0.0));
        rec.insert("total_s", total_s);
        rec.insert("loss", tm.get(self.eng, "loss") as f64);
        rec.insert("pg_loss", tm.get(self.eng, "pg_loss") as f64);
        rec.insert("kl", tm.get(self.eng, "kl") as f64);
        rec.insert("entropy", tm.get(self.eng, "entropy") as f64);
        rec.insert("clip_frac", tm.get(self.eng, "clip_frac") as f64);
        rec.insert("grad_norm", tm.get(self.eng, "grad_norm") as f64);
        rec.insert("distinct1", d1);
        rec.insert("self_bleu", sbleu);
        rec.insert("rouge1_prev_epoch", rouge);
        self.report.push_map(&rec);
        timer.add("others", (total_s - known).max(0.0));
        self.stage_totals.merge(&timer.take());
        Ok(rec)
    }

    /// Run the configured number of steps; returns the summary.
    pub fn run(&mut self, label: &str) -> Result<RunSummary> {
        let t0 = std::time::Instant::now();
        let mut last_reward = 0.0;
        for s in 0..self.cfg.steps {
            let rec = self.step(s)?;
            last_reward = rec["reward"];
            if s % 5 == 0 || s + 1 == self.cfg.steps {
                log::info!(
                    "[{label}] step {s}: reward={:.3} new_tok={} reused={} prefix={:.1} rollout={:.2}s",
                    rec["reward"],
                    rec["tokens_new"] as u64,
                    rec["tokens_reused"] as u64,
                    rec["prefix_len"],
                    rec["rollout_s"],
                );
            }
        }
        let total = t0.elapsed().as_secs_f64();
        let final_eval = eval::evaluate(
            self.eng,
            self.pool.shard_mut(0),
            &self.policy,
            &self.tok,
            self.cfg.eval_n,
            self.cfg.eval_samples_hard,
            &mut self.rng,
        )?;
        self.report.save()?;

        let steps = self.cfg.steps;
        let mut stage_means = BTreeMap::new();
        for (k, v) in self.stage_totals.stages() {
            stage_means.insert(*k, v / steps as f64);
        }
        Ok(RunSummary {
            label: label.to_string(),
            steps,
            total_new_tokens: self.cum_new_tokens,
            total_reused_tokens: self.cum_reused_tokens,
            rollout_secs: self.stage_totals.get("rollout"),
            verify_secs: self.stage_totals.get("verification"),
            assembly_secs: self.stage_totals.get("assembly"),
            total_secs: total,
            final_reward: last_reward,
            final_eval,
            stage_means,
        })
    }
}
