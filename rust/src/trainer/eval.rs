//! Benchmark-suite evaluation (Pass@1, optionally averaged over k samples).

use anyhow::Result;

use crate::model::Policy;
use crate::rollout::{RolloutEngine, SampleCfg, SeqTask};
use crate::runtime::Engine;
use crate::tasks::{eval_suites, reward, EvalSuite};
use crate::tokenizer::Tokenizer;
use crate::util::{Rng, StageTimer};

/// Evaluate one suite: mean binary reward over its tasks, averaged over
/// `samples` independent rollouts (the paper's Pass@1-over-k protocol).
pub fn eval_suite(
    eng: &Engine,
    rollout: &mut RolloutEngine,
    policy: &Policy,
    tok: &Tokenizer,
    suite: &EvalSuite,
    samples: usize,
    rng: &mut Rng,
) -> Result<f64> {
    let _ = eng;
    let cfg = SampleCfg { top_p: 0.95, ..SampleCfg::default() };
    let mut timer = StageTimer::new();
    let mut total = 0f64;
    for _ in 0..samples.max(1) {
        let tasks: Vec<SeqTask> = suite
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| SeqTask::fresh(i, tok.encode_prompt(&t.prompt)))
            .collect();
        let (results, _) = rollout.run(&policy.blob, tasks, cfg, rng, &mut timer)?;
        let mut acc = 0f64;
        for r in &results {
            let text = tok.decode_clean(&r.response);
            acc += reward(&text, &suite.tasks[r.id].answer, suite.exact) as f64;
        }
        total += acc / suite.tasks.len() as f64;
    }
    Ok(total / samples.max(1) as f64)
}

/// Run the full battery; `samples_hard` extra sampling applies to the
/// hardest math suite ("add-hard", the AIME analog).
pub fn evaluate(
    eng: &Engine,
    rollout: &mut RolloutEngine,
    policy: &Policy,
    tok: &Tokenizer,
    n_per_suite: usize,
    samples_hard: usize,
    rng: &mut Rng,
) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for suite in eval_suites(n_per_suite) {
        let samples = if suite.name == "add-hard" { samples_hard } else { 1 };
        let acc = eval_suite(eng, rollout, policy, tok, &suite, samples, rng)?;
        out.push((suite.name.to_string(), acc));
    }
    Ok(out)
}

/// Math-suite average, OOD average, and overall average (Table 1 columns).
pub fn summarize(evals: &[(String, f64)]) -> (f64, f64, f64) {
    let math: Vec<f64> = evals
        .iter()
        .filter(|(n, _)| !matches!(n.as_str(), "compare" | "format"))
        .map(|(_, a)| *a)
        .collect();
    let ood: Vec<f64> = evals
        .iter()
        .filter(|(n, _)| matches!(n.as_str(), "compare" | "format"))
        .map(|(_, a)| *a)
        .collect();
    let avg = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let all: Vec<f64> = evals.iter().map(|(_, a)| *a).collect();
    (avg(&math), avg(&ood), avg(&all))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_splits_groups() {
        let evals = vec![
            ("add-easy".to_string(), 0.8),
            ("chain".to_string(), 0.4),
            ("compare".to_string(), 0.5),
            ("format".to_string(), 0.3),
        ];
        let (math, ood, all) = summarize(&evals);
        assert!((math - 0.6).abs() < 1e-9);
        assert!((ood - 0.4).abs() < 1e-9);
        assert!((all - 0.5).abs() < 1e-9);
    }
}
