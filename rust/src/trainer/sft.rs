//! Supervised pretraining — the "base model" producer.
//!
//! Teaches the response format (`prompt = answer<eos>` / chain-of-thought
//! steps) over all task families so RLVR starts from a policy with a
//! non-zero reward signal, playing the role of the paper's pretrained
//! Qwen/LLaMA backbones. Runs on the same AOT `train_sft` entry.

use anyhow::Result;

use crate::model::Policy;
use crate::runtime::Engine;
use crate::tasks::{sft_corpus, SftExample};
use crate::tokenizer::{Tokenizer, EOS, PAD};
use crate::util::Rng;

/// SFT configuration.
#[derive(Clone, Debug)]
pub struct SftConfig {
    pub bundle: String,
    pub steps: usize,
    pub lr: f32,
    pub examples: usize,
    pub seed: u64,
    /// Resume from a checkpoint instead of the init blob.
    pub init_from: Option<String>,
}

impl Default for SftConfig {
    fn default() -> Self {
        SftConfig {
            bundle: "tiny_b32".into(),
            steps: 300,
            lr: 1e-3,
            examples: 4096,
            seed: 7,
            init_from: None,
        }
    }
}

/// Pack an SFT batch: canonical layout, loss only on response tokens
/// (including EOS).
pub fn pack_sft_batch(
    examples: &[&SftExample],
    tok: &Tokenizer,
    batch: usize,
    prompt_len: usize,
    total_len: usize,
) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let mut tokens = vec![PAD; batch * total_len];
    let mut valid = vec![0f32; batch * total_len];
    let mut loss_mask = vec![0f32; batch * total_len];
    for (row, ex) in examples.iter().enumerate() {
        let prompt = tok.encode_prompt(&ex.prompt);
        let mut resp = tok.encode(&ex.response);
        resp.push(EOS);
        let start = prompt_len - prompt.len();
        for (i, &t) in prompt.iter().enumerate() {
            tokens[row * total_len + start + i] = t;
            valid[row * total_len + start + i] = 1.0;
        }
        for (j, &t) in resp.iter().enumerate().take(total_len - prompt_len) {
            tokens[row * total_len + prompt_len + j] = t;
            valid[row * total_len + prompt_len + j] = 1.0;
            loss_mask[row * total_len + prompt_len + j] = 1.0;
        }
    }
    (tokens, valid, loss_mask)
}

/// Run SFT from the bundle's init blob; returns the trained base policy
/// and the loss curve.
pub fn run_sft(eng: &Engine, cfg: &SftConfig) -> Result<(Policy, Vec<f32>)> {
    let info = eng.bundle(&cfg.bundle)?.clone();
    let (b, p, t) = (info.batch, eng.manifest.prompt_len, eng.manifest.total_len);
    let tok = Tokenizer::new(&eng.manifest.charset);
    let corpus = sft_corpus(cfg.examples, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0xF00D);
    let mut policy = match &cfg.init_from {
        Some(path) => Policy::load(eng, &cfg.bundle, path)?,
        None => Policy::from_init(eng, &cfg.bundle)?,
    };
    // hp: [lr, _, _, _, _, _, weight_decay, max_grad_norm]
    let hp = [cfg.lr, 0.0, 0.0, 0.0, 0.0, 1.0, 0.01, 1.0];
    let hp_buf = eng.upload_f32(&hp, &[8])?;

    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let batch: Vec<&SftExample> =
            (0..b).map(|_| &corpus[rng.below(corpus.len())]).collect();
        let (tokens, valid, loss_mask) = pack_sft_batch(&batch, &tok, b, p, t);
        let tok_buf = eng.upload_i32(&tokens, &[b, t])?;
        let val_buf = eng.upload_f32(&valid, &[b, t])?;
        let lm_buf = eng.upload_f32(&loss_mask, &[b, t])?;
        let new_blob = eng.call(
            &cfg.bundle,
            "train_sft",
            &[&policy.blob, &tok_buf, &val_buf, &lm_buf, &hp_buf],
        )?;
        policy.swap(new_blob);
        let m = policy.metrics(eng)?;
        let loss = m.get(eng, "loss");
        losses.push(loss);
        if step % 50 == 0 || step + 1 == cfg.steps {
            log::info!(
                "[sft:{}] step {step}: loss={loss:.4} acc={:.3}",
                cfg.bundle,
                m.get(eng, "entropy"), // slot 3 carries accuracy for SFT
            );
        }
    }
    Ok((policy, losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_masks_only_response() {
        let tok = Tokenizer::default_charset();
        let ex = SftExample { prompt: "1+1=".into(), response: "2".into() };
        let (tokens, valid, lm) = pack_sft_batch(&[&ex], &tok, 2, 8, 16);
        // prompt occupies slots 3..8 (BOS + 4 chars), response slot 8..10 (char+EOS)
        assert_eq!(valid[3..10], [1.0; 7]);
        assert_eq!(&lm[..8], &[0.0; 8]);
        assert_eq!(lm[8], 1.0);
        assert_eq!(lm[9], 1.0);
        assert_eq!(tokens[9], EOS);
        // second row is empty filler
        assert!(valid[16..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn long_responses_truncate() {
        let tok = Tokenizer::default_charset();
        let ex = SftExample { prompt: "1=".into(), response: "123456789012345".into() };
        let (_, valid, _) = pack_sft_batch(&[&ex], &tok, 1, 8, 12);
        // response region is 4 slots; no overflow
        assert_eq!(valid.len(), 12);
    }
}
