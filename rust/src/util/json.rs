//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! Parses the subset emitted by `python -m json` — objects, arrays,
//! strings (with escapes), numbers, booleans, null — which covers
//! `artifacts/manifest.json`, plus a writer used by the metrics reporters.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chain; panics with a useful message if missing.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("manifest: missing key '{key}' in {self:.60?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number as a usize — `None` for non-numbers, negatives, and
    /// non-integral values (they used to truncate silently via `as usize`,
    /// turning `-3` into 0 and `2.5` into 2).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= usize::MAX as f64)
            .map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap_or("\u{fffd}"));
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// -- writer -------------------------------------------------------------
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].req("b").as_str(), Some("c"));
        assert!(j.req("d").as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":true,"c":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::Num(42.0).as_usize(), Some(42));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-3.0).as_usize(), None, "negatives used to truncate to 0");
        assert_eq!(Json::Num(2.5).as_usize(), None, "fractions used to truncate to 2");
        assert_eq!(Json::Num(-0.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None, "strings never coerce");
        // usize_arr drops the rejects rather than mangling them
        let j = Json::parse("[3, -1, 2.5, 4]").unwrap();
        assert_eq!(j.usize_arr(), vec![3, 4]);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"vocab": 52, "geometry": {"prompt_len": 16, "total_len": 64},
                      "bundles": {"tiny_b32": {"batch": 32, "entries":
                      {"score": {"file": "tiny_b32/score.hlo.txt",
                       "inputs": [{"name":"blob","dtype":"f32","shape":[100]}]}}}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req("vocab").as_usize(), Some(52));
        let e = j.req("bundles").req("tiny_b32").req("entries").req("score");
        assert_eq!(e.req("inputs").as_arr().unwrap()[0].req("shape").usize_arr(), vec![100]);
    }
}
