//! Deterministic pseudo-random number generation (SplitMix64 seeding +
//! xoshiro256** core), plus the categorical / top-p samplers used by the
//! rollout engine.
//!
//! All randomness in the coordinator flows through [`Rng`] so every
//! experiment is reproducible from a single `u64` seed; verification
//! uniforms are drawn here and shipped to the device (the L1 acceptance
//! kernel consumes them — the device never owns RNG state).

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full f32 mantissa coverage
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free (bias negligible for our n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fill a slice with U(0,1) f32 samples.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.f32();
        }
    }
}

/// Sample an index from a (not necessarily normalized) probability vector
/// with optional top-p (nucleus) truncation.
///
/// `probs` is read-only; scratch allocations are the caller's via
/// [`TopPSampler`] for the hot path.
pub fn sample_top_p(probs: &[f32], top_p: f32, rng: &mut Rng) -> usize {
    let mut sampler = TopPSampler::new(probs.len());
    sampler.sample(probs, top_p, rng)
}

/// Reusable top-p sampler: owns its scratch so the per-token decode loop
/// does not allocate (see DESIGN.md §Perf L3 rules).
pub struct TopPSampler {
    order: Vec<u32>,
}

impl TopPSampler {
    pub fn new(vocab: usize) -> Self {
        TopPSampler { order: (0..vocab as u32).collect() }
    }

    /// Nucleus sampling: keep the smallest prefix of the sorted
    /// distribution whose mass reaches `top_p`, renormalize, inverse-CDF.
    pub fn sample(&mut self, probs: &[f32], top_p: f32, rng: &mut Rng) -> usize {
        debug_assert_eq!(probs.len(), self.order.len());
        if top_p >= 0.999_999 {
            // plain categorical: inverse CDF over the raw distribution
            let total: f32 = probs.iter().sum();
            let mut u = rng.f32() * total;
            for (i, &p) in probs.iter().enumerate() {
                u -= p;
                if u <= 0.0 {
                    return i;
                }
            }
            return probs.len() - 1;
        }
        for (i, o) in self.order.iter_mut().enumerate() {
            *o = i as u32;
        }
        // prob-descending with an index-ascending tie-break: a total order,
        // so the sort is deterministic and the device-side stable argsort of
        // `-probs` reproduces it exactly (ARCHITECTURE.md §12)
        self.order.sort_unstable_by(|&a, &b| {
            probs[b as usize].total_cmp(&probs[a as usize]).then(a.cmp(&b))
        });
        let total: f32 = probs.iter().sum();
        let budget = top_p * total;
        let mut mass = 0.0f32;
        let mut cut = self.order.len();
        for (rank, &i) in self.order.iter().enumerate() {
            mass += probs[i as usize];
            if mass >= budget {
                cut = rank + 1;
                break;
            }
        }
        let kept = &self.order[..cut];
        let kept_mass: f32 = kept.iter().map(|&i| probs[i as usize]).sum();
        let mut u = rng.f32() * kept_mass;
        for &i in kept {
            u -= probs[i as usize];
            if u <= 0.0 {
                return i as usize;
            }
        }
        kept[kept.len() - 1] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(5);
        let mean: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn categorical_matches_distribution() {
        let mut r = Rng::new(8);
        let probs = vec![0.1, 0.2, 0.3, 0.4];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[sample_top_p(&probs, 1.0, &mut r)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let freq = c as f64 / 40_000.0;
            assert!((freq - probs[i] as f64).abs() < 0.02, "{i}: {freq}");
        }
    }

    #[test]
    fn top_p_truncates_tail() {
        let mut r = Rng::new(9);
        // 0.5/0.3/0.15/0.05 with top_p=0.8 keeps only the first two
        let probs = vec![0.5, 0.3, 0.15, 0.05];
        for _ in 0..2_000 {
            let s = sample_top_p(&probs, 0.8, &mut r);
            assert!(s < 2, "sampled tail index {s}");
        }
    }

    #[test]
    fn top_p_one_keeps_everything_reachable() {
        let mut r = Rng::new(10);
        let probs = vec![0.25, 0.25, 0.25, 0.25];
        let mut seen = [false; 4];
        for _ in 0..1_000 {
            seen[sample_top_p(&probs, 1.0, &mut r)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(12);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
