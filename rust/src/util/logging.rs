//! Tiny `log` facade backend (env_logger is unavailable offline).
//!
//! `SPEC_RL_LOG=debug|info|warn|error` controls the level (default info).

use log::{Level, LevelFilter, Metadata, Record};

struct SimpleLogger;

impl log::Log for SimpleLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: SimpleLogger = SimpleLogger;

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("SPEC_RL_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
