//! Stage timing: the instrument behind the paper's Table 4 (end-to-end
//! per-stage breakdown) and Figure 11 (rollout-time trajectories).
//!
//! A [`StageTimer`] accumulates wall-clock per named stage per step; the
//! trainer snapshots and resets it every step so reports can show both
//! per-step series and run totals.

use std::collections::BTreeMap;
use std::time::Instant;

/// Accumulates per-stage durations (seconds).
#[derive(Default, Debug, Clone)]
pub struct StageTimer {
    acc: BTreeMap<&'static str, f64>,
}

/// RAII guard measuring one stage span.
pub struct Span<'a> {
    timer: &'a mut StageTimer,
    stage: &'static str,
    start: Instant,
}

impl StageTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<T>(&mut self, stage: &'static str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.add(stage, start.elapsed().as_secs_f64());
        out
    }

    /// Start an explicit span (for code that is not closure-shaped).
    pub fn span(&mut self, stage: &'static str) -> Span<'_> {
        Span { stage, start: Instant::now(), timer: self }
    }

    pub fn add(&mut self, stage: &'static str, secs: f64) {
        *self.acc.entry(stage).or_insert(0.0) += secs;
    }

    pub fn get(&self, stage: &str) -> f64 {
        self.acc.get(stage).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.acc.values().sum()
    }

    /// Snapshot current accumulations and reset.
    pub fn take(&mut self) -> BTreeMap<&'static str, f64> {
        std::mem::take(&mut self.acc)
    }

    pub fn stages(&self) -> impl Iterator<Item = (&&'static str, &f64)> {
        self.acc.iter()
    }

    /// Merge another snapshot into this accumulator.
    pub fn merge(&mut self, other: &BTreeMap<&'static str, f64>) {
        for (k, v) in other {
            *self.acc.entry(k).or_insert(0.0) += v;
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let secs = self.start.elapsed().as_secs_f64();
        self.timer.add(self.stage, secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_takes() {
        let mut t = StageTimer::new();
        t.add("rollout", 1.0);
        t.add("rollout", 0.5);
        t.add("verify", 0.25);
        assert_eq!(t.get("rollout"), 1.5);
        assert_eq!(t.total(), 1.75);
        let snap = t.take();
        assert_eq!(snap["verify"], 0.25);
        assert_eq!(t.total(), 0.0);
    }

    #[test]
    fn time_closure_measures_something() {
        let mut t = StageTimer::new();
        let v = t.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t.get("work") >= 0.004);
    }

    #[test]
    fn span_guard_records_on_drop() {
        let mut t = StageTimer::new();
        {
            let _s = t.span("guarded");
            std::thread::sleep(std::time::Duration::from_millis(3));
        }
        assert!(t.get("guarded") >= 0.002);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageTimer::new();
        a.add("x", 1.0);
        let mut b = StageTimer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b.take());
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }
}
