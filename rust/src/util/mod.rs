//! Utility substrate: RNG, JSON, timers, simple logging.
//!
//! The offline crate mirror in this image only carries the `xla`
//! dependency closure, so the usual ecosystem crates (rand, serde_json,
//! env_logger) are replaced by these small, fully-tested implementations.

pub mod json;
pub mod logging;
pub mod npy;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::{sample_top_p, Rng, TopPSampler};
pub use timer::StageTimer;
