//! Minimal NumPy `.npy` writer for f32 arrays.
//!
//! The vendored xla crate's `Literal::write_npy` is broken for f32 (it
//! copies through a u8-typed `copy_raw_to`, which type-checks against the
//! literal element type and fails); checkpoints therefore use this writer.
//! Reading stays on `xla::Literal::read_npy`, which works.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write a 1-D f32 array as `.npy` (v1.0, little-endian).
pub fn write_npy_f32(path: impl AsRef<Path>, data: &[f32]) -> Result<()> {
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({},), }}",
        data.len()
    );
    // pad so that magic(6)+version(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.extend(std::iter::repeat_n(' ', pad));
    header.push('\n');

    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    // SAFETY-free byte conversion
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&bytes)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use xla::FromRawBytes;

    #[test]
    fn roundtrips_through_xla_reader() {
        let path = std::env::temp_dir().join("specrl_npy_writer_test.npy");
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        write_npy_f32(&path, &data).unwrap();
        let lit = xla::Literal::read_npy(&path, &()).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn numpy_can_read_it_header_shape() {
        // structural check without numpy: magic + parseable header
        let path = std::env::temp_dir().join("specrl_npy_writer_test2.npy");
        write_npy_f32(&path, &[1.0, 2.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..6], b"\x93NUMPY");
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
        let header = std::str::from_utf8(&bytes[10..10 + hlen]).unwrap();
        assert!(header.contains("'<f4'"));
        assert!(header.contains("(2,)"));
        let _ = std::fs::remove_file(path);
    }
}
