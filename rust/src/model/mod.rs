//! Policy/value handles: device-resident parameter blobs + checkpointing.
//!
//! A [`Policy`] owns the flat `[params | adam_m | adam_v | step | metrics]`
//! blob as a PJRT buffer. Train entries consume and produce whole blobs, so
//! "apply an update" is a buffer swap — parameters never round-trip through
//! the host except for checkpoint save/load (npy via the xla crate).

use anyhow::{Context, Result};
use xla::FromRawBytes;

use crate::runtime::Engine;

/// Device-resident model state bound to a manifest bundle.
pub struct Policy {
    /// Bundle name, e.g. "tiny_b32".
    pub bundle: String,
    /// The state blob (device).
    pub blob: xla::PjRtBuffer,
    /// Cached sizes from the manifest.
    pub n_params: usize,
    pub blob_size: usize,
}

/// Step counter + train metrics read back from a train call.
#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub step: f32,
    /// Raw metric slots (names in `manifest.metric_slots`).
    pub slots: Vec<f32>,
}

impl TrainMetrics {
    pub fn get(&self, engine: &Engine, name: &str) -> f32 {
        self.slots[engine.manifest.metric_index(name)]
    }
}

impl Policy {
    /// Fresh policy from the bundle's init blob.
    pub fn from_init(engine: &Engine, bundle: &str) -> Result<Policy> {
        let info = engine.bundle(bundle)?.clone();
        let blob = engine.upload_npy(&info.init_blob)?;
        Ok(Policy {
            bundle: bundle.to_string(),
            blob,
            n_params: info.n_params,
            blob_size: info.blob_size,
        })
    }

    /// Deep-copy the blob (host round-trip; used to freeze the reference
    /// policy for GRPO's KL term).
    pub fn duplicate(&self, engine: &Engine) -> Result<Policy> {
        let host = engine.read_f32(&self.blob)?;
        Ok(Policy {
            bundle: self.bundle.clone(),
            blob: engine.upload_f32(&host, &[host.len()])?,
            n_params: self.n_params,
            blob_size: self.blob_size,
        })
    }

    /// Replace the blob (after a train call).
    pub fn swap(&mut self, new_blob: xla::PjRtBuffer) {
        self.blob = new_blob;
    }

    /// Read `[step | metrics]` via the bundle's `read_metrics` entry.
    pub fn metrics(&self, engine: &Engine) -> Result<TrainMetrics> {
        let out = engine.call(&self.bundle, "read_metrics", &[&self.blob])?;
        let host = engine.read_f32(&out)?;
        Ok(TrainMetrics { step: host[0], slots: host[1..].to_vec() })
    }

    /// Save the whole blob to an .npy checkpoint.
    pub fn save(&self, engine: &Engine, path: impl AsRef<std::path::Path>) -> Result<()> {
        let host = engine.read_f32(&self.blob)?;
        crate::util::npy::write_npy_f32(path.as_ref(), &host)
            .context("writing checkpoint npy")?;
        Ok(())
    }

    /// Load a checkpoint written by [`Policy::save`].
    pub fn load(engine: &Engine, bundle: &str, path: impl AsRef<std::path::Path>) -> Result<Policy> {
        let info = engine.bundle(bundle)?.clone();
        let lit = xla::Literal::read_npy(path.as_ref(), &())
            .with_context(|| format!("reading checkpoint {:?}", path.as_ref()))?;
        let host = lit.to_vec::<f32>()?;
        anyhow::ensure!(
            host.len() == info.blob_size,
            "checkpoint size {} != bundle blob size {} (wrong bundle?)",
            host.len(),
            info.blob_size
        );
        let blob = engine.upload_f32(&host, &[host.len()])?;
        Ok(Policy {
            bundle: bundle.to_string(),
            blob,
            n_params: info.n_params,
            blob_size: info.blob_size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let eng = Engine::load("artifacts").unwrap();
        let p = Policy::from_init(&eng, "tiny_b32").unwrap();
        let dir = std::env::temp_dir().join("specrl_test_ckpt.npy");
        p.save(&eng, &dir).unwrap();
        let q = Policy::load(&eng, "tiny_b32", &dir).unwrap();
        let a = eng.read_f32(&p.blob).unwrap();
        let b = eng.read_f32(&q.blob).unwrap();
        assert_eq!(a, b);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn metrics_of_fresh_blob_are_zero() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let eng = Engine::load("artifacts").unwrap();
        let p = Policy::from_init(&eng, "tiny_b32").unwrap();
        let m = p.metrics(&eng).unwrap();
        assert_eq!(m.step, 0.0);
        assert!(m.slots.iter().all(|&x| x == 0.0));
    }
}
