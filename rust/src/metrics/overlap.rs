//! ROUGE-1 overlap between consecutive-epoch rollouts (Figure 2).
//!
//! The paper motivates SPEC-RL by measuring token overlap (ROUGE-1) between
//! rollouts of the same prompt in consecutive epochs under vanilla RLVR.
//! The trainer computes this from the shadow cache whenever a prompt
//! reappears.

use std::collections::HashMap;

/// ROUGE-1 F1 between two token sequences (clipped unigram overlap).
pub fn rouge1_f1(a: &[i32], b: &[i32]) -> f64 {
    if a.is_empty() || b.is_empty() {
        return 0.0;
    }
    let mut ca: HashMap<i32, usize> = HashMap::new();
    for &t in a {
        *ca.entry(t).or_insert(0) += 1;
    }
    let mut cb: HashMap<i32, usize> = HashMap::new();
    for &t in b {
        *cb.entry(t).or_insert(0) += 1;
    }
    let overlap: usize = ca.iter().map(|(t, c)| (*c).min(*cb.get(t).unwrap_or(&0))).sum();
    let p = overlap as f64 / b.len() as f64;
    let r = overlap as f64 / a.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Longest-common-prefix length (the quantity SPEC-RL actually exploits).
pub fn common_prefix_len(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_one() {
        assert!((rouge1_f1(&[1, 2, 3], &[1, 2, 3]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(rouge1_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn order_invariant_unigrams() {
        assert!((rouge1_f1(&[1, 2, 3], &[3, 2, 1]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn partial_overlap() {
        // a={1,2}, b={2,3}: overlap 1, p=r=0.5, f1=0.5
        assert!((rouge1_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clipped_counts() {
        // repeated token only counts up to min multiplicity
        let f = rouge1_f1(&[5, 5, 5, 5], &[5]);
        // overlap=1, p=1.0, r=0.25 => f1=0.4
        assert!((f - 0.4).abs() < 1e-9);
    }

    #[test]
    fn prefix_len() {
        assert_eq!(common_prefix_len(&[1, 2, 3, 9], &[1, 2, 3, 4, 5]), 3);
        assert_eq!(common_prefix_len(&[], &[1]), 0);
        assert_eq!(common_prefix_len(&[7], &[7]), 1);
    }
}
