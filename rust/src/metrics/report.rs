//! Run reporting: per-step CSV series + aligned-text table rendering.
//!
//! Every bench/example writes its series to `out/<name>.csv` (the data
//! behind Tables 7–27 and Figures 4–11) and prints paper-shaped tables via
//! [`Table`].

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Append-oriented CSV writer for per-step series.
pub struct Report {
    path: PathBuf,
    columns: Vec<String>,
    rows: Vec<Vec<f64>>,
}

impl Report {
    pub fn new(path: impl AsRef<Path>, columns: &[&str]) -> Report {
        Report {
            path: path.as_ref().to_path_buf(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add one row; missing trailing values are allowed (NaN-filled).
    pub fn push(&mut self, values: &[f64]) {
        let mut row = values.to_vec();
        row.resize(self.columns.len(), f64::NAN);
        self.rows.push(row);
    }

    /// Add a row from (column, value) pairs; unnamed columns get NaN.
    pub fn push_map(&mut self, map: &BTreeMap<&str, f64>) {
        let row: Vec<f64> = self
            .columns
            .iter()
            .map(|c| map.get(c.as_str()).copied().unwrap_or(f64::NAN))
            .collect();
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[Vec<f64>] {
        &self.rows
    }

    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[idx]).collect())
    }

    /// Write the CSV (creates parent dirs).
    pub fn save(&self) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut f = std::fs::File::create(&self.path)
            .with_context(|| format!("creating {:?}", self.path))?;
        writeln!(f, "{}", self.columns.join(","))?;
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .map(|v| if v.is_nan() { String::new() } else { format!("{v:.6}") })
                .collect();
            writeln!(f, "{}", line.join(","))?;
        }
        Ok(())
    }
}

/// Paper-shaped aligned-text table.
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        s.push_str(&fmt_row(&self.header, &widths));
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r, &widths));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("specrl_report_test.csv");
        let mut r = Report::new(&dir, &["step", "reward"]);
        r.push(&[1.0, 0.5]);
        r.push(&[2.0]);
        r.save().unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(text.starts_with("step,reward\n"));
        assert!(text.contains("1.000000,0.500000"));
        // missing value -> empty cell
        assert!(text.contains("2.000000,\n"));
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn column_extraction() {
        let mut r = Report::new("/tmp/unused.csv", &["a", "b"]);
        r.push(&[1.0, 2.0]);
        r.push(&[3.0, 4.0]);
        assert_eq!(r.column("b").unwrap(), vec![2.0, 4.0]);
        assert!(r.column("c").is_none());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["algo", "speedup"]);
        t.row(vec!["grpo".into(), "2.29x".into()]);
        t.row(vec!["grpo+spec".into(), "1.00x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("grpo+spec"));
        // columns aligned: each data line same length
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn push_map_fills_by_name() {
        let mut r = Report::new("/tmp/unused2.csv", &["x", "y"]);
        let mut m = BTreeMap::new();
        m.insert("y", 7.0);
        r.push_map(&m);
        assert!(r.rows()[0][0].is_nan());
        assert_eq!(r.rows()[0][1], 7.0);
    }
}
