//! Rollout diversity: Distinct-1 (Li et al., 2016) and Self-BLEU
//! (Zhu et al., 2018) — the two metrics of the paper's Figure 6.

use std::collections::HashMap;

/// Distinct-1: unique unigrams / total unigrams over a set of sequences.
pub fn distinct_1(seqs: &[Vec<i32>]) -> f64 {
    let mut uniq = std::collections::HashSet::new();
    let mut total = 0usize;
    for s in seqs {
        for &t in s {
            uniq.insert(t);
            total += 1;
        }
    }
    if total == 0 {
        return 0.0;
    }
    uniq.len() as f64 / total as f64
}

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// BLEU-n of `hyp` against multiple references (clipped n-gram precision,
/// geometric mean over 1..=max_n, brevity penalty vs closest ref length).
fn bleu(hyp: &[i32], refs: &[&Vec<i32>], max_n: usize) -> f64 {
    if hyp.is_empty() || refs.is_empty() {
        return 0.0;
    }
    let mut log_sum = 0f64;
    let mut valid_orders = 0usize;
    for n in 1..=max_n.min(hyp.len()) {
        let hc = ngram_counts(hyp, n);
        // max reference count per n-gram
        let mut rc: HashMap<&[i32], usize> = HashMap::new();
        for r in refs {
            for (g, c) in ngram_counts(r, n) {
                let e = rc.entry(g).or_insert(0);
                *e = (*e).max(c);
            }
        }
        let total: usize = hc.values().sum();
        let matched: usize = hc.iter().map(|(g, c)| (*c).min(*rc.get(g).unwrap_or(&0))).sum();
        if total == 0 {
            continue;
        }
        // smoothed precision (add-eps) so a zero order doesn't nuke the mean
        let p = (matched as f64 + 1e-9) / total as f64;
        log_sum += p.ln();
        valid_orders += 1;
    }
    if valid_orders == 0 {
        return 0.0;
    }
    let prec = (log_sum / valid_orders as f64).exp();
    // brevity penalty against the closest reference length
    let hl = hyp.len() as f64;
    let rl = refs
        .iter()
        .map(|r| r.len() as f64)
        .min_by(|a, b| ((a - hl).abs()).total_cmp(&(b - hl).abs()))
        .unwrap_or(hl);
    let bp = if hl >= rl { 1.0 } else { (1.0 - rl / hl).exp() };
    bp * prec
}

/// Self-BLEU over a batch: mean BLEU-4 of each sequence against the rest.
/// Higher = less diverse.
pub fn self_bleu(seqs: &[Vec<i32>]) -> f64 {
    if seqs.len() < 2 {
        return 0.0;
    }
    let mut sum = 0f64;
    for (i, hyp) in seqs.iter().enumerate() {
        let refs: Vec<&Vec<i32>> =
            seqs.iter().enumerate().filter(|(j, _)| *j != i).map(|(_, s)| s).collect();
        sum += bleu(hyp, &refs, 4);
    }
    sum / seqs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct1_bounds() {
        let all_same = vec![vec![1, 1, 1], vec![1, 1]];
        assert!((distinct_1(&all_same) - 0.2).abs() < 1e-9);
        let all_diff = vec![vec![1, 2], vec![3, 4]];
        assert_eq!(distinct_1(&all_diff), 1.0);
        assert_eq!(distinct_1(&[]), 0.0);
    }

    #[test]
    fn self_bleu_identical_is_high() {
        let seqs = vec![vec![1, 2, 3, 4, 5]; 4];
        assert!(self_bleu(&seqs) > 0.99);
    }

    #[test]
    fn self_bleu_disjoint_is_low() {
        let seqs = vec![
            vec![1, 2, 3, 4, 5, 6],
            vec![7, 8, 9, 10, 11, 12],
            vec![13, 14, 15, 16, 17, 18],
        ];
        assert!(self_bleu(&seqs) < 0.05);
    }

    #[test]
    fn self_bleu_ordering_matches_diversity() {
        let similar = vec![
            vec![1, 2, 3, 4, 5, 6, 7, 8],
            vec![1, 2, 3, 4, 5, 6, 7, 9],
            vec![1, 2, 3, 4, 5, 6, 8, 9],
        ];
        let diverse = vec![
            vec![1, 2, 3, 4, 5, 6, 7, 8],
            vec![9, 10, 11, 1, 2, 12, 13, 14],
            vec![15, 16, 3, 4, 17, 18, 19, 20],
        ];
        assert!(self_bleu(&similar) > self_bleu(&diverse));
    }

    #[test]
    fn singleton_batch_is_zero() {
        assert_eq!(self_bleu(&[vec![1, 2, 3]]), 0.0);
    }
}
