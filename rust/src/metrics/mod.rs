//! Metrics: rollout diversity, cross-epoch overlap, run reports.
//!
//! - [`diversity`] — Distinct-1 and Self-BLEU (Figure 6).
//! - [`overlap`] — ROUGE-1 between consecutive-epoch rollouts (Figure 2).
//! - [`report`] — CSV/JSONL writers for per-step series (Tables 7–27,
//!   Figures 8–11) and the table renderer used by the benches.

pub mod diversity;
pub mod overlap;
pub mod report;

pub use diversity::{distinct_1, self_bleu};
pub use overlap::rouge1_f1;
pub use report::{Report, Table};
