//! Char-level tokenizer matching `python/compile/config.py`.
//!
//! The vocabulary is `[<pad>, <bos>, <eos>] + CHARSET` where the charset is
//! read from the artifact manifest at engine load (so the two sides can
//! never drift); [`Tokenizer::default_charset`] mirrors the python constant
//! for manifest-free unit tests.

/// Token id constants (match python config).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const N_SPECIALS: usize = 3;

/// Charset mirror of `python/compile/config.CHARSET`.
pub const DEFAULT_CHARSET: &str = "0123456789+-*/%()=<> abcdefghijklmnopqrstuvwxyz?";

/// Char-level encoder/decoder.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    chars: Vec<char>,
    /// char -> id lookup (ASCII only; charset is ASCII by construction)
    lut: [i32; 128],
}

impl Tokenizer {
    pub fn new(charset: &str) -> Self {
        let chars: Vec<char> = charset.chars().collect();
        let mut lut = [-1i32; 128];
        for (i, &c) in chars.iter().enumerate() {
            assert!((c as u32) < 128, "charset must be ASCII");
            lut[c as usize] = (N_SPECIALS + i) as i32;
        }
        Tokenizer { chars, lut }
    }

    pub fn default_charset() -> Self {
        Self::new(DEFAULT_CHARSET)
    }

    pub fn vocab_size(&self) -> usize {
        N_SPECIALS + self.chars.len()
    }

    /// Encode text to ids; panics on chars outside the charset (task
    /// generators only produce charset text — anything else is a bug).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.chars()
            .map(|c| {
                let id = if (c as u32) < 128 { self.lut[c as usize] } else { -1 };
                assert!(id >= 0, "char {c:?} not in charset");
                id
            })
            .collect()
    }

    /// Encode with a BOS prefix (prompt form).
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(BOS);
        v.extend(self.encode(text));
        v
    }

    /// Decode ids to text; specials render as markers, unknown ids as '#'.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            match id {
                PAD => s.push('_'),
                BOS => s.push('^'),
                EOS => s.push('$'),
                id if (id as usize) >= N_SPECIALS
                    && ((id as usize) - N_SPECIALS) < self.chars.len() =>
                {
                    s.push(self.chars[(id as usize) - N_SPECIALS])
                }
                _ => s.push('#'),
            }
        }
        s
    }

    /// Decode skipping pads/bos and stopping at the first EOS.
    pub fn decode_clean(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            match id {
                PAD | BOS => continue,
                EOS => break,
                id if (id as usize) >= N_SPECIALS
                    && ((id as usize) - N_SPECIALS) < self.chars.len() =>
                {
                    s.push(self.chars[(id as usize) - N_SPECIALS])
                }
                _ => {}
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::default_charset();
        let s = "17+25=42";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn vocab_matches_python() {
        let t = Tokenizer::default_charset();
        assert_eq!(t.vocab_size(), 51);
    }

    #[test]
    fn prompt_has_bos() {
        let t = Tokenizer::default_charset();
        let ids = t.encode_prompt("1+1=");
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode_clean(&ids), "1+1=");
    }

    #[test]
    fn decode_clean_stops_at_eos() {
        let t = Tokenizer::default_charset();
        let mut ids = t.encode("42");
        ids.push(EOS);
        ids.extend(t.encode("junk"));
        assert_eq!(t.decode_clean(&ids), "42");
    }

    #[test]
    fn every_charset_char_roundtrips() {
        let t = Tokenizer::default_charset();
        for c in DEFAULT_CHARSET.chars() {
            let ids = t.encode(&c.to_string());
            assert_eq!(t.decode(&ids), c.to_string());
        }
    }

    #[test]
    #[should_panic]
    fn out_of_charset_panics() {
        Tokenizer::default_charset().encode("A");
    }
}
