//! Task family generators.
//!
//! Each family produces `(prompt, canonical_response, answer)` triples:
//! the prompt ends with `=` (or `)=`), the canonical response is what SFT
//! teaches (including intermediate steps for chain tasks — the analog of
//! chain-of-thought, which is what gives SPEC-RL long reusable prefixes),
//! and the answer is the string the verifier compares against.

use crate::util::Rng;

/// Task families. The first five are "math reasoning" (RL-trained);
/// `Compare`/`SortDigits` are the held-out OOD family (MMLU-STEM analog);
/// `Format` is the instruction-following family (IFEval analog).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// 1-2 digit addition: `17+25=`
    Add2,
    /// 3 digit addition: `123+456=`
    Add3,
    /// subtraction (may be negative): `17-25=`
    Sub,
    /// single-digit multiplier: `17*4=`
    Mul1,
    /// modular reduction: `123%7=`
    Mod,
    /// two-step chain with precedence: `2+3*4=` -> `3*4=12 2+12=14`
    Chain,
    /// OOD: `max(17 25)=` / `min(17 25)=` (space-sep; no comma in charset)
    Compare,
    /// OOD: `sort(3142)=` -> ascending digit string
    SortDigits,
    /// instruction-following: `pad4(17+8)=` -> zero-padded to width 4
    Format,
}

impl Family {
    pub const ALL: [Family; 9] = [
        Family::Add2,
        Family::Add3,
        Family::Sub,
        Family::Mul1,
        Family::Mod,
        Family::Chain,
        Family::Compare,
        Family::SortDigits,
        Family::Format,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Family::Add2 => "add2",
            Family::Add3 => "add3",
            Family::Sub => "sub",
            Family::Mul1 => "mul1",
            Family::Mod => "mod",
            Family::Chain => "chain",
            Family::Compare => "compare",
            Family::SortDigits => "sort",
            Family::Format => "format",
        }
    }
}

/// One verifiable task.
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub family: Family,
    /// Prompt text (without BOS; ends in `=`).
    pub prompt: String,
    /// Canonical gold response (what SFT teaches), ending implicitly in EOS.
    pub canonical: String,
    /// Ground-truth answer for the verifier.
    pub answer: String,
}

/// Generate one instance of `family` from `rng`.
pub fn generate(family: Family, rng: &mut Rng) -> TaskInstance {
    match family {
        Family::Add2 => {
            let a = rng.range_i64(1, 99);
            let b = rng.range_i64(1, 99);
            simple(family, format!("{a}+{b}="), (a + b).to_string())
        }
        Family::Add3 => {
            let a = rng.range_i64(100, 999);
            let b = rng.range_i64(100, 999);
            simple(family, format!("{a}+{b}="), (a + b).to_string())
        }
        Family::Sub => {
            let a = rng.range_i64(1, 99);
            let b = rng.range_i64(1, 99);
            simple(family, format!("{a}-{b}="), (a - b).to_string())
        }
        Family::Mul1 => {
            let a = rng.range_i64(2, 99);
            let b = rng.range_i64(2, 9);
            simple(family, format!("{a}*{b}="), (a * b).to_string())
        }
        Family::Mod => {
            let a = rng.range_i64(10, 999);
            let b = rng.range_i64(2, 9);
            simple(family, format!("{a}%{b}="), (a % b).to_string())
        }
        Family::Chain => {
            // a+b*c with standard precedence; canonical shows the two steps.
            let a = rng.range_i64(1, 99);
            let b = rng.range_i64(2, 9);
            let c = rng.range_i64(2, 9);
            let m = b * c;
            let r = a + m;
            TaskInstance {
                family,
                prompt: format!("{a}+{b}*{c}="),
                canonical: format!("{b}*{c}={m} {a}+{m}={r}"),
                answer: r.to_string(),
            }
        }
        Family::Compare => {
            let a = rng.range_i64(1, 99);
            let mut b = rng.range_i64(1, 99);
            if b == a {
                b += 1;
            }
            let mx = rng.below(2) == 0;
            let ans = if mx { a.max(b) } else { a.min(b) };
            simple(
                family,
                format!("{}({a} {b})=", if mx { "max" } else { "min" }),
                ans.to_string(),
            )
        }
        Family::SortDigits => {
            let n = 3 + rng.below(3); // 3..=5 digits
            let mut digits: Vec<u8> = (0..n).map(|_| rng.below(10) as u8).collect();
            let prompt = format!(
                "sort({})=",
                digits.iter().map(|d| d.to_string()).collect::<String>()
            );
            digits.sort_unstable();
            simple(
                family,
                prompt,
                digits.iter().map(|d| d.to_string()).collect::<String>(),
            )
        }
        Family::Format => {
            let a = rng.range_i64(1, 99);
            let b = rng.range_i64(1, 9);
            let w = 3 + rng.below(2); // pad3 or pad4
            let raw = (a + b).to_string();
            let padded = format!("{:0>width$}", raw, width = w);
            simple(family, format!("pad{w}({a}+{b})="), padded)
        }
    }
}

fn simple(family: Family, prompt: String, answer: String) -> TaskInstance {
    TaskInstance { family, prompt, canonical: answer.clone(), answer }
}

/// Max prompt chars any generator can emit (checked by tests; the AOT
/// geometry reserves prompt_len-1 chars + BOS).
pub const MAX_PROMPT_CHARS: usize = 15;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_fit_geometry() {
        let mut rng = Rng::new(1);
        for fam in Family::ALL {
            for _ in 0..200 {
                let t = generate(fam, &mut rng);
                assert!(
                    t.prompt.len() <= MAX_PROMPT_CHARS,
                    "{:?}: {} ({} chars)",
                    fam,
                    t.prompt,
                    t.prompt.len()
                );
            }
        }
    }

    #[test]
    fn all_text_is_in_charset() {
        let tok = crate::tokenizer::Tokenizer::default_charset();
        let mut rng = Rng::new(2);
        for fam in Family::ALL {
            for _ in 0..100 {
                let t = generate(fam, &mut rng);
                tok.encode(&t.prompt);
                tok.encode(&t.canonical);
                tok.encode(&t.answer);
            }
        }
    }

    #[test]
    fn answers_are_correct_arithmetic() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let t = generate(Family::Add2, &mut rng);
            let body = t.prompt.trim_end_matches('=');
            let (a, b) = body.split_once('+').unwrap();
            assert_eq!(
                t.answer.parse::<i64>().unwrap(),
                a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap()
            );
        }
    }

    #[test]
    fn chain_canonical_is_consistent() {
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let t = generate(Family::Chain, &mut rng);
            // canonical ends with "=answer"
            assert!(t.canonical.ends_with(&format!("={}", t.answer)), "{t:?}");
            // canonical has exactly two steps
            assert_eq!(t.canonical.matches('=').count(), 2, "{t:?}");
        }
    }

    #[test]
    fn sort_output_is_sorted() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let t = generate(Family::SortDigits, &mut rng);
            let mut ch: Vec<char> = t.answer.chars().collect();
            let orig = ch.clone();
            ch.sort_unstable();
            assert_eq!(ch, orig);
        }
    }

    #[test]
    fn format_width_is_respected() {
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let t = generate(Family::Format, &mut rng);
            let w: usize = t.prompt[3..4].parse().unwrap();
            assert_eq!(t.answer.len(), w, "{t:?}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a: Vec<String> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| generate(Family::Chain, &mut rng).prompt).collect()
        };
        let b: Vec<String> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| generate(Family::Chain, &mut rng).prompt).collect()
        };
        assert_eq!(a, b);
    }
}
