//! Named train sets + the SFT corpus builder.
//!
//! `SynthMath-A` substitutes for DeepMath-6K, `SynthMath-B` for SimpleRL-8K
//! (different family mix and difficulty — "a distinct training
//! distribution" is all Table 6 needs). SFT plays the role of base-model
//! pretraining: it teaches the response format over *all* families (OOD
//! ones at low weight) so RLVR has signal to amplify, mirroring
//! "Qwen3-*-Base knows some math already".

use super::gen::{generate, Family, TaskInstance};
use crate::util::Rng;

/// Specification of a procedurally generated train set.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub seed: u64,
    /// (family, weight) mixture.
    pub mix: Vec<(Family, f64)>,
}

impl DatasetSpec {
    /// DeepMath-6K analog: the paper's primary train distribution.
    pub fn synthmath_a() -> Self {
        DatasetSpec {
            name: "SynthMath-A",
            seed: 0xA11CE,
            mix: vec![
                (Family::Add2, 0.30),
                (Family::Sub, 0.25),
                (Family::Mul1, 0.25),
                (Family::Chain, 0.20),
            ],
        }
    }

    /// SimpleRL-8K analog: different mixture & difficulty.
    pub fn synthmath_b() -> Self {
        DatasetSpec {
            name: "SynthMath-B",
            seed: 0xB0B,
            mix: vec![
                (Family::Add3, 0.35),
                (Family::Mod, 0.30),
                (Family::Chain, 0.20),
                (Family::Mul1, 0.15),
            ],
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "SynthMath-A" | "a" | "A" => Some(Self::synthmath_a()),
            "SynthMath-B" | "b" | "B" => Some(Self::synthmath_b()),
            _ => None,
        }
    }

    fn sample_family(&self, rng: &mut Rng) -> Family {
        let total: f64 = self.mix.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64() * total;
        for &(fam, w) in &self.mix {
            u -= w;
            if u <= 0.0 {
                return fam;
            }
        }
        self.mix.last().unwrap().0
    }
}

/// Materialize `n` training prompts (deduplicated by prompt text so each
/// prompt is a distinct "instance" the policy revisits across epochs).
pub fn train_set(spec: &DatasetSpec, n: usize) -> Vec<TaskInstance> {
    let mut rng = Rng::new(spec.seed);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    let mut guard = 0;
    while out.len() < n && guard < n * 100 {
        guard += 1;
        let fam = spec.sample_family(&mut rng);
        let t = generate(fam, &mut rng);
        if seen.insert(t.prompt.clone()) {
            out.push(t);
        }
    }
    assert_eq!(out.len(), n, "could not generate {n} unique prompts");
    out
}

/// One supervised example: prompt + gold response.
#[derive(Clone, Debug)]
pub struct SftExample {
    pub prompt: String,
    pub response: String,
}

/// Build the SFT ("pretraining") corpus: all families, OOD families at low
/// weight, canonical responses as targets.
pub fn sft_corpus(n: usize, seed: u64) -> Vec<SftExample> {
    let mix: Vec<(Family, f64)> = vec![
        (Family::Add2, 0.18),
        (Family::Add3, 0.12),
        (Family::Sub, 0.15),
        (Family::Mul1, 0.15),
        (Family::Mod, 0.10),
        (Family::Chain, 0.15),
        (Family::Compare, 0.05),
        (Family::SortDigits, 0.05),
        (Family::Format, 0.05),
    ];
    let spec = DatasetSpec { name: "sft", seed, mix };
    let mut rng = Rng::new(seed ^ 0x5F7);
    (0..n)
        .map(|_| {
            let fam = spec.sample_family(&mut rng);
            let t = generate(fam, &mut rng);
            SftExample { prompt: t.prompt, response: t.canonical }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_set_is_unique_and_deterministic() {
        let spec = DatasetSpec::synthmath_a();
        let a = train_set(&spec, 96);
        let b = train_set(&spec, 96);
        assert_eq!(a.len(), 96);
        let prompts: std::collections::HashSet<_> = a.iter().map(|t| &t.prompt).collect();
        assert_eq!(prompts.len(), 96);
        assert_eq!(
            a.iter().map(|t| &t.prompt).collect::<Vec<_>>(),
            b.iter().map(|t| &t.prompt).collect::<Vec<_>>()
        );
    }

    #[test]
    fn datasets_differ() {
        let a = train_set(&DatasetSpec::synthmath_a(), 32);
        let b = train_set(&DatasetSpec::synthmath_b(), 32);
        assert_ne!(
            a.iter().map(|t| &t.prompt).collect::<Vec<_>>(),
            b.iter().map(|t| &t.prompt).collect::<Vec<_>>()
        );
    }

    #[test]
    fn synthmath_a_has_no_ood_families() {
        for t in train_set(&DatasetSpec::synthmath_a(), 128) {
            assert!(!matches!(
                t.family,
                Family::Compare | Family::SortDigits | Family::Format
            ));
        }
    }

    #[test]
    fn sft_corpus_covers_all_families() {
        let corpus = sft_corpus(2000, 42);
        assert_eq!(corpus.len(), 2000);
        // canonical responses must be verifiable against themselves
        for ex in corpus.iter().take(200) {
            let r = crate::tasks::verifier::extract_answer(&ex.response);
            assert!(!r.is_empty());
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(DatasetSpec::by_name("SynthMath-A").is_some());
        assert!(DatasetSpec::by_name("b").is_some());
        assert!(DatasetSpec::by_name("nope").is_none());
    }
}
