//! Graded eval suites — the benchmark battery.
//!
//! Mapping to the paper's evaluation (DESIGN.md substitution table):
//!
//! | paper benchmark | suite here   | content                         |
//! |-----------------|--------------|---------------------------------|
//! | MATH-500        | `add-easy`   | 1-2 digit addition              |
//! | AMC23           | `sub`        | subtraction (negatives)         |
//! | Minerva Math    | `mul`        | single-digit multiplication     |
//! | OlympiadBench   | `chain`      | two-step precedence chains      |
//! | AIME24          | `add-hard`   | 3-digit addition (hardest)      |
//! | MMLU-STEM (OOD) | `compare`    | max/min + digit sorting         |
//! | IFEval (OOD)    | `format`     | zero-padding instructions       |
//!
//! Suites are generated from seeds disjoint from every train-set seed.

use super::gen::{generate, Family, TaskInstance};
use crate::util::Rng;

/// A named benchmark suite.
#[derive(Clone, Debug)]
pub struct EvalSuite {
    pub name: &'static str,
    /// Whether answers must match exactly (format family) or numerically.
    pub exact: bool,
    /// True for the OOD group (reported separately like the paper).
    pub ood: bool,
    pub tasks: Vec<TaskInstance>,
}

fn suite(name: &'static str, fams: &[Family], n: usize, seed: u64, exact: bool, ood: bool) -> EvalSuite {
    let mut rng = Rng::new(seed);
    let mut tasks = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    let mut guard = 0;
    while tasks.len() < n && guard < n * 200 {
        guard += 1;
        let fam = fams[rng.below(fams.len())];
        let t = generate(fam, &mut rng);
        if seen.insert(t.prompt.clone()) {
            tasks.push(t);
        }
    }
    EvalSuite { name, exact, ood, tasks }
}

/// The standard battery (sizes scaled for the CPU testbed; `n` per suite).
pub fn eval_suites(n: usize) -> Vec<EvalSuite> {
    vec![
        suite("add-easy", &[Family::Add2], n, 0xE0A1, false, false),
        suite("add-hard", &[Family::Add3], n, 0xE0A2, false, false),
        suite("sub", &[Family::Sub], n, 0xE0A3, false, false),
        suite("mul", &[Family::Mul1], n, 0xE0A4, false, false),
        suite("chain", &[Family::Chain], n, 0xE0A5, false, false),
        suite("compare", &[Family::Compare, Family::SortDigits], n, 0xE0A6, false, true),
        suite("format", &[Family::Format], n, 0xE0A7, true, true),
    ]
}

/// Names in report order (math suites then OOD), mirroring Table 1 columns.
pub fn suite_names() -> Vec<&'static str> {
    vec!["add-easy", "add-hard", "sub", "mul", "chain", "compare", "format"]
}

/// Per-family mean canonical-response length over the eval battery — the
/// zero-history length priors for predicted-length scheduling
/// (`ARCHITECTURE.md` §14). Fresh prompts have no EWMA history yet; their
/// task family's typical answer length is the cheapest unbiased guess,
/// and the suites are seeded independently of every train set, so the
/// prior never leaks a specific training answer. Families the battery
/// does not cover are simply absent (the predictor then falls back to
/// its default prior). Deterministic: same `n`, same priors.
pub fn family_length_priors(n: usize) -> Vec<(Family, f64)> {
    let mut sums: Vec<(Family, f64, usize)> = Vec::new();
    for s in eval_suites(n) {
        for t in &s.tasks {
            let len = t.canonical.len() as f64;
            match sums.iter_mut().find(|(f, _, _)| *f == t.family) {
                Some((_, sum, cnt)) => {
                    *sum += len;
                    *cnt += 1;
                }
                None => sums.push((t.family, len, 1)),
            }
        }
    }
    sums.into_iter().map(|(f, sum, cnt)| (f, sum / cnt as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn battery_has_seven_suites() {
        let suites = eval_suites(16);
        assert_eq!(suites.len(), 7);
        for s in &suites {
            assert_eq!(s.tasks.len(), 16);
        }
    }

    #[test]
    fn ood_flags() {
        let suites = eval_suites(8);
        let ood: Vec<_> = suites.iter().filter(|s| s.ood).map(|s| s.name).collect();
        assert_eq!(ood, vec!["compare", "format"]);
    }

    #[test]
    fn suites_are_deterministic() {
        let a = eval_suites(8);
        let b = eval_suites(8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.tasks.iter().map(|t| &t.prompt).collect::<Vec<_>>(),
                y.tasks.iter().map(|t| &t.prompt).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn length_priors_are_deterministic_and_positive() {
        let a = family_length_priors(16);
        let b = family_length_priors(16);
        assert_eq!(a.len(), b.len());
        for ((fa, pa), (fb, pb)) in a.iter().zip(&b) {
            assert_eq!(fa, fb);
            assert_eq!(pa, pb, "same n must give bit-identical priors");
            assert!(*pa > 0.0, "{fa:?} prior must be positive");
        }
        // every suite family is represented
        for fam in [
            Family::Add2,
            Family::Add3,
            Family::Sub,
            Family::Mul1,
            Family::Chain,
            Family::Compare,
            Family::SortDigits,
            Family::Format,
        ] {
            assert!(a.iter().any(|(f, _)| *f == fam), "{fam:?} missing");
        }
        // chains answer with two worked steps, so their canonical responses
        // run longer than single-step easy addition on average
        let of = |fam| a.iter().find(|(f, _)| *f == fam).unwrap().1;
        assert!(of(Family::Chain) > of(Family::Add2));
    }

    #[test]
    fn suites_disjoint_from_train_sets() {
        use crate::tasks::dataset::{train_set, DatasetSpec};
        let train: std::collections::HashSet<String> = train_set(&DatasetSpec::synthmath_a(), 96)
            .into_iter()
            .map(|t| t.prompt)
            .collect();
        let mut overlap = 0usize;
        let mut total = 0usize;
        for s in eval_suites(64) {
            for t in &s.tasks {
                total += 1;
                if train.contains(&t.prompt) {
                    overlap += 1;
                }
            }
        }
        // tiny numeric spaces can collide; require <3% overlap
        assert!((overlap as f64) < 0.03 * total as f64, "{overlap}/{total}");
    }
}
