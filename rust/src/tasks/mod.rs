//! The verifiable-task environment: the substrate standing in for the
//! paper's DeepMath-6K / SimpleRL-8K training sets, its math benchmarks
//! (AMC/AIME/MATH-500/Minerva/Olympiad), its OOD benchmarks
//! (MMLU-STEM/IFEval), and the `math-verify` reward.
//!
//! Everything is procedurally generated from seeds, so train sets are
//! fixed-but-arbitrary (the paper's "small curated set, many epochs"
//! regime) and eval suites are disjoint by seed-space construction.
//!
//! - [`gen`] — task family generators (arithmetic, modular, multi-step
//!   chains, comparison/sorting, format-following).
//! - [`dataset`] — named train sets (`SynthMath-A`, `SynthMath-B`) and the
//!   SFT corpus builder.
//! - [`suites`] — graded eval suites mapped to the paper's benchmarks.
//! - [`verifier`] — the rule-based binary reward (math-verify analog).

pub mod dataset;
pub mod gen;
pub mod suites;
pub mod verifier;

pub use dataset::{sft_corpus, train_set, DatasetSpec, SftExample};
pub use gen::{Family, TaskInstance};
pub use suites::{eval_suites, family_length_priors, EvalSuite};
pub use verifier::{extract_answer, reward};
