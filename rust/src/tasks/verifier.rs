//! Rule-based binary reward — the `math-verify` analog.
//!
//! The paper: "+1 if the final boxed or numeric answer matches the ground
//! truth and 0 otherwise". Our responses carry the final answer after the
//! last `=` (chain-of-thought steps each end in `=value`), so extraction is:
//! take the text after the last `=` if any, else the whole response; strip
//! spaces; compare to the expected string, numerically where both parse.

/// Extract the model's final answer from decoded response text.
pub fn extract_answer(response: &str) -> &str {
    let tail = match response.rfind('=') {
        Some(i) => &response[i + 1..],
        None => response,
    };
    tail.trim()
}

/// Compare an extracted answer against ground truth.
///
/// Numeric comparison when both sides parse as integers (so `042` == `42`
/// *except* for the Format family, which demands the exact padded string —
/// callers pass `exact=true` for it, mirroring IFEval's format checks).
pub fn answer_matches(predicted: &str, expected: &str, exact: bool) -> bool {
    if exact {
        return predicted == expected;
    }
    match (predicted.parse::<i64>(), expected.parse::<i64>()) {
        (Ok(a), Ok(b)) => a == b,
        _ => predicted == expected,
    }
}

/// Binary reward for a decoded response.
pub fn reward(response: &str, expected: &str, exact: bool) -> f32 {
    if answer_matches(extract_answer(response), expected, exact) {
        1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_after_last_equals() {
        assert_eq!(extract_answer("3*4=12 2+12=14"), "14");
        assert_eq!(extract_answer("42"), "42");
        assert_eq!(extract_answer("x=1 y= 2 "), "2");
    }

    #[test]
    fn numeric_match_ignores_leading_zeros() {
        assert!(answer_matches("042", "42", false));
        assert!(!answer_matches("042", "42", true));
    }

    #[test]
    fn negative_numbers() {
        assert!(answer_matches("-8", "-8", false));
        assert!(!answer_matches("8", "-8", false));
    }

    #[test]
    fn reward_binary() {
        assert_eq!(reward("12 2+12=14", "14", false), 1.0);
        assert_eq!(reward("12 2+12=15", "14", false), 0.0);
        assert_eq!(reward("", "14", false), 0.0);
        assert_eq!(reward("junk", "14", false), 0.0);
    }

    #[test]
    fn format_family_requires_exact() {
        assert_eq!(reward("025", "025", true), 1.0);
        assert_eq!(reward("25", "025", true), 0.0);
    }
}
