//! Command-line parsing (clap substitute for the offline image).
//!
//! Grammar: `spec-rl <command> [--flag value]... [--switch]...`
//! plus `--set section.key=value` config overrides (repeatable).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::{ConfigDoc, RunConfig};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    /// `--set` overrides in config syntax.
    pub sets: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Cli> {
        let mut it = args.into_iter();
        let mut cli = Cli { command: it.next().unwrap_or_default(), ..Default::default() };
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name == "set" {
                    let Some(v) = it.next() else { bail!("--set needs key=value") };
                    cli.sets.push(v);
                    continue;
                }
                // peek: flag with value or bare switch
                match it.next() {
                    Some(v) if !v.starts_with("--") => {
                        cli.flags.insert(name.to_string(), v);
                    }
                    Some(v) => {
                        cli.switches.push(name.to_string());
                        // v was actually the next flag; re-process it
                        if let Some(n2) = v.strip_prefix("--") {
                            match it.next() {
                                Some(v2) if !v2.starts_with("--") => {
                                    cli.flags.insert(n2.to_string(), v2);
                                }
                                Some(v2) => {
                                    cli.switches.push(n2.to_string());
                                    if let Some(n3) = v2.strip_prefix("--") {
                                        cli.switches.push(n3.to_string());
                                    }
                                }
                                None => cli.switches.push(n2.to_string()),
                            }
                        }
                    }
                    None => cli.switches.push(name.to_string()),
                }
            } else {
                bail!("unexpected positional argument '{arg}'");
            }
        }
        Ok(cli)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Assemble the run config: defaults <- --config file <- --set overrides
    /// <- dedicated flags.
    pub fn run_config(&self) -> Result<RunConfig> {
        let mut doc = match self.flag("config") {
            Some(path) => ConfigDoc::parse(&std::fs::read_to_string(path)?)?,
            None => ConfigDoc::default(),
        };
        // --set run.steps=10 style overrides
        for s in &self.sets {
            doc.merge(ConfigDoc::parse(s)?);
        }
        // dedicated convenience flags
        let mut extra = String::new();
        if let Some(v) = self.flag("algo") {
            extra += &format!("run.algo = \"{v}\"\n");
        }
        if let Some(v) = self.flag("bundle") {
            extra += &format!("run.bundle = \"{v}\"\n");
        }
        if let Some(v) = self.flag("steps") {
            extra += &format!("run.steps = {v}\n");
        }
        if let Some(v) = self.flag("dataset") {
            extra += &format!("run.dataset = \"{v}\"\n");
        }
        if let Some(v) = self.flag("variant") {
            extra += &format!("spec.variant = \"{v}\"\n");
        }
        if let Some(v) = self.flag("lenience") {
            extra += &format!("spec.lenience = \"{v}\"\n");
        }
        if let Some(v) = self.flag("seed") {
            extra += &format!("run.seed = {v}\n");
        }
        if let Some(v) = self.flag("n-prompts") {
            extra += &format!("run.n_prompts = {v}\n");
        }
        if !extra.is_empty() {
            doc.merge(ConfigDoc::parse(&extra)?);
        }
        RunConfig::from_doc(&doc)
    }
}

pub const USAGE: &str = "spec-rl — speculative rollouts for RLVR (paper reproduction)

USAGE:
    spec-rl <command> [flags]

COMMANDS:
    info         print the artifact manifest summary
    sft          supervised pretraining -> base checkpoint
                   --bundle tiny_b32 --steps 300 --out out/base_tiny.npy
    train        RL training (GRPO/PPO/DAPO, with/without SPEC-RL)
                   --algo grpo --variant spec --lenience e0.5 --steps 45
                   --base out/base_tiny.npy [--config run.toml] [--set k=v]
    eval         evaluate a checkpoint on the benchmark battery
                   --base out/base_tiny.npy [--bundle tiny_b32] [--n 32]
    overlap      measure cross-epoch rollout overlap (Figure 2)
                   --base out/base_tiny.npy --steps 24
    case-study   show verified-prefix reuse on sample prompts (Figures 12-15)
                   --base out/base_tiny.npy

Flags common to RL commands: --bundle, --seed, --n-prompts, --dataset.
SPEC_RL_LOG=debug for verbose logs.";

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let c = cli("train --algo grpo --steps 10 --quiet");
        assert_eq!(c.command, "train");
        assert_eq!(c.flag("algo"), Some("grpo"));
        assert_eq!(c.usize_flag("steps", 0), 10);
        assert!(c.has("quiet"));
    }

    #[test]
    fn set_overrides_accumulate() {
        let c = cli("train --set run.steps=9 --set spec.lenience=\"e0.5\"");
        assert_eq!(c.sets.len(), 2);
        let rc = c.run_config().unwrap();
        assert_eq!(rc.steps, 9);
    }

    #[test]
    fn dedicated_flags_build_config() {
        let c = cli("train --algo dapo --variant off --steps 7");
        let rc = c.run_config().unwrap();
        assert_eq!(rc.algo.name(), "dapo");
        assert_eq!(rc.variant.name(), "off");
        assert_eq!(rc.steps, 7);
    }

    #[test]
    fn positional_rejected() {
        assert!(Cli::parse(vec!["train".into(), "oops".into()]).is_err());
    }
}
