//! The rollout cache: previous trajectories + their sampling log-probs.
//!
//! Keyed by sequence id (prompt index × group + sample slot). Each entry
//! keeps the latest rollout and the one before it (the Delayed-Reuse
//! ablation draws drafts from two steps back). "Log-probs" are the
//! current-policy log-probs recorded when the trajectory was produced —
//! exactly the `p_prev` of the acceptance rule next time the prompt
//! reappears.

use std::collections::HashMap;

use crate::rollout::SeqResult;

/// One cached trajectory.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub response: Vec<i32>,
    pub logps: Vec<f32>,
    /// Trainer step at which this rollout was produced.
    pub version: u64,
    /// Whether the trajectory terminated with EOS.
    pub finished: bool,
}

impl CacheEntry {
    pub fn from_result(r: &SeqResult, version: u64) -> Self {
        debug_assert_eq!(r.response.len(), r.logps.len());
        CacheEntry {
            response: r.response.clone(),
            logps: r.logps.clone(),
            version,
            finished: r.finished,
        }
    }
}

/// Latest + previous entry per sequence id.
#[derive(Default, Debug)]
pub struct RolloutCache {
    slots: HashMap<usize, (CacheEntry, Option<CacheEntry>)>,
}

impl RolloutCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Most recent cached rollout for `id`.
    pub fn latest(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).map(|(latest, _)| latest)
    }

    /// The rollout before the latest (Delayed-Reuse ablation).
    pub fn previous(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).and_then(|(_, prev)| prev.as_ref())
    }

    /// Insert a fresh rollout, demoting the current latest to `previous`.
    pub fn insert(&mut self, id: usize, entry: CacheEntry) {
        match self.slots.remove(&id) {
            Some((old_latest, _)) => {
                self.slots.insert(id, (entry, Some(old_latest)));
            }
            None => {
                self.slots.insert(id, (entry, None));
            }
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn clear(&mut self) {
        self.slots.clear();
    }

    /// Total cached tokens (memory telemetry).
    pub fn total_tokens(&self) -> usize {
        self.slots
            .values()
            .map(|(l, p)| l.response.len() + p.as_ref().map_or(0, |e| e.response.len()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: &[i32], version: u64) -> CacheEntry {
        CacheEntry {
            response: tokens.to_vec(),
            logps: vec![-1.0; tokens.len()],
            version,
            finished: true,
        }
    }

    #[test]
    fn insert_and_latest() {
        let mut c = RolloutCache::new();
        assert!(c.latest(0).is_none());
        c.insert(0, entry(&[1, 2], 0));
        assert_eq!(c.latest(0).unwrap().response, vec![1, 2]);
        assert!(c.previous(0).is_none());
    }

    #[test]
    fn insert_demotes_latest() {
        let mut c = RolloutCache::new();
        c.insert(7, entry(&[1], 0));
        c.insert(7, entry(&[2], 1));
        assert_eq!(c.latest(7).unwrap().response, vec![2]);
        assert_eq!(c.previous(7).unwrap().response, vec![1]);
        c.insert(7, entry(&[3], 2));
        assert_eq!(c.latest(7).unwrap().response, vec![3]);
        assert_eq!(c.previous(7).unwrap().response, vec![2]);
    }

    #[test]
    fn versions_track_steps() {
        let mut c = RolloutCache::new();
        c.insert(1, entry(&[1], 10));
        c.insert(1, entry(&[2], 11));
        assert_eq!(c.latest(1).unwrap().version, 11);
        assert_eq!(c.previous(1).unwrap().version, 10);
    }

    #[test]
    fn token_accounting() {
        let mut c = RolloutCache::new();
        c.insert(0, entry(&[1, 2, 3], 0));
        c.insert(0, entry(&[4, 5], 1));
        assert_eq!(c.total_tokens(), 5);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
    }
}
