//! The rollout cache: previous trajectories + their sampling log-probs,
//! stored as a **prefix trie** so shared spines are resident once.
//!
//! Keyed by sequence id (prompt index × group + sample slot). Each id
//! keeps the latest rollout and the one before it (the Delayed-Reuse
//! ablation draws drafts from two steps back). "Log-probs" are the
//! current-policy log-probs recorded when the trajectory was produced —
//! exactly the `p_prev` of the acceptance rule next time the prompt
//! reappears.
//!
//! # Trie layout (`ARCHITECTURE.md` §8)
//!
//! Trajectories of the same prompt key (`id / group`) live in one trie of
//! interned token **runs**: a node holds a maximal run of (token,
//! log-prob) pairs shared by every trajectory through it, with children
//! at the points where samples diverged. Two trajectories share a run
//! only when both the tokens *and* the log-prob bits agree — the cached
//! log-probs are the `p_prev` of the acceptance rule, so sharing anything
//! less than bitwise-equal pairs would change verification outcomes.
//! Each cached trajectory is a **leaf**: a pointer at the node where its
//! path ends (insertion splits runs so a path always ends at a node
//! boundary) plus its length/version/finished flags.
//!
//! - [`RolloutCache::latest`] / [`RolloutCache::previous`] materialize a
//!   leaf by the root-to-leaf walk — the draft a [`super::ReuseVariant`]
//!   hands to verification is byte-identical to what a flat cache would
//!   have stored.
//! - Refresh ([`RolloutCache::insert_batch`]) interns each finished
//!   trajectory, splitting runs at the first divergence from the cached
//!   spine.
//! - [`RolloutCache::total_tokens`] stays O(1) and counts each shared run
//!   **once** — the n trajectories of a GRPO/DAPO group and consecutive
//!   epochs' accepted prefixes no longer duplicate their common spine
//!   (`bench_cache` pins the footprint win vs [`FlatCache`]).
//!
//! Memory is bounded by an optional **token budget** over that
//! deduplicated total: when an insert pushes it over budget, leaves are
//! evicted oldest-version-first — `previous` leaves (only the Delayed
//! ablation reads them) before whole slots — and each evicted leaf frees
//! its *exclusive* subtree (runs still on a surviving path stay). The
//! eviction counters feed the per-step pipeline telemetry.

use std::collections::{HashMap, HashSet};

use crate::rollout::SeqResult;

/// One cached trajectory (materialized form).
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub response: Vec<i32>,
    pub logps: Vec<f32>,
    /// Trainer step at which this rollout was produced.
    pub version: u64,
    /// Whether the trajectory terminated with EOS.
    pub finished: bool,
}

impl CacheEntry {
    pub fn from_result(r: &SeqResult, version: u64) -> Self {
        debug_assert_eq!(r.response.len(), r.logps.len());
        CacheEntry {
            response: r.response.clone(),
            logps: r.logps.clone(),
            version,
            finished: r.finished,
        }
    }

    /// A synthetic draft built from a dead shard's accepted prefix
    /// (`ARCHITECTURE.md` §13). `version` is 0 and `finished` is false:
    /// the prefix is a mid-flight truncation, not a cached trajectory —
    /// it exists only to re-enter the verify lane, where the §6 uniform
    /// stream re-accepts every token on a surviving shard.
    pub fn requeue_draft(response: Vec<i32>, logps: Vec<f32>) -> Self {
        debug_assert_eq!(response.len(), logps.len());
        CacheEntry { response, logps, version: 0, finished: false }
    }
}

/// A cached trajectory's handle: where its root-to-leaf path ends, plus
/// the per-generation metadata that is not shared with other paths.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Leaf {
    /// Terminal trie node (`None` for an empty response).
    node: Option<usize>,
    /// Total response length (sum of the runs on the path).
    len: usize,
    version: u64,
    finished: bool,
}

/// One interned token run. `refs` counts the leaves terminating at or
/// below this node; `terminals` lists the ids terminating exactly here
/// (with multiplicity — an id's latest *and* previous generation can end
/// at the same node), so a split can re-point their leaves.
#[derive(Debug)]
struct Node {
    /// Prompt key (`id / group`) — identifies the root list this trie
    /// hangs from.
    key: usize,
    tokens: Vec<i32>,
    logps: Vec<f32>,
    parent: Option<usize>,
    children: Vec<usize>,
    terminals: Vec<usize>,
    refs: usize,
}

/// Prefix-trie rollout cache: latest + previous leaf per sequence id over
/// interned token runs, under an optional deduplicated-token budget.
#[derive(Debug)]
pub struct RolloutCache {
    /// Node arena; freed slots are recycled through `free`.
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// Top-level runs per prompt key (a forest: group samples may differ
    /// from the first token).
    roots: HashMap<usize, Vec<usize>>,
    slots: HashMap<usize, (Leaf, Option<Leaf>)>,
    /// Ids `[k * group, (k+1) * group)` share prompt key `k` (GRPO/DAPO
    /// id layout). 1 = every id its own trie (still dedups across epochs).
    group: usize,
    /// Max resident (deduplicated) tokens (None = unbounded).
    token_budget: Option<usize>,
    /// Incrementally-tracked resident tokens, each shared run counted
    /// once (never rescanned).
    tokens: usize,
    /// What a flat per-trajectory cache would hold: the sum of every
    /// leaf's length. `flat_tokens - tokens` = tokens saved by sharing.
    flat_tokens: usize,
    live_nodes: usize,
    evictions: u64,
    evicted_tokens: u64,
}

impl Default for RolloutCache {
    fn default() -> Self {
        RolloutCache {
            nodes: Vec::new(),
            free: Vec::new(),
            roots: HashMap::new(),
            slots: HashMap::new(),
            group: 1,
            token_budget: None,
            tokens: 0,
            flat_tokens: 0,
            live_nodes: 0,
            evictions: 0,
            evicted_tokens: 0,
        }
    }
}

impl RolloutCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache that evicts oldest-version leaves past `budget` resident
    /// (deduplicated) tokens.
    pub fn with_budget(budget: usize) -> Self {
        RolloutCache { token_budget: Some(budget), ..Self::default() }
    }

    /// (Re)set the token budget, enforcing it immediately.
    pub fn set_token_budget(&mut self, budget: Option<usize>) {
        self.token_budget = budget;
        self.enforce_budget();
    }

    /// Set the group size so ids `[k * group, (k+1) * group)` share one
    /// prompt trie. Must be called before any insert — re-keying resident
    /// tries is not supported.
    pub fn set_group(&mut self, group: usize) {
        assert!(group > 0, "group must be positive");
        assert!(
            self.slots.is_empty(),
            "group keying must be configured before the first insert"
        );
        self.group = group;
    }

    /// Builder form of [`RolloutCache::set_group`].
    pub fn with_group(mut self, group: usize) -> Self {
        self.set_group(group);
        self
    }

    /// Configured group size (ids per shared prompt trie).
    pub fn group(&self) -> usize {
        self.group
    }

    /// Most recent cached rollout for `id`, materialized by the
    /// root-to-leaf walk.
    pub fn latest(&self, id: usize) -> Option<CacheEntry> {
        self.slots.get(&id).map(|(latest, _)| self.materialize(latest))
    }

    /// Total response length of the latest cached rollout for `id` —
    /// read straight off the leaf in O(1), no root-to-leaf
    /// materialization. This is the length predictor's seed
    /// (`ARCHITECTURE.md` §14): the prior epoch's accepted length.
    pub fn cached_len(&self, id: usize) -> Option<usize> {
        self.slots.get(&id).map(|(latest, _)| latest.len)
    }

    /// The rollout before the latest (Delayed-Reuse ablation),
    /// materialized by the root-to-leaf walk.
    pub fn previous(&self, id: usize) -> Option<CacheEntry> {
        self.slots.get(&id).and_then(|(_, prev)| prev.as_ref()).map(|p| self.materialize(p))
    }

    /// Rebuild a dead shard's draft for `id` from the trie: the latest
    /// cached trajectory truncated to its `accepted` prefix, shaped as a
    /// requeue draft ([`CacheEntry::requeue_draft`], `ARCHITECTURE.md`
    /// §13). Equals the entry the pool harvests from the shard's own
    /// layout whenever the seated draft came from this cache — the
    /// trie-backed recovery path for callers that no longer hold the
    /// dead shard's host state. `None` if `id` was never cached.
    pub fn requeue_draft(&self, id: usize, accepted: usize) -> Option<CacheEntry> {
        self.latest(id).map(|mut e| {
            e.response.truncate(accepted);
            e.logps.truncate(accepted);
            CacheEntry::requeue_draft(e.response, e.logps)
        })
    }

    /// The longest surviving leaf under `id`'s prompt root, materialized —
    /// the **sibling-spine fallback draft** (`ARCHITECTURE.md` §8). When
    /// `id`'s own leaf was evicted (or the prompt is fresh this epoch),
    /// any leaf of the same prompt key is still a usable draft: its
    /// cached log-probs are the verifier's `p_prev`, and the §6 uniform
    /// stream that scores it is keyed by the *requesting* id, so the
    /// donor's identity never leaks into verification randomness.
    ///
    /// Selection is deterministic and shard-count-invariant: candidates
    /// are scanned in ascending id order over `[key*group, (key+1)*group)`
    /// (latest tier before previous per id — never HashMap order), and
    /// the winner maximizes `(len, version, tier)` with strict inequality
    /// so the first-seen candidate wins ties. Empty-response leaves never
    /// win. O(group) leaf-length reads plus one materialization.
    pub fn sibling_spine(&self, id: usize) -> Option<CacheEntry> {
        let key = id / self.group;
        let lo = key * self.group;
        // (len, version, tier): longest first, then freshest, then the
        // latest tier over the previous tier. tier 1 = latest, 0 = prev.
        let mut best: Option<(usize, u64, u8, Leaf)> = None;
        for sid in lo..lo + self.group {
            let Some((latest, prev)) = self.slots.get(&sid) else { continue };
            for (tier, leaf) in [(1u8, Some(latest)), (0u8, prev.as_ref())] {
                let Some(leaf) = leaf else { continue };
                if leaf.len == 0 {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some((l, v, t, _)) => (leaf.len, leaf.version, tier) > (*l, *v, *t),
                };
                if better {
                    best = Some((leaf.len, leaf.version, tier, *leaf));
                }
            }
        }
        best.map(|(_, _, _, leaf)| self.materialize(&leaf))
    }

    /// Depth (in tokens) of the shared spine under `id`'s prompt root:
    /// the run lengths accumulated from the root down the single-child
    /// chain, stopping at (and including) the first node with zero or
    /// several children. A forest root (group samples diverging from the
    /// first token) reports 0; a prompt with nothing cached reports
    /// `None`. This is the free per-prompt divergence signal
    /// [`super::draft::DraftControl::sibling_cap`] turns into a draft
    /// length before any acceptance feedback exists (`ARCHITECTURE.md`
    /// §8): deep shared spines earn long offers, early divergence clamps
    /// toward the floor. O(spine nodes), no materialization.
    pub fn branch_depth(&self, id: usize) -> Option<usize> {
        let key = id / self.group;
        let list = self.roots.get(&key)?;
        if list.len() != 1 {
            return Some(0);
        }
        let mut depth = 0usize;
        let mut cur = list[0];
        loop {
            let n = self.node(cur);
            depth += n.tokens.len();
            // A node with one child but terminating leaves still extends
            // the spine: every surviving path through it shares the run.
            if n.children.len() == 1 {
                cur = n.children[0];
            } else {
                return Some(depth);
            }
        }
    }

    /// Insert a fresh rollout, demoting the current latest to `previous`,
    /// then enforce the budget.
    pub fn insert(&mut self, id: usize, entry: CacheEntry) {
        self.insert_unenforced(id, entry);
        self.enforce_budget();
    }

    /// Insert a whole step's rollouts, enforcing the token budget once at
    /// the end — a binding budget would otherwise trigger a victim scan
    /// per insert. Same eviction policy (oldest (version, id) leaf
    /// first), so the surviving set matches per-insert enforcement for
    /// fresh-version batches.
    pub fn insert_batch(&mut self, entries: impl IntoIterator<Item = (usize, CacheEntry)>) {
        for (id, entry) in entries {
            self.insert_unenforced(id, entry);
        }
        self.enforce_budget();
    }

    fn insert_unenforced(&mut self, id: usize, entry: CacheEntry) {
        let key = id / self.group;
        let leaf =
            self.intern(key, id, &entry.response, &entry.logps, entry.version, entry.finished);
        let dropped = match self.slots.remove(&id) {
            Some((old_latest, old_prev)) => {
                self.slots.insert(id, (leaf, Some(old_latest)));
                old_prev
            }
            None => {
                self.slots.insert(id, (leaf, None));
                None
            }
        };
        // A displaced two-generations-old leaf is routine turnover, not a
        // budget eviction: it leaves the counters alone (same contract as
        // the flat cache's silent `previous` replacement).
        if let Some(p) = dropped {
            self.drop_leaf(id, p);
        }
    }

    /// Walk `resp` root-to-leaf through the `key` trie, splitting the run
    /// at the first divergence and interning the unshared tail, then
    /// register the new leaf at its terminal node. Sharing requires the
    /// tokens *and* the log-prob bits to agree.
    fn intern(
        &mut self,
        key: usize,
        id: usize,
        resp: &[i32],
        lps: &[f32],
        version: u64,
        finished: bool,
    ) -> Leaf {
        debug_assert_eq!(resp.len(), lps.len());
        self.flat_tokens += resp.len();
        if resp.is_empty() {
            return Leaf { node: None, len: 0, version, finished };
        }
        let mut pos = 0usize;
        let mut parent: Option<usize> = None;
        let terminal = loop {
            match self.matching_child(key, parent, resp[pos], lps[pos]) {
                None => {
                    // nothing cached continues this way: intern the whole
                    // remaining tail as one run
                    let tail =
                        self.alloc_node(key, parent, resp[pos..].to_vec(), lps[pos..].to_vec());
                    match parent {
                        Some(p) => self.node_mut(p).children.push(tail),
                        None => self.roots.entry(key).or_default().push(tail),
                    }
                    break tail;
                }
                Some(nid) => {
                    let shared = {
                        let n = self.node(nid);
                        let cap = n.tokens.len().min(resp.len() - pos);
                        let mut m = 0usize;
                        while m < cap
                            && n.tokens[m] == resp[pos + m]
                            && n.logps[m].to_bits() == lps[pos + m].to_bits()
                        {
                            m += 1;
                        }
                        m
                    };
                    debug_assert!(shared >= 1, "matching_child matched the first pair");
                    if shared < self.node(nid).tokens.len() {
                        self.split_node(nid, shared);
                    }
                    pos += shared;
                    if pos == resp.len() {
                        break nid;
                    }
                    parent = Some(nid);
                }
            }
        };
        self.add_leaf_at(terminal, id);
        Leaf { node: Some(terminal), len: resp.len(), version, finished }
    }

    /// The child of `parent` (or root run of `key`) whose run starts with
    /// exactly `(tok, lp)`. At most one exists: siblings always differ in
    /// their first (token, log-prob-bits) pair.
    fn matching_child(
        &self,
        key: usize,
        parent: Option<usize>,
        tok: i32,
        lp: f32,
    ) -> Option<usize> {
        let list: &[usize] = match parent {
            Some(p) => &self.node(p).children,
            None => match self.roots.get(&key) {
                Some(v) => v,
                None => return None,
            },
        };
        list.iter().copied().find(|&c| {
            let n = self.node(c);
            n.tokens[0] == tok && n.logps[0].to_bits() == lp.to_bits()
        })
    }

    /// Split the run of `nid` at offset `at`: the head keeps `nid`'s
    /// identity (so its parent's child list is untouched), the tail moves
    /// to a new child that inherits `nid`'s children and terminating
    /// leaves. The resident-token total is unchanged.
    fn split_node(&mut self, nid: usize, at: usize) {
        let (key, tail_tokens, tail_logps, moved_children, moved_terminals, refs) = {
            let n = self.node_mut(nid);
            debug_assert!(at >= 1 && at < n.tokens.len(), "split strictly inside the run");
            let tt = n.tokens.split_off(at);
            let tl = n.logps.split_off(at);
            (
                n.key,
                tt,
                tl,
                std::mem::take(&mut n.children),
                std::mem::take(&mut n.terminals),
                n.refs,
            )
        };
        // the head shrank by the tail's length and alloc_node re-adds it:
        // a split never changes the resident total
        self.tokens -= tail_tokens.len();
        let tail = self.alloc_node(key, Some(nid), tail_tokens, tail_logps);
        for &c in &moved_children {
            self.node_mut(c).parent = Some(tail);
        }
        // leaves that ended at the full (pre-split) run now end at the
        // tail — their handles move with the terminal list
        for &lid in &moved_terminals {
            if let Some((latest, prev)) = self.slots.get_mut(&lid) {
                if latest.node == Some(nid) {
                    latest.node = Some(tail);
                }
                if let Some(p) = prev {
                    if p.node == Some(nid) {
                        p.node = Some(tail);
                    }
                }
            }
        }
        {
            let t = self.node_mut(tail);
            t.children = moved_children;
            t.terminals = moved_terminals;
            t.refs = refs;
        }
        self.node_mut(nid).children.push(tail);
    }

    /// Register a leaf of `id` terminating at `terminal`: every node on
    /// the path to the root gains one reference.
    fn add_leaf_at(&mut self, terminal: usize, id: usize) {
        self.node_mut(terminal).terminals.push(id);
        let mut cur = Some(terminal);
        while let Some(nid) = cur {
            let n = self.node_mut(nid);
            n.refs += 1;
            cur = n.parent;
        }
    }

    /// Drop a leaf of `id`: walk terminal-to-root releasing one reference
    /// per node; nodes whose last reference goes (the leaf's *exclusive*
    /// subtree — by the refs invariant their children are already gone)
    /// are detached and freed. Returns the resident tokens freed, which
    /// is 0 when the whole path is still shared by surviving leaves.
    fn drop_leaf(&mut self, id: usize, leaf: Leaf) -> usize {
        self.flat_tokens -= leaf.len;
        let Some(terminal) = leaf.node else { return 0 };
        {
            let n = self.node_mut(terminal);
            let at = n
                .terminals
                .iter()
                .position(|&t| t == id)
                .expect("leaf recorded at its terminal node");
            n.terminals.swap_remove(at);
        }
        let mut freed = 0usize;
        let mut cur = Some(terminal);
        while let Some(nid) = cur {
            let parent = self.node(nid).parent;
            let now_dead = {
                let n = self.node_mut(nid);
                n.refs -= 1;
                n.refs == 0
            };
            if now_dead {
                match parent {
                    Some(p) => {
                        let ch = &mut self.node_mut(p).children;
                        let at = ch.iter().position(|&c| c == nid).expect("child linked");
                        ch.swap_remove(at);
                    }
                    None => {
                        let key = self.node(nid).key;
                        let list = self.roots.get_mut(&key).expect("root list present");
                        let at = list.iter().position(|&c| c == nid).expect("root linked");
                        list.swap_remove(at);
                        if list.is_empty() {
                            self.roots.remove(&key);
                        }
                    }
                }
                freed += self.free_node(nid);
            }
            cur = parent;
        }
        freed
    }

    fn evict_leaf(&mut self, id: usize, leaf: Leaf) {
        let freed = self.drop_leaf(id, leaf);
        self.evictions += 1;
        self.evicted_tokens += freed as u64;
    }

    /// Evict oldest-version leaves until the budget holds: `previous`
    /// leaves first (pure ablation fodder), then whole slots. One scan
    /// per tier (victims sorted by (version, id) for determinism).
    /// Evicting a leaf frees only its exclusive subtree, so a fully
    /// shared victim frees nothing and the loop moves to the next —
    /// termination is still guaranteed (an empty cache holds 0 tokens).
    fn enforce_budget(&mut self) {
        let Some(budget) = self.token_budget else { return };
        if self.tokens <= budget {
            return;
        }
        let mut prev_victims: Vec<(u64, usize)> = self
            .slots
            .iter()
            .filter_map(|(id, (_, p))| p.as_ref().map(|l| (l.version, *id)))
            .collect();
        prev_victims.sort_unstable();
        for (_, id) in prev_victims {
            if self.tokens <= budget {
                return;
            }
            let leaf = {
                let (_, prev) = self.slots.get_mut(&id).expect("victim vanished");
                prev.take().expect("victim had a previous")
            };
            self.evict_leaf(id, leaf);
        }
        let mut latest_victims: Vec<(u64, usize)> =
            self.slots.iter().map(|(id, (l, _))| (l.version, *id)).collect();
        latest_victims.sort_unstable();
        for (_, id) in latest_victims {
            if self.tokens <= budget {
                return;
            }
            let (leaf, prev) = self.slots.remove(&id).expect("victim vanished");
            debug_assert!(prev.is_none(), "previous tier drained first");
            self.evict_leaf(id, leaf);
        }
    }

    /// Rebuild a leaf's trajectory by the root-to-leaf walk — the
    /// "longest cached continuation" the variants hand to verification.
    fn materialize(&self, leaf: &Leaf) -> CacheEntry {
        let mut chain = Vec::new();
        let mut cur = leaf.node;
        while let Some(nid) = cur {
            chain.push(nid);
            cur = self.node(nid).parent;
        }
        let mut response = Vec::with_capacity(leaf.len);
        let mut logps = Vec::with_capacity(leaf.len);
        for &nid in chain.iter().rev() {
            let n = self.node(nid);
            response.extend_from_slice(&n.tokens);
            logps.extend_from_slice(&n.logps);
        }
        debug_assert_eq!(response.len(), leaf.len);
        CacheEntry { response, logps, version: leaf.version, finished: leaf.finished }
    }

    fn alloc_node(
        &mut self,
        key: usize,
        parent: Option<usize>,
        tokens: Vec<i32>,
        logps: Vec<f32>,
    ) -> usize {
        debug_assert!(!tokens.is_empty(), "runs are never empty");
        debug_assert_eq!(tokens.len(), logps.len());
        self.tokens += tokens.len();
        self.live_nodes += 1;
        let node = Node {
            key,
            tokens,
            logps,
            parent,
            children: Vec::new(),
            terminals: Vec::new(),
            refs: 0,
        };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Some(node);
                i
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    /// Free a dead node (refs == 0; children already detached themselves)
    /// and return its run length.
    fn free_node(&mut self, nid: usize) -> usize {
        let n = self.nodes[nid].take().expect("double free");
        debug_assert!(n.children.is_empty(), "dead node with live children");
        debug_assert!(n.terminals.is_empty(), "dead node with terminating leaves");
        self.tokens -= n.tokens.len();
        self.live_nodes -= 1;
        self.free.push(nid);
        n.tokens.len()
    }

    fn node(&self, nid: usize) -> &Node {
        self.nodes[nid].as_ref().expect("dangling node id")
    }

    fn node_mut(&mut self, nid: usize) -> &mut Node {
        self.nodes[nid].as_mut().expect("dangling node id")
    }

    /// Cumulative (leaves evicted, resident tokens freed by eviction)
    /// since construction; the pipeline driver diffs this across a step
    /// for telemetry. A fully shared victim frees 0 tokens but still
    /// counts as one eviction.
    pub fn eviction_stats(&self) -> (u64, u64) {
        (self.evictions, self.evicted_tokens)
    }

    /// Number of ids with at least one cached generation.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn clear(&mut self) {
        self.nodes.clear();
        self.free.clear();
        self.roots.clear();
        self.slots.clear();
        self.tokens = 0;
        self.flat_tokens = 0;
        self.live_nodes = 0;
    }

    /// Resident cached tokens, each shared run counted **once** (the
    /// memory the trie actually holds; what `spec.cache_budget` bounds).
    /// O(1): tracked on every insert/split/eviction, never recomputed by
    /// scanning.
    pub fn total_tokens(&self) -> usize {
        self.tokens
    }

    /// What a flat per-trajectory cache would hold for the same contents
    /// (every leaf's length summed). O(1).
    pub fn flat_tokens(&self) -> usize {
        self.flat_tokens
    }

    /// Tokens saved by prefix sharing: [`RolloutCache::flat_tokens`]
    /// minus [`RolloutCache::total_tokens`]. O(1).
    pub fn shared_tokens(&self) -> usize {
        debug_assert!(self.flat_tokens >= self.tokens);
        self.flat_tokens.saturating_sub(self.tokens)
    }

    /// Live interned runs (trie nodes). O(1).
    pub fn cache_nodes(&self) -> usize {
        self.live_nodes
    }

    /// Full structural audit, used by the invariant tests: arena/counter
    /// agreement, parent/child linkage, sibling divergence, the refs
    /// invariant, leaf/terminal agreement, no orphaned or unreachable
    /// nodes, and both token counters against a fresh scan.
    pub fn check_invariants(&self) -> Result<(), String> {
        let live: Vec<usize> =
            (0..self.nodes.len()).filter(|&i| self.nodes[i].is_some()).collect();
        if live.len() != self.live_nodes {
            return Err(format!("live_nodes {} != arena scan {}", self.live_nodes, live.len()));
        }
        if self.free.len() + live.len() != self.nodes.len() {
            return Err(format!(
                "free list {} + live {} != arena {}",
                self.free.len(),
                live.len(),
                self.nodes.len()
            ));
        }
        if self.free.iter().any(|&f| self.nodes[f].is_some()) {
            return Err("free list points at a live node".into());
        }
        let mut token_scan = 0usize;
        for &nid in &live {
            let n = self.node(nid);
            if n.tokens.is_empty() {
                return Err(format!("node {nid} holds an empty run"));
            }
            if n.tokens.len() != n.logps.len() {
                return Err(format!("node {nid} token/logp length mismatch"));
            }
            token_scan += n.tokens.len();
            match n.parent {
                Some(p) => {
                    let Some(pn) = self.nodes.get(p).and_then(|o| o.as_ref()) else {
                        return Err(format!("node {nid} has a dead parent {p}"));
                    };
                    if !pn.children.contains(&nid) {
                        return Err(format!("node {nid} missing from parent {p}'s children"));
                    }
                    if pn.key != n.key {
                        return Err(format!("node {nid} crosses prompt keys via parent {p}"));
                    }
                }
                None => {
                    if !self.roots.get(&n.key).is_some_and(|l| l.contains(&nid)) {
                        return Err(format!("top-level node {nid} missing from roots"));
                    }
                }
            }
            let mut firsts = HashSet::new();
            let mut child_refs = 0usize;
            for &c in &n.children {
                let Some(cn) = self.nodes.get(c).and_then(|o| o.as_ref()) else {
                    return Err(format!("node {nid} links dead child {c}"));
                };
                if cn.parent != Some(nid) {
                    return Err(format!("child {c} does not point back at {nid}"));
                }
                if !firsts.insert((cn.tokens[0], cn.logps[0].to_bits())) {
                    return Err(format!("node {nid} has duplicate branch pairs"));
                }
                child_refs += cn.refs;
            }
            if n.refs != n.terminals.len() + child_refs {
                return Err(format!(
                    "node {nid} refs {} != terminals {} + child refs {child_refs}",
                    n.refs,
                    n.terminals.len()
                ));
            }
        }
        if token_scan != self.tokens {
            return Err(format!("resident tokens {} != scan {token_scan}", self.tokens));
        }
        // reachability: everything hangs off a root exactly once
        let mut seen = HashSet::new();
        let mut stack: Vec<usize> = self.roots.values().flatten().copied().collect();
        while let Some(nid) = stack.pop() {
            if !seen.insert(nid) {
                return Err(format!("node {nid} reached twice (cycle or double link)"));
            }
            stack.extend(self.node(nid).children.iter().copied());
        }
        if seen.len() != self.live_nodes {
            return Err(format!(
                "{} of {} live nodes unreachable from roots (orphans)",
                self.live_nodes - seen.len(),
                self.live_nodes
            ));
        }
        // leaves: lengths, terminal registration, flat counter
        let mut flat_scan = 0usize;
        let mut leaf_terms: HashMap<(usize, usize), usize> = HashMap::new();
        for (&id, (latest, prev)) in &self.slots {
            for leaf in std::iter::once(latest).chain(prev.iter()) {
                flat_scan += leaf.len;
                match leaf.node {
                    None => {
                        if leaf.len != 0 {
                            return Err(format!("id {id}: nodeless leaf with {} tokens", leaf.len));
                        }
                    }
                    Some(t) => {
                        let mut sum = 0usize;
                        let mut cur = Some(t);
                        while let Some(nid) = cur {
                            let n = self.node(nid);
                            sum += n.tokens.len();
                            cur = n.parent;
                        }
                        if sum != leaf.len {
                            return Err(format!(
                                "id {id}: leaf length {} != path length {sum}",
                                leaf.len
                            ));
                        }
                        *leaf_terms.entry((t, id)).or_default() += 1;
                    }
                }
            }
        }
        if flat_scan != self.flat_tokens {
            return Err(format!("flat tokens {} != scan {flat_scan}", self.flat_tokens));
        }
        let mut listed_terms: HashMap<(usize, usize), usize> = HashMap::new();
        for &nid in &live {
            for &id in &self.node(nid).terminals {
                *listed_terms.entry((nid, id)).or_default() += 1;
            }
        }
        if leaf_terms != listed_terms {
            return Err(format!(
                "terminal lists disagree with leaves: listed {listed_terms:?} vs leaves {leaf_terms:?}"
            ));
        }
        if let Some(b) = self.token_budget {
            if self.tokens > b {
                return Err(format!("budget violated: {} > {b}", self.tokens));
            }
        }
        Ok(())
    }
}

/// The pre-trie flat cache — one owned `CacheEntry` per generation per
/// id, no sharing. Kept as the `bench_cache` baseline: identical insert
/// streams into [`FlatCache`] and [`RolloutCache`] pin the trie's
/// resident-token win and the byte-identity of materialized drafts.
#[derive(Default, Debug)]
pub struct FlatCache {
    slots: HashMap<usize, (CacheEntry, Option<CacheEntry>)>,
    /// Max total cached tokens (None = unbounded).
    token_budget: Option<usize>,
    /// Incrementally-tracked total (never rescanned).
    tokens: usize,
    evictions: u64,
    evicted_tokens: u64,
}

impl FlatCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache that evicts oldest-version entries past `budget` tokens.
    pub fn with_budget(budget: usize) -> Self {
        FlatCache { token_budget: Some(budget), ..Self::default() }
    }

    /// (Re)set the token budget, enforcing it immediately.
    pub fn set_token_budget(&mut self, budget: Option<usize>) {
        self.token_budget = budget;
        self.enforce_budget();
    }

    /// Most recent cached rollout for `id`.
    pub fn latest(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).map(|(latest, _)| latest)
    }

    /// The rollout before the latest.
    pub fn previous(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).and_then(|(_, prev)| prev.as_ref())
    }

    /// Insert a fresh rollout, demoting the current latest to `previous`,
    /// then enforce the budget.
    pub fn insert(&mut self, id: usize, entry: CacheEntry) {
        self.insert_unenforced(id, entry);
        self.enforce_budget();
    }

    /// Insert a whole step's rollouts, enforcing the budget once at the
    /// end (same policy as [`RolloutCache::insert_batch`]).
    pub fn insert_batch(&mut self, entries: impl IntoIterator<Item = (usize, CacheEntry)>) {
        for (id, entry) in entries {
            self.insert_unenforced(id, entry);
        }
        self.enforce_budget();
    }

    fn insert_unenforced(&mut self, id: usize, entry: CacheEntry) {
        use std::collections::hash_map::Entry;
        let added = entry.response.len();
        let mut dropped = 0usize;
        match self.slots.entry(id) {
            Entry::Occupied(mut o) => {
                let (latest, prev) = o.get_mut();
                if let Some(old_prev) = prev.take() {
                    dropped = old_prev.response.len();
                }
                *prev = Some(std::mem::replace(latest, entry));
            }
            Entry::Vacant(v) => {
                v.insert((entry, None));
            }
        }
        self.tokens = self.tokens + added - dropped;
    }

    /// Evict oldest-version material until the budget holds: `previous`
    /// entries first, then whole slots, ordered by (version, id).
    fn enforce_budget(&mut self) {
        let Some(budget) = self.token_budget else { return };
        if self.tokens <= budget {
            return;
        }
        let mut prev_victims: Vec<(u64, usize)> = self
            .slots
            .iter()
            .filter_map(|(id, (_, p))| p.as_ref().map(|e| (e.version, *id)))
            .collect();
        prev_victims.sort_unstable();
        for (_, id) in prev_victims {
            if self.tokens <= budget {
                return;
            }
            let (_, prev) = self.slots.get_mut(&id).expect("victim vanished");
            let e = prev.take().expect("victim had a previous");
            self.note_eviction(e.response.len());
        }
        let mut latest_victims: Vec<(u64, usize)> =
            self.slots.iter().map(|(id, (l, _))| (l.version, *id)).collect();
        latest_victims.sort_unstable();
        for (_, id) in latest_victims {
            if self.tokens <= budget {
                return;
            }
            let (latest, _) = self.slots.remove(&id).expect("victim vanished");
            self.note_eviction(latest.response.len());
        }
    }

    fn note_eviction(&mut self, freed: usize) {
        self.tokens -= freed;
        self.evictions += 1;
        self.evicted_tokens += freed as u64;
    }

    /// Cumulative (entries evicted, tokens evicted) since construction.
    pub fn eviction_stats(&self) -> (u64, u64) {
        (self.evictions, self.evicted_tokens)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn clear(&mut self) {
        self.slots.clear();
        self.tokens = 0;
    }

    /// Total cached tokens — every trajectory counted in full (the
    /// duplication [`RolloutCache`] removes). O(1).
    pub fn total_tokens(&self) -> usize {
        self.tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: &[i32], version: u64) -> CacheEntry {
        CacheEntry {
            response: tokens.to_vec(),
            logps: vec![-1.0; tokens.len()],
            version,
            finished: true,
        }
    }

    /// Entry with per-position logps (sharing requires bitwise-equal
    /// pairs, so tests that pin divergence-by-logp need control here).
    fn entry_lp(tokens: &[i32], logps: &[f32], version: u64) -> CacheEntry {
        assert_eq!(tokens.len(), logps.len());
        CacheEntry { response: tokens.to_vec(), logps: logps.to_vec(), version, finished: true }
    }

    fn assert_entry(c: &RolloutCache, id: usize, tokens: &[i32]) {
        let e = c.latest(id).expect("entry present");
        assert_eq!(e.response, tokens, "id {id}");
        assert_eq!(e.logps.len(), e.response.len(), "id {id}");
        c.check_invariants().unwrap();
    }

    #[test]
    fn insert_and_latest_roundtrip() {
        let mut c = RolloutCache::new();
        assert!(c.latest(0).is_none());
        c.insert(0, entry(&[1, 2], 0));
        assert_entry(&c, 0, &[1, 2]);
        assert!(c.previous(0).is_none());
        assert_eq!(c.total_tokens(), 2);
        assert_eq!(c.flat_tokens(), 2);
        assert_eq!(c.shared_tokens(), 0);
    }

    #[test]
    fn insert_demotes_latest() {
        let mut c = RolloutCache::new();
        c.insert(7, entry(&[1], 0));
        c.insert(7, entry(&[2], 1));
        assert_eq!(c.latest(7).unwrap().response, vec![2]);
        assert_eq!(c.previous(7).unwrap().response, vec![1]);
        c.insert(7, entry(&[3], 2));
        assert_eq!(c.latest(7).unwrap().response, vec![3]);
        assert_eq!(c.previous(7).unwrap().response, vec![2]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn versions_track_steps() {
        let mut c = RolloutCache::new();
        c.insert(1, entry(&[1], 10));
        c.insert(1, entry(&[2], 11));
        assert_eq!(c.latest(1).unwrap().version, 11);
        assert_eq!(c.previous(1).unwrap().version, 10);
    }

    #[test]
    fn requeue_draft_truncates_latest_from_the_trie() {
        let mut c = RolloutCache::new();
        c.insert(3, entry_lp(&[5, 6, 7, 8], &[-0.1, -0.2, -0.3, -0.4], 9));
        let d = c.requeue_draft(3, 2).expect("cached id");
        assert_eq!(d.response, vec![5, 6]);
        assert_eq!(d.logps, vec![-0.1, -0.2]);
        // Shaped as CacheEntry::requeue_draft: synthetic version, unfinished.
        assert_eq!(d.version, 0);
        assert!(!d.finished);
        // Matches the direct constructor over the harvested prefix.
        let direct = CacheEntry::requeue_draft(vec![5, 6], vec![-0.1, -0.2]);
        assert_eq!(d.response, direct.response);
        assert_eq!(d.logps, direct.logps);
        // Truncation past the cached length keeps the whole trajectory;
        // an id never cached yields None.
        assert_eq!(c.requeue_draft(3, 10).unwrap().response, vec![5, 6, 7, 8]);
        assert!(c.requeue_draft(99, 1).is_none());
    }

    #[test]
    fn group_samples_share_their_spine_once() {
        // 4 samples of one prompt share a 4-token spine, then diverge:
        // resident = 4 (spine) + 4 * 2 (tails); flat would hold 4 * 6.
        let mut c = RolloutCache::new().with_group(4);
        for k in 0..4usize {
            c.insert(k, entry(&[5, 6, 7, 8, 10 + k as i32, 20 + k as i32], 0));
        }
        assert_eq!(c.total_tokens(), 4 + 4 * 2);
        assert_eq!(c.flat_tokens(), 4 * 6);
        assert_eq!(c.shared_tokens(), 4 * 6 - (4 + 4 * 2));
        // spine + 4 tails = 5 nodes
        assert_eq!(c.cache_nodes(), 5);
        for k in 0..4usize {
            assert_entry(&c, k, &[5, 6, 7, 8, 10 + k as i32, 20 + k as i32]);
        }
    }

    #[test]
    fn cross_epoch_extension_shares_the_accepted_prefix() {
        // epoch 1 fully accepts epoch 0's rollout and extends it: the
        // previous generation is an interior termination, resident count
        // holds the union once.
        let mut c = RolloutCache::new();
        c.insert(3, entry(&[1, 2, 3, 4], 0));
        c.insert(3, entry(&[1, 2, 3, 4, 5, 6], 1));
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.flat_tokens(), 10);
        assert_eq!(c.shared_tokens(), 4);
        assert_eq!(c.latest(3).unwrap().response, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(c.previous(3).unwrap().response, vec![1, 2, 3, 4]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn mid_run_split_repoints_existing_leaves() {
        let mut c = RolloutCache::new().with_group(2);
        c.insert(0, entry(&[1, 2, 3, 4], 0));
        // id 1 diverges inside id 0's run: the run splits at offset 2 and
        // id 0's leaf must follow the tail
        c.insert(1, entry(&[1, 2, 9], 0));
        assert_eq!(c.total_tokens(), 2 + 2 + 1);
        assert_eq!(c.cache_nodes(), 3);
        assert_entry(&c, 0, &[1, 2, 3, 4]);
        assert_entry(&c, 1, &[1, 2, 9]);
    }

    #[test]
    fn identical_tokens_different_logps_never_share() {
        // log-probs are the acceptance rule's p_prev: bitwise inequality
        // must force separate runs even for identical token content.
        let mut c = RolloutCache::new().with_group(2);
        c.insert(0, entry_lp(&[4, 5, 6], &[-1.0, -1.0, -1.0], 0));
        c.insert(1, entry_lp(&[4, 5, 6], &[-2.0, -2.0, -2.0], 0));
        assert_eq!(c.total_tokens(), 6, "no sharing across logp-divergent paths");
        let a = c.latest(0).unwrap();
        let b = c.latest(1).unwrap();
        assert_eq!(a.response, b.response);
        assert_ne!(a.logps, b.logps);
        c.check_invariants().unwrap();
    }

    #[test]
    fn group_keying_isolates_prompts() {
        // same content under different prompt keys stays separate
        let mut c = RolloutCache::new().with_group(2);
        c.insert(0, entry(&[7, 8, 9], 0)); // key 0
        c.insert(2, entry(&[7, 8, 9], 0)); // key 1
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.shared_tokens(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn empty_responses_are_cached_without_nodes() {
        let mut c = RolloutCache::new();
        c.insert(5, entry(&[], 0));
        let e = c.latest(5).unwrap();
        assert!(e.response.is_empty());
        assert_eq!(c.total_tokens(), 0);
        assert_eq!(c.cache_nodes(), 0);
        c.insert(5, entry(&[1], 1));
        assert_eq!(c.latest(5).unwrap().response, vec![1]);
        assert!(c.previous(5).unwrap().response.is_empty());
        c.check_invariants().unwrap();
    }

    #[test]
    fn incremental_tokens_match_scan_under_churn() {
        let mut c = RolloutCache::new().with_group(2);
        for step in 0..6u64 {
            for id in 0..4usize {
                let len = 1 + (id + step as usize) % 5;
                let toks: Vec<i32> = (0..len as i32).map(|j| 3 + j + (id as i32 % 2)).collect();
                c.insert(id, entry(&toks, step));
            }
            c.check_invariants().unwrap();
        }
    }

    #[test]
    fn budget_evicts_previous_leaves_first() {
        // disjoint contents: the trie degenerates to flat accounting, so
        // the flat cache's eviction arithmetic carries over exactly
        let mut c = RolloutCache::with_budget(6);
        c.insert(0, entry(&[1, 1, 1], 0));
        c.insert(1, entry(&[2, 2, 2], 0));
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.eviction_stats(), (0, 0));
        c.insert(0, entry(&[4, 4, 4], 1));
        assert_eq!(c.total_tokens(), 6);
        assert!(c.previous(0).is_none(), "previous evicted");
        assert_eq!(c.latest(0).unwrap().response, vec![4, 4, 4], "fresh latest kept");
        assert_eq!(c.latest(1).unwrap().response, vec![2, 2, 2], "neighbour kept");
        assert_eq!(c.eviction_stats(), (1, 3));
        c.check_invariants().unwrap();
    }

    #[test]
    fn budget_evicts_oldest_slots_when_no_previous_left() {
        let mut c = RolloutCache::with_budget(4);
        c.insert(0, entry(&[1, 1], 0));
        c.insert(1, entry(&[2, 2], 1));
        c.insert(2, entry(&[3, 3], 2)); // 6 tokens > 4: id 0 (oldest) goes
        assert!(c.latest(0).is_none());
        assert!(c.latest(1).is_some());
        assert!(c.latest(2).is_some());
        assert_eq!(c.total_tokens(), 4);
        assert_eq!(c.eviction_stats(), (1, 2));
        c.check_invariants().unwrap();
    }

    #[test]
    fn fully_shared_victims_free_nothing_but_still_count() {
        // previous == latest (full cross-epoch reuse): evicting the
        // previous leaf frees no resident tokens — the budget then falls
        // back to whole-slot eviction, and no node is ever orphaned.
        let mut c = RolloutCache::new();
        c.insert(0, entry(&[1, 2, 3, 4], 0));
        c.insert(0, entry(&[1, 2, 3, 4], 1));
        assert_eq!(c.total_tokens(), 4);
        assert_eq!(c.flat_tokens(), 8);
        c.set_token_budget(Some(3));
        // previous evicted (freeing 0), then the latest slot (freeing 4)
        assert_eq!(c.eviction_stats(), (2, 4));
        assert_eq!(c.total_tokens(), 0);
        assert!(c.latest(0).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn subtree_eviction_keeps_shared_spine_for_survivors() {
        // 3 group samples share a spine; evicting one sample must free
        // only its private tail
        let mut c = RolloutCache::new().with_group(4);
        c.insert(0, entry(&[5, 6, 10, 11], 0));
        c.insert(1, entry(&[5, 6, 20, 21], 1));
        c.insert(2, entry(&[5, 6, 30, 31], 2));
        assert_eq!(c.total_tokens(), 2 + 3 * 2);
        // budget 6 forces out the oldest leaf (id 0, version 0): its
        // private tail [10, 11] frees, the spine [5, 6] survives
        c.set_token_budget(Some(6));
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.eviction_stats(), (1, 2));
        assert!(c.latest(0).is_none());
        assert_entry(&c, 1, &[5, 6, 20, 21]);
        assert_entry(&c, 2, &[5, 6, 30, 31]);
    }

    #[test]
    fn set_budget_enforces_immediately() {
        let mut c = RolloutCache::new();
        for id in 0..5 {
            c.insert(id, entry(&[7; 4], id as u64));
        }
        assert_eq!(c.total_tokens(), 20);
        c.set_token_budget(Some(8));
        assert_eq!(c.total_tokens(), 8);
        assert_eq!(c.len(), 2);
        // the newest versions survive
        assert!(c.latest(3).is_some() && c.latest(4).is_some());
        c.check_invariants().unwrap();
        c.set_token_budget(None);
        c.insert(9, entry(&[1; 50], 9));
        assert_eq!(c.total_tokens(), 58, "unbounded again");
    }

    #[test]
    fn insert_batch_enforces_once_at_end() {
        let mut c = RolloutCache::with_budget(6);
        c.insert_batch((0..5).map(|id| (id, entry(&[7; 3], 1))));
        assert!(c.total_tokens() <= 6);
        // same-version ties evict ascending id: the highest ids survive
        assert!(c.latest(3).is_some() && c.latest(4).is_some());
        assert!(c.latest(0).is_none());
        assert_eq!(c.eviction_stats(), (3, 9));
        c.check_invariants().unwrap();
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let mut c = RolloutCache::new();
        for step in 0..20u64 {
            c.insert(0, entry(&[5; 40], step));
        }
        assert_eq!(c.eviction_stats(), (0, 0));
        // every generation is identical: latest + previous share one run
        assert_eq!(c.total_tokens(), 40);
        assert_eq!(c.flat_tokens(), 80);
        c.check_invariants().unwrap();
    }

    #[test]
    fn clear_resets_everything_but_eviction_counters() {
        let mut c = RolloutCache::with_budget(4);
        for id in 0..4 {
            c.insert(id, entry(&[2, 2], id as u64));
        }
        let stats = c.eviction_stats();
        assert!(stats.0 > 0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.total_tokens(), 0);
        assert_eq!(c.flat_tokens(), 0);
        assert_eq!(c.cache_nodes(), 0);
        assert_eq!(c.eviction_stats(), stats, "counters are cumulative");
        c.check_invariants().unwrap();
        c.insert(0, entry(&[1], 9));
        assert_entry(&c, 0, &[1]);
    }

    #[test]
    fn grouped_churn_matches_flat_materialization_and_never_orphans() {
        // Deterministic grouped churn with divergence at varying depths:
        // every generation materialized from the trie must equal what the
        // flat cache stored, and the structural audit must pass after
        // every insert (insert/split/walk round-trip + no orphans).
        let group = 4usize;
        let mut trie = RolloutCache::new().with_group(group);
        let mut flat = FlatCache::new();
        for step in 0..6u64 {
            for pi in 0..3usize {
                for k in 0..group {
                    let id = pi * group + k;
                    // shared spine per (prompt, step with overlap): the
                    // first tokens depend only on pi, the divergence point
                    // on k, the tail on (k, step)
                    let spine = 2 + (pi + step as usize) % 3;
                    let tail = 1 + (k + step as usize) % 4;
                    let mut toks: Vec<i32> =
                        (0..spine as i32).map(|j| 10 + pi as i32 + j).collect();
                    toks.extend((0..tail as i32).map(|j| 40 + k as i32 * 4 + j + step as i32 % 2));
                    let e = entry(&toks, step);
                    trie.insert(id, e.clone());
                    flat.insert(id, e);
                    trie.check_invariants().unwrap();
                }
            }
            for id in 0..3 * group {
                match (trie.latest(id), flat.latest(id)) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.response, b.response, "id {id} step {step}");
                        assert_eq!(a.logps, b.logps, "id {id} step {step}");
                        assert_eq!((a.version, a.finished), (b.version, b.finished));
                    }
                    (a, b) => panic!("presence diverged: {a:?} vs {b:?}"),
                }
                match (trie.previous(id), flat.previous(id)) {
                    (Some(a), Some(b)) => assert_eq!(a.response, b.response, "prev id {id}"),
                    (None, None) => {}
                    (a, b) => panic!("prev presence diverged: {a:?} vs {b:?}"),
                }
            }
            assert_eq!(trie.flat_tokens(), flat.total_tokens(), "step {step}");
            assert!(trie.total_tokens() < flat.total_tokens(), "sharing must engage");
        }
    }

    // ---- sibling spines and branch depths --------------------------------

    #[test]
    fn sibling_spine_returns_longest_surviving_group_leaf() {
        let mut c = RolloutCache::new().with_group(4);
        c.insert(0, entry(&[5, 6, 7, 8, 1], 0));
        c.insert(1, entry(&[5, 6, 9], 0));
        // id 2 never rolled out: the fallback is id 0's longer leaf, and
        // the materialization is byte-identical to the donor's own draft.
        let sib = c.sibling_spine(2).expect("group has survivors");
        let donor = c.latest(0).unwrap();
        assert_eq!(sib.response, donor.response);
        assert_eq!(sib.logps, donor.logps);
        assert_eq!((sib.version, sib.finished), (donor.version, donor.finished));
        // a prompt key with nothing cached has no fallback
        assert!(c.sibling_spine(4).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn sibling_spine_breaks_ties_by_version_then_tier_then_id() {
        let mut c = RolloutCache::new().with_group(4);
        // equal lengths: the fresher version wins
        c.insert(0, entry(&[1, 2, 3], 0));
        c.insert(1, entry(&[4, 5, 6], 1));
        assert_eq!(c.sibling_spine(3).unwrap().response, vec![4, 5, 6]);
        // equal length and version across tiers: latest beats previous
        let mut c = RolloutCache::new().with_group(4);
        c.insert(1, entry(&[9, 9, 9], 5)); // becomes id 1's previous
        c.insert(1, entry(&[9], 6));
        c.insert(0, entry(&[1, 2, 3], 5)); // id 0's latest, same (len, version)
        assert_eq!(c.sibling_spine(2).unwrap().response, vec![1, 2, 3]);
        // full tie (len, version, tier): the lowest id wins — scan order
        // is ascending ids, never HashMap order
        let mut c = RolloutCache::new().with_group(4);
        c.insert(2, entry(&[7, 7], 3));
        c.insert(1, entry(&[8, 8], 3));
        assert_eq!(c.sibling_spine(0).unwrap().response, vec![8, 8]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn sibling_spine_never_resurrects_evicted_leaves() {
        let mut c = RolloutCache::new().with_group(4);
        c.insert(0, entry(&[5, 6, 10, 11, 12], 0));
        c.insert(1, entry(&[5, 6, 20, 21], 1));
        // budget evicts the oldest leaf (id 0): the longest candidate is
        // gone and the fallback must come from what actually survived
        c.set_token_budget(Some(6));
        assert!(c.latest(0).is_none(), "id 0 evicted");
        let sib = c.sibling_spine(0).expect("id 1 survives");
        assert_eq!(sib.response, vec![5, 6, 20, 21]);
        assert_eq!(sib.response, c.latest(1).unwrap().response);
        // evicting the whole group leaves nothing to fall back on
        c.set_token_budget(Some(0));
        assert!(c.sibling_spine(0).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn sibling_spine_skips_empty_leaves() {
        let mut c = RolloutCache::new().with_group(2);
        c.insert(0, entry(&[], 0));
        assert!(c.sibling_spine(1).is_none(), "empty leaves are not drafts");
        c.insert(1, entry(&[3, 4], 0));
        assert_eq!(c.sibling_spine(0).unwrap().response, vec![3, 4]);
        c.check_invariants().unwrap();
    }

    #[test]
    fn branch_depth_tracks_shared_spine() {
        let mut c = RolloutCache::new().with_group(4);
        assert!(c.branch_depth(0).is_none(), "nothing cached");
        // one trajectory: the whole path is spine
        c.insert(0, entry(&[5, 6, 7, 8], 0));
        assert_eq!(c.branch_depth(1), Some(4));
        // divergence at offset 2 splits the root: spine shrinks to 2
        c.insert(1, entry(&[5, 6, 9, 9], 0));
        assert_eq!(c.branch_depth(0), Some(2));
        // a first-token divergence makes the root a forest: depth 0
        c.insert(2, entry(&[3, 3], 0));
        assert_eq!(c.branch_depth(0), Some(0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn branch_depth_spans_interior_terminals() {
        // cross-epoch extension: the previous generation terminates
        // mid-chain, but the single-child chain is still one shared spine
        let mut c = RolloutCache::new();
        c.insert(0, entry(&[1, 2, 3], 0));
        c.insert(0, entry(&[1, 2, 3, 4, 5], 1));
        assert_eq!(c.branch_depth(0), Some(5));
        c.check_invariants().unwrap();
    }

    // ---- flat baseline ---------------------------------------------------

    fn flat_scan_tokens(c: &FlatCache) -> usize {
        c.slots
            .values()
            .map(|(l, p)| l.response.len() + p.as_ref().map_or(0, |e| e.response.len()))
            .sum()
    }

    #[test]
    fn flat_insert_and_latest() {
        let mut c = FlatCache::new();
        assert!(c.latest(0).is_none());
        c.insert(0, entry(&[1, 2], 0));
        assert_eq!(c.latest(0).unwrap().response, vec![1, 2]);
        assert!(c.previous(0).is_none());
    }

    #[test]
    fn flat_token_accounting() {
        let mut c = FlatCache::new();
        c.insert(0, entry(&[1, 2, 3], 0));
        c.insert(0, entry(&[4, 5], 1));
        assert_eq!(c.total_tokens(), 5);
        assert_eq!(c.total_tokens(), flat_scan_tokens(&c));
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.total_tokens(), 0);
    }

    #[test]
    fn flat_budget_evicts_previous_entries_first() {
        let mut c = FlatCache::with_budget(6);
        c.insert(0, entry(&[1, 1, 1], 0));
        c.insert(1, entry(&[2, 2, 2], 0));
        assert_eq!(c.total_tokens(), 6);
        // demoting id 0 to previous pushes to 9 tokens: its old latest
        // (now `previous`, version 0) must be the first casualty
        c.insert(0, entry(&[4, 4, 4], 1));
        assert_eq!(c.total_tokens(), 6);
        assert!(c.previous(0).is_none(), "previous evicted");
        assert_eq!(c.eviction_stats(), (1, 3));
        assert_eq!(c.total_tokens(), flat_scan_tokens(&c));
    }

    #[test]
    fn flat_insert_batch_enforces_once_at_end() {
        let mut c = FlatCache::with_budget(6);
        c.insert_batch((0..5).map(|id| (id, entry(&[7; 3], 1))));
        assert!(c.total_tokens() <= 6);
        assert_eq!(c.total_tokens(), flat_scan_tokens(&c));
        assert!(c.latest(3).is_some() && c.latest(4).is_some());
        assert!(c.latest(0).is_none());
        assert_eq!(c.eviction_stats(), (3, 9));
    }
}
