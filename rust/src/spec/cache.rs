//! The rollout cache: previous trajectories + their sampling log-probs.
//!
//! Keyed by sequence id (prompt index × group + sample slot). Each entry
//! keeps the latest rollout and the one before it (the Delayed-Reuse
//! ablation draws drafts from two steps back). "Log-probs" are the
//! current-policy log-probs recorded when the trajectory was produced —
//! exactly the `p_prev` of the acceptance rule next time the prompt
//! reappears.
//!
//! Memory is bounded by an optional **token budget**: the cache tracks its
//! total cached tokens incrementally (O(1) [`RolloutCache::total_tokens`])
//! and, when an insert pushes it over budget, evicts oldest-version
//! material first — `previous` entries (only the Delayed ablation reads
//! them) before whole slots — until it fits. Eviction counters feed the
//! per-step pipeline telemetry.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::rollout::SeqResult;

/// One cached trajectory.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub response: Vec<i32>,
    pub logps: Vec<f32>,
    /// Trainer step at which this rollout was produced.
    pub version: u64,
    /// Whether the trajectory terminated with EOS.
    pub finished: bool,
}

impl CacheEntry {
    pub fn from_result(r: &SeqResult, version: u64) -> Self {
        debug_assert_eq!(r.response.len(), r.logps.len());
        CacheEntry {
            response: r.response.clone(),
            logps: r.logps.clone(),
            version,
            finished: r.finished,
        }
    }
}

/// Latest + previous entry per sequence id, under an optional token budget.
#[derive(Default, Debug)]
pub struct RolloutCache {
    slots: HashMap<usize, (CacheEntry, Option<CacheEntry>)>,
    /// Max total cached tokens (None = unbounded).
    token_budget: Option<usize>,
    /// Incrementally-tracked total (never rescanned).
    tokens: usize,
    evictions: u64,
    evicted_tokens: u64,
}

impl RolloutCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cache that evicts oldest-version entries past `budget` tokens.
    pub fn with_budget(budget: usize) -> Self {
        RolloutCache { token_budget: Some(budget), ..Self::default() }
    }

    /// (Re)set the token budget, enforcing it immediately.
    pub fn set_token_budget(&mut self, budget: Option<usize>) {
        self.token_budget = budget;
        self.enforce_budget();
    }

    /// Most recent cached rollout for `id`.
    pub fn latest(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).map(|(latest, _)| latest)
    }

    /// The rollout before the latest (Delayed-Reuse ablation).
    pub fn previous(&self, id: usize) -> Option<&CacheEntry> {
        self.slots.get(&id).and_then(|(_, prev)| prev.as_ref())
    }

    /// Insert a fresh rollout, demoting the current latest to `previous`
    /// (one hash lookup via the entry API), then enforce the budget.
    pub fn insert(&mut self, id: usize, entry: CacheEntry) {
        self.insert_unenforced(id, entry);
        self.enforce_budget();
    }

    /// Insert a whole step's rollouts, enforcing the token budget once at
    /// the end — a binding budget would otherwise trigger a victim scan
    /// per insert. Same eviction policy (oldest (version, id) first), so
    /// the surviving set matches per-insert enforcement for fresh-version
    /// batches.
    pub fn insert_batch(&mut self, entries: impl IntoIterator<Item = (usize, CacheEntry)>) {
        for (id, entry) in entries {
            self.insert_unenforced(id, entry);
        }
        self.enforce_budget();
    }

    fn insert_unenforced(&mut self, id: usize, entry: CacheEntry) {
        let added = entry.response.len();
        let mut dropped = 0usize;
        match self.slots.entry(id) {
            Entry::Occupied(mut o) => {
                let (latest, prev) = o.get_mut();
                if let Some(old_prev) = prev.take() {
                    dropped = old_prev.response.len();
                }
                *prev = Some(std::mem::replace(latest, entry));
            }
            Entry::Vacant(v) => {
                v.insert((entry, None));
            }
        }
        self.tokens = self.tokens + added - dropped;
    }

    /// Evict oldest-version material until the budget holds: `previous`
    /// entries first (pure ablation fodder), then whole slots. One scan
    /// per tier (victims sorted by (version, id) for determinism) rather
    /// than a rescan per evicted entry, so a tight budget costs O(n log n)
    /// per overflowing insert, not O(n) per eviction.
    fn enforce_budget(&mut self) {
        let Some(budget) = self.token_budget else { return };
        if self.tokens <= budget {
            return;
        }
        let mut prev_victims: Vec<(u64, usize)> = self
            .slots
            .iter()
            .filter_map(|(id, (_, p))| p.as_ref().map(|e| (e.version, *id)))
            .collect();
        prev_victims.sort_unstable();
        for (_, id) in prev_victims {
            if self.tokens <= budget {
                return;
            }
            let (_, prev) = self.slots.get_mut(&id).expect("victim vanished");
            let e = prev.take().expect("victim had a previous");
            self.note_eviction(e.response.len());
        }
        let mut latest_victims: Vec<(u64, usize)> =
            self.slots.iter().map(|(id, (l, _))| (l.version, *id)).collect();
        latest_victims.sort_unstable();
        for (_, id) in latest_victims {
            if self.tokens <= budget {
                return;
            }
            let (latest, _) = self.slots.remove(&id).expect("victim vanished");
            self.note_eviction(latest.response.len());
        }
    }

    fn note_eviction(&mut self, freed: usize) {
        self.tokens -= freed;
        self.evictions += 1;
        self.evicted_tokens += freed as u64;
    }

    /// Cumulative (entries evicted, tokens evicted) since construction;
    /// the pipeline driver diffs this across a step for telemetry.
    pub fn eviction_stats(&self) -> (u64, u64) {
        (self.evictions, self.evicted_tokens)
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn clear(&mut self) {
        self.slots.clear();
        self.tokens = 0;
    }

    /// Total cached tokens (memory telemetry). O(1): tracked on every
    /// insert/eviction, never recomputed by scanning.
    pub fn total_tokens(&self) -> usize {
        self.tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: &[i32], version: u64) -> CacheEntry {
        CacheEntry {
            response: tokens.to_vec(),
            logps: vec![-1.0; tokens.len()],
            version,
            finished: true,
        }
    }

    fn scan_tokens(c: &RolloutCache) -> usize {
        c.slots
            .values()
            .map(|(l, p)| l.response.len() + p.as_ref().map_or(0, |e| e.response.len()))
            .sum()
    }

    #[test]
    fn insert_and_latest() {
        let mut c = RolloutCache::new();
        assert!(c.latest(0).is_none());
        c.insert(0, entry(&[1, 2], 0));
        assert_eq!(c.latest(0).unwrap().response, vec![1, 2]);
        assert!(c.previous(0).is_none());
    }

    #[test]
    fn insert_demotes_latest() {
        let mut c = RolloutCache::new();
        c.insert(7, entry(&[1], 0));
        c.insert(7, entry(&[2], 1));
        assert_eq!(c.latest(7).unwrap().response, vec![2]);
        assert_eq!(c.previous(7).unwrap().response, vec![1]);
        c.insert(7, entry(&[3], 2));
        assert_eq!(c.latest(7).unwrap().response, vec![3]);
        assert_eq!(c.previous(7).unwrap().response, vec![2]);
    }

    #[test]
    fn versions_track_steps() {
        let mut c = RolloutCache::new();
        c.insert(1, entry(&[1], 10));
        c.insert(1, entry(&[2], 11));
        assert_eq!(c.latest(1).unwrap().version, 11);
        assert_eq!(c.previous(1).unwrap().version, 10);
    }

    #[test]
    fn token_accounting() {
        let mut c = RolloutCache::new();
        c.insert(0, entry(&[1, 2, 3], 0));
        c.insert(0, entry(&[4, 5], 1));
        assert_eq!(c.total_tokens(), 5);
        assert_eq!(c.total_tokens(), scan_tokens(&c));
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.total_tokens(), 0);
    }

    #[test]
    fn incremental_tokens_match_scan_under_churn() {
        let mut c = RolloutCache::new();
        for step in 0..6u64 {
            for id in 0..4usize {
                c.insert(id, entry(&vec![3; 1 + (id + step as usize) % 5], step));
            }
            assert_eq!(c.total_tokens(), scan_tokens(&c), "step {step}");
        }
    }

    #[test]
    fn budget_evicts_previous_entries_first() {
        let mut c = RolloutCache::with_budget(6);
        c.insert(0, entry(&[1, 1, 1], 0));
        c.insert(1, entry(&[2, 2, 2], 0));
        assert_eq!(c.total_tokens(), 6);
        assert_eq!(c.eviction_stats(), (0, 0));
        // demoting id 0 to previous pushes to 9 tokens: its old latest
        // (now `previous`, version 0) must be the first casualty
        c.insert(0, entry(&[4, 4, 4], 1));
        assert_eq!(c.total_tokens(), 6);
        assert!(c.previous(0).is_none(), "previous evicted");
        assert_eq!(c.latest(0).unwrap().response, vec![4, 4, 4], "fresh latest kept");
        assert_eq!(c.latest(1).unwrap().response, vec![2, 2, 2], "neighbour kept");
        assert_eq!(c.eviction_stats(), (1, 3));
        assert_eq!(c.total_tokens(), scan_tokens(&c));
    }

    #[test]
    fn budget_evicts_oldest_slots_when_no_previous_left() {
        let mut c = RolloutCache::with_budget(4);
        c.insert(0, entry(&[1, 1], 0));
        c.insert(1, entry(&[2, 2], 1));
        c.insert(2, entry(&[3, 3], 2)); // 6 tokens > 4: id 0 (oldest) goes
        assert!(c.latest(0).is_none());
        assert!(c.latest(1).is_some());
        assert!(c.latest(2).is_some());
        assert_eq!(c.total_tokens(), 4);
        let (n, tok) = c.eviction_stats();
        assert_eq!((n, tok), (1, 2));
        assert_eq!(c.total_tokens(), scan_tokens(&c));
    }

    #[test]
    fn set_budget_enforces_immediately() {
        let mut c = RolloutCache::new();
        for id in 0..5 {
            c.insert(id, entry(&[7; 4], id as u64));
        }
        assert_eq!(c.total_tokens(), 20);
        c.set_token_budget(Some(8));
        assert_eq!(c.total_tokens(), 8);
        assert_eq!(c.len(), 2);
        // the newest versions survive
        assert!(c.latest(3).is_some() && c.latest(4).is_some());
        c.set_token_budget(None);
        c.insert(9, entry(&[1; 50], 9));
        assert_eq!(c.total_tokens(), 58, "unbounded again");
    }

    #[test]
    fn insert_batch_enforces_once_at_end() {
        let mut c = RolloutCache::with_budget(6);
        c.insert_batch((0..5).map(|id| (id, entry(&[7; 3], 1))));
        assert!(c.total_tokens() <= 6);
        assert_eq!(c.total_tokens(), scan_tokens(&c));
        // same-version ties evict ascending id: the highest ids survive
        assert!(c.latest(3).is_some() && c.latest(4).is_some());
        assert!(c.latest(0).is_none());
        assert_eq!(c.eviction_stats(), (3, 9));
    }

    #[test]
    fn unbounded_cache_never_evicts() {
        let mut c = RolloutCache::new();
        for step in 0..20u64 {
            c.insert(0, entry(&[5; 40], step));
        }
        assert_eq!(c.eviction_stats(), (0, 0));
        assert_eq!(c.total_tokens(), 80);
    }
}
