//! Packed draft verification over the AOT `verify` entry.
//!
//! All of a step's drafts are packed into canonical `[B, T]` layouts
//! (left-padded prompts + draft responses) and verified in batched engine
//! calls — the paper's "all draft verification requests within a training
//! batch are packed into a single call to the rollout engine". Each call
//! runs one teacher-forced forward (L1 attention kernel), the fused
//! log-prob kernel, and the L1 acceptance scan, returning the first
//! rejection offset per row.

use anyhow::Result;

use super::cache::CacheEntry;
use super::RolloutRequest;
use crate::model::Policy;
use crate::rollout::batch::BatchLayout;
use crate::rollout::SeqTask;
use crate::runtime::Engine;
use crate::util::Rng;

/// Batched verifier bound to one bundle.
pub struct SpecVerifier<'e> {
    eng: &'e Engine,
    bundle: String,
    batch: usize,
    prompt_len: usize,
    total_len: usize,
}

impl<'e> SpecVerifier<'e> {
    pub fn new(eng: &'e Engine, bundle: &str) -> Result<Self> {
        let info = eng.bundle(bundle)?;
        Ok(SpecVerifier {
            eng,
            bundle: bundle.to_string(),
            batch: info.batch,
            prompt_len: eng.manifest.prompt_len,
            total_len: eng.manifest.total_len,
        })
    }

    /// Verify drafts; returns accepted-prefix lengths (one per draft, in
    /// input order) and the number of engine calls made.
    pub fn verify(
        &self,
        policy: &Policy,
        drafts: &[(usize, &RolloutRequest, CacheEntry)],
        log_lenience: f32,
        temperature: f32,
        rng: &mut Rng,
    ) -> Result<(Vec<usize>, usize)> {
        let g = self.total_len - self.prompt_len;
        let mut accepted = Vec::with_capacity(drafts.len());
        let mut calls = 0usize;

        for chunk in drafts.chunks(self.batch) {
            // Pack drafts as if they were finished sequences.
            let tasks: Vec<SeqTask> = chunk
                .iter()
                .map(|(id, req, entry)| SeqTask {
                    id: *id,
                    prompt: req.prompt.clone(),
                    prefix: entry.response.clone(),
                    prefix_logps: entry.logps.clone(),
                })
                .collect();
            let layout = BatchLayout::pack(&tasks, self.batch, self.prompt_len, self.total_len);

            let mut logp_prev = vec![0f32; self.batch * g];
            let mut draft_valid = vec![0f32; self.batch * g];
            let mut uniforms = vec![0f32; self.batch * g];
            rng.fill_uniform(&mut uniforms);
            for (r, (_, _, entry)) in chunk.iter().enumerate() {
                for (j, &lp) in entry.logps.iter().enumerate() {
                    logp_prev[r * g + j] = lp;
                    draft_valid[r * g + j] = 1.0;
                }
            }

            let tok = self.eng.upload_i32(&layout.tokens, &[self.batch, self.total_len])?;
            let val = self.eng.upload_f32(&layout.valid, &[self.batch, self.total_len])?;
            let lp = self.eng.upload_f32(&logp_prev, &[self.batch, g])?;
            let un = self.eng.upload_f32(&uniforms, &[self.batch, g])?;
            let dv = self.eng.upload_f32(&draft_valid, &[self.batch, g])?;
            let ll = self.eng.upload_f32(&[log_lenience], &[1])?;
            let tp = self.eng.upload_f32(&[temperature], &[1])?;

            let out = self.eng.call(
                &self.bundle,
                "verify",
                &[&policy.blob, &tok, &val, &lp, &un, &dv, &ll, &tp],
            )?;
            calls += 1;
            let host = self.eng.read_f32(&out)?;
            for (r, (_, _, entry)) in chunk.iter().enumerate() {
                let n = host[r].round() as usize;
                accepted.push(n.min(entry.response.len()));
            }
        }
        Ok((accepted, calls))
    }
}
