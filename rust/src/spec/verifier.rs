//! Draft-verification planning: packing and acceptance bookkeeping,
//! engine-free.
//!
//! [`VerifyPlanner`] owns the host-side scratch for packing drafts into
//! canonical `[B, T]` layouts (left-padded prompts + draft responses) plus
//! the acceptance side vectors (`logp_prev` / `uniforms` / `draft_valid`)
//! consumed by the AOT `verify` and `verify_seat` entries. It makes **no
//! engine calls** — the engine-facing executor lives in
//! [`crate::rollout::engine::RolloutEngine`] (`verify_wave` for the
//! blocking two-phase oracle, the `verify_seat` path inside
//! `run_pipeline` for the interleaved default).
//!
//! Acceptance uniforms come from **per-task RNG streams**
//! ([`verify_rng`]): the uniforms a draft is judged against depend only on
//! (verify nonce, task id), never on which sub-batch, row, **or shard**
//! the draft happens to be packed into. That packing invariance is what
//! lets the phase-aware pipeline verify drafts in opportunistic
//! sub-batches — and [`crate::rollout::pool::EnginePool`] spread them
//! across engines — while staying byte-identical to the blocking
//! full-wave path (the same property per-task sampling streams give the
//! decode phase). See `ARCHITECTURE.md`, "RNG-stream contract".

use crate::rollout::batch::BatchLayout;
use crate::runtime::BatchShape;
use crate::util::Rng;

use super::cache::CacheEntry;

/// A drafted sequence awaiting speculative verification — the `Verify`
/// phase of the rollout pipeline (`Draft -> Verify -> Decode -> Done`).
#[derive(Clone, Debug)]
pub struct VerifyTask {
    /// Stable cache key; results carry it back.
    pub id: usize,
    /// BOS + prompt token ids.
    pub prompt: Vec<i32>,
    /// The cached draft to verify (tokens + sampling-time log-probs).
    pub entry: CacheEntry,
}

impl VerifyTask {
    /// Draft length in tokens.
    pub fn draft_len(&self) -> usize {
        self.entry.response.len()
    }
}

/// Per-task uniform stream for the acceptance rule. Distinct mixing
/// constants from the decode-phase `task_rng`, so verification and
/// sampling never share randomness even under the same nonce.
pub fn verify_rng(nonce: u64, id: usize) -> Rng {
    Rng::new(nonce ^ (id as u64).wrapping_add(0x5851).wrapping_mul(0xD1B5_4A32_D192_ED03))
}

/// Host-side packing/acceptance scratch for one bundle geometry, reused
/// across verify calls and trainer steps (constructed once per
/// [`crate::rollout::engine::RolloutEngine`]).
pub struct VerifyPlanner {
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    /// Canonical `[B, T]` tokens/valid pack (prompt + full draft).
    pub layout: BatchLayout,
    /// `[B, G]` log-probs recorded when each draft token was sampled.
    pub logp_prev: Vec<f32>,
    /// `[B, G]` 1.0 where the draft actually has a token.
    pub draft_valid: Vec<f32>,
    /// `[B, G]` U(0,1) acceptance draws from per-task streams.
    pub uniforms: Vec<f32>,
}

impl VerifyPlanner {
    pub fn new(shape: BatchShape) -> Self {
        let g = shape.gen_len();
        VerifyPlanner {
            batch: shape.batch,
            prompt_len: shape.prompt_len,
            total_len: shape.total_len,
            layout: BatchLayout::new(shape.batch, shape.prompt_len, shape.total_len),
            logp_prev: vec![0.0; shape.batch * g],
            draft_valid: vec![0.0; shape.batch * g],
            uniforms: vec![0.0; shape.batch * g],
        }
    }

    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }

    /// Reset every row to inert filler (allocations kept).
    pub fn clear(&mut self) {
        self.layout.clear();
        self.logp_prev.fill(0.0);
        self.draft_valid.fill(0.0);
        self.uniforms.fill(0.0);
    }

    /// Pack one draft into row `r`, drawing its acceptance uniforms from
    /// the task-keyed stream (packing-invariant by construction).
    pub fn set_row(&mut self, r: usize, task: &VerifyTask, nonce: u64) {
        self.layout.set_row(r, &task.prompt, &task.entry.response);
        let g = self.gen_len();
        let base = r * g;
        let mut rng = verify_rng(nonce, task.id);
        for (j, &lp) in task.entry.logps.iter().enumerate() {
            self.logp_prev[base + j] = lp;
            self.draft_valid[base + j] = 1.0;
            self.uniforms[base + j] = rng.f32();
        }
    }

    /// Interpret a device-reported first-rejection offset for `task`
    /// (clamped into `[0, draft_len]`).
    pub fn accepted(&self, raw: f32, task: &VerifyTask) -> usize {
        let n = raw.round().max(0.0) as usize;
        n.min(task.draft_len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> BatchShape {
        BatchShape { batch: 3, prompt_len: 4, total_len: 12, vocab: 8 }
    }

    fn task(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![1, 5],
            entry: CacheEntry {
                response: (0..len as i32).map(|j| 3 + j).collect(),
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn uniforms_are_packing_invariant() {
        let nonce = 99;
        let mut a = VerifyPlanner::new(shape());
        let mut b = VerifyPlanner::new(shape());
        let t = task(7, 5);
        a.set_row(0, &t, nonce);
        b.set_row(2, &t, nonce);
        let g = a.gen_len();
        assert_eq!(a.uniforms[..g], b.uniforms[2 * g..3 * g]);
        assert_eq!(a.logp_prev[..g], b.logp_prev[2 * g..3 * g]);
    }

    #[test]
    fn distinct_tasks_get_distinct_streams() {
        let mut p = VerifyPlanner::new(shape());
        p.set_row(0, &task(1, 5), 7);
        p.set_row(1, &task(2, 5), 7);
        let g = p.gen_len();
        assert_ne!(p.uniforms[..5], p.uniforms[g..g + 5]);
    }

    #[test]
    fn set_row_fills_side_vectors_only_for_draft_positions() {
        let mut p = VerifyPlanner::new(shape());
        p.set_row(1, &task(4, 3), 1);
        let g = p.gen_len();
        assert_eq!(&p.draft_valid[g..g + 3], &[1.0, 1.0, 1.0]);
        assert!(p.draft_valid[g + 3..2 * g].iter().all(|&x| x == 0.0));
        assert!(p.uniforms[g..g + 3].iter().all(|&u| (0.0..1.0).contains(&u)));
        p.clear();
        assert!(p.draft_valid.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accepted_clamps_to_draft_len() {
        let p = VerifyPlanner::new(shape());
        let t = task(0, 4);
        assert_eq!(p.accepted(2.0, &t), 2);
        assert_eq!(p.accepted(9.0, &t), 4);
        assert_eq!(p.accepted(-1.0, &t), 0);
    }

    #[test]
    fn verify_rng_differs_from_decode_stream() {
        // same nonce, same id: the verification stream must not replay
        // the sampling stream
        let mut v = verify_rng(42, 3);
        let mut d = crate::rollout::engine::task_rng(42, 3);
        assert_ne!(v.next_u64(), d.next_u64());
    }
}
