//! Packed draft verification over the AOT `verify` entry.
//!
//! All of a step's drafts are packed into canonical `[B, T]` layouts
//! (left-padded prompts + draft responses) and verified in batched engine
//! calls — the paper's "all draft verification requests within a training
//! batch are packed into a single call to the rollout engine". Each call
//! runs one teacher-forced forward (L1 attention kernel), the fused
//! log-prob kernel, and the L1 acceptance scan, returning the first
//! rejection offset per row.
//!
//! Packing writes prompt/response slices straight into one reused
//! [`BatchLayout`] scratch (no intermediate `SeqTask` clones), the side
//! vectors (`logp_prev`/`uniforms`/`draft_valid`) are allocated once per
//! verify call and reused across chunks, and the scalar lenience /
//! temperature buffers upload once per call rather than once per chunk.

use anyhow::Result;

use super::cache::CacheEntry;
use super::RolloutRequest;
use crate::rollout::batch::BatchLayout;
use crate::runtime::{Backend, Engine};
use crate::util::Rng;

/// Batched verifier bound to one bundle.
pub struct SpecVerifier<'e, B: Backend = Engine> {
    eng: &'e B,
    h_verify: B::Entry,
    batch: usize,
    prompt_len: usize,
    total_len: usize,
}

impl<'e, B: Backend> SpecVerifier<'e, B> {
    pub fn new(eng: &'e B, bundle: &str) -> Result<Self> {
        let shape = eng.shape(bundle)?;
        Ok(SpecVerifier {
            eng,
            h_verify: eng.resolve(bundle, "verify")?,
            batch: shape.batch,
            prompt_len: shape.prompt_len,
            total_len: shape.total_len,
        })
    }

    /// Verify drafts; returns accepted-prefix lengths (one per draft, in
    /// input order) and the number of engine calls made.
    pub fn verify(
        &self,
        blob: &B::Buf,
        drafts: &[(usize, &RolloutRequest, CacheEntry)],
        log_lenience: f32,
        temperature: f32,
        rng: &mut Rng,
    ) -> Result<(Vec<usize>, usize)> {
        let (b, t) = (self.batch, self.total_len);
        let g = t - self.prompt_len;
        let mut accepted = Vec::with_capacity(drafts.len());
        let mut calls = 0usize;

        // One scratch set reused across chunks.
        let mut layout = BatchLayout::new(b, self.prompt_len, t);
        let mut logp_prev = vec![0f32; b * g];
        let mut draft_valid = vec![0f32; b * g];
        let mut uniforms = vec![0f32; b * g];
        let ll = self.eng.upload_f32(&[log_lenience], &[1])?;
        let tp = self.eng.upload_f32(&[temperature], &[1])?;

        for chunk in drafts.chunks(b) {
            layout.clear();
            logp_prev.fill(0.0);
            draft_valid.fill(0.0);
            rng.fill_uniform(&mut uniforms);
            for (r, (_, req, entry)) in chunk.iter().enumerate() {
                layout.set_row(r, &req.prompt, &entry.response);
                for (j, &lp) in entry.logps.iter().enumerate() {
                    logp_prev[r * g + j] = lp;
                    draft_valid[r * g + j] = 1.0;
                }
            }

            let tok = self.eng.upload_i32(&layout.tokens, &[b, t])?;
            let val = self.eng.upload_f32(&layout.valid, &[b, t])?;
            let lp = self.eng.upload_f32(&logp_prev, &[b, g])?;
            let un = self.eng.upload_f32(&uniforms, &[b, g])?;
            let dv = self.eng.upload_f32(&draft_valid, &[b, g])?;

            let out = self.eng.call_entry(
                &self.h_verify,
                &[blob, &tok, &val, &lp, &un, &dv, &ll, &tp],
            )?;
            calls += 1;
            let host = self.eng.read_f32(&out)?;
            for (r, (_, _, entry)) in chunk.iter().enumerate() {
                let n = host[r].round() as usize;
                accepted.push(n.min(entry.response.len()));
            }
        }
        Ok((accepted, calls))
    }
}
