//! SPEC-RL: speculative rollouts via draft-and-verify reuse, run as a
//! phase-aware pipeline.
//!
//! Every sequence of a step moves through an explicit lifecycle:
//!
//! ```text
//! Draft -> Verify -> Decode -> Done
//! ```
//!
//! - **Draft**: [`cache::RolloutCache`] stores each sequence's previous
//!   rollout (tokens + the log-probs the sampling policy assigned them) in
//!   a per-prompt **prefix trie**: the n group samples of a prompt and
//!   consecutive epochs' accepted prefixes share interned token runs, so
//!   the resident footprint counts each shared spine once. Drafts
//!   materialize by the root-to-leaf walk; refresh splits runs at the
//!   first divergence; an optional token budget evicts the oldest leaves'
//!   exclusive subtrees. [`variants::ReuseVariant`] picks the draft (or
//!   none).
//! - **Verify**: drafts whose acceptance needs the current policy
//!   (Spec/Delayed) become [`VerifyTask`]s and are verified *inside* the
//!   rollout engine's slot pool: the `verify_seat` entry scores a packed
//!   sub-batch of drafts under Algorithm 1's lenient rule
//!   `u <= min(1, l * p_curr/p_prev)` and seats each accepted prefix's
//!   KV/valid/probs into the generation blob in the same forward. Variants
//!   that need no engine (Random/Full) resolve here on the host.
//! - **Decode**: fresh prompts and verified rows share the continuous
//!   batching slot scheduler; a verified row starts decoding the moment
//!   its rejection offset is read back — there is no global verify
//!   barrier, and no refill forward for verified rows.
//! - **Done**: fully-reused terminal drafts bypass the device entirely.
//!
//! [`SpecRollout::collect`] is a thin driver over this pipeline: it splits
//! requests into decode-ready tasks and verify tasks, hands both lanes to
//! an [`EnginePool`] as one shared steal-queue (every shard pulls
//! LPT-first whenever it has free slots, mid-step included — one shard is
//! the plain single-engine pipeline), and folds cache/telemetry
//! bookkeeping into the merged per-step [`PipelineStats`] report.
//! [`SpecRollout::placement`] selects the pool discipline
//! ([`Placement::Steal`] by default; `Static` keeps PR 3's one-pass
//! spill as a measurable baseline).
//! [`SpecRollout::run_two_phase`] keeps the original blocking
//! verify-then-decode discipline as the equivalence oracle: per-task
//! sampling *and* verification RNG streams make the two paths
//! byte-identical (`rust/tests/sched_continuous.rs` pins this down across
//! variants, skewed draft lengths, and mid-stream refills).
//!
//! [`variants`] implements the paper's ablation baselines (Random Reuse,
//! Delayed Reuse, Full Reuse, and Off == vanilla RLVR).

pub mod cache;
pub mod draft;
pub mod lenience;
pub mod variants;
pub mod verifier;

use anyhow::Result;

use crate::rollout::{
    EnginePool, LenEstimates, LenPredictor, PipelineStats, Placement, RolloutEngine, SampleCfg,
    SeqResult, SeqTask,
};
use crate::runtime::Backend;
use crate::util::{Rng, StageTimer};

pub use cache::{CacheEntry, FlatCache, RolloutCache};
pub use draft::DraftControl;
pub use lenience::Lenience;
pub use variants::ReuseVariant;
pub use verifier::{VerifyPlanner, VerifyTask};

/// Back-compat name: the per-step speculative-reuse telemetry merged into
/// the unified pipeline report in PR 2.
pub type SpecStepStats = PipelineStats;

/// A prompt to roll out this step: `id` is the stable cache key
/// (prompt index × group + sample index).
#[derive(Clone, Debug)]
pub struct RolloutRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
}

/// The speculative rollout coordinator.
pub struct SpecRollout {
    pub cache: RolloutCache,
    pub variant: ReuseVariant,
    pub lenience: Lenience,
    /// Pool placement discipline for [`SpecRollout::collect`]
    /// ([`Placement::Steal`] by default; results are byte-identical
    /// either way, only the per-shard device-call split differs).
    pub placement: Placement,
    /// Predicted-length scheduling (`rollout.predict_len`,
    /// `ARCHITECTURE.md` §14): per-task total-length and acceptance
    /// EWMAs feeding the queue's LPT keys. Disabled by default — the
    /// queue then orders by the raw keys, bit-exactly the old behavior.
    pub predictor: LenPredictor,
    /// Per-row adaptive draft-length clamp
    /// (`spec.draft_len_{min,max,adapt}`, §14). A no-op by default.
    pub draft_ctl: DraftControl,
    /// Trie-aware fallback drafts (`spec.sibling_drafts`, on by default):
    /// when a slot's own leaf was evicted or the prompt is fresh this
    /// epoch, offer the longest surviving sibling spine under the same
    /// prompt root instead of decoding from scratch, clamped by the
    /// group's branch-point depth (`ARCHITECTURE.md` §8). Only variants
    /// whose drafts pass through the verifier take the fallback
    /// ([`ReuseVariant::verification_gated`]); with the knob off — and on
    /// every own-leaf path regardless — behavior is bit-exact to the
    /// pre-sibling coordinator.
    pub sibling_drafts: bool,
    /// Current step counter (cache versioning).
    pub step: u64,
}

impl SpecRollout {
    pub fn new(variant: ReuseVariant, lenience: Lenience) -> Self {
        SpecRollout {
            cache: RolloutCache::new(),
            variant,
            lenience,
            placement: Placement::Steal,
            predictor: LenPredictor::default(),
            draft_ctl: DraftControl::default(),
            sibling_drafts: true,
            step: 0,
        }
    }

    /// Enable/disable sibling-spine fallback drafts
    /// (`spec.sibling_drafts`). Off restores the own-leaf-only draft
    /// selection bit-exactly; on only changes rows that today would start
    /// fresh, and every offered fallback token is still verified under
    /// the requesting id's own §6 stream.
    pub fn with_sibling_drafts(mut self, enabled: bool) -> Self {
        self.sibling_drafts = enabled;
        self
    }

    /// Select the pool placement discipline (`bench_steal` uses this to
    /// measure `Static` against the `Steal` default).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    /// Enable/disable predicted-length scheduling (`rollout.predict_len`).
    /// Estimates only reorder seating, so outputs are byte-identical
    /// either way (`ARCHITECTURE.md` §14).
    pub fn with_predict(mut self, enabled: bool) -> Self {
        self.predictor = LenPredictor::new(enabled);
        self
    }

    /// Configure the draft-length clamp (`spec.draft_len_{min,max,adapt}`:
    /// shrink floor, static ceiling with 0 = uncapped, adaptive on/off).
    pub fn with_draft_control(mut self, min: usize, max: usize, adapt: bool) -> Self {
        self.draft_ctl = DraftControl::new(min, max, adapt);
        self
    }

    /// Load a per-task length prior for a zero-history prompt (the
    /// trainer seeds these from `tasks::suites::family_length_priors`).
    pub fn set_len_prior(&mut self, id: usize, len: f64) {
        self.predictor.set_prior(id, len);
    }

    /// Vanilla RLVR (no reuse, cache still shadow-updated for overlap
    /// diagnostics like Figure 2).
    pub fn vanilla() -> Self {
        Self::new(ReuseVariant::Off, Lenience::Fixed(0.0))
    }

    /// Bound the rollout cache to `budget` resident (deduplicated)
    /// tokens (oldest-version leaf eviction; `None` = unbounded).
    pub fn with_cache_budget(mut self, budget: Option<usize>) -> Self {
        self.cache.set_token_budget(budget);
        self
    }

    /// Group size for the cache's prompt keying: sequence ids
    /// `[k * group, (k + 1) * group)` share one prefix trie, so a GRPO
    /// group's samples intern their common spine once. Must be set before
    /// the first rollout.
    pub fn with_group(mut self, group: usize) -> Self {
        self.cache.set_group(group);
        self
    }

    /// Split a step's requests into decode-ready tasks and verify tasks,
    /// drawing this step's verification/sampling nonces. Host-resolvable
    /// acceptance (Random/Full) happens here; Spec/Delayed drafts go to
    /// the engine's Verify phase; the draft-length clamp clips each
    /// materialized draft and the predictor freezes this step's
    /// [`LenEstimates`] (§14 — the predictor consumes **no** RNG, so both
    /// drive paths see identical nonce streams whatever it is set to).
    /// Returns `(vnonce, rnonce, tasks, drafts, draft stats, estimates)`.
    fn prepare(
        &mut self,
        requests: &[RolloutRequest],
        rng: &mut Rng,
    ) -> (u64, u64, Vec<SeqTask>, Vec<VerifyTask>, PipelineStats, LenEstimates) {
        // Both nonces are drawn unconditionally and in a fixed order, so
        // the pipeline and two-phase paths consume the caller's RNG
        // identically — a precondition for byte-identical outputs.
        let vnonce = rng.next_u64();
        let rnonce = rng.next_u64();
        let mut pre = PipelineStats::default();
        let mut tasks: Vec<SeqTask> = Vec::with_capacity(requests.len());
        let mut drafts: Vec<VerifyTask> = Vec::new();
        // Branch-point depths observed once per prompt root this step
        // (the gauge behind `branch_depth_mean`; sibling path only).
        let mut depth_seen: std::collections::HashSet<usize> = std::collections::HashSet::new();
        self.draft_ctl.begin_step();
        for req in requests {
            self.predictor.seed_from_cache(&self.cache, req.id);
            let mut own = self.variant.draft_for(&self.cache, req.id, self.step);
            // Trie-aware fallback (§8): the slot's own leaf is gone but a
            // sibling under the same prompt root survived. Sound only for
            // verified variants — the fallback's every token is re-scored
            // under the *requesting* id's verification stream (§6), so no
            // foreign content enters unverified. Deterministic and
            // shard-count-invariant: the selection reads only the shared
            // cache, before any work is placed, and consumes no RNG.
            let mut sib_depth: Option<usize> = None;
            if own.is_none() && self.sibling_drafts && self.variant.verification_gated() {
                if let Some(mut sib) = self.cache.sibling_spine(req.id) {
                    let depth = self.cache.branch_depth(req.id).unwrap_or(0);
                    // Divergence-guided cap, before any acceptance
                    // feedback exists for this row: deep shared spines
                    // earn longer offers, early divergence clamps toward
                    // `draft_len_min`.
                    variants::clip_entry(&mut sib, self.draft_ctl.sibling_cap(depth));
                    if !sib.response.is_empty() {
                        sib_depth = Some(depth);
                        own = Some(sib);
                    }
                }
            }
            if self.sibling_drafts
                && self.variant.verification_gated()
                && depth_seen.insert(req.id / self.cache.group().max(1))
            {
                if let Some(d) = self.cache.branch_depth(req.id) {
                    pre.branch_depth_sum += d;
                    pre.branch_depth_rows += 1;
                }
            }
            let Some(mut entry) = own else {
                tasks.push(SeqTask::fresh(req.id, req.prompt.clone()));
                continue;
            };
            // Clip before acceptance resolution: Random's rejection offset
            // and the verifier both see the same (clamped) draft, keeping
            // the two drive paths byte-identical per settings.
            if self.draft_ctl.clip(req.id, &mut entry) {
                pre.draft_trunc += 1;
            }
            let offered = entry.response.len();
            if let Some(depth) = sib_depth {
                pre.sibling_draft_hits += 1;
                pre.sibling_draft_tokens += offered;
                // Seed the acceptance EWMA with the divergence signal:
                // about `depth / offered` of a sibling draft is the
                // provably-shared prefix. Seeding touches no RNG (§14).
                if offered > 0 {
                    self.predictor.seed_acceptance(req.id, depth as f64 / offered as f64);
                }
            }
            pre.draft_len_sum += offered;
            pre.draft_len_lo =
                if pre.draft_len_rows == 0 { offered } else { pre.draft_len_lo.min(offered) };
            pre.draft_len_hi = pre.draft_len_hi.max(offered);
            pre.draft_len_rows += 1;
            match self.variant {
                ReuseVariant::Random | ReuseVariant::Full => {
                    let len = entry.response.len();
                    let n_acc = if self.variant == ReuseVariant::Random {
                        variants::random_reject(vnonce, req.id, len)
                    } else {
                        len
                    };
                    pre.drafts += 1;
                    pre.prefix_tokens += n_acc;
                    if n_acc == len {
                        pre.full_reuses += 1;
                    }
                    tasks.push(SeqTask {
                        id: req.id,
                        prompt: req.prompt.clone(),
                        prefix: entry.response[..n_acc].to_vec(),
                        prefix_logps: entry.logps[..n_acc].to_vec(),
                    });
                }
                _ => drafts.push(VerifyTask {
                    id: req.id,
                    prompt: req.prompt.clone(),
                    entry,
                }),
            }
        }
        let est = self.predictor.estimates(&tasks, &drafts);
        (vnonce, rnonce, tasks, drafts, pre, est)
    }

    /// Cache refresh (the paper's "always the most recent policy's
    /// rollouts"; the Off variant keeps a shadow cache so overlap metrics
    /// stay measurable) + predictor/draft-control feedback + telemetry
    /// finalization.
    fn finish(&mut self, results: &[SeqResult], mut stats: PipelineStats) -> PipelineStats {
        // Feedback pass (§14). The prediction error is measured *before*
        // this step's lengths fold into the EWMA — it gauges the estimate
        // the scheduler actually used.
        for r in results {
            let offered = self.draft_ctl.last_offered(r.id);
            if let Some(p) = self.predictor.predict(r.id) {
                stats.predict_err_sum += (p - r.response.len() as f64).abs();
                stats.predict_rows += 1;
            }
            if self.predictor.enabled() {
                self.predictor.observe_len(r.id, r.response.len());
                if offered > 0 {
                    self.predictor.observe_acceptance(r.id, r.reused, offered);
                }
            }
            if offered > 0 {
                self.draft_ctl.observe(r.id, r.reused, offered);
            }
        }
        let (e0, t0) = self.cache.eviction_stats();
        let step = self.step;
        self.cache
            .insert_batch(results.iter().map(|r| (r.id, CacheEntry::from_result(r, step))));
        let (e1, t1) = self.cache.eviction_stats();
        stats.cache_evictions = (e1 - e0) as usize;
        stats.cache_evicted_tokens = (t1 - t0) as usize;
        stats.cache_nodes = self.cache.cache_nodes();
        stats.cache_shared_tokens = self.cache.shared_tokens();
        stats.finalize_draft_means();
        self.step += 1;
        stats
    }

    /// Roll out one step's batch with speculative reuse through the
    /// interleaved phase-aware pipeline, sharded across an [`EnginePool`]
    /// (the trainer default; a one-shard pool is the original
    /// single-engine pipeline, unchanged). Under the default
    /// [`Placement::Steal`] the step's unstarted tail drains to whichever
    /// shard has free slots (`stats.steal_count` reports the mid-step
    /// pulls); `cfg.verify_seat_min` tunes how full a packed verify
    /// sub-batch must be before it seats.
    ///
    /// `blobs` carries one policy blob per shard — every shard must hold
    /// the same weights, or results stop being placement-invariant (the
    /// sharding contract in `ARCHITECTURE.md`). The single shared
    /// [`RolloutCache`] refreshes once from the merged, id-sorted results,
    /// so the `spec.cache_budget` token budget is global across shards.
    ///
    /// Returns results (sorted by id) and the merged per-step report
    /// (including per-shard `device_calls` totals). Stage timing:
    /// `verification` (verify-seat sub-batches), `rollout` / `assembly`
    /// (inside the engines).
    pub fn collect<B: Backend>(
        &mut self,
        pool: &mut EnginePool<'_, B>,
        blobs: &[&B::Buf],
        requests: &[RolloutRequest],
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let loglen = self.lenience.log_value(self.step);
        let (vnonce, rnonce, tasks, drafts, pre, est) = self.prepare(requests, rng);
        let (results, mut stats) = pool.run_pipeline_with(
            self.placement, blobs, tasks, drafts, loglen, cfg, vnonce, rnonce, &est, timer,
        )?;
        stats.drafts += pre.drafts;
        stats.prefix_tokens += pre.prefix_tokens;
        stats.full_reuses += pre.full_reuses;
        stats.absorb_draft_lens(&pre);
        let stats = self.finish(&results, stats);
        Ok((results, stats))
    }

    /// The original blocking discipline — verify *every* draft in packed
    /// full-batch waves, then decode — retained as the pipeline's
    /// equivalence oracle and the `bench_pipeline` baseline. Same RNG
    /// consumption, same per-task streams: byte-identical results to
    /// [`SpecRollout::collect`].
    pub fn run_two_phase<B: Backend>(
        &mut self,
        rollout: &mut RolloutEngine<'_, B>,
        blob: &B::Buf,
        requests: &[RolloutRequest],
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let loglen = self.lenience.log_value(self.step);
        // The oracle ignores the estimate table: its decode path is
        // order-invariant by construction, which is exactly why it can
        // pin the predictor-on pipeline byte-identical (§14).
        let (vnonce, rnonce, mut tasks, drafts, pre, _est) = self.prepare(requests, rng);
        let mut verified = PipelineStats::default();
        if !drafts.is_empty() {
            let span = std::time::Instant::now();
            let (accepted, calls) =
                rollout.verify_wave(blob, &drafts, loglen, cfg.temperature, vnonce)?;
            verified.verify_calls = calls;
            for (task, n_acc) in drafts.into_iter().zip(accepted) {
                verified.drafts += 1;
                verified.prefix_tokens += n_acc;
                if n_acc == task.entry.response.len() {
                    verified.full_reuses += 1;
                }
                tasks.push(SeqTask {
                    id: task.id,
                    prompt: task.prompt,
                    prefix: task.entry.response[..n_acc].to_vec(),
                    prefix_logps: task.entry.logps[..n_acc].to_vec(),
                });
            }
            timer.add("verification", span.elapsed().as_secs_f64());
        }
        let (results, mut stats) = rollout.run_with_nonce(blob, tasks, cfg, rnonce, timer)?;
        stats.verify_calls += verified.verify_calls;
        stats.drafts += pre.drafts + verified.drafts;
        stats.prefix_tokens += pre.prefix_tokens + verified.prefix_tokens;
        stats.full_reuses += pre.full_reuses + verified.full_reuses;
        stats.absorb_draft_lens(&pre);
        let stats = self.finish(&results, stats);
        Ok((results, stats))
    }
}
