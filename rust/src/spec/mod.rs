//! SPEC-RL: speculative rollouts via draft-and-verify reuse.
//!
//! The paper's contribution, as a drop-in wrapper around the rollout
//! engine:
//!
//! 1. [`cache::RolloutCache`] stores each sequence's previous rollout
//!    (tokens + the log-probs the sampling policy assigned them) and is
//!    refreshed immediately after every step.
//! 2. [`verifier::SpecVerifier`] packs all cached drafts of a step into
//!    batched calls of the AOT `verify` entry — one teacher-forced forward
//!    whose L1 kernels score every draft token under the current policy and
//!    scan for the first rejection under the lenient acceptance rule
//!    `u <= min(1, l * p_curr/p_prev)` (Algorithm 1).
//! 3. [`SpecRollout::collect`] assembles verified prefixes into
//!    [`SeqTask`]s, lets the rollout engine decode only the continuations,
//!    and updates the cache with the new trajectories.
//!
//! [`variants`] implements the paper's ablation baselines (Random Reuse,
//! Delayed Reuse, Full Reuse, and Off == vanilla RLVR).

pub mod cache;
pub mod lenience;
pub mod variants;
pub mod verifier;

use anyhow::Result;

use crate::model::Policy;
use crate::rollout::{RolloutEngine, SampleCfg, SeqResult, SeqTask};
use crate::runtime::Engine;
use crate::util::{Rng, StageTimer};

pub use cache::{CacheEntry, RolloutCache};
pub use lenience::Lenience;
pub use variants::ReuseVariant;
pub use verifier::SpecVerifier;

/// Per-step speculative-reuse telemetry (Figures 8/9 series).
#[derive(Clone, Debug, Default)]
pub struct SpecStepStats {
    /// Sequences that had a cached draft to verify.
    pub drafts: usize,
    /// Mean verified prefix length over drafted sequences.
    pub mean_prefix_len: f64,
    /// Fraction of drafted sequences whose draft was fully reused.
    pub full_reuse_ratio: f64,
    /// Total reused tokens / newly decoded tokens.
    pub reused_tokens: usize,
    pub new_tokens: usize,
    /// Number of `verify` executable invocations.
    pub verify_calls: usize,
}

/// A prompt to roll out this step: `id` is the stable cache key
/// (prompt index × group + sample index).
#[derive(Clone, Debug)]
pub struct RolloutRequest {
    pub id: usize,
    pub prompt: Vec<i32>,
}

/// The speculative rollout coordinator.
pub struct SpecRollout {
    pub cache: RolloutCache,
    pub variant: ReuseVariant,
    pub lenience: Lenience,
    /// Current step counter (cache versioning).
    pub step: u64,
}

impl SpecRollout {
    pub fn new(variant: ReuseVariant, lenience: Lenience) -> Self {
        SpecRollout { cache: RolloutCache::new(), variant, lenience, step: 0 }
    }

    /// Vanilla RLVR (no reuse, cache still shadow-updated for overlap
    /// diagnostics like Figure 2).
    pub fn vanilla() -> Self {
        Self::new(ReuseVariant::Off, Lenience::Fixed(0.0))
    }

    /// Roll out one step's batch with speculative reuse.
    ///
    /// Returns results (sorted by id) and reuse telemetry. Stage timing:
    /// `verification` (verify calls + acceptance), `rollout` / `assembly`
    /// (inside the engine).
    pub fn collect(
        &mut self,
        eng: &Engine,
        rollout: &mut RolloutEngine,
        policy: &Policy,
        requests: &[RolloutRequest],
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, SpecStepStats)> {
        let mut stats = SpecStepStats::default();
        let loglen = self.lenience.log_value(self.step);

        // 1. split into drafted / fresh
        let mut tasks: Vec<SeqTask> = Vec::with_capacity(requests.len());
        let mut to_verify: Vec<(usize, &RolloutRequest, CacheEntry)> = Vec::new();
        for req in requests {
            match self.variant.draft_for(&self.cache, req.id, self.step) {
                Some(entry) => to_verify.push((req.id, req, entry)),
                None => tasks.push(SeqTask::fresh(req.id, req.prompt.clone())),
            }
        }

        // 2. verification (one packed engine call per wave of drafts)
        if !to_verify.is_empty() {
            let span = std::time::Instant::now();
            let verifier = SpecVerifier::new(eng, &policy.bundle)?;
            let accepted = match self.variant {
                ReuseVariant::Random => variants::random_rejects(&to_verify, rng),
                ReuseVariant::Full => {
                    to_verify.iter().map(|(_, _, e)| e.response.len()).collect()
                }
                _ => {
                    let (rejects, calls) =
                        verifier.verify(&policy.blob, &to_verify, loglen, cfg.temperature, rng)?;
                    stats.verify_calls = calls;
                    rejects
                }
            };
            stats.drafts = to_verify.len();
            let mut prefix_sum = 0usize;
            let mut full = 0usize;
            for ((id, req, entry), n_acc) in to_verify.into_iter().zip(accepted) {
                prefix_sum += n_acc;
                if n_acc == entry.response.len() {
                    full += 1;
                }
                tasks.push(SeqTask {
                    id,
                    prompt: req.prompt.clone(),
                    prefix: entry.response[..n_acc].to_vec(),
                    prefix_logps: entry.logps[..n_acc].to_vec(),
                });
            }
            stats.mean_prefix_len = prefix_sum as f64 / stats.drafts.max(1) as f64;
            stats.full_reuse_ratio = full as f64 / stats.drafts.max(1) as f64;
            timer.add("verification", span.elapsed().as_secs_f64());
        }

        // 3. generate continuations (continuous-batching scheduler)
        let (results, rstats) = rollout.run(&policy.blob, tasks, cfg, rng, timer)?;
        stats.reused_tokens = rstats.reused_tokens;
        stats.new_tokens = rstats.new_tokens;

        // 4. immediate cache refresh (the paper's "always the most recent
        //    policy's rollouts"); Off-variant keeps a shadow cache so
        //    overlap metrics stay measurable.
        for r in &results {
            self.cache.insert(r.id, CacheEntry::from_result(r, self.step));
        }
        self.step += 1;

        Ok((results, stats))
    }
}
