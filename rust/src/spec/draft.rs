//! Per-row adaptive draft-length control (`ARCHITECTURE.md` §14).
//!
//! A stale draft is pure overhead past its rejection point: every token
//! materialized, uploaded, and teacher-forced through `verify_seat`
//! beyond the accepted prefix is work the verifier throws away. The
//! `benchkit::stale` workload is the directed failure mode — rows whose
//! acceptance collapses re-offer their full dead draft every step.
//!
//! [`DraftControl`] clamps how much of a cached draft is materialized,
//! per row: when a row's acceptance ratio collapses below
//! [`SHRINK_BELOW`], its cap halves (floored at `spec.draft_len_min`);
//! when a capped row's acceptance recovers above [`GROW_ABOVE`], the cap
//! doubles back (ceilinged at `spec.draft_len_max`, 0 = uncapped) until
//! it un-caps entirely. `spec.draft_len_max` alone acts as a static
//! global clamp with adaptation off.
//!
//! Truncation changes draft *content*, so unlike queue reordering it is
//! not output-neutral across settings — the §6 identity obligation is
//! pipeline-vs-two-phase under the *same* control settings: clipping
//! happens in `SpecRollout::prepare`, shared verbatim by both paths, and
//! the controller's observations come from the step's merged results,
//! which the invariant already makes byte-identical. Two coordinators
//! configured alike therefore evolve identical caps
//! (`rust/tests/sched_continuous.rs` pins the sweep).

use std::collections::HashMap;

use super::cache::CacheEntry;
use super::variants::clip_entry;

/// Acceptance ratio below which a row's cap halves.
pub const SHRINK_BELOW: f64 = 0.5;

/// Acceptance ratio at or above which a capped row's cap doubles back.
pub const GROW_ABOVE: f64 = 0.9;

/// Tokens of sibling draft offered per token of shared spine depth
/// (`ARCHITECTURE.md` §8). A deep branch point means the group's samples
/// agreed that far, so a sibling's continuation is likely to survive
/// verification well past the spine; factor 2 keeps some speculative
/// reach beyond the provably-shared prefix without offering a stale
/// sibling's whole tail.
pub const DIVERGENCE_CAP_FACTOR: usize = 2;

/// Per-row draft-length clamp: a static `max` ceiling plus, with `adapt`
/// on, multiplicative-decrease / multiplicative-increase per-id caps
/// driven by realized acceptance.
#[derive(Clone, Debug)]
pub struct DraftControl {
    adapt: bool,
    /// Floor for adaptive shrinking (`spec.draft_len_min`, >= 1).
    min: usize,
    /// Static ceiling (`spec.draft_len_max`; 0 = uncapped).
    max: usize,
    /// Per-id adaptive caps (absent = row is at the static ceiling).
    caps: HashMap<usize, usize>,
    /// Draft lengths actually offered this step, by id — the denominator
    /// for the next [`DraftControl::observe`].
    offered: HashMap<usize, usize>,
}

impl Default for DraftControl {
    fn default() -> Self {
        DraftControl { adapt: false, min: 1, max: 0, caps: HashMap::new(), offered: HashMap::new() }
    }
}

impl DraftControl {
    /// A controller with the `spec.draft_len_{min,max,adapt}` knobs.
    /// `max == 0` means uncapped; `min` is clamped to at least 1.
    pub fn new(min: usize, max: usize, adapt: bool) -> Self {
        DraftControl { adapt, min: min.max(1), max, ..Self::default() }
    }

    /// True when the controller can never alter a draft (`adapt` off and
    /// no static ceiling) — the default-config fast path.
    pub fn is_noop(&self) -> bool {
        !self.adapt && self.max == 0
    }

    /// Static ceiling as a usable bound (`usize::MAX` when uncapped).
    fn ceiling(&self) -> usize {
        if self.max == 0 {
            usize::MAX
        } else {
            self.max
        }
    }

    /// Current effective cap for `id`.
    pub fn cap(&self, id: usize) -> usize {
        self.caps.get(&id).copied().unwrap_or(usize::MAX).min(self.ceiling())
    }

    /// Draft length offered for `id` in the step being prepared (0 if the
    /// row offered no draft).
    pub fn last_offered(&self, id: usize) -> usize {
        self.offered.get(&id).copied().unwrap_or(0)
    }

    /// Start a step: forget the previous step's offered lengths.
    pub fn begin_step(&mut self) {
        self.offered.clear();
    }

    /// Clamp `entry` to `id`'s effective cap, recording the offered
    /// length. Returns true when the draft was actually truncated (a
    /// truncated draft cannot still claim its terminal EOS —
    /// [`clip_entry`] clears `finished`).
    pub fn clip(&mut self, id: usize, entry: &mut CacheEntry) -> bool {
        let truncated = clip_entry(entry, self.cap(id));
        self.offered.insert(id, entry.response.len());
        truncated
    }

    /// Divergence-guided cap for a sibling-spine fallback draft, from the
    /// prompt's branch-point `depth` (`RolloutCache::branch_depth`): a
    /// row with no acceptance history of its own borrows the group's
    /// divergence signal instead. Deep shared spines earn
    /// [`DIVERGENCE_CAP_FACTOR`] tokens of offer per spine token; early
    /// divergence (depth 0) clamps to the `spec.draft_len_min` floor.
    /// Never exceeds the static ceiling. A pure function of the cache
    /// shape — no RNG, no per-row state — so it is identical on both
    /// drive paths and across shard counts.
    pub fn sibling_cap(&self, depth: usize) -> usize {
        depth.saturating_mul(DIVERGENCE_CAP_FACTOR).max(self.min).min(self.ceiling())
    }

    /// Fold one row's realized acceptance (`accepted` of the `offered`
    /// draft tokens survived verification) into its cap: halve below
    /// [`SHRINK_BELOW`] (floor `min`), double back at [`GROW_ABOVE`]
    /// (un-capping once the doubled cap clears both the offered length
    /// and the static ceiling). No-op unless `adapt` is on.
    pub fn observe(&mut self, id: usize, accepted: usize, offered: usize) {
        if !self.adapt || offered == 0 {
            return;
        }
        let ratio = accepted as f64 / offered as f64;
        if ratio < SHRINK_BELOW {
            self.caps.insert(id, (offered / 2).max(self.min));
        } else if ratio >= GROW_ABOVE {
            if let Some(&c) = self.caps.get(&id) {
                let grown = c.saturating_mul(2);
                // Doubling past the static ceiling stops binding — drop
                // the per-row cap and let the ceiling do the clamping.
                if grown >= self.ceiling() {
                    self.caps.remove(&id);
                } else {
                    self.caps.insert(id, grown);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(len: usize) -> CacheEntry {
        CacheEntry {
            response: (0..len as i32).collect(),
            logps: vec![-1.0; len],
            version: 0,
            finished: true,
        }
    }

    #[test]
    fn noop_controller_never_touches_a_draft() {
        let mut c = DraftControl::new(1, 0, false);
        assert!(c.is_noop());
        let mut e = entry(10);
        assert!(!c.clip(0, &mut e));
        assert_eq!(e.response.len(), 10);
        assert!(e.finished, "untouched draft keeps its terminal flag");
        assert_eq!(c.last_offered(0), 10);
    }

    #[test]
    fn static_ceiling_clamps_without_adaptation() {
        let mut c = DraftControl::new(1, 4, false);
        assert!(!c.is_noop());
        let mut e = entry(10);
        assert!(c.clip(0, &mut e));
        assert_eq!(e.response.len(), 4);
        assert_eq!(e.logps.len(), 4);
        assert!(!e.finished, "a truncated draft cannot claim terminal EOS");
        let mut short = entry(3);
        assert!(!c.clip(1, &mut short), "under-ceiling drafts pass through");
        assert!(short.finished);
    }

    #[test]
    fn collapsing_acceptance_halves_the_cap_to_the_floor() {
        let mut c = DraftControl::new(2, 0, true);
        c.observe(0, 1, 16); // ratio 1/16 < 0.5 -> cap 8
        assert_eq!(c.cap(0), 8);
        c.observe(0, 0, 8); // -> cap 4
        c.observe(0, 0, 4); // -> cap 2
        c.observe(0, 0, 2); // floored at min
        assert_eq!(c.cap(0), 2);
        let mut e = entry(16);
        assert!(c.clip(0, &mut e));
        assert_eq!(e.response.len(), 2);
    }

    #[test]
    fn high_acceptance_doubles_a_shrunk_cap_gradually() {
        let mut c = DraftControl::new(1, 0, true);
        c.observe(0, 0, 16); // ratio 0 -> cap 8
        assert_eq!(c.cap(0), 8);
        c.observe(0, 8, 8); // ratio 1.0 -> cap 16
        assert_eq!(c.cap(0), 16);
        c.observe(0, 15, 16); // ratio ~0.94 -> cap 32
        assert_eq!(c.cap(0), 32, "recovery doubles, it does not jump to uncapped");
    }

    #[test]
    fn growth_past_the_static_ceiling_uncaps_the_row() {
        let mut c = DraftControl::new(1, 12, true);
        c.observe(0, 0, 16); // cap 8
        assert_eq!(c.cap(0), 8);
        c.observe(0, 8, 8); // grown 16 >= ceiling 12 -> per-row cap dropped
        assert_eq!(c.cap(0), 12, "effective cap falls back to the static ceiling");
        let mut e = entry(20);
        c.clip(0, &mut e);
        assert_eq!(e.response.len(), 12);
    }

    #[test]
    fn middling_acceptance_leaves_the_cap_alone() {
        let mut c = DraftControl::new(1, 0, true);
        c.observe(0, 0, 10); // cap 5
        c.observe(0, 3, 5); // ratio 0.6: between thresholds
        assert_eq!(c.cap(0), 5);
        c.observe(0, 0, 0); // zero offer never divides by zero
        assert_eq!(c.cap(0), 5);
    }

    #[test]
    fn sibling_cap_scales_with_branch_depth() {
        let c = DraftControl::new(2, 0, false);
        assert_eq!(c.sibling_cap(0), 2, "early divergence clamps to the floor");
        assert_eq!(c.sibling_cap(1), 2);
        assert_eq!(c.sibling_cap(5), 10, "deep spines earn FACTOR tokens per spine token");
        assert_eq!(c.sibling_cap(usize::MAX), usize::MAX, "saturates, never wraps");
    }

    #[test]
    fn sibling_cap_respects_the_static_ceiling() {
        let c = DraftControl::new(1, 6, false);
        assert_eq!(c.sibling_cap(0), 1);
        assert_eq!(c.sibling_cap(2), 4);
        assert_eq!(c.sibling_cap(50), 6, "ceiling binds before the divergence signal");
    }

    #[test]
    fn begin_step_clears_offer_bookkeeping() {
        let mut c = DraftControl::new(1, 0, true);
        let mut e = entry(6);
        c.clip(3, &mut e);
        assert_eq!(c.last_offered(3), 6);
        c.begin_step();
        assert_eq!(c.last_offered(3), 0);
    }
}
