//! The lenience parameter ℓ and its schedules.
//!
//! ℓ shifts the acceptance boundary `u <= min(1, l * p_curr/p_prev)`:
//! ℓ=1 is exact speculative decoding, ℓ→∞ full reuse, ℓ→0 vanilla RLVR.
//! The paper uses fixed ℓ (e^0.5 GRPO, e^0.3 PPO, e^0.15 DAPO) and names
//! adaptive scheduling as future work — [`Lenience::Linear`] implements
//! the obvious first version of that extension (see DESIGN.md).

/// Lenience schedule; values are **log** lenience (log ℓ).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Lenience {
    /// Constant log ℓ.
    Fixed(f32),
    /// Full reuse (ℓ = ∞).
    Infinite,
    /// No reuse (ℓ = 0) — degenerates to vanilla RLVR.
    Zero,
    /// Linear ramp of log ℓ from `from` to `to` over `steps` (extension:
    /// conservative early when the policy moves fast, lenient late).
    Linear { from: f32, to: f32, steps: u64 },
}

impl Lenience {
    /// log ℓ at a given trainer step.
    pub fn log_value(&self, step: u64) -> f32 {
        match *self {
            Lenience::Fixed(x) => x,
            Lenience::Infinite => 1e9,
            Lenience::Zero => -1e9,
            Lenience::Linear { from, to, steps } => {
                if steps == 0 {
                    return to;
                }
                let a = (step.min(steps)) as f32 / steps as f32;
                from + (to - from) * a
            }
        }
    }

    /// Parse "e0.5", "1.0", "inf", "zero", "linear:0:0.8:45".
    pub fn parse(s: &str) -> Option<Lenience> {
        match s {
            "inf" | "infinite" => Some(Lenience::Infinite),
            "zero" | "off" | "0" => Some(Lenience::Zero),
            _ if s.starts_with("linear:") => {
                let parts: Vec<&str> = s[7..].split(':').collect();
                if parts.len() != 3 {
                    return None;
                }
                Some(Lenience::Linear {
                    from: parts[0].parse().ok()?,
                    to: parts[1].parse().ok()?,
                    steps: parts[2].parse().ok()?,
                })
            }
            _ if s.starts_with('e') => s[1..].parse().ok().map(Lenience::Fixed),
            _ => s.parse::<f32>().ok().map(|l| Lenience::Fixed(l.ln())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let l = Lenience::Fixed(0.5);
        assert_eq!(l.log_value(0), 0.5);
        assert_eq!(l.log_value(1000), 0.5);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Lenience::parse("e0.5"), Some(Lenience::Fixed(0.5)));
        assert_eq!(Lenience::parse("inf"), Some(Lenience::Infinite));
        assert_eq!(Lenience::parse("zero"), Some(Lenience::Zero));
        // plain number = ℓ itself: log applied
        let Some(Lenience::Fixed(x)) = Lenience::parse("1.0") else { panic!() };
        assert!(x.abs() < 1e-6);
        assert_eq!(
            Lenience::parse("linear:0:0.8:45"),
            Some(Lenience::Linear { from: 0.0, to: 0.8, steps: 45 })
        );
        assert_eq!(Lenience::parse("garbage"), None);
    }

    #[test]
    fn linear_ramps() {
        let l = Lenience::Linear { from: 0.0, to: 1.0, steps: 10 };
        assert_eq!(l.log_value(0), 0.0);
        assert!((l.log_value(5) - 0.5).abs() < 1e-6);
        assert_eq!(l.log_value(10), 1.0);
        assert_eq!(l.log_value(50), 1.0);
    }

    #[test]
    fn extremes() {
        assert!(Lenience::Infinite.log_value(3) > 1e8);
        assert!(Lenience::Zero.log_value(3) < -1e8);
    }
}
