//! Reuse variants: SPEC-RL proper plus the paper's ablation baselines.

use super::cache::{CacheEntry, RolloutCache};

/// How drafts are selected and accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReuseVariant {
    /// Vanilla RLVR: no reuse at all (cache shadow-updated for telemetry).
    Off,
    /// SPEC-RL: latest cached rollout, lenient speculative verification.
    Spec,
    /// Table 2 "Random Reuse": rejection offset ~ U{0..=len}, no verify.
    Random,
    /// Table 2 "Delayed Reuse": drafts from two steps back (the `previous`
    /// cache slot), speculative verification as usual.
    Delayed,
    /// ℓ=∞ shortcut: full reuse without running the verifier.
    Full,
}

impl ReuseVariant {
    pub fn parse(s: &str) -> Option<ReuseVariant> {
        match s {
            "off" | "vanilla" => Some(ReuseVariant::Off),
            "spec" | "spec-rl" => Some(ReuseVariant::Spec),
            "random" => Some(ReuseVariant::Random),
            "delayed" => Some(ReuseVariant::Delayed),
            "full" => Some(ReuseVariant::Full),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReuseVariant::Off => "off",
            ReuseVariant::Spec => "spec",
            ReuseVariant::Random => "random",
            ReuseVariant::Delayed => "delayed",
            ReuseVariant::Full => "full",
        }
    }

    /// Pick the draft for a sequence, if this variant reuses one: the
    /// root-to-leaf trie walk materializes the longest cached
    /// continuation for `id` (latest generation, or the one before for
    /// Delayed Reuse).
    pub fn draft_for(&self, cache: &RolloutCache, id: usize, _step: u64) -> Option<CacheEntry> {
        match self {
            ReuseVariant::Off => None,
            ReuseVariant::Spec | ReuseVariant::Random | ReuseVariant::Full => {
                cache.latest(id).filter(|e| !e.response.is_empty())
            }
            ReuseVariant::Delayed => cache.previous(id).filter(|e| !e.response.is_empty()),
        }
    }

    /// Whether this variant's drafts pass through the lenient verifier
    /// (token-by-token acceptance against `p_prev`). Only these variants
    /// may take the sibling-spine fallback: a cross-slot draft is sound
    /// precisely because every offered token is *verified* under the
    /// requesting id's §6 uniform stream. Random and Full resolve
    /// acceptance host-side with no scoring at all, so handing them a
    /// sibling's content would splice unverified foreign tokens into the
    /// requesting sequence.
    pub fn verification_gated(&self) -> bool {
        matches!(self, ReuseVariant::Spec | ReuseVariant::Delayed)
    }
}

/// Random-Reuse acceptance: uniform rejection offset per draft
/// ("roughly half of the tokens reused on expectation", zero verify cost).
/// Drawn from the task-keyed verification stream, so the offset depends
/// only on (verify nonce, task id) — order- and packing-invariant, which
/// keeps the interleaved pipeline byte-identical to the two-phase oracle
/// for this variant too.
pub fn random_reject(vnonce: u64, id: usize, draft_len: usize) -> usize {
    super::verifier::verify_rng(vnonce, id).below(draft_len + 1)
}

/// Truncate a materialized draft to at most `cap` tokens (response and
/// log-probs together). A truncated draft no longer carries its terminal
/// EOS, so `finished` is cleared — offering a clipped prefix as "complete"
/// would let full-reuse skip the decode the dropped tail still needs.
/// Returns whether anything was cut. The adaptive controller
/// (`spec::draft::DraftControl`) is the only production caller; it lives
/// here beside the other draft-shaping rules.
pub fn clip_entry(entry: &mut CacheEntry, cap: usize) -> bool {
    if entry.response.len() <= cap {
        return false;
    }
    entry.response.truncate(cap);
    entry.logps.truncate(cap);
    entry.finished = false;
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rollout::SeqResult;

    fn seed_cache() -> RolloutCache {
        let mut c = RolloutCache::new();
        for step in 0..3u64 {
            c.insert(
                5,
                CacheEntry::from_result(
                    &SeqResult {
                        id: 5,
                        response: vec![step as i32 + 10; 4],
                        logps: vec![-1.0; 4],
                        reused: 0,
                        new_tokens: 4,
                        finished: true,
                    },
                    step,
                ),
            );
        }
        c
    }

    #[test]
    fn off_never_drafts() {
        let c = seed_cache();
        assert!(ReuseVariant::Off.draft_for(&c, 5, 3).is_none());
    }

    #[test]
    fn spec_uses_latest() {
        let c = seed_cache();
        let d = ReuseVariant::Spec.draft_for(&c, 5, 3).unwrap();
        assert_eq!(d.version, 2);
    }

    #[test]
    fn delayed_uses_previous() {
        let c = seed_cache();
        let d = ReuseVariant::Delayed.draft_for(&c, 5, 3).unwrap();
        assert_eq!(d.version, 1);
    }

    #[test]
    fn miss_returns_none() {
        let c = seed_cache();
        assert!(ReuseVariant::Spec.draft_for(&c, 99, 3).is_none());
    }

    #[test]
    fn random_rejects_in_range() {
        let mut seen_full = false;
        let mut seen_zero = false;
        for nonce in 0..200u64 {
            let r = random_reject(nonce, 5, 4);
            assert!(r <= 4);
            seen_full |= r == 4;
            seen_zero |= r == 0;
        }
        assert!(seen_full && seen_zero);
    }

    #[test]
    fn random_reject_is_order_invariant() {
        // depends only on (nonce, id), not on call order or neighbours
        assert_eq!(random_reject(9, 3, 7), random_reject(9, 3, 7));
        assert_ne!(
            (0..50).map(|n| random_reject(n, 1, 7)).collect::<Vec<_>>(),
            (0..50).map(|n| random_reject(n, 2, 7)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn clip_entry_truncates_and_clears_terminal_flag() {
        let mut e = CacheEntry {
            response: vec![1, 2, 3, 4, 5],
            logps: vec![-0.1, -0.2, -0.3, -0.4, -0.5],
            version: 3,
            finished: true,
        };
        assert!(clip_entry(&mut e, 3));
        assert_eq!(e.response, vec![1, 2, 3]);
        assert_eq!(e.logps.len(), 3);
        assert!(!e.finished, "clipped drafts lose their terminal EOS claim");
        assert_eq!(e.version, 3, "version is untouched");

        let mut whole = e.clone();
        assert!(!clip_entry(&mut whole, 3), "cap == len cuts nothing");
        assert_eq!(whole.response, vec![1, 2, 3]);
        assert!(!clip_entry(&mut whole, usize::MAX), "uncapped is a no-op");
    }

    #[test]
    fn only_verified_variants_take_sibling_fallbacks() {
        assert!(ReuseVariant::Spec.verification_gated());
        assert!(ReuseVariant::Delayed.verification_gated());
        assert!(!ReuseVariant::Off.verification_gated());
        assert!(!ReuseVariant::Random.verification_gated(), "host-resolved acceptance");
        assert!(!ReuseVariant::Full.verification_gated(), "no verifier at all");
    }

    #[test]
    fn parse_names_roundtrip() {
        for v in [
            ReuseVariant::Off,
            ReuseVariant::Spec,
            ReuseVariant::Random,
            ReuseVariant::Delayed,
            ReuseVariant::Full,
        ] {
            assert_eq!(ReuseVariant::parse(v.name()), Some(v));
        }
    }
}
