//! `spec-rl` launcher: info / sft / train / eval / overlap / case-study.
//!
//! The heavier experiment drivers (paper tables & figures) live in
//! `benches/` and `examples/`; this binary is the day-to-day entry point.

use anyhow::{Context, Result};
use spec_rl::cli::{Cli, USAGE};
use spec_rl::metrics::Table;
use spec_rl::model::Policy;
use spec_rl::rollout::RolloutEngine;
use spec_rl::runtime::Engine;
use spec_rl::tokenizer::Tokenizer;
use spec_rl::trainer::eval::{evaluate, summarize};
use spec_rl::trainer::sft::{run_sft, SftConfig};
use spec_rl::trainer::Trainer;
use spec_rl::util::{logging, Rng};

fn main() {
    logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "--help" || args[0] == "help" {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "info" => info(&cli),
        "sft" => sft(&cli),
        "train" => train(&cli),
        "eval" => eval_cmd(&cli),
        "overlap" => overlap(&cli),
        "case-study" => case_study(&cli),
        other => {
            println!("{USAGE}");
            anyhow::bail!("unknown command '{other}'")
        }
    }
}

fn engine(cli: &Cli) -> Result<Engine> {
    Engine::load(cli.flag_or("artifacts", "artifacts"))
}

fn info(cli: &Cli) -> Result<()> {
    let eng = engine(cli)?;
    let m = &eng.manifest;
    println!(
        "artifacts: {:?}\nvocab {} | prompt_len {} | total_len {} | pallas {}",
        m.dir, m.vocab, m.prompt_len, m.total_len, m.use_pallas
    );
    let mut t = Table::new("bundles", &["bundle", "layers", "d_model", "heads", "params", "entries"]);
    for (name, b) in &m.bundles {
        t.row(vec![
            name.clone(),
            b.model.n_layers.to_string(),
            b.model.d_model.to_string(),
            b.model.n_heads.to_string(),
            b.n_params.to_string(),
            b.entries.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn sft(cli: &Cli) -> Result<()> {
    let eng = engine(cli)?;
    let bundle = cli.flag_or("bundle", "tiny_b32");
    let cfg = SftConfig {
        bundle: bundle.clone(),
        steps: cli.usize_flag("steps", 300),
        lr: cli.flag("lr").and_then(|s| s.parse().ok()).unwrap_or(1e-3),
        examples: cli.usize_flag("examples", 4096),
        seed: cli.usize_flag("seed", 7) as u64,
        init_from: cli.flag("resume").map(|s| s.to_string()),
    };
    let (policy, losses) = run_sft(&eng, &cfg)?;
    let out = cli.flag_or("out", &format!("out/base_{bundle}.npy"));
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    policy.save(&eng, &out)?;
    println!(
        "sft done: loss {:.4} -> {:.4}; checkpoint {out}",
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN)
    );
    Ok(())
}

fn load_base(eng: &Engine, cli: &Cli, bundle: &str) -> Result<Policy> {
    match cli.flag("base") {
        Some(path) => Policy::load(eng, bundle, path)
            .with_context(|| format!("loading base checkpoint {path}")),
        None => {
            log::warn!("no --base checkpoint: starting RL from the raw init blob");
            Policy::from_init(eng, bundle)
        }
    }
}

fn train(cli: &Cli) -> Result<()> {
    let cfg = cli.run_config()?;
    let eng = Engine::load(&cfg.artifacts_dir)?;
    let base = load_base(&eng, cli, &cfg.bundle)?;
    let label = format!("{}+{}", cfg.algo.name(), cfg.variant.name());
    let mut trainer = Trainer::new(&eng, cfg, base)?;
    let summary = trainer.run(&label)?;
    let (math, ood, avg) = summarize(&summary.final_eval);
    let mut t = Table::new(&format!("run summary: {label}"), &["metric", "value"]);
    t.row(vec!["steps".into(), summary.steps.to_string()]);
    t.row(vec!["new tokens".into(), summary.total_new_tokens.to_string()]);
    t.row(vec!["reused tokens".into(), summary.total_reused_tokens.to_string()]);
    t.row(vec!["rollout secs".into(), format!("{:.2}", summary.rollout_secs)]);
    t.row(vec!["verify secs".into(), format!("{:.2}", summary.verify_secs)]);
    t.row(vec!["total secs".into(), format!("{:.2}", summary.total_secs)]);
    t.row(vec!["final train reward".into(), format!("{:.3}", summary.final_reward)]);
    for (name, acc) in &summary.final_eval {
        t.row(vec![format!("eval {name}"), format!("{acc:.3}")]);
    }
    t.row(vec!["math avg".into(), format!("{math:.3}")]);
    t.row(vec!["ood avg".into(), format!("{ood:.3}")]);
    t.row(vec!["avg".into(), format!("{avg:.3}")]);
    println!("{}", t.render());
    if let Some(out) = cli.flag("out") {
        trainer.policy.save(&eng, out)?;
        println!("saved checkpoint {out}");
    }
    Ok(())
}

fn eval_cmd(cli: &Cli) -> Result<()> {
    let eng = engine(cli)?;
    let bundle = cli.flag_or("bundle", "tiny_b32");
    let policy = load_base(&eng, cli, &bundle)?;
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, &bundle)?;
    let mut rng = Rng::new(cli.usize_flag("seed", 33) as u64);
    let evals = evaluate(
        &eng,
        &mut rollout,
        &policy,
        &tok,
        cli.usize_flag("n", 32),
        cli.usize_flag("samples-hard", 4),
        &mut rng,
    )?;
    let mut t = Table::new(&format!("eval: {bundle}"), &["suite", "accuracy"]);
    for (name, acc) in &evals {
        t.row(vec![name.clone(), format!("{acc:.3}")]);
    }
    let (math, ood, avg) = summarize(&evals);
    t.row(vec!["MATH-AVG".into(), format!("{math:.3}")]);
    t.row(vec!["OOD-AVG".into(), format!("{ood:.3}")]);
    t.row(vec!["AVG".into(), format!("{avg:.3}")]);
    println!("{}", t.render());
    Ok(())
}

/// Figure 2: cross-epoch ROUGE-1 overlap under vanilla training.
fn overlap(cli: &Cli) -> Result<()> {
    let mut cfg = cli.run_config()?;
    cfg.variant = spec_rl::spec::ReuseVariant::Off;
    cfg.steps = cli.usize_flag("steps", 2 * cfg.steps_per_epoch());
    let eng = Engine::load(&cfg.artifacts_dir)?;
    let base = load_base(&eng, cli, &cfg.bundle)?;
    let mut trainer = Trainer::new(&eng, cfg, base)?;
    let mut series = Vec::new();
    for s in 0..trainer.cfg.steps {
        let rec = trainer.step(s)?;
        if !rec["rouge1_prev_epoch"].is_nan() {
            series.push((s, rec["rouge1_prev_epoch"]));
        }
    }
    trainer.report.save()?;
    let mut t = Table::new("cross-epoch ROUGE-1 overlap (Figure 2)", &["step", "rouge1"]);
    for (s, r) in &series {
        t.row(vec![s.to_string(), format!("{r:.3}")]);
    }
    println!("{}", t.render());
    println!(
        "mean overlap: {:.3}",
        series.iter().map(|(_, r)| r).sum::<f64>() / series.len().max(1) as f64
    );
    Ok(())
}

/// Figures 12-15: show reused prefix vs regenerated suffix for one batch.
fn case_study(cli: &Cli) -> Result<()> {
    let mut cfg = cli.run_config()?;
    cfg.steps = cfg.steps_per_epoch() + 1; // just into epoch 2
    let eng = Engine::load(&cfg.artifacts_dir)?;
    let base = load_base(&eng, cli, &cfg.bundle)?;
    let mut trainer = Trainer::new(&eng, cfg, base)?;
    // run one epoch to fill the cache
    for s in 0..trainer.cfg.steps_per_epoch() {
        trainer.step(s)?;
    }
    // then show the first step of epoch 2 with verification detail
    let tok = trainer.tok.clone();
    let prompt_ids: Vec<usize> = (0..trainer.cfg.prompts_per_step).collect();
    let mut drafts = Vec::new();
    for &pi in prompt_ids.iter().take(4) {
        let id = pi * trainer.cfg.group;
        if let Some(prev) = trainer.spec.cache.latest(id) {
            drafts.push((pi, id, prev.response.clone()));
        }
    }
    let rec = trainer.step(trainer.cfg.steps_per_epoch())?;
    println!(
        "step stats: prefix_len={:.1} full_reuse={:.2} new_tokens={}",
        rec["prefix_len"], rec["full_reuse"], rec["tokens_new"] as u64
    );
    for (pi, id, draft) in drafts {
        println!("--- prompt: {}", trainer.train_set[pi].prompt);
        println!("  old rollout (draft): {}", tok.decode(&draft));
        if let Some(cur) = trainer.spec.cache.latest(id) {
            let shared = spec_rl::metrics::overlap::common_prefix_len(&draft, &cur.response);
            println!("  new rollout        : {}", tok.decode(&cur.response));
            println!("  verified prefix    : {} tokens", shared);
        }
    }
    Ok(())
}
