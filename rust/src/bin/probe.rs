// Round-trip probe: load an AOT-lowered entry point, execute it on the PJRT
// CPU client with buffer-resident args, and read a sub-range of the flat
// output. Validates the blob-in/blob-out runtime design end to end.
use anyhow::Result;
use spec_rl::runtime::manifest::Manifest;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let client = xla::PjRtClient::cpu()?;
    println!("platform={}", client.platform_name());

    // The typed manifest parser replaces the old substring scrape, which
    // silently misread any key that was a substring of another (e.g.
    // "batch" matching inside "rollout_batch").
    let manifest = Manifest::load(&dir)?;
    let bundle = manifest.bundle("tiny_b8")?;
    let blob_size = bundle.blob_size;
    let (b, t, g) = (bundle.batch, manifest.total_len, manifest.gen_len());
    println!("blob_size={blob_size} batch={b} total_len={t} gen_len={g}");

    let proto = xla::HloModuleProto::from_text_file(&format!("{dir}/tiny_b8/score.hlo.txt"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let t0 = std::time::Instant::now();
    let exe = client.compile(&comp)?;
    println!("compile score: {:?}", t0.elapsed());

    // init blob from npy
    use xla::FromRawBytes;
    let lit = xla::Literal::read_npy(format!("{dir}/{}", bundle.init_blob), &())?;
    println!("init blob elems={}", lit.element_count());
    let blob_host = lit.to_vec::<f32>()?;
    let blob = client.buffer_from_host_buffer(&blob_host, &[blob_size], None)?;

    let tokens: Vec<i32> = (0..b * t).map(|i| 3 + (i as i32 % 40)).collect();
    let valid: Vec<f32> = vec![1.0; b * t];
    let temp: Vec<f32> = vec![1.0];
    let tok_buf = client.buffer_from_host_buffer(&tokens, &[b, t], None)?;
    let val_buf = client.buffer_from_host_buffer(&valid, &[b, t], None)?;
    let temp_buf = client.buffer_from_host_buffer(&temp, &[1], None)?;

    let t1 = std::time::Instant::now();
    let outs = exe.execute_b(&[&blob, &tok_buf, &val_buf, &temp_buf])?;
    println!("execute: {:?} n_out_buffers={}", t1.elapsed(), outs[0].len());
    let out = &outs[0][0];
    println!("out shape={:?}", out.on_device_shape()?);

    // CopyRawToHost is not implemented on this CPU plugin: read via literal.
    let t15 = std::time::Instant::now();
    let out_lit = out.to_literal_sync()?;
    println!("to_literal: {:?}", t15.elapsed());
    let all = out_lit.to_vec::<f32>()?;
    println!("logp[0..4]={:?} ent[0..4]={:?}", &all[..4], &all[b * g..b * g + 4]);
    // steady-state timing
    for i in 0..3 {
        let t2 = std::time::Instant::now();
        let _ = exe.execute_b(&[&blob, &tok_buf, &val_buf, &temp_buf])?;
        println!("execute{}: {:?}", i + 3, t2.elapsed());
    }
    println!("probe OK");
    Ok(())
}
