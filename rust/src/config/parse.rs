//! TOML-subset parser.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A scalar or flat-array config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key -> value` (keys before any section header
/// live in the "" section).
#[derive(Clone, Debug, Default)]
pub struct ConfigDoc {
    pub entries: BTreeMap<String, Value>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header '{raw}'", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            doc.entries.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Merge `other` over `self` (CLI overrides).
    pub fn merge(&mut self, other: ConfigDoc) {
        self.entries.extend(other.entries);
    }

    // typed getters with defaults ------------------------------------------
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|x| x as usize).unwrap_or(default)
    }

    /// Unsigned 64-bit getter (the RPC knobs in §13 are millisecond and
    /// retry counts): negative integers floor at zero instead of
    /// wrapping, so a typo'd `-1` cannot become a 584-million-year
    /// timeout.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_i64()).map(|x| x.max(0) as u64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(body) = inner.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string {s}");
        };
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("line {lineno}: unterminated array {s}");
        };
        let mut items = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word => string (ergonomic for CLI overrides)
    Ok(Value::Str(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = ConfigDoc::parse(
            r#"
            top = 1
            [run]
            algo = "grpo"       # the algorithm
            steps = 45
            lr = 3e-4
            spec = true
            mix = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top"), Some(&Value::Int(1)));
        assert_eq!(doc.str_or("run.algo", ""), "grpo");
        assert_eq!(doc.usize_or("run.steps", 0), 45);
        assert!((doc.f64_or("run.lr", 0.0) - 3e-4).abs() < 1e-12);
        assert!(doc.bool_or("run.spec", false));
        assert_eq!(
            doc.get("run.mix"),
            Some(&Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
    }

    #[test]
    fn comments_and_hash_in_strings() {
        let doc = ConfigDoc::parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.str_or("name", ""), "a#b");
    }

    #[test]
    fn merge_overrides() {
        let mut a = ConfigDoc::parse("[run]\nsteps = 10\nalgo = \"grpo\"").unwrap();
        let b = ConfigDoc::parse("[run]\nsteps = 20").unwrap();
        a.merge(b);
        assert_eq!(a.usize_or("run.steps", 0), 20);
        assert_eq!(a.str_or("run.algo", ""), "grpo");
    }

    #[test]
    fn bad_lines_error() {
        assert!(ConfigDoc::parse("[unclosed").is_err());
        assert!(ConfigDoc::parse("novalue").is_err());
        assert!(ConfigDoc::parse("s = \"open").is_err());
    }

    #[test]
    fn defaults_apply() {
        let doc = ConfigDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("x", 7), 7);
        assert_eq!(doc.str_or("y", "d"), "d");
    }

    #[test]
    fn rpc_knob_keys_parse_with_defaults_and_negative_floor() {
        // the remote-backend knobs (`ARCHITECTURE.md` §13) ride the plain
        // TOML-subset path: integers under [rollout]
        let doc =
            ConfigDoc::parse("[rollout]\nrpc_timeout_ms = 250\nmax_retries = 5").unwrap();
        assert_eq!(doc.u64_or("rollout.rpc_timeout_ms", 5_000), 250);
        assert_eq!(doc.u64_or("rollout.max_retries", 2), 5);
        // missing keys fall back to the caller's default
        let doc = ConfigDoc::parse("").unwrap();
        assert_eq!(doc.u64_or("rollout.rpc_timeout_ms", 5_000), 5_000);
        // negative integers floor at zero rather than wrapping to huge
        let doc = ConfigDoc::parse("[rollout]\nmax_retries = -3").unwrap();
        assert_eq!(doc.u64_or("rollout.max_retries", 2), 0);
    }
}
