//! Configuration system: a TOML-subset parser + the typed run config.
//!
//! (The `toml`/`serde` crates are unavailable offline; [`parse`] covers
//! the subset real configs use: `[section]`, `key = value` with strings,
//! ints, floats, bools and flat arrays, plus `#` comments.)

pub mod parse;
pub mod run;

pub use parse::{ConfigDoc, Value};
pub use run::RunConfig;
