//! The typed run configuration assembled from defaults + config file + CLI.

use anyhow::{Context, Result};

use super::parse::ConfigDoc;
use crate::algo::{Algo, AlgoParams};
use crate::runtime::remote::{DEFAULT_MAX_RETRIES, DEFAULT_RPC_TIMEOUT_MS};
use crate::spec::{Lenience, ReuseVariant};

/// Everything a training run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    // -- environment ---------------------------------------------------------
    pub artifacts_dir: String,
    pub out_dir: String,
    pub bundle: String,
    pub critic_bundle: String,
    pub seed: u64,

    // -- data -----------------------------------------------------------------
    /// "SynthMath-A" or "SynthMath-B".
    pub dataset: String,
    /// Number of distinct training prompts (the paper's 6K/8K axis).
    pub n_prompts: usize,

    // -- RL loop ----------------------------------------------------------------
    pub algo: Algo,
    pub params: AlgoParams,
    /// Prompts per step (rollout batch = prompts_per_step * group).
    pub prompts_per_step: usize,
    /// Samples per prompt (GRPO group size; the paper's rollout N).
    pub group: usize,
    pub steps: usize,
    pub temperature: f32,
    pub top_p: f32,
    /// Engines in the rollout pool (`rollout.shards`, default 1). Each
    /// shard runs its own slot pool; work spills across them LPT-first
    /// (see `rollout::pool`). Results are shard-count-invariant.
    pub rollout_shards: usize,
    /// Per-complete RPC timeout in milliseconds for remote-backend shards
    /// (`rollout.rpc_timeout_ms`, default 5000, clamped to
    /// [1, 3_600_000]). Only consulted by
    /// `runtime::remote::RemoteBackend`; in-process shards ignore it.
    pub rpc_timeout_ms: u64,
    /// Retry budget per ticketed RPC (`rollout.max_retries`, default 2,
    /// clamped to <= 64). Retries are idempotent-safe by the transport
    /// contract — a resubmitted ticket can never double-apply a forward
    /// (`ARCHITECTURE.md` §13) — so raising this trades latency under
    /// flaky links for fewer shard failures, never correctness.
    pub rpc_max_retries: u64,

    // -- SPEC-RL -----------------------------------------------------------------
    pub variant: ReuseVariant,
    pub lenience: Lenience,
    /// Rollout-cache token budget (0 = unbounded). Past it, oldest-version
    /// entries are evicted (see `spec::cache`).
    pub cache_budget_tokens: usize,
    /// Adaptive verify seating (`spec.verify_seat_min`, default 1): seat a
    /// packed `verify_seat` sub-batch only when at least this many slots
    /// are free (clamped to the bundle batch). 1 = seat eagerly; larger
    /// values trade verify latency for fuller sub-batches. Results are
    /// identical for every value.
    pub verify_seat_min: usize,
    /// Predicted-length scheduling (`rollout.predict_len`, default off):
    /// per-task EWMA length estimates replace the raw prefix/draft
    /// lengths as the work queue's LPT keys (`ARCHITECTURE.md` §14).
    /// Pure reordering — results are byte-identical either way.
    pub predict_len: bool,
    /// Adaptive draft-length floor (`spec.draft_len_min`, default 1,
    /// must be >= 1): shrinking never clamps a draft below this.
    pub draft_len_min: usize,
    /// Static draft-length ceiling (`spec.draft_len_max`, default 0 =
    /// uncapped): no materialized draft exceeds this many tokens.
    pub draft_len_max: usize,
    /// Per-row adaptive draft-length control (`spec.draft_len_adapt`,
    /// default off): halve a row's draft cap when its acceptance
    /// collapses, double it back on high-acceptance steps (§14).
    pub draft_len_adapt: bool,
    /// Trie-aware sibling-spine fallback drafts (`spec.sibling_drafts`,
    /// default on): a row whose own cached leaf was evicted (or whose
    /// prompt is fresh this epoch) is offered the longest surviving
    /// sibling leaf under the same prompt root, clamped by the group's
    /// branch-point depth (`ARCHITECTURE.md` §8). Off = bit-exact
    /// own-leaf-only draft selection.
    pub sibling_drafts: bool,

    // -- evaluation ---------------------------------------------------------------
    pub eval_every: usize,
    pub eval_n: usize,
    /// Pass@1 sample count for the hard suite (paper: 16/32 for AMC/AIME).
    pub eval_samples_hard: usize,

    // -- SFT (base-model pretraining) ------------------------------------------------
    pub sft_steps: usize,
    pub sft_lr: f32,
    pub sft_examples: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        let algo = Algo::Grpo;
        RunConfig {
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
            bundle: "tiny_b32".into(),
            critic_bundle: "critic_b32".into(),
            seed: 17,
            dataset: "SynthMath-A".into(),
            n_prompts: 96,
            algo,
            params: algo.default_params(),
            prompts_per_step: 8,
            group: 4,
            steps: 45,
            temperature: 1.0,
            top_p: 1.0,
            rollout_shards: 1,
            rpc_timeout_ms: DEFAULT_RPC_TIMEOUT_MS,
            rpc_max_retries: DEFAULT_MAX_RETRIES,
            variant: ReuseVariant::Spec,
            lenience: Lenience::Fixed(0.5),
            cache_budget_tokens: 0,
            verify_seat_min: 1,
            predict_len: false,
            draft_len_min: 1,
            draft_len_max: 0,
            draft_len_adapt: false,
            sibling_drafts: true,
            eval_every: 5,
            eval_n: 32,
            eval_samples_hard: 4,
            sft_steps: 300,
            sft_lr: 1e-3,
            sft_examples: 4096,
        }
    }
}

impl RunConfig {
    /// Rollout batch per step.
    pub fn rollout_batch(&self) -> usize {
        self.prompts_per_step * self.group
    }

    /// Steps per epoch over the prompt set.
    pub fn steps_per_epoch(&self) -> usize {
        self.n_prompts.div_ceil(self.prompts_per_step)
    }

    /// Build from a parsed doc (all keys optional; `algo` resets params to
    /// that algorithm's defaults before key-level overrides apply).
    pub fn from_doc(doc: &ConfigDoc) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        if let Some(v) = doc.get("run.algo").and_then(|v| v.as_str()) {
            c.algo = Algo::parse(v).with_context(|| format!("unknown algo '{v}'"))?;
            c.params = c.algo.default_params();
            c.lenience = Lenience::Fixed(c.params.default_log_lenience);
        }
        c.artifacts_dir = doc.str_or("run.artifacts_dir", &c.artifacts_dir);
        c.out_dir = doc.str_or("run.out_dir", &c.out_dir);
        c.bundle = doc.str_or("run.bundle", &c.bundle);
        c.critic_bundle = doc.str_or("run.critic_bundle", &c.critic_bundle);
        c.seed = doc.usize_or("run.seed", c.seed as usize) as u64;
        c.dataset = doc.str_or("run.dataset", &c.dataset);
        c.n_prompts = doc.usize_or("run.n_prompts", c.n_prompts);
        c.prompts_per_step = doc.usize_or("run.prompts_per_step", c.prompts_per_step);
        c.group = doc.usize_or("run.group", c.group);
        c.steps = doc.usize_or("run.steps", c.steps);
        c.temperature = doc.f64_or("run.temperature", c.temperature as f64) as f32;
        c.top_p = doc.f64_or("run.top_p", c.top_p as f64) as f32;
        c.rollout_shards = doc.usize_or("rollout.shards", c.rollout_shards);
        c.rpc_timeout_ms =
            doc.u64_or("rollout.rpc_timeout_ms", c.rpc_timeout_ms).clamp(1, 3_600_000);
        c.rpc_max_retries = doc.u64_or("rollout.max_retries", c.rpc_max_retries).min(64);
        if let Some(v) = doc.get("spec.variant").and_then(|v| v.as_str()) {
            c.variant =
                ReuseVariant::parse(v).with_context(|| format!("unknown variant '{v}'"))?;
        }
        if let Some(v) = doc.get("spec.lenience").and_then(|v| v.as_str()) {
            c.lenience =
                Lenience::parse(v).with_context(|| format!("bad lenience '{v}'"))?;
        }
        c.cache_budget_tokens = doc.usize_or("spec.cache_budget", c.cache_budget_tokens);
        c.verify_seat_min = doc.usize_or("spec.verify_seat_min", c.verify_seat_min);
        c.predict_len = doc.bool_or("rollout.predict_len", c.predict_len);
        c.draft_len_min = doc.usize_or("spec.draft_len_min", c.draft_len_min);
        c.draft_len_max = doc.usize_or("spec.draft_len_max", c.draft_len_max);
        c.draft_len_adapt = doc.bool_or("spec.draft_len_adapt", c.draft_len_adapt);
        c.sibling_drafts = doc.bool_or("spec.sibling_drafts", c.sibling_drafts);
        c.params.lr = doc.f64_or("train.lr", c.params.lr as f64) as f32;
        c.params.critic_lr = doc.f64_or("train.critic_lr", c.params.critic_lr as f64) as f32;
        c.params.kl_coef = doc.f64_or("train.kl_coef", c.params.kl_coef as f64) as f32;
        c.params.ent_coef = doc.f64_or("train.ent_coef", c.params.ent_coef as f64) as f32;
        c.params.clip_low = doc.f64_or("train.clip_low", c.params.clip_low as f64) as f32;
        c.params.clip_high = doc.f64_or("train.clip_high", c.params.clip_high as f64) as f32;
        c.eval_every = doc.usize_or("eval.every", c.eval_every);
        c.eval_n = doc.usize_or("eval.n", c.eval_n);
        c.eval_samples_hard = doc.usize_or("eval.samples_hard", c.eval_samples_hard);
        c.sft_steps = doc.usize_or("sft.steps", c.sft_steps);
        c.sft_lr = doc.f64_or("sft.lr", c.sft_lr as f64) as f32;
        c.sft_examples = doc.usize_or("sft.examples", c.sft_examples);
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.group >= 1, "group must be >= 1");
        anyhow::ensure!(
            self.algo != Algo::Grpo && self.algo != Algo::Dapo || self.group >= 2,
            "GRPO/DAPO need group >= 2 for group-relative advantages"
        );
        anyhow::ensure!(self.prompts_per_step >= 1, "prompts_per_step must be >= 1");
        anyhow::ensure!(self.n_prompts >= self.prompts_per_step, "n_prompts < prompts_per_step");
        anyhow::ensure!(self.temperature > 0.0, "temperature must be > 0");
        anyhow::ensure!((0.0..=1.0).contains(&self.top_p), "top_p in (0, 1]");
        anyhow::ensure!(self.rollout_shards >= 1, "rollout.shards must be >= 1");
        anyhow::ensure!(self.rpc_timeout_ms >= 1, "rollout.rpc_timeout_ms must be >= 1");
        anyhow::ensure!(self.rpc_max_retries <= 64, "rollout.max_retries must be <= 64");
        anyhow::ensure!(self.verify_seat_min >= 1, "spec.verify_seat_min must be >= 1");
        anyhow::ensure!(self.draft_len_min >= 1, "spec.draft_len_min must be >= 1");
        anyhow::ensure!(
            self.draft_len_max == 0 || self.draft_len_max >= self.draft_len_min,
            "spec.draft_len_max must be 0 (uncapped) or >= spec.draft_len_min"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn from_doc_applies_algo_defaults_then_overrides() {
        let doc = ConfigDoc::parse(
            r#"
            [run]
            algo = "dapo"
            steps = 10
            [train]
            clip_high = 0.3
            "#,
        )
        .unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.algo, Algo::Dapo);
        assert!(c.params.dynamic_sampling);
        assert_eq!(c.params.clip_high, 0.3); // override wins
        assert_eq!(c.steps, 10);
        // DAPO's paper lenience default
        assert_eq!(c.lenience, Lenience::Fixed(0.15));
    }

    #[test]
    fn rollout_shards_parses_and_validates() {
        let doc = ConfigDoc::parse("[rollout]\nshards = 4").unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.rollout_shards, 4);
        assert_eq!(RunConfig::default().rollout_shards, 1, "single engine by default");
        let doc = ConfigDoc::parse("[rollout]\nshards = 0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "zero shards rejected");
    }

    #[test]
    fn rpc_knobs_parse_default_and_clamp() {
        let doc =
            ConfigDoc::parse("[rollout]\nrpc_timeout_ms = 250\nmax_retries = 5").unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.rpc_timeout_ms, 250);
        assert_eq!(c.rpc_max_retries, 5);
        let d = RunConfig::default();
        assert_eq!(d.rpc_timeout_ms, DEFAULT_RPC_TIMEOUT_MS);
        assert_eq!(d.rpc_max_retries, DEFAULT_MAX_RETRIES);
        // clamps: a zero timeout floors at 1ms, an absurd one caps at an
        // hour, and the retry budget caps at 64
        let doc = ConfigDoc::parse("[rollout]\nrpc_timeout_ms = 0").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().rpc_timeout_ms, 1);
        let doc = ConfigDoc::parse("[rollout]\nrpc_timeout_ms = 999999999").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().rpc_timeout_ms, 3_600_000);
        let doc = ConfigDoc::parse("[rollout]\nmax_retries = 1000").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().rpc_max_retries, 64);
        // validate still guards hand-built configs that skip from_doc
        let mut c = RunConfig::default();
        c.rpc_timeout_ms = 0;
        assert!(c.validate().is_err(), "zero timeout rejected");
        let mut c = RunConfig::default();
        c.rpc_max_retries = 65;
        assert!(c.validate().is_err(), "over-budget retries rejected");
    }

    #[test]
    fn verify_seat_min_parses_and_validates() {
        let doc = ConfigDoc::parse("[spec]\nverify_seat_min = 4").unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.verify_seat_min, 4);
        assert_eq!(RunConfig::default().verify_seat_min, 1, "eager seating by default");
        let doc = ConfigDoc::parse("[spec]\nverify_seat_min = 0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "zero seat-min rejected");
    }

    #[test]
    fn predict_len_parses_and_defaults_off() {
        assert!(!RunConfig::default().predict_len, "raw LPT keys by default");
        let doc = ConfigDoc::parse("[rollout]\npredict_len = true").unwrap();
        assert!(RunConfig::from_doc(&doc).unwrap().predict_len);
        let doc = ConfigDoc::parse("[rollout]\npredict_len = false").unwrap();
        assert!(!RunConfig::from_doc(&doc).unwrap().predict_len);
    }

    #[test]
    fn draft_len_knobs_parse_and_validate() {
        let d = RunConfig::default();
        assert_eq!((d.draft_len_min, d.draft_len_max, d.draft_len_adapt), (1, 0, false));
        let doc = ConfigDoc::parse(
            "[spec]\ndraft_len_min = 2\ndraft_len_max = 32\ndraft_len_adapt = true",
        )
        .unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!((c.draft_len_min, c.draft_len_max, c.draft_len_adapt), (2, 32, true));
        // floor must stay >= 1
        let doc = ConfigDoc::parse("[spec]\ndraft_len_min = 0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "zero floor rejected");
        // a non-zero ceiling below the floor is contradictory
        let doc = ConfigDoc::parse("[spec]\ndraft_len_min = 8\ndraft_len_max = 4").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err(), "ceiling below floor rejected");
        // 0 ceiling always means uncapped, whatever the floor
        let doc = ConfigDoc::parse("[spec]\ndraft_len_min = 8\ndraft_len_max = 0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_ok());
    }

    #[test]
    fn sibling_drafts_parses_and_defaults_on() {
        assert!(RunConfig::default().sibling_drafts, "fallback drafts on by default");
        let doc = ConfigDoc::parse("[spec]\nsibling_drafts = false").unwrap();
        assert!(!RunConfig::from_doc(&doc).unwrap().sibling_drafts);
        let doc = ConfigDoc::parse("[spec]\nsibling_drafts = true").unwrap();
        assert!(RunConfig::from_doc(&doc).unwrap().sibling_drafts);
    }

    #[test]
    fn cache_budget_parses() {
        let doc = ConfigDoc::parse("[spec]\ncache_budget = 4096").unwrap();
        let c = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(c.cache_budget_tokens, 4096);
        assert_eq!(RunConfig::default().cache_budget_tokens, 0, "unbounded by default");
    }

    #[test]
    fn bad_algo_errors() {
        let doc = ConfigDoc::parse("[run]\nalgo = \"sarsa\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn invalid_group_rejected() {
        let mut c = RunConfig::default();
        c.group = 1;
        assert!(c.validate().is_err()); // GRPO needs group >= 2
        c.algo = Algo::Ppo;
        c.params = Algo::Ppo.default_params();
        assert!(c.validate().is_ok()); // PPO is fine with 1
    }

    #[test]
    fn derived_sizes() {
        let c = RunConfig::default();
        assert_eq!(c.rollout_batch(), 32);
        assert_eq!(c.steps_per_epoch(), 12);
    }
}
