//! Micro-benchmark harness (criterion substitute for the offline image).
//!
//! `cargo bench` benches use [`Bench`] for hot-path measurements
//! (warmup, N samples, mean/median/p95/stddev) and plain drivers for the
//! end-to-end table regenerations. [`drafted`] is the canonical skewed
//! drafted-step workload shared by the scheduling benches.

use std::time::Instant;

/// The canonical "skewed 40-draft" workload shared by `bench_pipeline`
/// and `bench_shards` — one definition, so the two benches cannot drift
/// apart (same geometry, same lenience, same two-nonce RNG replay).
pub mod drafted {
    use crate::rollout::SeqResult;
    use crate::spec::{CacheEntry, Lenience, ReuseVariant, RolloutRequest, SpecRollout};
    use crate::tokenizer::BOS;
    use crate::util::Rng;

    /// Slot rows per engine.
    pub const B: usize = 8;
    /// Prompt region length.
    pub const P: usize = 16;
    /// Total sequence length.
    pub const T: usize = 64;
    /// Vocabulary size.
    pub const V: usize = 51;
    /// Drafted tasks per step.
    pub const N_TASKS: usize = 40;
    /// Workload seed.
    pub const SEED: u64 = 7;
    /// Negative log-lenience stands in for policy drift on the mock's
    /// frozen policy: acceptance truncates drafts at varied,
    /// content-dependent offsets — the reuse-heavy skew SPEC-RL produces
    /// after its first epoch.
    pub const LOG_LENIENCE: f32 = -0.25;

    /// One step's request batch (prompt variety ⇒ skewed lengths).
    pub fn requests() -> Vec<RolloutRequest> {
        (0..N_TASKS)
            .map(|i| RolloutRequest {
                id: i,
                prompt: vec![BOS, 3 + (i as i32 % 40), 5 + (i as i32 % 11)],
            })
            .collect()
    }

    /// A [`SpecRollout`] warmed to the post-epoch-0 state (cache filled
    /// from the template rollouts, step = 1), so every measured pass
    /// benches exactly one fully-drafted step.
    pub fn warmed(template: &[SeqResult]) -> SpecRollout {
        let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(LOG_LENIENCE));
        for r in template {
            spec.cache.insert(r.id, CacheEntry::from_result(r, 0));
        }
        spec.step = 1;
        spec
    }

    /// The RNG exactly as `collect` left it after epoch 0 (two nonce
    /// draws in `prepare`).
    pub fn epoch1_rng() -> Rng {
        let mut rng = Rng::new(SEED);
        rng.next_u64();
        rng.next_u64();
        rng
    }
}

/// The adversarial **stale-draft** workload: the one-pass placement
/// worst case `bench_steal` and the steal tests share.
///
/// Every draft has the *same* length, so the LPT estimate
/// (`gen_len - draft_len`) is uninformative and PR 3's static placement
/// degenerates to deterministic round-robin by id. Every 4th draft is
/// **stale** — its recorded log-probs claim `p_prev = 1`, so lenient
/// verification rejects it at ~offset 0 and the row re-decodes its whole
/// response — while the rest are **fresh** (log-probs claim a tiny
/// `p_prev`, so the draft body is fully accepted and only the tail
/// re-decodes). Because staleness is id-correlated (`id % 4 == 0`),
/// static placement pins *all* expensive drafts to shard 0 at `shards ∈
/// {2, 4}`; the steal-queue drains them to whichever engine has free
/// slots. Run it on `eos_bias = 0` replicas so realized lengths are
/// deterministic (every rejected row decodes exactly to the cap).
pub mod stale {
    use crate::spec::{CacheEntry, Lenience, ReuseVariant, RolloutRequest, SpecRollout};
    use crate::tokenizer::{BOS, EOS};

    /// Drafted tasks per step (over 4x-the-slot-count queues the tail
    /// that stealing redistributes).
    pub const N_TASKS: usize = 40;
    /// Every 4th draft is stale.
    pub const STALE_MOD: usize = 4;

    /// One step's request batch (prompts stay inside `vocab`).
    pub fn requests(n: usize, vocab: usize) -> Vec<RolloutRequest> {
        (0..n)
            .map(|i| RolloutRequest {
                id: i,
                prompt: vec![BOS, 3 + (i % (vocab - 3)) as i32, 4 + (i % 7) as i32],
            })
            .collect()
    }

    /// The crafted cache entries: `len`-token drafts, every
    /// [`STALE_MOD`]th stale (`logps = 0.0` ⇒ rejected at ~0), the rest
    /// fresh (`logps = -50.0` ⇒ body accepted; the EOS tail re-decodes).
    pub fn entries(n: usize, len: usize, vocab: usize) -> Vec<(usize, CacheEntry)> {
        assert!(len >= 2, "stale entries need at least 2 tokens");
        (0..n)
            .map(|i| {
                let is_stale = i % STALE_MOD == 0;
                let mut response: Vec<i32> =
                    (0..len - 1).map(|j| 3 + ((i + j) % (vocab - 3)) as i32).collect();
                response.push(if is_stale { 3 + (i % (vocab - 3)) as i32 } else { EOS });
                let lp = if is_stale { 0.0 } else { -50.0 };
                let entry = CacheEntry {
                    logps: vec![lp; response.len()],
                    response,
                    version: 0,
                    finished: !is_stale,
                };
                (i, entry)
            })
            .collect()
    }

    /// A [`SpecRollout`] whose cache holds the crafted drafts, so the
    /// next `collect` is exactly one fully-drafted adversarial step.
    pub fn warmed(n: usize, len: usize, vocab: usize, log_lenience: f32) -> SpecRollout {
        let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(log_lenience));
        spec.cache.insert_batch(entries(n, len, vocab));
        spec.step = 1;
        spec
    }
}

/// The grouped GRPO-style workload: `prompts` prompts × `group` samples
/// each, with a controllable shared spine (divergence depth) and
/// cross-epoch prefix overlap.
///
/// `bench_cache` streams the crafted per-epoch rollouts into the trie
/// [`crate::spec::RolloutCache`] and the flat baseline
/// [`crate::spec::FlatCache`] to pin the dedup win; the byte-identity
/// sweeps (`bench_cache`, `sched_continuous.rs`) use the grouped request
/// batches, whose ids follow the trainer's `prompt × group + sample`
/// layout.
pub mod grouped {
    use crate::spec::{CacheEntry, RolloutRequest};
    use crate::tokenizer::BOS;

    /// Log-prob recorded on every crafted token: constant, so group
    /// samples and epochs share trie runs (sharing requires bitwise-equal
    /// log-probs — they are the acceptance rule's `p_prev`).
    pub const LOGP: f32 = -0.5;

    /// Shape of the crafted grouped workload.
    #[derive(Clone, Copy, Debug)]
    pub struct GroupedCfg {
        /// Distinct prompts per epoch.
        pub prompts: usize,
        /// Samples per prompt (the GRPO group size).
        pub group: usize,
        /// Response tokens all of a prompt's samples share before they
        /// diverge (the spine the trie should intern once per prompt).
        pub divergence_depth: usize,
        /// Leading response positions that stay identical across epochs —
        /// the accepted-prefix analogue. Positions past it mix the epoch
        /// into the content, so they never share across epochs.
        pub epoch_overlap: usize,
        /// Private tail tokens per sample after the spine.
        pub tail: usize,
        /// Crafted tokens stay in `[3, vocab)`.
        pub vocab: usize,
    }

    impl Default for GroupedCfg {
        fn default() -> Self {
            GroupedCfg {
                prompts: 6,
                group: 4,
                divergence_depth: 12,
                epoch_overlap: 16,
                tail: 6,
                vocab: 51,
            }
        }
    }

    impl GroupedCfg {
        /// Response length of every crafted rollout.
        pub fn resp_len(&self) -> usize {
            self.divergence_depth + self.tail
        }

        /// Rollouts per epoch.
        pub fn batch(&self) -> usize {
            self.prompts * self.group
        }

        /// What one epoch costs a flat per-trajectory cache.
        pub fn flat_tokens_per_epoch(&self) -> usize {
            self.batch() * self.resp_len()
        }
    }

    /// One step's grouped request batch: ids `pi * group + k`, one prompt
    /// per group (the trainer's id layout).
    pub fn requests(cfg: &GroupedCfg) -> Vec<RolloutRequest> {
        let mut reqs = Vec::with_capacity(cfg.batch());
        for pi in 0..cfg.prompts {
            let prompt = vec![
                BOS,
                3 + (pi as i32 % (cfg.vocab as i32 - 3)),
                4 + (pi as i32 % 7),
            ];
            for k in 0..cfg.group {
                reqs.push(RolloutRequest { id: pi * cfg.group + k, prompt: prompt.clone() });
            }
        }
        reqs
    }

    /// Deterministic crafted token for response position `j` of sample
    /// `k` of prompt `pi` at `epoch`: positions inside the divergence
    /// depth ignore `k` (the shared spine), positions inside the epoch
    /// overlap ignore the epoch (the cross-epoch shared prefix).
    fn token(cfg: &GroupedCfg, pi: usize, k: usize, j: usize, epoch: u64) -> i32 {
        let sample = if j < cfg.divergence_depth { 0 } else { k + 1 };
        let e = if j < cfg.epoch_overlap { 0 } else { epoch as usize + 1 };
        let mix = pi
            .wrapping_mul(31)
            .wrapping_add(j.wrapping_mul(7))
            .wrapping_add(sample.wrapping_mul(131))
            .wrapping_add(e.wrapping_mul(977));
        3 + (mix % (cfg.vocab - 3)) as i32
    }

    /// The crafted rollouts of one epoch as cache-insert pairs (versioned
    /// by the epoch), ready for `insert_batch` into either cache flavor.
    pub fn entries(cfg: &GroupedCfg, epoch: u64) -> Vec<(usize, CacheEntry)> {
        entries_with_logp(cfg, epoch, LOGP)
    }

    /// [`entries`] with an explicit recorded log-prob. One constant per
    /// workload keeps trie sharing intact (sharing requires bitwise-equal
    /// log-probs); the eviction-pressure family records [`LOGP_ACCEPT`]
    /// so lenient verification keeps crafted drafts wholesale.
    fn entries_with_logp(cfg: &GroupedCfg, epoch: u64, logp: f32) -> Vec<(usize, CacheEntry)> {
        let mut out = Vec::with_capacity(cfg.batch());
        for pi in 0..cfg.prompts {
            for k in 0..cfg.group {
                let response: Vec<i32> =
                    (0..cfg.resp_len()).map(|j| token(cfg, pi, k, j, epoch)).collect();
                out.push((
                    pi * cfg.group + k,
                    CacheEntry {
                        logps: vec![logp; response.len()],
                        response,
                        version: epoch,
                        finished: true,
                    },
                ));
            }
        }
        out
    }

    // -- eviction pressure ---------------------------------------------------
    //
    // The knob that exercises the sibling-spine fallback under realistic
    // churn (`spec.sibling_drafts`, ARCHITECTURE.md §8): each epoch's
    // refresh skips one rotating member per group, and a tightened token
    // budget then evicts exactly the lagging leaves — previous tiers
    // first, then the oldest latest-tier leaves (`spec::cache`) — so
    // every group enters the next step with one stranded id whose
    // surviving siblings still hold the shared spine.

    /// Recorded log-prob of the pressure workload's crafted tokens: a
    /// tiny claimed `p_prev`, so the lenient rule accepts crafted drafts
    /// outright and the measured on/off delta isolates *draft
    /// availability* (stranded rows re-decoding from scratch vs riding a
    /// sibling spine), not acceptance noise.
    pub const LOGP_ACCEPT: f32 = -50.0;

    /// The group member sitting out the refresh at `epoch` (rotates, so
    /// over `group` epochs every id takes a turn being stranded).
    pub fn stale_member(cfg: &GroupedCfg, epoch: u64) -> usize {
        epoch as usize % cfg.group
    }

    /// Full-batch pressure-workload insert for the warmup epoch: same
    /// crafted content as [`entries`], recorded at [`LOGP_ACCEPT`].
    pub fn pressure_entries(cfg: &GroupedCfg, epoch: u64) -> Vec<(usize, CacheEntry)> {
        entries_with_logp(cfg, epoch, LOGP_ACCEPT)
    }

    /// The rotating partial refresh: [`pressure_entries`] minus each
    /// group's [`stale_member`]. Inserted over a [`pressure_budget`]-bound
    /// cache this strands exactly the skipped ids — their leaves are the
    /// oldest surviving latest-tier entries, first in line once the
    /// previous-tier leftovers are gone.
    pub fn pressure_refresh(cfg: &GroupedCfg, epoch: u64) -> Vec<(usize, CacheEntry)> {
        let stale = stale_member(cfg, epoch);
        pressure_entries(cfg, epoch)
            .into_iter()
            .filter(|(id, _)| id % cfg.group != stale)
            .collect()
    }

    /// A token budget sized to hold each prompt's shared spine plus one
    /// private tail per *refreshed* member — and nothing else. Tighten it
    /// **after** inserting the [`pressure_refresh`] batch (warming with
    /// the full epoch first): the single enforce pass then reclaims every
    /// previous-tier leaf and each group's lagging latest-tier leaf to
    /// land exactly on the budget, stranding one id per group mid-epoch
    /// without touching a fresh sibling. Assumes the default
    /// `divergence_depth <= epoch_overlap <= resp_len()` ordering.
    pub fn pressure_budget(cfg: &GroupedCfg) -> usize {
        cfg.prompts * (cfg.divergence_depth + (cfg.group - 1) * cfg.tail)
    }
}

/// The **heavy-tail** workload: drafted steps whose *remaining* decode
/// work follows a Pareto-ish distribution with a controllable tail
/// index, the regime long-tail-aware scheduling targets
/// (ARCHITECTURE.md §14).
///
/// Every draft spans the full generation region; its leading tokens are
/// **accepting** (`logps = -50.0`, lenient verification keeps them) and
/// its last `r_i` tokens are **rejecting** (`logps = 0.0` claims
/// `p_prev = 1`, so verification cuts there). On `eos_bias = 0` replicas
/// a cut row decodes back to the cap, so row `i`'s remaining work is
/// exactly `r_i` — drawn from `scale · (1-u)^(-1/alpha)` (smaller
/// `alpha` ⇒ heavier tail). Ids are grouped into [`N_SUITES`] contiguous
/// "suite" blocks with growing Pareto scales (the trainer's
/// prompt-block id layout), giving zero-history rows meaningful
/// per-suite length priors. Raw LPT cannot see any of this — every
/// draft has the same length, so its id tie-break seats the cheap early
/// suites first and the expensive last block straggles (a
/// shortest-first schedule, the classic long-tail trap). A seeded
/// length predictor reverses exactly that.
pub mod longtail {
    use crate::spec::{CacheEntry, Lenience, ReuseVariant, RolloutRequest, SpecRollout};
    use crate::tokenizer::BOS;
    use crate::util::Rng;

    /// Slot rows per engine.
    pub const B: usize = 4;
    /// Prompt region length.
    pub const P: usize = 16;
    /// Total sequence length.
    pub const T: usize = 64;
    /// Vocabulary size.
    pub const V: usize = 51;
    /// Drafted tasks per step (well over the slot count, so ordering
    /// decides which tail rows straggle).
    pub const N_TASKS: usize = 48;
    /// Workload seed.
    pub const SEED: u64 = 0x7A11;
    /// Default tail index (heavy; the makespan gap grows as it drops).
    pub const ALPHA: f64 = 1.1;
    /// Minimum remaining tokens per row.
    pub const R_MIN: usize = 2;
    /// Contiguous suite blocks; suite `s` scales the Pareto draw by `s+1`.
    pub const N_SUITES: usize = 3;
    /// Accepting-prefix / rejecting-tail recorded log-probs (same
    /// mechanism as [`super::stale`], here applied per token).
    pub const LOG_LENIENCE: f32 = -0.25;

    /// Which length-prior suite an id belongs to: contiguous
    /// [`N_TASKS`]`/`[`N_SUITES`] blocks, cheapest scale first.
    pub fn suite_of(id: usize) -> usize {
        (id * N_SUITES / N_TASKS).min(N_SUITES - 1)
    }

    /// Remaining-work lengths `r_i`, deterministic per `(alpha, seed)`.
    /// Pointwise monotone in `alpha`: for every draw, a smaller tail
    /// index yields an equal-or-longer tail.
    pub fn remaining_lens(alpha: f64, seed: u64, gen_len: usize) -> Vec<usize> {
        assert!(alpha > 0.0 && gen_len > R_MIN);
        let mut rng = Rng::new(seed);
        (0..N_TASKS)
            .map(|i| {
                let u = rng.f64();
                let scale = (R_MIN * (suite_of(i) + 1)) as f64;
                let r = (scale * (1.0 - u).powf(-1.0 / alpha)).round() as usize;
                r.clamp(R_MIN, gen_len - 1)
            })
            .collect()
    }

    /// Per-suite mean remaining work — the zero-history length priors a
    /// scheduler may assume for fresh rows of each suite.
    pub fn suite_priors(alpha: f64, seed: u64, gen_len: usize) -> Vec<f64> {
        let lens = remaining_lens(alpha, seed, gen_len);
        let mut sums = vec![(0.0f64, 0usize); N_SUITES];
        for (i, r) in lens.iter().enumerate() {
            sums[suite_of(i)].0 += *r as f64;
            sums[suite_of(i)].1 += 1;
        }
        sums.into_iter().map(|(s, c)| s / c.max(1) as f64).collect()
    }

    /// One step's request batch (prompts stay inside `vocab`).
    pub fn requests(vocab: usize) -> Vec<RolloutRequest> {
        (0..N_TASKS)
            .map(|i| RolloutRequest {
                id: i,
                prompt: vec![BOS, 3 + (i % (vocab - 3)) as i32, 4 + (i % 7) as i32],
            })
            .collect()
    }

    /// The crafted drafts: full-`gen_len` responses, accepting for the
    /// first `gen_len - r_i` tokens, rejecting for the last `r_i`.
    pub fn entries(alpha: f64, seed: u64, gen_len: usize, vocab: usize) -> Vec<(usize, CacheEntry)> {
        remaining_lens(alpha, seed, gen_len)
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                let response: Vec<i32> =
                    (0..gen_len).map(|j| 3 + ((i + j) % (vocab - 3)) as i32).collect();
                let logps: Vec<f32> =
                    (0..gen_len).map(|j| if j < gen_len - r { -50.0 } else { 0.0 }).collect();
                (i, CacheEntry { response, logps, version: 0, finished: false })
            })
            .collect()
    }

    /// A [`SpecRollout`] whose cache holds one heavy-tail drafted step.
    pub fn warmed(alpha: f64, seed: u64, gen_len: usize, vocab: usize) -> SpecRollout {
        let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(LOG_LENIENCE));
        spec.cache.insert_batch(entries(alpha, seed, gen_len, vocab));
        spec.step = 1;
        spec
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean_secs: f64,
    pub median_secs: f64,
    pub p95_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10}  median {:>10}  p95 {:>10}  sd {:>10}  (n={})",
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.median_secs),
            fmt_secs(self.p95_secs),
            fmt_secs(self.stddev_secs),
            self.samples
        )
    }
}

/// Human-friendly seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Benchmark runner.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, samples: 15 }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize) -> Self {
        Bench { warmup, samples }
    }

    /// Measure `f` (which should perform one unit of work per call).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.total_cmp(b));
        let n = times.len();
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
        let p95_idx = ((n as f64 * 0.95) as usize).min(n - 1);
        let result = BenchResult {
            name: name.to_string(),
            samples: n,
            mean_secs: mean,
            median_secs: times[n / 2],
            p95_secs: times[p95_idx],
            stddev_secs: var.sqrt(),
            min_secs: times[0],
        };
        println!("{}", result.report());
        result
    }
}

/// Minimal machine-readable bench output (serde is unavailable offline):
/// an ordered flat JSON object of numbers/strings, written to stdout
/// and/or a file so CI and plots can diff bench runs.
#[derive(Default)]
pub struct JsonReport {
    pairs: Vec<(String, String)>,
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        let rendered = if v.is_finite() { format!("{v}") } else { "null".to_string() };
        self.pairs.push((key.to_string(), rendered));
        self
    }

    pub fn int(&mut self, key: &str, v: usize) -> &mut Self {
        self.pairs.push((key.to_string(), format!("{v}")));
        self
    }

    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        let mut escaped = String::with_capacity(v.len() + 2);
        for c in v.chars() {
            match c {
                '\\' => escaped.push_str("\\\\"),
                '"' => escaped.push_str("\\\""),
                '\n' => escaped.push_str("\\n"),
                '\r' => escaped.push_str("\\r"),
                '\t' => escaped.push_str("\\t"),
                c if (c as u32) < 0x20 => escaped.push_str(&format!("\\u{:04x}", c as u32)),
                c => escaped.push(c),
            }
        }
        self.pairs.push((key.to_string(), format!("\"{escaped}\"")));
        self
    }

    /// Record a [`BenchResult`]'s headline numbers under `<prefix>_*`.
    pub fn bench(&mut self, prefix: &str, r: &BenchResult) -> &mut Self {
        self.num(&format!("{prefix}_mean_secs"), r.mean_secs)
            .num(&format!("{prefix}_median_secs"), r.median_secs)
            .num(&format!("{prefix}_p95_secs"), r.p95_secs)
    }

    pub fn render(&self) -> String {
        let body: Vec<String> =
            self.pairs.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!("{{{}}}", body.join(", "))
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.render() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_renders_flat_object() {
        let mut j = JsonReport::new();
        j.int("steps", 12).num("secs", 0.5).text("name", "a \"b\"");
        assert_eq!(
            j.render(),
            "{\"steps\": 12, \"secs\": 0.5, \"name\": \"a \\\"b\\\"\"}"
        );
    }

    #[test]
    fn measures_work() {
        let b = Bench::new(1, 5);
        let r = b.run("sleep1ms", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(r.mean_secs >= 0.0009, "{}", r.mean_secs);
        assert!(r.median_secs >= 0.0009);
        assert_eq!(r.samples, 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(0.000002).ends_with("µs"));
    }

    #[test]
    fn longtail_lengths_are_deterministic_per_seed() {
        let gen_len = longtail::T - longtail::P;
        let a = longtail::remaining_lens(longtail::ALPHA, longtail::SEED, gen_len);
        let b = longtail::remaining_lens(longtail::ALPHA, longtail::SEED, gen_len);
        assert_eq!(a, b, "same (alpha, seed) must reproduce the distribution");
        assert_eq!(a.len(), longtail::N_TASKS);
        assert!(a.iter().all(|&r| (longtail::R_MIN..gen_len).contains(&r)));
        let c = longtail::remaining_lens(longtail::ALPHA, longtail::SEED + 1, gen_len);
        assert_ne!(a, c, "a different seed must reshuffle the tail");
    }

    #[test]
    fn longtail_tail_index_controls_heaviness() {
        let gen_len = longtail::T - longtail::P;
        let heavy = longtail::remaining_lens(0.8, longtail::SEED, gen_len);
        let light = longtail::remaining_lens(3.0, longtail::SEED, gen_len);
        // pointwise monotone: every draw grows as alpha drops
        for (h, l) in heavy.iter().zip(&light) {
            assert!(h >= l, "heavy {h} < light {l}");
        }
        assert!(
            heavy.iter().sum::<usize>() > light.iter().sum::<usize>(),
            "a lower tail index must add remaining work somewhere"
        );
        // genuinely skewed at the default index: the longest straggler
        // dwarfs the median row
        let mut sorted = longtail::remaining_lens(longtail::ALPHA, longtail::SEED, gen_len);
        sorted.sort_unstable();
        assert!(sorted[sorted.len() - 1] >= 2 * sorted[sorted.len() / 2]);
    }

    #[test]
    fn longtail_entries_split_accepting_prefix_and_rejecting_tail() {
        let gen_len = longtail::T - longtail::P;
        let lens = longtail::remaining_lens(longtail::ALPHA, longtail::SEED, gen_len);
        let entries = longtail::entries(longtail::ALPHA, longtail::SEED, gen_len, longtail::V);
        assert_eq!(entries.len(), longtail::N_TASKS);
        for (i, e) in &entries {
            assert_eq!(e.response.len(), gen_len, "every draft spans the region");
            assert!(!e.finished);
            let tail = lens[*i];
            assert!(e.logps[..gen_len - tail].iter().all(|&p| p == -50.0));
            assert!(e.logps[gen_len - tail..].iter().all(|&p| p == 0.0));
        }
    }

    #[test]
    fn pressure_refresh_rotates_the_stranded_member() {
        let cfg = grouped::GroupedCfg::default();
        for epoch in 0..4u64 {
            let skip = grouped::stale_member(&cfg, epoch);
            let refresh = grouped::pressure_refresh(&cfg, epoch);
            assert_eq!(refresh.len(), cfg.prompts * (cfg.group - 1));
            assert!(refresh.iter().all(|(id, e)| {
                id % cfg.group != skip
                    && e.logps.iter().all(|&p| p == grouped::LOGP_ACCEPT)
            }));
        }
        // the sit-out rotates, so no id is stranded two epochs running
        assert_ne!(grouped::stale_member(&cfg, 0), grouped::stale_member(&cfg, 1));
    }

    #[test]
    fn pressure_budget_strands_one_id_per_group_with_siblings_intact() {
        use crate::spec::RolloutCache;
        let cfg = grouped::GroupedCfg::default();
        let mut cache = RolloutCache::new().with_group(cfg.group);
        cache.insert_batch(grouped::pressure_entries(&cfg, 0));
        cache.insert_batch(grouped::pressure_refresh(&cfg, 1));
        cache.set_token_budget(Some(grouped::pressure_budget(&cfg)));
        cache.check_invariants().unwrap();
        let stale = grouped::stale_member(&cfg, 1);
        for pi in 0..cfg.prompts {
            for k in 0..cfg.group {
                let id = pi * cfg.group + k;
                if k == stale {
                    assert!(cache.latest(id).is_none(), "id {id} should be stranded");
                    let sib = cache.sibling_spine(id).expect("fresh siblings must survive");
                    assert_eq!(sib.response.len(), cfg.resp_len());
                    assert_eq!(sib.version, 1, "fallback rides the refreshed epoch");
                    assert_eq!(cache.branch_depth(id), Some(cfg.divergence_depth));
                } else {
                    let own = cache.latest(id).expect("refreshed ids keep their own leaves");
                    assert_eq!(own.version, 1);
                    assert_eq!(own.response.len(), cfg.resp_len());
                }
            }
        }
        // every previous-tier leaf plus one latest-tier leaf per group
        let (evictions, _) = cache.eviction_stats();
        assert_eq!(evictions as usize, cfg.prompts * (cfg.group - 1) + cfg.prompts);
    }

    #[test]
    fn longtail_suite_priors_track_their_scales() {
        let gen_len = longtail::T - longtail::P;
        let p = longtail::suite_priors(longtail::ALPHA, longtail::SEED, gen_len);
        assert_eq!(p.len(), longtail::N_SUITES);
        // suite scales grow with the index, and the clamp only ever pulls
        // draws down, so the first suite stays the cheapest prior
        assert!(p[0] < p[longtail::N_SUITES - 1], "{p:?}");
    }
}
