//! Continuous-batching generation over the PJRT decode entries.
//!
//! [`RolloutEngine::run`] drives the slot scheduler: one prefill for the
//! initial batch, then a decode loop in which finished rows are refilled
//! from the pending queue via the `refill` entry (a masked per-row
//! prefill) without stalling live rows. [`RolloutEngine::run_lockstep`]
//! preserves the old wave discipline — same results, more decode steps —
//! for equivalence tests and the `bench_sched` comparison.
//!
//! Host↔device traffic per decode step is three `[B]` i32 vectors; the
//! `[B, T]` valid mask lives device-side in the generation blob and is
//! extended there by the decode entry (see `rollout/sched.rs` for the full
//! contract). All host scratch (layout, step vectors, probs readback,
//! sampler order) is allocated once per engine and reused across runs.

use std::time::Instant;

use anyhow::Result;

use super::batch::{BatchLayout, SeqResult, SeqTask};
use super::sched::SlotScheduler;
use crate::runtime::{Backend, Engine};
use crate::tokenizer::EOS;
use crate::util::{Rng, StageTimer, TopPSampler};

/// Aggregate statistics for one `run` call.
#[derive(Clone, Debug, Default)]
pub struct RolloutStats {
    /// Newly decoded tokens (the paper's "Tokens" efficiency metric).
    pub new_tokens: usize,
    /// Tokens taken from verified prefixes.
    pub reused_tokens: usize,
    /// Decode executable invocations.
    pub decode_steps: usize,
    /// Prefill batches executed (lockstep: one per wave; continuous: 1).
    pub waves: usize,
    /// Refill executable invocations (continuous scheduler only).
    pub refills: usize,
    /// Sum over decode steps of rows that did not advance a sequence —
    /// the utilization gap continuous batching exists to close.
    pub slot_idle_steps: usize,
}

impl RolloutStats {
    /// Fraction of row-steps wasted on idle slots (0 = perfectly packed).
    pub fn slot_idle_fraction(&self, batch: usize) -> f64 {
        let total = self.decode_steps * batch;
        if total == 0 {
            return 0.0;
        }
        self.slot_idle_steps as f64 / total as f64
    }
}

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_p: f32,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 1.0, top_p: 1.0 }
    }
}

/// Per-task RNG stream: sampling depends only on (run nonce, task id), so
/// results are invariant to slot assignment and scheduling order — the
/// property the lockstep-vs-continuous equivalence tests pin down.
fn task_rng(nonce: u64, id: usize) -> Rng {
    Rng::new(nonce ^ (id as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Live occupant of one scheduler slot.
struct SlotState {
    id: usize,
    reused: usize,
    logps: Vec<f32>,
    rng: Rng,
}

impl SlotState {
    fn new(task: SeqTask, nonce: u64) -> SlotState {
        SlotState {
            rng: task_rng(nonce, task.id),
            id: task.id,
            reused: task.prefix.len(),
            logps: task.prefix_logps,
        }
    }
}

/// The batched rollout engine bound to one (backend, bundle).
pub struct RolloutEngine<'e, B: Backend = Engine> {
    eng: &'e B,
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub vocab: usize,
    sampler: TopPSampler,
    // Pre-resolved entry handles: zero lookups in the decode loop.
    h_prefill: B::Entry,
    h_decode: B::Entry,
    h_read_gen: B::Entry,
    h_refill: B::Entry,
    // Persistent host scratch, reused across runs: the decode loop
    // allocates nothing per step.
    layout: BatchLayout,
    token_in: Vec<i32>,
    slot_in: Vec<i32>,
    lpos_in: Vec<i32>,
    rowmask: Vec<f32>,
    probs: Vec<f32>,
    /// Cached temperature scalar buffer, keyed by bit pattern.
    temp_buf: Option<(u32, B::Buf)>,
}

impl<'e, B: Backend> RolloutEngine<'e, B> {
    pub fn new(eng: &'e B, bundle: &str) -> Result<Self> {
        let shape = eng.shape(bundle)?;
        Ok(RolloutEngine {
            eng,
            batch: shape.batch,
            prompt_len: shape.prompt_len,
            total_len: shape.total_len,
            vocab: shape.vocab,
            sampler: TopPSampler::new(shape.vocab),
            h_prefill: eng.resolve(bundle, "prefill")?,
            h_decode: eng.resolve(bundle, "decode")?,
            h_read_gen: eng.resolve(bundle, "read_gen")?,
            h_refill: eng.resolve(bundle, "refill")?,
            layout: BatchLayout::new(shape.batch, shape.prompt_len, shape.total_len),
            token_in: vec![0; shape.batch],
            slot_in: vec![shape.total_len as i32; shape.batch],
            lpos_in: vec![0; shape.batch],
            rowmask: vec![0.0; shape.batch],
            probs: vec![0.0; shape.batch * shape.vocab],
            temp_buf: None,
        })
    }

    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }

    /// Prime the cached temperature buffer for this run's config.
    fn ensure_temp(&mut self, temperature: f32) -> Result<()> {
        let bits = temperature.to_bits();
        if !matches!(&self.temp_buf, Some((b, _)) if *b == bits) {
            let buf = self.eng.upload_f32(&[temperature], &[1])?;
            self.temp_buf = Some((bits, buf));
        }
        Ok(())
    }

    fn temp_ref(&self) -> &B::Buf {
        &self.temp_buf.as_ref().expect("ensure_temp not called").1
    }

    /// Pull fully-reused terminal drafts straight into results; return the
    /// tasks that actually need decode slots.
    fn split_terminal(
        &self,
        tasks: Vec<SeqTask>,
        results: &mut Vec<SeqResult>,
        stats: &mut RolloutStats,
    ) -> Vec<SeqTask> {
        let gen_len = self.gen_len();
        let mut pending = Vec::with_capacity(tasks.len());
        for t in tasks {
            if t.prefix_is_terminal(gen_len) {
                stats.reused_tokens += t.prefix.len();
                let finished = t.prefix.last() == Some(&EOS);
                results.push(SeqResult {
                    id: t.id,
                    reused: t.prefix.len(),
                    new_tokens: 0,
                    finished,
                    logps: t.prefix_logps,
                    response: t.prefix,
                });
            } else {
                pending.push(t);
            }
        }
        pending
    }

    /// Refresh `self.probs` from the generation blob.
    fn read_probs(&mut self, gen: &B::Buf) -> Result<()> {
        let out = self.eng.call_entry(&self.h_read_gen, &[gen])?;
        self.eng.read_f32_into(&out, &mut self.probs)
    }

    /// Generate all tasks with the continuous-batching slot scheduler.
    /// Stage accounting: device work under `"rollout"`, result assembly
    /// under `"assembly"`. Results are id-sorted.
    pub fn run(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, RolloutStats)> {
        let mut stats = RolloutStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len());
        let pending = self.split_terminal(tasks, &mut results, &mut stats);
        let run_nonce = rng.next_u64();
        if pending.is_empty() {
            results.sort_by_key(|r| r.id);
            return Ok((results, stats));
        }

        let (b, t, v) = (self.batch, self.total_len, self.vocab);
        let gen_len = self.gen_len();
        let mut sched = SlotScheduler::new(b, pending);
        let mut slots: Vec<Option<SlotState>> = (0..b).map(|_| None).collect();
        self.ensure_temp(cfg.temperature)?;

        // --- initial fill + prefill -------------------------------------
        let span = Instant::now();
        self.layout.clear();
        for (slot, task) in sched.fill() {
            self.layout.set_row(slot, &task.prompt, &task.prefix);
            slots[slot] = Some(SlotState::new(task, run_nonce));
        }
        let tok_b = self.eng.upload_i32(&self.layout.tokens, &[b, t])?;
        let val_b = self.eng.upload_f32(&self.layout.valid, &[b, t])?;
        let last_b = self.eng.upload_i32(&self.layout.last, &[b])?;
        let mut gen = self.eng.call_entry(
            &self.h_prefill,
            &[blob, &tok_b, &val_b, &last_b, self.temp_ref()],
        )?;
        stats.waves += 1;
        self.read_probs(&gen)?;
        timer.add("rollout", span.elapsed().as_secs_f64());

        // --- decode loop -------------------------------------------------
        loop {
            let span = Instant::now();
            // 1. sample one token for every occupied slot
            let mut writes = 0usize;
            for r in 0..b {
                self.token_in[r] = 0;
                self.slot_in[r] = t as i32; // out-of-range => no cache write
                self.lpos_in[r] = 0;
                if slots[r].is_none() {
                    continue;
                }
                let row = r * v;
                let tok = {
                    let st = slots[r].as_mut().unwrap();
                    self.sampler.sample(&self.probs[row..row + v], cfg.top_p, &mut st.rng)
                        as i32
                };
                let lp = self.probs[row + tok as usize].max(1e-30).ln();
                let slot_pos = self.layout.push_token(r, tok);
                stats.new_tokens += 1;
                let done_eos = tok == EOS;
                let done = done_eos || self.layout.resp_len[r] >= gen_len;
                if done {
                    let mut st = slots[r].take().unwrap();
                    st.logps.push(lp);
                    let response = self.layout.response(r);
                    stats.reused_tokens += st.reused;
                    results.push(SeqResult {
                        id: st.id,
                        reused: st.reused,
                        new_tokens: response.len() - st.reused,
                        finished: done_eos,
                        logps: st.logps,
                        response,
                    });
                    sched.release(r);
                } else {
                    slots[r].as_mut().unwrap().logps.push(lp);
                    self.token_in[r] = tok;
                    self.slot_in[r] = slot_pos as i32;
                    self.lpos_in[r] = (self.layout.n_valid(r) - 1) as i32;
                    writes += 1;
                }
            }

            // 2. advance surviving rows: three [B] uploads, no [B,T] mask
            if sched.busy() > 0 {
                let tok_b = self.eng.upload_i32(&self.token_in, &[b])?;
                let slot_b = self.eng.upload_i32(&self.slot_in, &[b])?;
                let lpos_b = self.eng.upload_i32(&self.lpos_in, &[b])?;
                gen = self.eng.call_entry(
                    &self.h_decode,
                    &[blob, &gen, &tok_b, &slot_b, &lpos_b, self.temp_ref()],
                )?;
                stats.decode_steps += 1;
                stats.slot_idle_steps += b - writes;
            }

            // 3. refill freed slots (after the decode so refill probs are
            //    the freshest state for the next sampling round)
            let fills = sched.fill();
            if !fills.is_empty() {
                for (slot, task) in fills {
                    self.layout.set_row(slot, &task.prompt, &task.prefix);
                    self.rowmask[slot] = 1.0;
                    slots[slot] = Some(SlotState::new(task, run_nonce));
                }
                let tok_b = self.eng.upload_i32(&self.layout.tokens, &[b, t])?;
                let val_b = self.eng.upload_f32(&self.layout.valid, &[b, t])?;
                let rm_b = self.eng.upload_f32(&self.rowmask, &[b])?;
                let last_b = self.eng.upload_i32(&self.layout.last, &[b])?;
                gen = self.eng.call_entry(
                    &self.h_refill,
                    &[blob, &gen, &tok_b, &val_b, &rm_b, &last_b, self.temp_ref()],
                )?;
                stats.refills += 1;
                self.rowmask.fill(0.0);
            }

            if sched.is_done() {
                timer.add("rollout", span.elapsed().as_secs_f64());
                break;
            }
            self.read_probs(&gen)?;
            timer.add("rollout", span.elapsed().as_secs_f64());
        }

        let span = Instant::now();
        results.sort_by_key(|r| r.id);
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok((results, stats))
    }

    /// The pre-scheduler wave discipline: tasks bind to slots in waves of
    /// `batch`, every wave decodes in lockstep until its slowest row
    /// finishes. Byte-identical outputs to [`RolloutEngine::run`] (same
    /// per-task RNG streams); kept as the equivalence oracle and the
    /// `bench_sched` baseline.
    pub fn run_lockstep(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, RolloutStats)> {
        let mut stats = RolloutStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len());
        let mut pending = self.split_terminal(tasks, &mut results, &mut stats);
        let run_nonce = rng.next_u64();

        // Longest prefixes first => rows within a wave have similar
        // remaining lengths (the old scheduler's only lever).
        pending.sort_by(|a, b| b.prefix.len().cmp(&a.prefix.len()).then(a.id.cmp(&b.id)));

        let mut idx = 0;
        while idx < pending.len() {
            let wave = &pending[idx..(idx + self.batch).min(pending.len())];
            self.run_wave(blob, wave, cfg, run_nonce, timer, &mut stats, &mut results)?;
            idx += self.batch;
            stats.waves += 1;
        }
        let span = Instant::now();
        results.sort_by_key(|r| r.id);
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok((results, stats))
    }

    /// One lockstep wave: prefill + decode until every row finishes.
    #[allow(clippy::too_many_arguments)]
    fn run_wave(
        &mut self,
        blob: &B::Buf,
        tasks: &[SeqTask],
        cfg: SampleCfg,
        run_nonce: u64,
        timer: &mut StageTimer,
        stats: &mut RolloutStats,
        results: &mut Vec<SeqResult>,
    ) -> Result<()> {
        let (b, t, v) = (self.batch, self.total_len, self.vocab);
        let gen_len = self.gen_len();
        let n = tasks.len();
        self.ensure_temp(cfg.temperature)?;

        let span = Instant::now();
        self.layout.clear();
        for (r, task) in tasks.iter().enumerate() {
            self.layout.set_row(r, &task.prompt, &task.prefix);
        }
        let mut logps: Vec<Vec<f32>> = tasks.iter().map(|x| x.prefix_logps.clone()).collect();
        let mut rngs: Vec<Rng> = tasks.iter().map(|x| task_rng(run_nonce, x.id)).collect();
        let mut finished = vec![false; n];
        let mut eos_emitted = vec![false; n];

        let tok_b = self.eng.upload_i32(&self.layout.tokens, &[b, t])?;
        let val_b = self.eng.upload_f32(&self.layout.valid, &[b, t])?;
        let last_b = self.eng.upload_i32(&self.layout.last, &[b])?;
        let mut gen = self.eng.call_entry(
            &self.h_prefill,
            &[blob, &tok_b, &val_b, &last_b, self.temp_ref()],
        )?;
        self.read_probs(&gen)?;
        timer.add("rollout", span.elapsed().as_secs_f64());

        loop {
            let span = Instant::now();
            let mut writes = 0usize;
            for r in 0..b {
                self.token_in[r] = 0;
                self.slot_in[r] = t as i32; // inert write
                self.lpos_in[r] = 0;
                if r >= n || finished[r] || self.layout.resp_len[r] >= gen_len {
                    continue;
                }
                let row = r * v;
                let tok =
                    self.sampler.sample(&self.probs[row..row + v], cfg.top_p, &mut rngs[r])
                        as i32;
                let lp = self.probs[row + tok as usize].max(1e-30).ln();
                let slot_pos = self.layout.push_token(r, tok);
                logps[r].push(lp);
                stats.new_tokens += 1;
                if tok == EOS {
                    finished[r] = true;
                    eos_emitted[r] = true;
                } else if self.layout.resp_len[r] >= gen_len {
                    finished[r] = true;
                } else {
                    self.token_in[r] = tok;
                    self.slot_in[r] = slot_pos as i32;
                    self.lpos_in[r] = (self.layout.n_valid(r) - 1) as i32;
                    writes += 1;
                }
            }
            if writes == 0 {
                timer.add("rollout", span.elapsed().as_secs_f64());
                break;
            }
            let tok_b = self.eng.upload_i32(&self.token_in, &[b])?;
            let slot_b = self.eng.upload_i32(&self.slot_in, &[b])?;
            let lpos_b = self.eng.upload_i32(&self.lpos_in, &[b])?;
            gen = self.eng.call_entry(
                &self.h_decode,
                &[blob, &gen, &tok_b, &slot_b, &lpos_b, self.temp_ref()],
            )?;
            stats.decode_steps += 1;
            stats.slot_idle_steps += b - writes;
            self.read_probs(&gen)?;
            timer.add("rollout", span.elapsed().as_secs_f64());
        }

        let span = Instant::now();
        for (r, task) in tasks.iter().enumerate() {
            let response = self.layout.response(r);
            stats.reused_tokens += task.prefix.len();
            results.push(SeqResult {
                id: task.id,
                reused: task.prefix.len(),
                new_tokens: response.len() - task.prefix.len(),
                finished: eos_emitted[r],
                logps: std::mem::take(&mut logps[r]),
                response,
            });
        }
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok(())
    }
}
