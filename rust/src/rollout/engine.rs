//! Phase-aware continuous-batching generation over the PJRT entries.
//!
//! [`RolloutEngine::run_pipeline`] drives the full sequence lifecycle
//! (`Draft -> Verify -> Decode -> Done`) through one slot pool: fresh
//! prompts start decoding immediately while drafted sequences verify in
//! packed `verify_seat` sub-batches and transition to decode the moment
//! their first rejection is read back — no global verify barrier, and no
//! separate refill forward for verified rows (the verify forward's KV is
//! reused in place; see `rollout/sched.rs` for the entry contract).
//!
//! The pipeline is a resumable state machine (PR 4):
//! [`RolloutEngine::pipeline_start`] seats the initial work and returns a
//! [`PipelineRun`], and [`RolloutEngine::pipeline_step`] advances it one
//! decode round at a time, pulling replacement work from a caller-supplied
//! [`WorkQueue`] whenever slots free up. Since PR 5 each round is itself
//! split in two: [`RolloutEngine::step_submit`] issues the round's whole
//! device chain (decode → refill → verify-seat → sample → read_step,
//! linked through pending handles; read_gen replaces the last two links
//! under forced host sampling) without blocking, and
//! [`RolloutEngine::step_complete`] cashes the returned [`StepTicket`] in
//! — the only host-blocking half. `pipeline_step` is the composed
//! (blocking) form; `run_pipeline` is the one-engine driver over it
//! (private queue). [`crate::rollout::pool::EnginePool`] drives the two
//! halves separately across N engines over one *shared* queue: every live
//! shard's round is submitted before any shard's is completed, so engine
//! forwards on distinct devices run concurrently instead of
//! host-serialized, while mid-step work stealing keeps working and no
//! seated row ever migrates (`ARCHITECTURE.md` §11).
//!
//! [`RolloutEngine::run`] is the decode-only subset (no drafts) used by
//! evaluation and the scheduler benches; [`RolloutEngine::run_lockstep`]
//! preserves the old wave discipline — same results, more decode steps —
//! as the scheduling-equivalence oracle. The blocking verify wave behind
//! the two-phase oracle lives here too ([`RolloutEngine::verify_wave`]),
//! executing plans packed by [`VerifyPlanner`] (which itself makes no
//! engine calls).
//!
//! Host↔device traffic per decode step is three `[B]` i32 vectors plus
//! the `[B, 3]` sampler ctrl block up, and the fused `[B tok | B ptok |
//! B aux]` readback down (PR 6, `ARCHITECTURE.md` §12): sampling runs on
//! device through the `sample` entry, which replays each task's host RNG
//! stream statelessly, so the O(B·V) probs payload of `read_gen` leaves
//! the hot path entirely. The `[B, T]` valid mask lives device-side in
//! the generation blob and is extended there by the decode entry. All
//! host scratch (layout, verify planner, step vectors, readbacks, sampler
//! order) is allocated once per engine and reused across runs and trainer
//! steps; `readback_bytes` / `upload_bytes` in [`PipelineStats`] account
//! the traffic.
//!
//! Every discipline shares one sample-token/finish-row decode block
//! (`sample_row`, plus `sample_round` / `decode_advance` /
//! `prefill_layout` / `refill_slots`), so the oracles cannot drift from
//! the pipeline silently — the host-sampling path stays byte-identical to
//! the device path ([`RolloutEngine::set_host_sampling`] forces it, and
//! bundles without the `sample`/`read_step` entries fall back to it). One
//! engine serves one backend; the sharded multi-engine layer is
//! [`crate::rollout::pool::EnginePool`].

use std::time::Instant;

use anyhow::{bail, Result};

use super::batch::{BatchLayout, SeqResult, SeqTask};
use super::predict::LenEstimates;
use super::sched::{SlotScheduler, WorkQueue};
use crate::runtime::{Backend, Engine};
use crate::spec::cache::CacheEntry;
use crate::spec::verifier::{VerifyPlanner, VerifyTask};
use crate::tokenizer::EOS;
use crate::util::{Rng, StageTimer, TopPSampler};

/// The per-step pipeline report: generation, verification, and cache
/// telemetry merged into one struct (previously split across
/// `RolloutStats` and `SpecStepStats`).
#[derive(Clone, Debug, Default)]
pub struct PipelineStats {
    /// Newly decoded tokens (the paper's "Tokens" efficiency metric).
    pub new_tokens: usize,
    /// Tokens taken from verified prefixes.
    pub reused_tokens: usize,
    /// Decode executable invocations.
    pub decode_steps: usize,
    /// Prefill batches executed (lockstep: one per wave; continuous: 1).
    pub waves: usize,
    /// Refill executable invocations.
    pub refills: usize,
    /// Sum over decode steps of rows that did not advance a sequence —
    /// the utilization gap continuous batching exists to close.
    pub slot_idle_steps: usize,
    /// Sequences that had a cached draft (verified or variant-resolved).
    pub drafts: usize,
    /// Total accepted-prefix tokens over drafted sequences (raw sum).
    pub prefix_tokens: usize,
    /// Drafted sequences whose draft was fully reused (raw count).
    pub full_reuses: usize,
    /// Mean verified prefix length (derived; see `finalize_draft_means`).
    pub mean_prefix_len: f64,
    /// Fraction of drafts fully reused (derived).
    pub full_reuse_ratio: f64,
    /// `verify` / `verify_seat` executable invocations.
    pub verify_calls: usize,
    /// Work items (tasks or drafts) a shard pulled from the shared
    /// steal-queue *after* the pool's initial seating pass — i.e. work
    /// that one-pass placement would have pinned to a single engine up
    /// front. Always 0 for single-engine runs and static placement.
    /// Under failure recovery, requeued work re-entering on a survivor
    /// also counts here (it is, literally, a mid-step pull).
    pub steal_count: usize,
    /// Shards marked dead this step: a transport error or injected fault
    /// surfaced from one of the shard's entry calls, its unfinished work
    /// was requeued, and the step completed on the survivors
    /// (`ARCHITECTURE.md` §13). Always 0 on the no-failure path.
    pub shard_failures: usize,
    /// Seated rows harvested off dead shards and re-entered into the
    /// work queue as fresh items (decode rows as tasks, accepted
    /// prefixes as drafts). Never-seated queue items returning to the
    /// pool are not counted — they were never bound to the dead shard.
    pub requeued_tasks: usize,
    /// Rollout-cache leaves evicted by the token budget this step.
    pub cache_evictions: usize,
    /// Resident tokens freed by those evictions (a fully shared leaf's
    /// eviction frees 0 — its runs stay for the surviving paths).
    pub cache_evicted_tokens: usize,
    /// Live interned runs in the prefix-trie rollout cache after this
    /// step's refresh. A post-refresh gauge set once by
    /// [`crate::spec::SpecRollout`] on the merged step report (the single
    /// cache is global across shards), not a per-shard counter — `absorb`
    /// takes the max rather than summing.
    pub cache_nodes: usize,
    /// Tokens the trie saves over flat per-trajectory storage
    /// (`flat_tokens - total_tokens`) after this step's refresh. Same
    /// gauge semantics as [`PipelineStats::cache_nodes`].
    pub cache_shared_tokens: usize,
    /// Per-shard `device_calls()` totals when the step ran through an
    /// [`crate::rollout::pool::EnginePool`] (one entry per shard, in shard
    /// order). Empty for engine-level runs that bypass the pool.
    pub shard_device_calls: Vec<usize>,
    /// Realized virtual makespan of the step under the driver actually
    /// used (`ARCHITECTURE.md` §11): host-clock delta across the pool
    /// run. Only a backend with a virtual clock
    /// ([`crate::testing::mock::MockEngine`]) can report it; on real
    /// devices it stays 0. Under the overlapped steal driver this is the
    /// quantity that drops below [`PipelineStats::serial_makespan`].
    pub overlap_makespan: f64,
    /// What a host-serialized driver would have realized for the same
    /// step: the sum of every shard's device-busy virtual seconds (a
    /// serialized driver never lets two forwards overlap, so its
    /// makespan is exactly that sum). 0 without a virtual clock.
    pub serial_makespan: f64,
    /// Bytes read device→host this step (`read_gen` / `read_step`
    /// payloads). The quantity the fused `[B tokens | B aux]` readback
    /// shrinks from O(B·V) to O(B) per decode round
    /// (`ARCHITECTURE.md` §12); `bench_readback` pins the drop.
    pub readback_bytes: usize,
    /// Bytes uploaded host→device for per-call entry arguments this step
    /// (prefill/refill layouts, decode step vectors, verify plans, sample
    /// ctrl rows). One-time cached scalars (temperature, log-lenience,
    /// top-p, nonce) are excluded — they are not per-step traffic.
    pub upload_bytes: usize,
    /// Sum over predicted rows of `|predicted - realized|` total response
    /// length (`ARCHITECTURE.md` §14). Raw accumulator; the gauge is
    /// [`PipelineStats::mean_predict_err`]. 0 with the predictor off.
    pub predict_err_sum: f64,
    /// Rows that carried a length prediction this step.
    pub predict_rows: usize,
    /// Mean absolute predicted-vs-actual length error (derived; see
    /// `finalize_draft_means`).
    pub mean_predict_err: f64,
    /// Sum of materialized (post-clip) draft lengths this step.
    pub draft_len_sum: usize,
    /// Drafted rows contributing to [`PipelineStats::draft_len_sum`].
    pub draft_len_rows: usize,
    /// Shortest materialized draft this step (histogram floor; 0 when no
    /// draft was offered).
    pub draft_len_lo: usize,
    /// Longest materialized draft this step (histogram ceiling).
    pub draft_len_hi: usize,
    /// Drafts the adaptive controller truncated below their cached length
    /// this step (`spec.draft_len_{min,max,adapt}`).
    pub draft_trunc: usize,
    /// Rows whose draft this step was a sibling-spine fallback — the
    /// slot's own leaf was gone, so the longest surviving leaf under the
    /// same prompt root was offered instead (`spec.sibling_drafts`,
    /// `ARCHITECTURE.md` §8). A prepare-side counter like the
    /// `draft_len_*` family, merged by `absorb_draft_lens`.
    pub sibling_draft_hits: usize,
    /// Post-clip tokens offered by those sibling fallbacks — the reuse
    /// that was previously left on the table entirely.
    pub sibling_draft_tokens: usize,
    /// Sum of branch-point depths over the prompt groups drafted this
    /// step (one observation per unique prompt root). Raw accumulator;
    /// the gauge is [`PipelineStats::branch_depth_mean`].
    pub branch_depth_sum: usize,
    /// Prompt groups contributing to [`PipelineStats::branch_depth_sum`].
    pub branch_depth_rows: usize,
    /// Mean materialized draft length (derived; see
    /// `finalize_draft_means`).
    pub mean_draft_len: f64,
    /// Mean branch-point depth across drafted prompt groups — how far the
    /// group's cached rollouts agree before diverging (derived; see
    /// `finalize_draft_means`).
    pub branch_depth_mean: f64,
}

impl PipelineStats {
    /// Fraction of row-steps wasted on idle slots (0 = perfectly packed).
    pub fn slot_idle_fraction(&self, batch: usize) -> f64 {
        let total = self.decode_steps * batch;
        if total == 0 {
            return 0.0;
        }
        self.slot_idle_steps as f64 / total as f64
    }

    /// Derive `mean_prefix_len` / `full_reuse_ratio` from the raw draft
    /// counters (called once per step by the pipeline driver).
    pub fn finalize_draft_means(&mut self) {
        let d = self.drafts.max(1) as f64;
        self.mean_prefix_len = self.prefix_tokens as f64 / d;
        self.full_reuse_ratio = self.full_reuses as f64 / d;
        self.mean_predict_err = self.predict_err_sum / self.predict_rows.max(1) as f64;
        self.mean_draft_len = self.draft_len_sum as f64 / self.draft_len_rows.max(1) as f64;
        self.branch_depth_mean =
            self.branch_depth_sum as f64 / self.branch_depth_rows.max(1) as f64;
    }

    /// Total verify + decode + refill executable invocations — the
    /// interleaved-vs-two-phase comparison metric (`bench_pipeline`) and,
    /// per shard, the critical-path metric of `bench_shards`.
    pub fn device_calls(&self) -> usize {
        self.verify_calls + self.decode_steps + self.refills
    }

    /// Merge another report's raw counters into this one (the pool's
    /// cross-shard aggregation). Derived means are *not* merged — they are
    /// recomputed from the raw sums by
    /// [`PipelineStats::finalize_draft_means`] at the step boundary.
    pub fn absorb(&mut self, o: &PipelineStats) {
        self.new_tokens += o.new_tokens;
        self.reused_tokens += o.reused_tokens;
        self.decode_steps += o.decode_steps;
        self.waves += o.waves;
        self.refills += o.refills;
        self.slot_idle_steps += o.slot_idle_steps;
        self.drafts += o.drafts;
        self.prefix_tokens += o.prefix_tokens;
        self.full_reuses += o.full_reuses;
        self.verify_calls += o.verify_calls;
        self.steal_count += o.steal_count;
        self.shard_failures += o.shard_failures;
        self.requeued_tasks += o.requeued_tasks;
        self.cache_evictions += o.cache_evictions;
        self.cache_evicted_tokens += o.cache_evicted_tokens;
        // cache_nodes / cache_shared_tokens are whole-cache gauges, not
        // per-shard counters: merging keeps the larger observation
        self.cache_nodes = self.cache_nodes.max(o.cache_nodes);
        self.cache_shared_tokens = self.cache_shared_tokens.max(o.cache_shared_tokens);
        self.overlap_makespan += o.overlap_makespan;
        self.serial_makespan += o.serial_makespan;
        self.readback_bytes += o.readback_bytes;
        self.upload_bytes += o.upload_bytes;
        self.predict_err_sum += o.predict_err_sum;
        self.predict_rows += o.predict_rows;
        self.absorb_draft_lens(o);
        if self.shard_device_calls.len() < o.shard_device_calls.len() {
            self.shard_device_calls.resize(o.shard_device_calls.len(), 0);
        }
        for (a, b) in self.shard_device_calls.iter_mut().zip(&o.shard_device_calls) {
            *a += b;
        }
    }

    /// Merge another report's draft-length histogram summary
    /// (`draft_len_*`, `draft_trunc`) into this one. Split out of
    /// [`PipelineStats::absorb`] because the coordinator records these in
    /// its prepare pass, outside the engines' own reports. Histogram
    /// bounds only merge from sides that saw a draft — a draft-free
    /// report's 0 floor must not clobber a real minimum.
    pub fn absorb_draft_lens(&mut self, o: &PipelineStats) {
        if o.draft_len_rows > 0 {
            self.draft_len_lo = if self.draft_len_rows > 0 {
                self.draft_len_lo.min(o.draft_len_lo)
            } else {
                o.draft_len_lo
            };
            self.draft_len_hi = self.draft_len_hi.max(o.draft_len_hi);
        }
        self.draft_len_sum += o.draft_len_sum;
        self.draft_len_rows += o.draft_len_rows;
        self.draft_trunc += o.draft_trunc;
        self.sibling_draft_hits += o.sibling_draft_hits;
        self.sibling_draft_tokens += o.sibling_draft_tokens;
        self.branch_depth_sum += o.branch_depth_sum;
        self.branch_depth_rows += o.branch_depth_rows;
    }
}

/// Back-compat name for the decode-side view of the merged report.
pub type RolloutStats = PipelineStats;

/// Per-run sampling + seating configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_p: f32,
    /// Adaptive verify seating (`spec.verify_seat_min`): a packed
    /// `verify_seat` sub-batch is seated only when at least this many
    /// slots are free (clamped into `[1, batch]`; 1 = seat eagerly, the
    /// pre-PR 4 behavior). Larger values trade verify latency for packing
    /// — fewer, fuller `verify_seat` calls. Results are byte-identical
    /// for every value (per-task RNG streams; `ARCHITECTURE.md` §6).
    pub verify_seat_min: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 1.0, top_p: 1.0, verify_seat_min: 1 }
    }
}

/// Per-task RNG stream: sampling depends only on (run nonce, task id), so
/// results are invariant to slot assignment and scheduling order — the
/// property the lockstep / two-phase / pipeline equivalence tests pin down.
pub(crate) fn task_rng(nonce: u64, id: usize) -> Rng {
    Rng::new(nonce ^ (id as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Live decoding occupant of one scheduler slot.
struct SlotState {
    id: usize,
    reused: usize,
    logps: Vec<f32>,
    rng: Rng,
    /// Uniform draws this task's RNG stream has consumed so far — the
    /// `draws` word of the device `sample` entry's ctrl row. The device
    /// replays the stream statelessly from `(nonce, id)` and skips this
    /// many values, so device and host sampling consume the *same*
    /// per-task stream position (`ARCHITECTURE.md` §12). Unused (stays 0)
    /// on the host sampling path, which advances `rng` directly.
    draws: usize,
    /// The row's reused prefix was *verified on this engine* (seated via
    /// `verify_seat` and resolved by `resolve_verified`), as opposed to
    /// arriving pre-resolved inside a [`SeqTask`]. The dead-shard harvest
    /// uses this to pick the requeue shape: verified prefixes re-enter as
    /// drafts (re-verification replays the same per-task uniform stream
    /// and re-accepts every token, §6), while variant-resolved prefixes —
    /// which never consumed verify uniforms — must re-enter as tasks.
    from_draft: bool,
}

impl SlotState {
    fn new(task: SeqTask, nonce: u64) -> SlotState {
        SlotState {
            rng: task_rng(nonce, task.id),
            id: task.id,
            reused: task.prefix.len(),
            logps: task.prefix_logps,
            draws: 0,
            from_draft: false,
        }
    }
}

/// The resumable state of one engine's pipeline run (PR 4): slot phases,
/// live occupants, the device generation blob, and the per-shard results
/// and stats accumulated so far. Produced by
/// [`RolloutEngine::pipeline_start`], advanced by
/// [`RolloutEngine::pipeline_step`], consumed by
/// [`PipelineRun::into_parts`].
///
/// The work a run draws on lives *outside* it, in the caller's
/// [`WorkQueue`] — that separation is what lets
/// [`crate::rollout::pool::EnginePool`] drive N runs round-robin over one
/// shared queue (mid-step work stealing) while each run's seated rows stay
/// pinned to its engine.
pub struct PipelineRun<B: Backend = Engine> {
    sched: SlotScheduler,
    slots: Vec<Option<SlotState>>,
    verifying: Vec<Option<VerifyTask>>,
    /// Device generation blob (`None` only for a run that started with
    /// nothing to do and made no device calls).
    gen: Option<B::Buf>,
    /// Uploaded log-lenience scalar, reused by every verify-seat call.
    ll: Option<B::Buf>,
    /// Uploaded top-p scalar for the device `sample` entry (device path
    /// only; the host path passes `cfg.top_p` to the host sampler).
    top_p_buf: Option<B::Buf>,
    /// Uploaded `(hi, lo)` bit-split of `rnonce` for the device `sample`
    /// entry (device path only; constant for the whole run).
    nonce_buf: Option<B::Buf>,
    /// Whether this run samples on the device (`sample` + `read_step`
    /// entries resolved and host sampling not forced). Captured at start
    /// so a run never switches paths mid-flight.
    device: bool,
    /// Device-sampled `(token, raw prob)` per row, ingested from the
    /// previous round's `read_step` payload and consumed by the next
    /// sampling round. `None` for rows the device left unarmed
    /// (tok lane < 0). Host-path runs never populate this.
    pending_tok: Vec<Option<(i32, f32)>>,
    cfg: SampleCfg,
    vnonce: u64,
    rnonce: u64,
    stats: PipelineStats,
    results: Vec<SeqResult>,
    done: bool,
}

impl<B: Backend> PipelineRun<B> {
    /// All seated rows finished and the queue had nothing left for this
    /// engine at the last check. (With a shared queue, a done run stays
    /// done: the queue only ever drains within a step.)
    pub fn done(&self) -> bool {
        self.done
    }

    /// Tear down into (unsorted results, this engine's stats).
    pub fn into_parts(self) -> (Vec<SeqResult>, PipelineStats) {
        (self.results, self.stats)
    }
}

/// The in-flight half of one pipeline round (PR 5): everything
/// [`RolloutEngine::step_submit`] issued to the device and has not yet
/// blocked on. Holding a ticket means the engine's device chain for this
/// round — decode → refill → verify-seat → read_gen, whichever of those
/// ran — is queued on its own timeline; the host is free to submit other
/// shards' chains before [`RolloutEngine::step_complete`] cashes this one
/// in. An empty ticket (no device call this round) completes as a no-op.
pub struct StepTicket<B: Backend = Engine> {
    /// Final pending forward of the round's gen-blob chain; its output is
    /// the round's new generation blob. `None` when no state-mutating
    /// entry ran this round.
    gen: Option<B::Pending>,
    /// Pending `read_gen` output for the round's probs/aux readback;
    /// `None` when the run finished during submission.
    read: Option<B::Pending>,
}

impl<B: Backend> StepTicket<B> {
    /// The round's current chain head: the buffer the next submit must
    /// consume as its gen argument — the in-flight chain's output if any
    /// stage has been submitted this round, else the run's completed blob
    /// (`fallback`). Keeping this in one place is what guarantees a newly
    /// added chain stage can never read a stale pre-round gen blob.
    fn chain_head<'a>(&'a self, eng: &B, fallback: &'a B::Buf) -> &'a B::Buf {
        match self.gen.as_ref() {
            Some(p) => eng.pending_buf(p),
            None => fallback,
        }
    }
}

/// The batched rollout engine bound to one (backend, bundle).
pub struct RolloutEngine<'e, B: Backend = Engine> {
    eng: &'e B,
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub vocab: usize,
    sampler: TopPSampler,
    // Pre-resolved entry handles: zero lookups in the decode loop. The
    // verify pair is optional so decode-only bundles stay usable; the
    // verify paths bail with context if the entry is absent.
    h_prefill: B::Entry,
    h_decode: B::Entry,
    h_read_gen: B::Entry,
    h_refill: B::Entry,
    h_verify: Option<B::Entry>,
    h_verify_seat: Option<B::Entry>,
    // Device-resident sampling pair (`ARCHITECTURE.md` §12). Optional so
    // bundles built before the `sample` entry existed keep working — the
    // pipeline silently falls back to host sampling + `read_gen` when
    // either is absent.
    h_sample: Option<B::Entry>,
    h_read_step: Option<B::Entry>,
    /// Force the host sampling path even when the bundle has the device
    /// pair — the byte-identity oracle and `bench_readback` baseline.
    force_host: bool,
    // Persistent host scratch, reused across runs and trainer steps: the
    // decode loop allocates nothing per step, and the verify executor
    // re-resolves nothing per step (it used to rebuild a SpecVerifier —
    // entry handles and all — on every trainer step).
    layout: BatchLayout,
    vplan: VerifyPlanner,
    token_in: Vec<i32>,
    slot_in: Vec<i32>,
    lpos_in: Vec<i32>,
    rowmask: Vec<f32>,
    /// `read_gen` readback: `[B*V probs | B aux]` — the aux tail carries
    /// `verify_seat`'s accepted-prefix lengths. Host sampling path only.
    readback: Vec<f32>,
    /// `read_step` readback: `[B tok | B ptok | B aux]` — the fused O(B)
    /// per-round payload of the device sampling path.
    step_read: Vec<f32>,
    /// Scratch for the `sample` entry's `[B, 3]` ctrl rows
    /// (task id, draws consumed so far, arm mode).
    ctrl: Vec<i32>,
    /// Cached temperature scalar buffer, keyed by bit pattern.
    temp_buf: Option<(u32, B::Buf)>,
}

impl<'e, B: Backend> RolloutEngine<'e, B> {
    pub fn new(eng: &'e B, bundle: &str) -> Result<Self> {
        let shape = eng.shape(bundle)?;
        Ok(RolloutEngine {
            eng,
            batch: shape.batch,
            prompt_len: shape.prompt_len,
            total_len: shape.total_len,
            vocab: shape.vocab,
            sampler: TopPSampler::new(shape.vocab),
            h_prefill: eng.resolve(bundle, "prefill")?,
            h_decode: eng.resolve(bundle, "decode")?,
            h_read_gen: eng.resolve(bundle, "read_gen")?,
            h_refill: eng.resolve(bundle, "refill")?,
            h_verify: eng.resolve(bundle, "verify").ok(),
            h_verify_seat: eng.resolve(bundle, "verify_seat").ok(),
            h_sample: eng.resolve(bundle, "sample").ok(),
            h_read_step: eng.resolve(bundle, "read_step").ok(),
            force_host: false,
            layout: BatchLayout::new(shape.batch, shape.prompt_len, shape.total_len),
            vplan: VerifyPlanner::new(shape),
            token_in: vec![0; shape.batch],
            slot_in: vec![shape.total_len as i32; shape.batch],
            lpos_in: vec![0; shape.batch],
            rowmask: vec![0.0; shape.batch],
            readback: vec![0.0; shape.batch * shape.vocab + shape.batch],
            step_read: vec![0.0; 3 * shape.batch],
            ctrl: vec![0; 3 * shape.batch],
            temp_buf: None,
        })
    }

    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }

    /// The backend this engine is bound to (the pool's overlap accounting
    /// reads its virtual clock through this).
    pub(crate) fn backend(&self) -> &B {
        self.eng
    }

    /// Force (or un-force) the host sampling path. With `true`, pipeline
    /// runs sample on the host from the `[B·V]` `read_gen` probs payload
    /// even when the bundle carries the device `sample`/`read_step` pair —
    /// the baseline side of `bench_readback` and the byte-identity sweeps.
    /// Outputs are identical either way (`ARCHITECTURE.md` §12).
    pub fn set_host_sampling(&mut self, force: bool) {
        self.force_host = force;
    }

    /// Whether pipeline runs started now will sample on the device: the
    /// bundle resolved both `sample` and `read_step` and host sampling is
    /// not forced. The oracles (`run`, `run_lockstep`, `run_wave`,
    /// `verify_wave`) always sample on the host regardless.
    pub fn device_sampling(&self) -> bool {
        !self.force_host && self.h_sample.is_some() && self.h_read_step.is_some()
    }

    /// Prime the cached temperature buffer for this run's config.
    fn ensure_temp(&mut self, temperature: f32) -> Result<()> {
        let bits = temperature.to_bits();
        if !matches!(&self.temp_buf, Some((b, _)) if *b == bits) {
            let buf = self.eng.upload_f32(&[temperature], &[1])?;
            self.temp_buf = Some((bits, buf));
        }
        Ok(())
    }

    fn temp_ref(&self) -> &B::Buf {
        &self.temp_buf.as_ref().expect("ensure_temp not called").1
    }

    /// Pull fully-reused terminal drafts straight into results; return the
    /// tasks that actually need decode slots. The pool driver calls this
    /// once before building the shared queue — every queued item needs a
    /// slot somewhere.
    pub(crate) fn split_terminal(
        &self,
        tasks: Vec<SeqTask>,
        results: &mut Vec<SeqResult>,
        stats: &mut PipelineStats,
    ) -> Vec<SeqTask> {
        let gen_len = self.gen_len();
        let mut pending = Vec::with_capacity(tasks.len());
        for t in tasks {
            if t.prefix_is_terminal(gen_len) {
                stats.reused_tokens += t.prefix.len();
                let finished = t.prefix.last() == Some(&EOS);
                results.push(SeqResult {
                    id: t.id,
                    reused: t.prefix.len(),
                    new_tokens: 0,
                    finished,
                    logps: t.prefix_logps,
                    response: t.prefix,
                });
            } else {
                pending.push(t);
            }
        }
        pending
    }

    /// Refresh `self.readback` (`[B*V probs | B aux]`) from the gen blob —
    /// the host sampling path's O(B·V) per-round readback.
    fn read_probs(&mut self, gen: &B::Buf, stats: &mut PipelineStats) -> Result<()> {
        let out = self.eng.call_entry(&self.h_read_gen, &[gen])?;
        stats.readback_bytes += self.readback.len() * 4;
        self.eng.read_f32_into(&out, &mut self.readback)
    }

    /// Reset row `r`'s decode-entry inputs to the inert convention
    /// (`slot == T` ⇒ no cache write) ahead of a sampling round.
    fn reset_step_row(&mut self, r: usize) {
        self.token_in[r] = 0;
        self.slot_in[r] = self.total_len as i32;
        self.lpos_in[r] = 0;
    }

    /// The sample-token/finish-row decode block shared by every discipline
    /// (`run_with_nonce`, `run_pipeline`, `run_wave` — formerly spelled out
    /// in each): sample row `r` from the current readback, append the token
    /// to the host layout, and arm the decode-entry inputs when the row
    /// survives. Callers own the phase bookkeeping (slot state vs wave
    /// arrays) and result assembly. Returns `(logp, done_eos, done)`.
    fn sample_row(
        &mut self,
        r: usize,
        top_p: f32,
        rng: &mut Rng,
        stats: &mut PipelineStats,
    ) -> (f32, bool, bool) {
        let v = self.vocab;
        let row = r * v;
        let tok = self.sampler.sample(&self.readback[row..row + v], top_p, rng) as i32;
        let lp = self.readback[row + tok as usize].max(1e-30).ln();
        let slot_pos = self.layout.push_token(r, tok);
        stats.new_tokens += 1;
        let done_eos = tok == EOS;
        let done = done_eos || self.layout.resp_len[r] >= self.gen_len();
        if !done {
            self.token_in[r] = tok;
            self.slot_in[r] = slot_pos as i32;
            self.lpos_in[r] = (self.layout.n_valid(r) - 1) as i32;
        }
        (lp, done_eos, done)
    }

    /// One sampling round over the slot pool: every decoding occupant
    /// samples one token; finished rows emit results and release their
    /// slot; verify-phase and free rows stay inert (out-of-range slot).
    /// Returns the number of surviving rows (armed decode writes).
    fn sample_round(
        &mut self,
        sched: &mut SlotScheduler,
        slots: &mut [Option<SlotState>],
        results: &mut Vec<SeqResult>,
        top_p: f32,
        stats: &mut PipelineStats,
    ) -> usize {
        let mut writes = 0usize;
        for r in 0..self.batch {
            self.reset_step_row(r);
            if slots[r].is_none() {
                continue;
            }
            let (lp, done_eos, done) = {
                let st = slots[r].as_mut().unwrap();
                let rng = &mut st.rng;
                self.sample_row(r, top_p, rng, stats)
            };
            if done {
                let mut st = slots[r].take().unwrap();
                st.logps.push(lp);
                let response = self.layout.response(r);
                stats.reused_tokens += st.reused;
                results.push(SeqResult {
                    id: st.id,
                    reused: st.reused,
                    new_tokens: response.len() - st.reused,
                    finished: done_eos,
                    logps: st.logps,
                    response,
                });
                sched.release(r);
            } else {
                slots[r].as_mut().unwrap().logps.push(lp);
                writes += 1;
            }
        }
        writes
    }

    /// The device-path twin of [`RolloutEngine::sample_round`]: tokens
    /// were already drawn by the previous round's `sample` entry and
    /// ingested from its `read_step` payload into `pending_tok`, so this
    /// round only ingests them — append to the host layout, emit finished
    /// rows, arm the decode-entry inputs for survivors. The logp is
    /// computed *here*, on the host, from the raw device probability
    /// (`ln(max(p, 1e-30))`), so logps stay bit-identical to the host
    /// sampler's — the device ships `p`, never `ln p`
    /// (`ARCHITECTURE.md` §12).
    fn sample_round_device(
        &mut self,
        sched: &mut SlotScheduler,
        slots: &mut [Option<SlotState>],
        pending_tok: &mut [Option<(i32, f32)>],
        results: &mut Vec<SeqResult>,
        stats: &mut PipelineStats,
    ) -> usize {
        let mut writes = 0usize;
        for r in 0..self.batch {
            self.reset_step_row(r);
            if slots[r].is_none() {
                continue;
            }
            let (tok, ptok) =
                pending_tok[r].take().expect("decoding row has a device-sampled token");
            let lp = ptok.max(1e-30).ln();
            let slot_pos = self.layout.push_token(r, tok);
            stats.new_tokens += 1;
            let done_eos = tok == EOS;
            let done = done_eos || self.layout.resp_len[r] >= self.gen_len();
            if !done {
                self.token_in[r] = tok;
                self.slot_in[r] = slot_pos as i32;
                self.lpos_in[r] = (self.layout.n_valid(r) - 1) as i32;
            }
            {
                let st = slots[r].as_mut().unwrap();
                st.draws += 1;
                st.logps.push(lp);
            }
            if done {
                let st = slots[r].take().unwrap();
                let response = self.layout.response(r);
                stats.reused_tokens += st.reused;
                results.push(SeqResult {
                    id: st.id,
                    reused: st.reused,
                    new_tokens: response.len() - st.reused,
                    finished: done_eos,
                    logps: st.logps,
                    response,
                });
                sched.release(r);
            } else {
                writes += 1;
            }
        }
        writes
    }

    /// Submit the round's device-side sampling over `gen`: one `[B, 3]`
    /// ctrl upload (task id, draws consumed so far, arm mode) against the
    /// run's cached nonce and top-p scalars. Decoding occupants arm
    /// unconditionally (mode 1) at their stream position; rows just
    /// seated by `verify_seat` arm conditionally on the blob's live lane
    /// (mode 2, draws 0) — the device knows their terminality before the
    /// host does, which is what keeps sampling on-chain. Everything else
    /// is inert (mode 0). The pending's buffer is the gen blob with the
    /// tok/ptok out-lanes written.
    fn sample_submit(
        &mut self,
        slots: &[Option<SlotState>],
        verifying: &[Option<VerifyTask>],
        nonce: &B::Buf,
        top_p: &B::Buf,
        gen: &B::Buf,
        stats: &mut PipelineStats,
    ) -> Result<B::Pending> {
        let b = self.batch;
        for r in 0..b {
            let (id, draws, mode) = match (&slots[r], &verifying[r]) {
                (Some(st), _) => (st.id as i32, st.draws as i32, 1),
                (None, Some(task)) => (task.id as i32, 0, 2),
                (None, None) => (0, 0, 0),
            };
            self.ctrl[3 * r] = id;
            self.ctrl[3 * r + 1] = draws;
            self.ctrl[3 * r + 2] = mode;
        }
        let ctrl_b = self.eng.upload_i32(&self.ctrl, &[b, 3])?;
        stats.upload_bytes += 3 * b * 4;
        let h = self.h_sample.as_ref().expect("device sampling path resolved 'sample'");
        self.eng.submit_entry(h, &[gen, &ctrl_b, nonce, top_p])
    }

    /// Submit one decode step over `gen`: three `[B]` uploads, never the
    /// `[B, T]` mask (inert rows carry out-of-range slots). Non-blocking;
    /// the returned pending's buffer is the advanced generation blob.
    fn decode_submit(
        &mut self,
        blob: &B::Buf,
        gen: &B::Buf,
        writes: usize,
        stats: &mut PipelineStats,
    ) -> Result<B::Pending> {
        let b = self.batch;
        let tok_b = self.eng.upload_i32(&self.token_in, &[b])?;
        let slot_b = self.eng.upload_i32(&self.slot_in, &[b])?;
        let lpos_b = self.eng.upload_i32(&self.lpos_in, &[b])?;
        let pending = self.eng.submit_entry(
            &self.h_decode,
            &[blob, gen, &tok_b, &slot_b, &lpos_b, self.temp_ref()],
        )?;
        stats.decode_steps += 1;
        stats.slot_idle_steps += b - writes;
        stats.upload_bytes += 3 * b * 4;
        Ok(pending)
    }

    /// Advance surviving rows one decode step, blocking — the synchronous
    /// composition of [`RolloutEngine::decode_submit`] + complete used by
    /// the single-chain drivers.
    fn decode_advance(
        &mut self,
        blob: &B::Buf,
        gen: &mut B::Buf,
        writes: usize,
        stats: &mut PipelineStats,
    ) -> Result<()> {
        let pending = self.decode_submit(blob, gen, writes, stats)?;
        *gen = self.eng.complete(pending)?;
        Ok(())
    }

    /// Submit the prefill of the current host layout — the only full-mask
    /// upload of a run (counts one wave). Non-blocking; the pending's
    /// buffer is the fresh generation blob. The pool submits every
    /// shard's prefill before completing any (`ARCHITECTURE.md` §12).
    fn prefill_submit(&mut self, blob: &B::Buf, stats: &mut PipelineStats) -> Result<B::Pending> {
        let (b, t) = (self.batch, self.total_len);
        let tok_b = self.eng.upload_i32(&self.layout.tokens, &[b, t])?;
        let val_b = self.eng.upload_f32(&self.layout.valid, &[b, t])?;
        let last_b = self.eng.upload_i32(&self.layout.last, &[b])?;
        let pending = self.eng.submit_entry(
            &self.h_prefill,
            &[blob, &tok_b, &val_b, &last_b, self.temp_ref()],
        )?;
        stats.waves += 1;
        stats.upload_bytes += (2 * b * t + b) * 4;
        Ok(pending)
    }

    /// Blocking [`RolloutEngine::prefill_submit`] + complete (the
    /// single-chain drivers' form).
    fn prefill_layout(&mut self, blob: &B::Buf, stats: &mut PipelineStats) -> Result<B::Buf> {
        let pending = self.prefill_submit(blob, stats)?;
        self.eng.complete(pending)
    }

    /// Re-seat freed slots from the queue's decode lane via the masked
    /// `refill` entry (several rows per call), arming their slot state.
    /// Runs after the decode step so refill probs are the freshest state
    /// for the next sampling round. Returns `None` (no submit) when no
    /// slot is free or the lane is drained. With a shared queue this is
    /// the steal point for decode work: whichever engine frees a slot
    /// first pulls the next task, never a row seated elsewhere.
    #[allow(clippy::too_many_arguments)]
    fn refill_submit(
        &mut self,
        sched: &mut SlotScheduler,
        slots: &mut [Option<SlotState>],
        queue: &mut WorkQueue,
        run_nonce: u64,
        blob: &B::Buf,
        gen: &B::Buf,
        stats: &mut PipelineStats,
    ) -> Result<Option<B::Pending>> {
        let fills = sched.fill(queue);
        if fills.is_empty() {
            return Ok(None);
        }
        for (slot, task) in fills {
            self.layout.set_row(slot, &task.prompt, &task.prefix);
            self.rowmask[slot] = 1.0;
            slots[slot] = Some(SlotState::new(task, run_nonce));
        }
        let (b, t) = (self.batch, self.total_len);
        let tok_b = self.eng.upload_i32(&self.layout.tokens, &[b, t])?;
        let val_b = self.eng.upload_f32(&self.layout.valid, &[b, t])?;
        let rm_b = self.eng.upload_f32(&self.rowmask, &[b])?;
        let last_b = self.eng.upload_i32(&self.layout.last, &[b])?;
        let pending = self.eng.submit_entry(
            &self.h_refill,
            &[blob, gen, &tok_b, &val_b, &rm_b, &last_b, self.temp_ref()],
        )?;
        stats.refills += 1;
        stats.upload_bytes += (2 * b * t + 2 * b) * 4;
        self.rowmask.fill(0.0);
        Ok(Some(pending))
    }

    /// Blocking [`RolloutEngine::refill_submit`] + complete (the
    /// single-chain drivers' form).
    #[allow(clippy::too_many_arguments)]
    fn refill_slots(
        &mut self,
        sched: &mut SlotScheduler,
        slots: &mut [Option<SlotState>],
        queue: &mut WorkQueue,
        run_nonce: u64,
        blob: &B::Buf,
        gen: &mut B::Buf,
        stats: &mut PipelineStats,
    ) -> Result<()> {
        if let Some(p) =
            self.refill_submit(sched, slots, queue, run_nonce, blob, gen, stats)?
        {
            *gen = self.eng.complete(p)?;
        }
        Ok(())
    }

    /// Upload the verify planner's packed buffers in the argument order
    /// shared by the `verify` and `verify_seat` entry signatures:
    /// `(tokens, valid, logp_prev, uniforms, draft_valid)`.
    #[allow(clippy::type_complexity)]
    fn upload_vplan(&self) -> Result<(B::Buf, B::Buf, B::Buf, B::Buf, B::Buf)> {
        let (b, t) = (self.batch, self.total_len);
        let g = self.gen_len();
        Ok((
            self.eng.upload_i32(&self.vplan.layout.tokens, &[b, t])?,
            self.eng.upload_f32(&self.vplan.layout.valid, &[b, t])?,
            self.eng.upload_f32(&self.vplan.logp_prev, &[b, g])?,
            self.eng.upload_f32(&self.vplan.uniforms, &[b, g])?,
            self.eng.upload_f32(&self.vplan.draft_valid, &[b, g])?,
        ))
    }

    /// Blocking packed verification over the `verify` entry — the
    /// two-phase oracle's executor. Returns accepted-prefix lengths (one
    /// per draft, in input order) and the number of engine calls made.
    pub fn verify_wave(
        &mut self,
        blob: &B::Buf,
        drafts: &[VerifyTask],
        loglen: f32,
        temperature: f32,
        vnonce: u64,
    ) -> Result<(Vec<usize>, usize)> {
        if drafts.is_empty() {
            return Ok((Vec::new(), 0));
        }
        let Some(h) = self.h_verify.clone() else {
            bail!("bundle has no 'verify' entry (rebuild artifacts)")
        };
        let b = self.batch;
        self.ensure_temp(temperature)?;
        let ll = self.eng.upload_f32(&[loglen], &[1])?;
        let mut accepted = Vec::with_capacity(drafts.len());
        let mut calls = 0usize;
        for chunk in drafts.chunks(b) {
            self.vplan.clear();
            for (r, task) in chunk.iter().enumerate() {
                self.vplan.set_row(r, task, vnonce);
            }
            let (tok, val, lp, un, dv) = self.upload_vplan()?;
            let out = self.eng.call_entry(
                &h,
                &[blob, &tok, &val, &lp, &un, &dv, &ll, self.temp_ref()],
            )?;
            calls += 1;
            let host = self.eng.read_f32(&out)?;
            for (r, task) in chunk.iter().enumerate() {
                accepted.push(self.vplan.accepted(host[r], task));
            }
        }
        Ok((accepted, calls))
    }

    /// Seat queued drafts into free slots via one packed `verify_seat`
    /// submit (verify + KV seat, no separate refill forward). Rows seated
    /// here stay in the Verify phase until `resolve_verified` reads their
    /// rejection offsets from the aux lane. Seating is adaptive
    /// (`seat_min`, see [`SampleCfg::verify_seat_min`]) and, with a shared
    /// queue, this is the steal point for draft work. Returns `None`
    /// (no submit) when nothing seats.
    #[allow(clippy::too_many_arguments)]
    fn seat_submit(
        &mut self,
        sched: &mut SlotScheduler,
        verifying: &mut [Option<VerifyTask>],
        queue: &mut WorkQueue,
        seat_min: usize,
        blob: &B::Buf,
        gen: &B::Buf,
        vnonce: u64,
        ll: &B::Buf,
        stats: &mut PipelineStats,
        timer: &mut StageTimer,
    ) -> Result<Option<B::Pending>> {
        let vfills = sched.fill_verify(queue, seat_min);
        if vfills.is_empty() {
            return Ok(None);
        }
        let span = Instant::now();
        let Some(h) = self.h_verify_seat.clone() else {
            bail!("bundle has no 'verify_seat' entry (rebuild artifacts)")
        };
        let b = self.batch;
        self.vplan.clear();
        for (slot, task) in vfills {
            self.vplan.set_row(slot, &task, vnonce);
            self.rowmask[slot] = 1.0;
            verifying[slot] = Some(task);
        }
        let (tok, val, lp, un, dv) = self.upload_vplan()?;
        let rm = self.eng.upload_f32(&self.rowmask, &[b])?;
        let pending = self.eng.submit_entry(
            &h,
            &[blob, gen, &tok, &val, &lp, &un, &dv, &rm, ll, self.temp_ref()],
        )?;
        stats.verify_calls += 1;
        stats.upload_bytes += (2 * b * self.total_len + 3 * b * self.gen_len() + b) * 4;
        self.rowmask.fill(0.0);
        timer.add("verification", span.elapsed().as_secs_f64());
        Ok(Some(pending))
    }

    /// Read back the aux lane for rows seated by `seat_submit`: terminal
    /// accepted prefixes emit results and free the slot; the rest
    /// transition `Verify -> Decode` with their accepted prefix mirrored
    /// into the host layout. The aux lane arrives at `[B·V + slot]` of
    /// the `read_gen` payload on the host path, `[2B + slot]` of the
    /// fused `read_step` payload on the device path.
    #[allow(clippy::too_many_arguments)]
    fn resolve_verified(
        &mut self,
        sched: &mut SlotScheduler,
        verifying: &mut [Option<VerifyTask>],
        slots: &mut [Option<SlotState>],
        rnonce: u64,
        device: bool,
        results: &mut Vec<SeqResult>,
        stats: &mut PipelineStats,
    ) {
        let (b, v) = (self.batch, self.vocab);
        let gen_len = self.gen_len();
        for slot in 0..b {
            let Some(task) = verifying[slot].take() else { continue };
            let raw = if device {
                self.step_read[2 * b + slot]
            } else {
                self.readback[b * v + slot]
            };
            let n_acc = self.vplan.accepted(raw, &task);
            stats.drafts += 1;
            stats.prefix_tokens += n_acc;
            if n_acc == task.draft_len() {
                stats.full_reuses += 1;
            }
            let prefix = &task.entry.response[..n_acc];
            let finished = prefix.last() == Some(&EOS);
            if finished || n_acc >= gen_len {
                stats.reused_tokens += n_acc;
                results.push(SeqResult {
                    id: task.id,
                    reused: n_acc,
                    new_tokens: 0,
                    finished,
                    logps: task.entry.logps[..n_acc].to_vec(),
                    response: prefix.to_vec(),
                });
                sched.release(slot);
            } else {
                self.layout.set_row(slot, &task.prompt, prefix);
                slots[slot] = Some(SlotState {
                    rng: task_rng(rnonce, task.id),
                    id: task.id,
                    reused: n_acc,
                    logps: task.entry.logps[..n_acc].to_vec(),
                    draws: 0,
                    from_draft: true,
                });
                sched.to_decode(slot);
            }
        }
    }

    /// Generate all tasks with the continuous-batching slot scheduler
    /// (decode phase only — no drafts). Stage accounting: device work
    /// under `"rollout"`, result assembly under `"assembly"`. Results are
    /// id-sorted.
    pub fn run(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let nonce = rng.next_u64();
        self.run_with_nonce(blob, tasks, cfg, nonce, timer)
    }

    /// [`RolloutEngine::run`] with an explicit sampling nonce (the
    /// two-phase driver shares nonces between paths to stay byte-identical
    /// to the pipeline).
    pub fn run_with_nonce(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        run_nonce: u64,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let mut stats = PipelineStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len());
        let pending = self.split_terminal(tasks, &mut results, &mut stats);
        if pending.is_empty() {
            results.sort_by_key(|r| r.id);
            return Ok((results, stats));
        }

        let b = self.batch;
        let mut queue = WorkQueue::tasks_only(pending);
        let mut sched = SlotScheduler::new(b);
        let mut slots: Vec<Option<SlotState>> = (0..b).map(|_| None).collect();
        self.ensure_temp(cfg.temperature)?;

        // --- initial fill + prefill -------------------------------------
        let span = Instant::now();
        self.layout.clear();
        for (slot, task) in sched.fill(&mut queue) {
            self.layout.set_row(slot, &task.prompt, &task.prefix);
            slots[slot] = Some(SlotState::new(task, run_nonce));
        }
        let mut gen = self.prefill_layout(blob, &mut stats)?;
        self.read_probs(&gen, &mut stats)?;
        timer.add("rollout", span.elapsed().as_secs_f64());

        // --- decode loop -------------------------------------------------
        loop {
            let span = Instant::now();
            // 1. sample one token for every occupied slot
            let writes =
                self.sample_round(&mut sched, &mut slots, &mut results, cfg.top_p, &mut stats);

            // 2. advance surviving rows: three [B] uploads, no [B,T] mask
            if sched.busy() > 0 {
                self.decode_advance(blob, &mut gen, writes, &mut stats)?;
            }

            // 3. refill freed slots
            self.refill_slots(
                &mut sched, &mut slots, &mut queue, run_nonce, blob, &mut gen, &mut stats,
            )?;

            if sched.is_done(&queue) {
                timer.add("rollout", span.elapsed().as_secs_f64());
                break;
            }
            self.read_probs(&gen, &mut stats)?;
            timer.add("rollout", span.elapsed().as_secs_f64());
        }

        let span = Instant::now();
        results.sort_by_key(|r| r.id);
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok((results, stats))
    }

    /// The interleaved phase-aware pipeline: decode-ready `tasks` and
    /// to-verify `drafts` share one slot pool. Fresh rows decode from the
    /// first step; drafts verify-seat into free slots as they appear and
    /// start decoding the moment their rejection offset is read back.
    /// Byte-identical to the two-phase verify-then-decode oracle (per-task
    /// sampling and verification streams), with strictly fewer device
    /// calls on draft-bearing workloads: verified rows never pay a refill
    /// forward, and the blocking verify wave disappears.
    ///
    /// This is the one-engine driver over the stepwise core
    /// ([`RolloutEngine::pipeline_start`] / [`RolloutEngine::pipeline_step`])
    /// with a private [`WorkQueue`]; the sharded driver is
    /// [`crate::rollout::pool::EnginePool`], which interleaves the same
    /// steps across N engines over one shared queue.
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipeline(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        self.run_pipeline_est(
            blob,
            tasks,
            drafts,
            loglen,
            cfg,
            vnonce,
            rnonce,
            LenEstimates::off(),
            timer,
        )
    }

    /// [`RolloutEngine::run_pipeline`] with an explicit length-estimate
    /// table ordering the private queue (`ARCHITECTURE.md` §14).
    /// Estimates only reorder seating — outputs are byte-identical for
    /// any table; [`LenEstimates::off`] reproduces the raw LPT keys.
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipeline_est(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        est: LenEstimates,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let mut stats = PipelineStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len() + drafts.len());
        let pending = self.split_terminal(tasks, &mut results, &mut stats);
        if pending.is_empty() && drafts.is_empty() {
            results.sort_by_key(|r| r.id);
            return Ok((results, stats));
        }

        let mut queue = WorkQueue::with_estimates(pending, drafts, est);
        let mut run = self.pipeline_start(blob, &mut queue, loglen, cfg, vnonce, rnonce, timer)?;
        while !run.done() {
            self.pipeline_step(&mut run, blob, &mut queue, timer)?;
        }
        let (r, s) = run.into_parts();
        results.extend(r);
        stats.absorb(&s);

        let span = Instant::now();
        results.sort_by_key(|r| r.id);
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok((results, stats))
    }

    /// Begin a pipeline run on this engine: seat the initial work from
    /// `queue` (decode fills + prefill, then the first packed verify-seat),
    /// read the first probs/aux back, and return the resumable
    /// [`PipelineRun`]. A run that finds neither a decode task nor a draft
    /// in the queue completes immediately with **zero** device calls — an
    /// idle shard of an over-provisioned pool costs nothing.
    ///
    /// The caller owns the queue: pass a private one for a single-engine
    /// run, or one shared queue to every shard's `pipeline_start` /
    /// [`RolloutEngine::pipeline_step`] to get mid-step work stealing (see
    /// [`crate::rollout::pool::EnginePool`]). Step nonces and `cfg` are
    /// captured in the run; results are byte-identical however the steps
    /// of concurrent runs interleave (`ARCHITECTURE.md` §6).
    ///
    /// This is the blocking composition of
    /// [`RolloutEngine::start_submit`] + [`RolloutEngine::start_complete`];
    /// the pool drives the halves separately so every shard's first
    /// prefill/seat chain is in flight before any shard blocks
    /// (`ARCHITECTURE.md` §12).
    #[allow(clippy::too_many_arguments)]
    pub fn pipeline_start(
        &mut self,
        blob: &B::Buf,
        queue: &mut WorkQueue,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        timer: &mut StageTimer,
    ) -> Result<PipelineRun<B>> {
        let (mut run, ticket) =
            self.start_submit(blob, queue, loglen, cfg, vnonce, rnonce, timer);
        self.start_complete(&mut run, ticket?, queue, timer)?;
        Ok(run)
    }

    /// Submit a pipeline run's opening device chain without blocking on
    /// any of it: pull the initial decode fills from `queue`, submit the
    /// prefill, chain the first packed verify-seat onto it, and chain the
    /// first readback (device path: the opening `sample` + `read_step`;
    /// host path: `read_gen`). Like [`RolloutEngine::step_submit`], the
    /// host returns as soon as everything is queued — the pool submits
    /// every shard's opening chain before cashing any ticket in, so
    /// first-step forwards overlap across shards exactly like steady-state
    /// rounds do. A shard that finds the queue empty returns a done run
    /// and an empty ticket, still at zero device calls.
    ///
    /// The run is returned even when the submission errors (the `Err`
    /// side of the ticket): by then the run may already hold rows popped
    /// from the shared queue, and the pool's dead-shard recovery
    /// ([`RolloutEngine::harvest_requeue`], `ARCHITECTURE.md` §13) must
    /// be able to return them — dropping the run would lose tasks.
    #[allow(clippy::too_many_arguments)]
    pub fn start_submit(
        &mut self,
        blob: &B::Buf,
        queue: &mut WorkQueue,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        timer: &mut StageTimer,
    ) -> (PipelineRun<B>, Result<StepTicket<B>>) {
        let b = self.batch;
        let mut run = PipelineRun {
            sched: SlotScheduler::new(b),
            slots: (0..b).map(|_| None).collect(),
            verifying: (0..b).map(|_| None).collect(),
            gen: None,
            ll: None,
            top_p_buf: None,
            nonce_buf: None,
            device: self.device_sampling(),
            pending_tok: (0..b).map(|_| None).collect(),
            cfg,
            vnonce,
            rnonce,
            stats: PipelineStats::default(),
            results: Vec::new(),
            done: false,
        };
        let ticket = self.start_submit_inner(&mut run, blob, queue, loglen, timer);
        (run, ticket)
    }

    fn start_submit_inner(
        &mut self,
        run: &mut PipelineRun<B>,
        blob: &B::Buf,
        queue: &mut WorkQueue,
        loglen: f32,
        timer: &mut StageTimer,
    ) -> Result<StepTicket<B>> {
        let (cfg, vnonce, rnonce) = (run.cfg, run.vnonce, run.rnonce);
        let mut ticket = StepTicket { gen: None, read: None };

        let span = Instant::now();
        self.layout.clear();
        let fills = run.sched.fill(queue);
        if fills.is_empty() && queue.pending_drafts() == 0 {
            // Nothing left for this shard: no prefill, no uploads.
            run.done = true;
            return Ok(ticket);
        }
        // Seat the fills host-side *before* any fallible device call, so a
        // failing upload leaves the popped tasks recoverable in `run.slots`
        // (the dead-shard harvest walks them) instead of dropped.
        for (slot, task) in fills {
            self.layout.set_row(slot, &task.prompt, &task.prefix);
            run.slots[slot] = Some(SlotState::new(task, rnonce));
        }
        self.ensure_temp(cfg.temperature)?;
        run.ll = Some(self.eng.upload_f32(&[loglen], &[1])?);
        if run.device {
            run.top_p_buf = Some(self.eng.upload_f32(&[cfg.top_p], &[1])?);
            let words = [(rnonce >> 32) as u32 as i32, rnonce as u32 as i32];
            run.nonce_buf = Some(self.eng.upload_i32(&words, &[2])?);
        }
        ticket.gen = Some(self.prefill_submit(blob, &mut run.stats)?);
        timer.add("rollout", span.elapsed().as_secs_f64());

        let seated = {
            let gen = self.eng.pending_buf(ticket.gen.as_ref().expect("prefill submitted"));
            self.seat_submit(
                &mut run.sched,
                &mut run.verifying,
                queue,
                cfg.verify_seat_min,
                blob,
                gen,
                vnonce,
                run.ll.as_ref().expect("loglen uploaded above"),
                &mut run.stats,
                timer,
            )?
        };
        if let Some(p) = seated {
            ticket.gen = Some(p);
        }
        self.submit_readback(run, &mut ticket)?;
        Ok(ticket)
    }

    /// Cash in the opening chain's ticket — identical to
    /// [`RolloutEngine::step_complete`] (named separately so pool drivers
    /// read as submit-all-starts / complete-all-starts).
    pub fn start_complete(
        &mut self,
        run: &mut PipelineRun<B>,
        ticket: StepTicket<B>,
        queue: &WorkQueue,
        timer: &mut StageTimer,
    ) -> Result<()> {
        self.step_complete(run, ticket, queue, timer)
    }

    /// An already-done empty run: zero device calls, zero stats, nothing
    /// seated. The pool's dead-shard recovery path (`ARCHITECTURE.md`
    /// §13) parks dead shards on one of these so a recovery cycle can
    /// still drive `shards[i]` uniformly by index.
    pub(crate) fn idle_run(&self, cfg: SampleCfg, vnonce: u64, rnonce: u64) -> PipelineRun<B> {
        let b = self.batch;
        PipelineRun {
            sched: SlotScheduler::new(b),
            slots: (0..b).map(|_| None).collect(),
            verifying: (0..b).map(|_| None).collect(),
            gen: None,
            ll: None,
            top_p_buf: None,
            nonce_buf: None,
            device: self.device_sampling(),
            pending_tok: (0..b).map(|_| None).collect(),
            cfg,
            vnonce,
            rnonce,
            stats: PipelineStats::default(),
            results: Vec::new(),
            done: true,
        }
    }

    /// Strip a dead shard's unfinished seated rows back into queueable
    /// work (`ARCHITECTURE.md` §13). Finished rows (already in
    /// `run.results`) are kept; every live occupant is reconstructed as
    /// the task that would reproduce it from scratch:
    ///
    /// - a row still awaiting verification returns its original
    ///   [`VerifyTask`] untouched;
    /// - a decoding row whose prefix was verified *on this engine*
    ///   (`SlotState::from_draft`) re-enters as a draft truncated to the
    ///   accepted length — re-verification replays the same per-task
    ///   uniform stream over the same `logp_prev` values and re-accepts
    ///   every token (§6), so the survivor reproduces this row's tokens
    ///   byte-for-byte;
    /// - any other decoding row (fresh, or seated from a pre-resolved
    ///   [`SeqTask`] prefix) re-enters as a task carrying its prefix and
    ///   log-probs verbatim. Variant-resolved prefixes never consumed
    ///   verify uniforms, so routing them through verification could
    ///   *reject* tokens the no-failure run kept — they must not become
    ///   drafts.
    ///
    /// Partial decode progress past the reused prefix is discarded: the
    /// per-task RNG stream is stateless (§6), so the survivor re-derives
    /// the identical continuation from stream position zero. Reads row
    /// content from this engine's private `layout`, which stays intact —
    /// a dead shard is never driven again, so nothing overwrites it.
    /// Leaves the run done (results/stats still harvestable via
    /// [`PipelineRun::into_parts`]).
    pub(crate) fn harvest_requeue(
        &mut self,
        run: &mut PipelineRun<B>,
    ) -> (Vec<SeqTask>, Vec<VerifyTask>) {
        let mut tasks = Vec::new();
        let mut drafts = Vec::new();
        for slot in 0..self.batch {
            if let Some(vt) = run.verifying[slot].take() {
                run.sched.release(slot);
                drafts.push(vt);
            } else if let Some(st) = run.slots[slot].take() {
                run.sched.release(slot);
                let prompt = self.layout.prompt(slot);
                let mut prefix = self.layout.response(slot);
                prefix.truncate(st.reused);
                let mut logps = st.logps;
                logps.truncate(st.reused);
                if st.from_draft && st.reused > 0 {
                    drafts.push(VerifyTask {
                        id: st.id,
                        prompt,
                        entry: CacheEntry::requeue_draft(prefix, logps),
                    });
                } else {
                    tasks.push(SeqTask { id: st.id, prompt, prefix, prefix_logps: logps });
                }
            }
            run.pending_tok[slot] = None;
        }
        run.done = true;
        (tasks, drafts)
    }

    /// Chain the round's readback onto the ticket: the device path first
    /// chains the `sample` entry (drawing next round's tokens on-device
    /// from the freshest probs) and reads the fused O(B)
    /// `[B tok | B ptok | B aux]` payload via `read_step`; the host path
    /// reads the O(B·V) `[B·V probs | B aux]` payload via `read_gen`.
    fn submit_readback(
        &mut self,
        run: &mut PipelineRun<B>,
        ticket: &mut StepTicket<B>,
    ) -> Result<()> {
        if run.device {
            let sampled = {
                let fallback = run.gen.as_ref();
                let gen = match ticket.gen.as_ref() {
                    Some(p) => self.eng.pending_buf(p),
                    None => fallback.expect("started run has a gen blob"),
                };
                self.sample_submit(
                    &run.slots,
                    &run.verifying,
                    run.nonce_buf.as_ref().expect("device run uploaded its nonce"),
                    run.top_p_buf.as_ref().expect("device run uploaded its top-p"),
                    gen,
                    &mut run.stats,
                )?
            };
            ticket.gen = Some(sampled);
            let read = {
                let gen = self.eng.pending_buf(ticket.gen.as_ref().expect("sample just chained"));
                let h = self.h_read_step.as_ref().expect("device path resolved 'read_step'");
                self.eng.submit_entry(h, &[gen])?
            };
            ticket.read = Some(read);
        } else {
            let read = {
                let fallback = run.gen.as_ref();
                let gen = match ticket.gen.as_ref() {
                    Some(p) => self.eng.pending_buf(p),
                    None => fallback.expect("started run has a gen blob"),
                };
                self.eng.submit_entry(&self.h_read_gen, &[gen])?
            };
            ticket.read = Some(read);
        }
        Ok(())
    }

    /// Issue one pipeline round's device work without blocking on any of
    /// it: sample every decoding slot from the current readback, submit
    /// the decode step for survivors, submit a refill for freed slots
    /// (pulling from the queue's decode lane), submit a packed
    /// verify-seat for slots still free (respecting
    /// [`SampleCfg::verify_seat_min`]), and finally submit the round's
    /// `read_gen`. The chain is linked through [`Backend::pending_buf`] —
    /// each forward consumes its predecessor's pending output on the
    /// device's own timeline — so the host returns as soon as everything
    /// is queued. Blocking happens only in
    /// [`RolloutEngine::step_complete`]; between the two, a pool driver
    /// submits the *other* shards' rounds, which is what lets engine
    /// forwards on distinct devices run concurrently
    /// (`ARCHITECTURE.md` §11).
    ///
    /// With a shared queue the refill/seat pulls are the steal points —
    /// this engine picks up work another shard would otherwise have
    /// queued behind its tail. Returns an empty ticket once the run is
    /// done; a round that finds nothing to do (no survivors, queue
    /// drained) marks the run done and also returns an empty ticket.
    pub fn step_submit(
        &mut self,
        run: &mut PipelineRun<B>,
        blob: &B::Buf,
        queue: &mut WorkQueue,
        timer: &mut StageTimer,
    ) -> Result<StepTicket<B>> {
        let mut ticket = StepTicket { gen: None, read: None };
        if run.done {
            return Ok(ticket);
        }
        let cfg = run.cfg;
        let span = Instant::now();
        // 1. sample one token for every decoding slot (verify-phase rows
        //    are inert: their slot_in entries stay out-of-range). On the
        //    device path the tokens were drawn by the previous round's
        //    `sample` entry; this only ingests them.
        let writes = if run.device {
            self.sample_round_device(
                &mut run.sched,
                &mut run.slots,
                &mut run.pending_tok,
                &mut run.results,
                &mut run.stats,
            )
        } else {
            self.sample_round(
                &mut run.sched, &mut run.slots, &mut run.results, cfg.top_p, &mut run.stats,
            )
        };

        // 2. submit the decode step for surviving rows
        if writes > 0 {
            let p = {
                let gen = run.gen.as_ref().expect("started run has a gen blob");
                self.decode_submit(blob, gen, writes, &mut run.stats)?
            };
            ticket.gen = Some(p);
        }

        // 3. submit a refill for freed slots from the queue's decode lane
        let refilled = {
            let fallback = run.gen.as_ref().expect("started run has a gen blob");
            let gen = ticket.chain_head(self.eng, fallback);
            self.refill_submit(
                &mut run.sched, &mut run.slots, queue, run.rnonce, blob, gen, &mut run.stats,
            )?
        };
        if let Some(p) = refilled {
            ticket.gen = Some(p);
        }
        timer.add("rollout", span.elapsed().as_secs_f64());

        // 4. submit a packed verify-seat into any slots still free
        let seated = {
            let fallback = run.gen.as_ref().expect("started run has a gen blob");
            let gen = ticket.chain_head(self.eng, fallback);
            self.seat_submit(
                &mut run.sched,
                &mut run.verifying,
                queue,
                cfg.verify_seat_min,
                blob,
                gen,
                run.vnonce,
                run.ll.as_ref().expect("started run has a loglen buffer"),
                &mut run.stats,
                timer,
            )?
        };
        if let Some(p) = seated {
            ticket.gen = Some(p);
        }

        if run.sched.is_done(queue) {
            // Nothing decoding, nothing verifying, queue drained: the
            // round submitted no forward (any occupied slot would have
            // kept `busy > 0`), so there is nothing to read back.
            run.done = true;
            return Ok(ticket);
        }

        // 5. submit the round's readback: one read serves both phases —
        //    next round's tokens (device) or fresh probs (host) for the
        //    next sampling round, aux offsets for the rows just seated
        self.submit_readback(run, &mut ticket)?;
        Ok(ticket)
    }

    /// Cash in a round's ticket: block on the device chain's final
    /// pending (the round's new generation blob), then on the readback
    /// output — the fused O(B) `[B tok | B ptok | B aux]` `read_step`
    /// payload on the device path, the O(B·V) `read_gen` payload on the
    /// host path — resolve just-verified rows, and (device path) ingest
    /// the device-sampled tokens for the next round. This is the only
    /// host-blocking half of the two-phase round; completing an empty
    /// ticket is free.
    pub fn step_complete(
        &mut self,
        run: &mut PipelineRun<B>,
        ticket: StepTicket<B>,
        queue: &WorkQueue,
        timer: &mut StageTimer,
    ) -> Result<()> {
        if let Some(p) = ticket.gen {
            run.gen = Some(self.eng.complete(p)?);
        }
        let Some(read) = ticket.read else {
            return Ok(());
        };
        let span = Instant::now();
        let out = self.eng.complete(read)?;
        if run.device {
            self.eng.read_f32_into(&out, &mut self.step_read)?;
            run.stats.readback_bytes += self.step_read.len() * 4;
        } else {
            self.eng.read_f32_into(&out, &mut self.readback)?;
            run.stats.readback_bytes += self.readback.len() * 4;
        }
        self.resolve_verified(
            &mut run.sched,
            &mut run.verifying,
            &mut run.slots,
            run.rnonce,
            run.device,
            &mut run.results,
            &mut run.stats,
        );
        if run.device {
            // Ingest the tok/ptok out-lanes: any row the device armed
            // (mode 1, or mode 2 with a live seat) carries its next token;
            // unarmed rows ship -1. Terminal mode-2 seats were just
            // released by `resolve_verified`, and their lane is -1 too.
            let b = self.batch;
            for r in 0..b {
                let t = self.step_read[r];
                run.pending_tok[r] = if t >= 0.0 {
                    Some((t as i32, self.step_read[b + r]))
                } else {
                    None
                };
            }
        }
        timer.add("rollout", span.elapsed().as_secs_f64());
        run.done = run.sched.is_done(queue);
        Ok(())
    }

    /// Advance a started run by one pipeline round, blocking: the
    /// composed [`RolloutEngine::step_submit`] +
    /// [`RolloutEngine::step_complete`]. Single-engine runs and
    /// [`crate::rollout::pool::Placement::Static`] drive this form — one
    /// chain, nothing to overlap with — so they are untouched by the
    /// two-phase split. No-op once the run is done.
    pub fn pipeline_step(
        &mut self,
        run: &mut PipelineRun<B>,
        blob: &B::Buf,
        queue: &mut WorkQueue,
        timer: &mut StageTimer,
    ) -> Result<()> {
        let ticket = self.step_submit(run, blob, queue, timer)?;
        self.step_complete(run, ticket, queue, timer)
    }

    /// The pre-scheduler wave discipline: tasks bind to slots in waves of
    /// `batch`, every wave decodes in lockstep until its slowest row
    /// finishes. Byte-identical outputs to [`RolloutEngine::run`] (same
    /// per-task RNG streams); kept as the equivalence oracle and the
    /// `bench_sched` baseline.
    pub fn run_lockstep(
        &mut self,
        blob: &B::Buf,
        tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let mut stats = PipelineStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len());
        let mut pending = self.split_terminal(tasks, &mut results, &mut stats);
        let run_nonce = rng.next_u64();

        // Longest prefixes first => rows within a wave have similar
        // remaining lengths (the old scheduler's only lever).
        pending.sort_by(|a, b| b.prefix.len().cmp(&a.prefix.len()).then(a.id.cmp(&b.id)));

        let mut idx = 0;
        while idx < pending.len() {
            let wave = &pending[idx..(idx + self.batch).min(pending.len())];
            self.run_wave(blob, wave, cfg, run_nonce, timer, &mut stats, &mut results)?;
            idx += self.batch;
        }
        let span = Instant::now();
        results.sort_by_key(|r| r.id);
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok((results, stats))
    }

    /// One lockstep wave: prefill + decode until every row finishes.
    #[allow(clippy::too_many_arguments)]
    fn run_wave(
        &mut self,
        blob: &B::Buf,
        tasks: &[SeqTask],
        cfg: SampleCfg,
        run_nonce: u64,
        timer: &mut StageTimer,
        stats: &mut PipelineStats,
        results: &mut Vec<SeqResult>,
    ) -> Result<()> {
        let gen_len = self.gen_len();
        let n = tasks.len();
        self.ensure_temp(cfg.temperature)?;

        let span = Instant::now();
        self.layout.clear();
        for (r, task) in tasks.iter().enumerate() {
            self.layout.set_row(r, &task.prompt, &task.prefix);
        }
        let mut logps: Vec<Vec<f32>> = tasks.iter().map(|x| x.prefix_logps.clone()).collect();
        let mut rngs: Vec<Rng> = tasks.iter().map(|x| task_rng(run_nonce, x.id)).collect();
        let mut finished = vec![false; n];
        let mut eos_emitted = vec![false; n];

        let mut gen = self.prefill_layout(blob, stats)?;
        self.read_probs(&gen, stats)?;
        timer.add("rollout", span.elapsed().as_secs_f64());

        loop {
            let span = Instant::now();
            let mut writes = 0usize;
            for r in 0..self.batch {
                self.reset_step_row(r);
                if r >= n || finished[r] || self.layout.resp_len[r] >= gen_len {
                    continue;
                }
                let (lp, done_eos, done) = self.sample_row(r, cfg.top_p, &mut rngs[r], stats);
                logps[r].push(lp);
                if done {
                    finished[r] = true;
                    eos_emitted[r] = done_eos;
                } else {
                    writes += 1;
                }
            }
            if writes == 0 {
                timer.add("rollout", span.elapsed().as_secs_f64());
                break;
            }
            self.decode_advance(blob, &mut gen, writes, stats)?;
            self.read_probs(&gen, stats)?;
            timer.add("rollout", span.elapsed().as_secs_f64());
        }

        let span = Instant::now();
        for (r, task) in tasks.iter().enumerate() {
            let response = self.layout.response(r);
            stats.reused_tokens += task.prefix.len();
            results.push(SeqResult {
                id: task.id,
                reused: task.prefix.len(),
                new_tokens: response.len() - task.prefix.len(),
                finished: eos_emitted[r],
                logps: std::mem::take(&mut logps[r]),
                response,
            });
        }
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok(())
    }
}
