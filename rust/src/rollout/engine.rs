//! Wave-scheduled batched generation over the PJRT decode entries.

use anyhow::Result;

use super::batch::{BatchLayout, SeqResult, SeqTask};
use crate::model::Policy;
use crate::runtime::Engine;
use crate::tokenizer::EOS;
use crate::util::{Rng, StageTimer, TopPSampler};

/// Aggregate statistics for one `run` call.
#[derive(Clone, Debug, Default)]
pub struct RolloutStats {
    /// Newly decoded tokens (the paper's "Tokens" efficiency metric).
    pub new_tokens: usize,
    /// Tokens taken from verified prefixes.
    pub reused_tokens: usize,
    /// Decode executable invocations (per-wave steps summed).
    pub decode_steps: usize,
    /// Waves executed.
    pub waves: usize,
}

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_p: f32,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { temperature: 1.0, top_p: 1.0 }
    }
}

/// The batched rollout engine bound to one (engine, bundle).
pub struct RolloutEngine<'e> {
    eng: &'e Engine,
    bundle: String,
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub vocab: usize,
    sampler: TopPSampler,
}

impl<'e> RolloutEngine<'e> {
    pub fn new(eng: &'e Engine, bundle: &str) -> Result<Self> {
        let info = eng.bundle(bundle)?.clone();
        Ok(RolloutEngine {
            eng,
            bundle: bundle.to_string(),
            batch: info.batch,
            prompt_len: eng.manifest.prompt_len,
            total_len: eng.manifest.total_len,
            vocab: info.model.vocab,
            sampler: TopPSampler::new(info.model.vocab),
        })
    }

    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }

    /// Generate all tasks, wave by wave. Stage accounting: decode work under
    /// `"rollout"`, result assembly under `"assembly"`.
    pub fn run(
        &mut self,
        policy: &Policy,
        mut tasks: Vec<SeqTask>,
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, RolloutStats)> {
        let mut stats = RolloutStats::default();
        let mut results: Vec<SeqResult> = Vec::with_capacity(tasks.len());

        // Fully-reused terminal drafts never enter a wave.
        let gen_len = self.gen_len();
        let mut pending: Vec<SeqTask> = Vec::with_capacity(tasks.len());
        for t in tasks.drain(..) {
            if t.prefix_is_terminal(gen_len) {
                stats.reused_tokens += t.prefix.len();
                let finished = t.prefix.last() == Some(&EOS);
                results.push(SeqResult {
                    id: t.id,
                    reused: t.prefix.len(),
                    new_tokens: 0,
                    finished,
                    logps: t.prefix_logps,
                    response: t.prefix,
                });
            } else {
                pending.push(t);
            }
        }

        // Wave scheduling: longest prefixes first => rows within a wave have
        // similar remaining lengths and wall-clock tracks token counts.
        pending.sort_by(|a, b| b.prefix.len().cmp(&a.prefix.len()).then(a.id.cmp(&b.id)));

        let mut idx = 0;
        while idx < pending.len() {
            let wave = &pending[idx..(idx + self.batch).min(pending.len())];
            let wave_res = self.run_wave(policy, wave, cfg, rng, timer, &mut stats)?;
            results.extend(wave_res);
            idx += self.batch;
            stats.waves += 1;
        }

        results.sort_by_key(|r| r.id);
        Ok((results, stats))
    }

    /// One wave: prefill + lockstep decode until every row finishes.
    fn run_wave(
        &mut self,
        policy: &Policy,
        tasks: &[SeqTask],
        cfg: SampleCfg,
        rng: &mut Rng,
        timer: &mut StageTimer,
        stats: &mut RolloutStats,
    ) -> Result<Vec<SeqResult>> {
        let (b, p, t) = (self.batch, self.prompt_len, self.total_len);
        let gen_len = self.gen_len();
        let mut layout = BatchLayout::pack(tasks, b, p, t);
        let n = tasks.len();

        let mut logps: Vec<Vec<f32>> = tasks.iter().map(|x| x.prefix_logps.clone()).collect();
        let mut finished = vec![false; n];
        let mut eos_emitted = vec![false; n];

        // --- prefill ---------------------------------------------------------
        let span = std::time::Instant::now();
        let temp_buf = self.eng.upload_f32(&[cfg.temperature], &[1])?;
        let tok_buf = self.eng.upload_i32(&layout.tokens, &[b, t])?;
        let val_buf = self.eng.upload_f32(&layout.valid, &[b, t])?;
        let last_buf = self.eng.upload_i32(&layout.last, &[b])?;
        let mut gen_blob = self.eng.call(
            &self.bundle,
            "prefill",
            &[&policy.blob, &tok_buf, &val_buf, &last_buf, &temp_buf],
        )?;
        let mut probs = self.read_probs(&gen_blob)?;
        timer.add("rollout", span.elapsed().as_secs_f64());

        // --- decode loop ------------------------------------------------------
        let mut token_in = vec![0i32; b];
        let mut slot_in = vec![t as i32; b]; // out-of-range => no cache write
        let mut lpos_in = vec![0i32; b];
        loop {
            let span = std::time::Instant::now();
            let mut any_active = false;
            for r in 0..n {
                if finished[r] || layout.resp_len[r] >= gen_len {
                    slot_in[r] = t as i32; // inert write
                    token_in[r] = 0;
                    continue;
                }
                let row = r * self.vocab;
                let pr = &probs[row..row + self.vocab];
                let tok = self.sampler.sample_with(pr, cfg.top_p, rng) as i32;
                let lp = pr[tok as usize].max(1e-30).ln();
                let slot = layout.push_token(r, tok);
                logps[r].push(lp);
                token_in[r] = tok;
                slot_in[r] = slot as i32;
                lpos_in[r] = (layout.n_valid(r) - 1) as i32;
                stats.new_tokens += 1;
                if tok == EOS {
                    finished[r] = true;
                    eos_emitted[r] = true;
                } else if layout.resp_len[r] >= gen_len {
                    finished[r] = true;
                } else {
                    any_active = true;
                }
            }
            timer.add("rollout", span.elapsed().as_secs_f64());
            if !any_active {
                break;
            }

            let span = std::time::Instant::now();
            let tok_b = self.eng.upload_i32(&token_in, &[b])?;
            let slot_b = self.eng.upload_i32(&slot_in, &[b])?;
            let lpos_b = self.eng.upload_i32(&lpos_in, &[b])?;
            let val_b = self.eng.upload_f32(&layout.valid, &[b, t])?;
            gen_blob = self.eng.call(
                &self.bundle,
                "decode",
                &[&policy.blob, &gen_blob, &tok_b, &slot_b, &lpos_b, &val_b, &temp_buf],
            )?;
            probs = self.read_probs(&gen_blob)?;
            stats.decode_steps += 1;
            timer.add("rollout", span.elapsed().as_secs_f64());
        }

        // --- assemble ---------------------------------------------------------
        let span = std::time::Instant::now();
        let mut out = Vec::with_capacity(n);
        for (r, task) in tasks.iter().enumerate() {
            let response = layout.response(r);
            stats.reused_tokens += task.prefix.len();
            out.push(SeqResult {
                id: task.id,
                reused: task.prefix.len(),
                new_tokens: response.len() - task.prefix.len(),
                finished: eos_emitted[r],
                logps: std::mem::take(&mut logps[r]),
                response,
            });
        }
        timer.add("assembly", span.elapsed().as_secs_f64());
        Ok(out)
    }

    fn read_probs(&mut self, gen_blob: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let out = self.eng.call(&self.bundle, "read_gen", &[gen_blob])?;
        self.eng.read_f32(&out)
    }
}

impl TopPSampler {
    /// Borrow-friendly alias used by the engine (self.sampler lives beside
    /// other &mut self fields).
    fn sample_with(&mut self, probs: &[f32], top_p: f32, rng: &mut Rng) -> usize {
        self.sample(probs, top_p, rng)
    }
}
