//! Continuous-batching slot scheduler.
//!
//! The rollout engine owns `B` physical rows ("slots") of the static-shape
//! AOT executables. The old wave loop bound a *set* of tasks to the slots
//! for the lifetime of the longest member: one slow row pinned the whole
//! wave while finished rows idled as inert filler. [`SlotScheduler`] keeps
//! the binding dynamic instead — the moment a slot's occupant finishes
//! (EOS or length cap), the slot is released and the next pending task is
//! assigned to it, so all `B` rows stay busy until the queue drains.
//!
//! Refilled rows re-enter via the `refill` AOT entry (see the decode-entry
//! contract below): a *batched per-row prefill* that recomputes the KV
//! cache, device-side valid mask, and next-token probs for exactly the
//! rows named by a `[B]` row mask, blending them into the persistent
//! generation blob without disturbing live neighbours. Several slots
//! freeing in the same step refill in one call.
//!
//! ## Decode-entry contract (shared with `python/compile`)
//!
//! The generation blob is `[cache_k | cache_v | valid | probs]` — the
//! `[B, T]` valid mask lives *device-side* and is maintained incrementally:
//!
//! - `prefill(blob, tokens, valid, last, temp)` uploads the mask once and
//!   seeds the blob;
//! - `decode(blob, gen, token, slot, lpos, temp)` extends the mask on
//!   device via a one-hot write at `slot` (out-of-range slot == inert row,
//!   no write) — the per-step host→device traffic is three `[B]` i32
//!   vectors, never the `[B, T]` mask;
//! - `refill(blob, gen, tokens, valid, rowmask, last, temp)` replaces the
//!   mask (and cache/probs) for masked rows only.
//!
//! Scheduling order is deterministic: tasks are sorted by **ascending
//! verified-prefix length** (then ascending id) — i.e. longest *remaining*
//! generation first, the LPT rule — so long fresh rows start early and the
//! short reuse-heavy tail packs into slots as they free, minimizing
//! makespan. Free slots are refilled in ascending slot order from the
//! front of the queue. Sampling uses per-task RNG streams, making results
//! invariant to slot assignment and bit-identical to the lockstep engine's
//! output for the same seed (which sorts the *opposite* way for wave
//! homogeneity — the orders differ, the outputs cannot).

use std::collections::VecDeque;

use super::batch::SeqTask;

/// Dynamic task→slot binding for one rollout run.
pub struct SlotScheduler {
    batch: usize,
    pending: VecDeque<SeqTask>,
    occupied: Vec<bool>,
}

impl SlotScheduler {
    /// Queue `tasks` (sorted: longest remaining generation first — i.e.
    /// ascending prefix length — ties by id) over `batch` initially-free
    /// slots.
    pub fn new(batch: usize, mut tasks: Vec<SeqTask>) -> Self {
        tasks.sort_by(|a, b| a.prefix.len().cmp(&b.prefix.len()).then(a.id.cmp(&b.id)));
        SlotScheduler {
            batch,
            pending: tasks.into(),
            occupied: vec![false; batch],
        }
    }

    /// Assign pending tasks to every free slot, in ascending slot order.
    /// Returns the (slot, task) assignments made; empty when no slot is
    /// free or the queue is drained.
    pub fn fill(&mut self) -> Vec<(usize, SeqTask)> {
        let mut out = Vec::new();
        for slot in 0..self.batch {
            if self.occupied[slot] {
                continue;
            }
            let Some(task) = self.pending.pop_front() else { break };
            self.occupied[slot] = true;
            out.push((slot, task));
        }
        out
    }

    /// Release a slot whose occupant finished.
    pub fn release(&mut self, slot: usize) {
        debug_assert!(self.occupied[slot], "releasing a free slot");
        self.occupied[slot] = false;
    }

    /// Occupied slot count.
    pub fn busy(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    /// Tasks not yet assigned to a slot.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Slots currently free.
    pub fn free(&self) -> usize {
        self.batch - self.busy()
    }

    /// Nothing running, nothing queued.
    pub fn is_done(&self) -> bool {
        self.busy() == 0 && self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![1],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    #[test]
    fn initial_fill_orders_longest_remaining_first() {
        let mut s = SlotScheduler::new(2, vec![task(0, 1), task(1, 5), task(2, 3)]);
        let fills = s.fill();
        let got: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(got, vec![0, 2], "shortest prefixes (longest remaining) go first");
        assert_eq!(fills[0].0, 0);
        assert_eq!(fills[1].0, 1);
        assert_eq!(s.pending(), 1);
        assert_eq!(s.busy(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut s = SlotScheduler::new(4, vec![task(3, 2), task(1, 2), task(2, 2)]);
        let ids: Vec<usize> = s.fill().into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn release_then_fill_reuses_the_slot() {
        let mut s = SlotScheduler::new(2, (0..5).map(|i| task(i, 0)).collect());
        s.fill();
        s.release(1);
        let fills = s.fill();
        assert_eq!(fills.len(), 1);
        assert_eq!(fills[0].0, 1);
        assert_eq!(fills[0].1.id, 2);
        assert_eq!(s.pending(), 2);
    }

    #[test]
    fn multiple_frees_batch_into_one_fill() {
        let mut s = SlotScheduler::new(3, (0..6).map(|i| task(i, 0)).collect());
        s.fill();
        s.release(0);
        s.release(2);
        let fills = s.fill();
        let slots: Vec<usize> = fills.iter().map(|(sl, _)| *sl).collect();
        let ids: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(slots, vec![0, 2], "ascending slot order");
        assert_eq!(ids, vec![3, 4], "queue order");
    }

    #[test]
    fn drains_to_done() {
        let mut s = SlotScheduler::new(2, (0..3).map(|i| task(i, 0)).collect());
        assert!(!s.is_done());
        s.fill();
        s.release(0);
        s.release(1);
        s.fill();
        assert_eq!(s.busy(), 1);
        s.release(0);
        assert!(s.is_done());
        assert!(s.fill().is_empty());
    }

    #[test]
    fn fill_with_no_pending_is_empty() {
        let mut s = SlotScheduler::new(2, vec![task(0, 0)]);
        s.fill();
        assert!(s.fill().is_empty());
        assert_eq!(s.free(), 1);
    }
}
