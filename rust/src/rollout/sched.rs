//! Phase-aware continuous-batching slot scheduler.
//!
//! The rollout engine owns `B` physical rows ("slots") of the static-shape
//! AOT executables. The old wave loop bound a *set* of tasks to the slots
//! for the lifetime of the longest member: one slow row pinned the whole
//! wave while finished rows idled as inert filler. [`SlotScheduler`] keeps
//! the binding dynamic instead — the moment a slot's occupant finishes
//! (EOS or length cap), the slot is released and the next pending task is
//! assigned to it, so all `B` rows stay busy until the queues drain.
//!
//! ## Sequence lifecycle (`Draft -> Verify -> Decode -> Done`)
//!
//! Since PR 2 the scheduler runs **two phases over one slot pool**:
//!
//! - *Decode-ready* tasks (fresh prompts, or drafts whose acceptance was
//!   resolved host-side by the Random/Full reuse variants) queue in
//!   `pending` and seat via `prefill`/`refill` as before.
//! - *Drafted* sequences ([`VerifyTask`]s) queue in `pending_verify` and
//!   seat into free slots via the `verify_seat` AOT entry, which scores
//!   the draft, finds its first rejection, **and** writes the accepted
//!   prefix's KV/valid/probs into the generation blob in the same call —
//!   the slot transitions `Verify -> Decode` ([`SlotScheduler::to_decode`])
//!   the moment its rejection offset is read back, with no separate
//!   refill forward and no global verify barrier.
//!
//! Free slots are offered to the decode queue first (those rows can sample
//! immediately), then to the verify queue; both fills proceed in ascending
//! slot order, so scheduling stays deterministic.
//!
//! Refilled rows re-enter via the `refill` AOT entry (see the decode-entry
//! contract below): a *batched per-row prefill* that recomputes the KV
//! cache, device-side valid mask, and next-token probs for exactly the
//! rows named by a `[B]` row mask, blending them into the persistent
//! generation blob without disturbing live neighbours. Several slots
//! freeing in the same step refill (or verify-seat) in one call.
//!
//! ## Decode-entry contract (shared with `python/compile`)
//!
//! The generation blob is `[cache_k | cache_v | valid | probs | aux]` — the
//! `[B, T]` valid mask lives *device-side* and is maintained incrementally:
//!
//! - `prefill(blob, tokens, valid, last, temp)` uploads the mask once,
//!   seeds the blob, and zeroes the `[B]` aux lane;
//! - `decode(blob, gen, token, slot, lpos, temp)` extends the mask on
//!   device via a one-hot write at `slot` (out-of-range slot == inert row,
//!   no write) — the per-step host→device traffic is three `[B]` i32
//!   vectors, never the `[B, T]` mask;
//! - `refill(blob, gen, tokens, valid, rowmask, last, temp)` replaces the
//!   mask (and cache/probs) for masked rows only;
//! - `verify_seat(blob, gen, tokens, valid, logp_prev, uniforms,
//!   draft_valid, rowmask, loglen, temp)` runs the teacher-forced verify
//!   forward for masked rows, truncates their masks at the first rejection,
//!   seats the accepted prefix's KV/probs, and reports the accepted length
//!   in the aux lane;
//! - `read_gen(gen)` returns `[probs | aux]` (`B*V + B` floats), so
//!   acceptance results ride the read the decode loop already performs.
//!
//! Scheduling order is deterministic: decode tasks sort by **ascending
//! verified-prefix length** (then ascending id) — i.e. longest *remaining*
//! generation first, the LPT rule — and drafts sort by ascending draft
//! length (a draft can reuse at most its own length, so short drafts have
//! the longest expected remainder). Sampling uses per-task RNG streams and
//! verification uses per-task uniform streams, making results invariant to
//! slot assignment, sub-batch packing, and scheduling order — byte-identical
//! to both the lockstep engine and the two-phase verify-then-decode oracle.
//!
//! One `SlotScheduler` spans one engine's `B` physical rows. The
//! cross-engine layer — N slot pools behind one LPT placement front-end,
//! with every row's lifecycle pinned to the engine it was placed on — is
//! [`super::pool::EnginePool`]. The full contract set (gen-blob layout,
//! inert slots, RNG streams, shard placement) lives in `ARCHITECTURE.md`.

use std::collections::VecDeque;

use super::batch::SeqTask;
use crate::spec::verifier::VerifyTask;

/// What currently occupies a slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotPhase {
    Free,
    /// Seated by `verify_seat`, rejection offset not yet read back.
    Verify,
    /// Actively decoding (fresh, refilled, or transitioned from Verify).
    Decode,
}

/// Dynamic task→slot binding for one rollout run, over both phases.
pub struct SlotScheduler {
    batch: usize,
    pending: VecDeque<SeqTask>,
    pending_verify: VecDeque<VerifyTask>,
    phase: Vec<SlotPhase>,
}

impl SlotScheduler {
    /// Queue `tasks` (sorted: longest remaining generation first — i.e.
    /// ascending prefix length — ties by id) over `batch` initially-free
    /// slots. No drafts: decode-only scheduling, exactly as before.
    pub fn new(batch: usize, tasks: Vec<SeqTask>) -> Self {
        Self::with_drafts(batch, tasks, Vec::new())
    }

    /// Queue decode-ready `tasks` and to-verify `drafts` over one pool.
    pub fn with_drafts(
        batch: usize,
        mut tasks: Vec<SeqTask>,
        mut drafts: Vec<VerifyTask>,
    ) -> Self {
        tasks.sort_by(|a, b| a.prefix.len().cmp(&b.prefix.len()).then(a.id.cmp(&b.id)));
        // Short drafts bound acceptance from above => longest expected
        // remainder first (the LPT proxy available before verification).
        drafts.sort_by(|a, b| a.draft_len().cmp(&b.draft_len()).then(a.id.cmp(&b.id)));
        SlotScheduler {
            batch,
            pending: tasks.into(),
            pending_verify: drafts.into(),
            phase: vec![SlotPhase::Free; batch],
        }
    }

    /// Assign pending decode tasks to every free slot, in ascending slot
    /// order. Returns the (slot, task) assignments made; empty when no
    /// slot is free or the queue is drained.
    pub fn fill(&mut self) -> Vec<(usize, SeqTask)> {
        let mut out = Vec::new();
        for slot in 0..self.batch {
            if self.phase[slot] != SlotPhase::Free {
                continue;
            }
            let Some(task) = self.pending.pop_front() else { break };
            self.phase[slot] = SlotPhase::Decode;
            out.push((slot, task));
        }
        out
    }

    /// Assign pending drafts to the remaining free slots (after a decode
    /// fill), in ascending slot order; the caller packs them into one
    /// `verify_seat` sub-batch.
    pub fn fill_verify(&mut self) -> Vec<(usize, VerifyTask)> {
        let mut out = Vec::new();
        for slot in 0..self.batch {
            if self.phase[slot] != SlotPhase::Free {
                continue;
            }
            let Some(task) = self.pending_verify.pop_front() else { break };
            self.phase[slot] = SlotPhase::Verify;
            out.push((slot, task));
        }
        out
    }

    /// Transition a verified occupant to decoding (its accepted prefix was
    /// read back and is not terminal).
    pub fn to_decode(&mut self, slot: usize) {
        debug_assert_eq!(self.phase[slot], SlotPhase::Verify, "to_decode on non-verify slot");
        self.phase[slot] = SlotPhase::Decode;
    }

    /// Release a slot whose occupant finished (or verified terminal).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(self.phase[slot] != SlotPhase::Free, "releasing a free slot");
        self.phase[slot] = SlotPhase::Free;
    }

    /// Occupied slot count (either phase).
    pub fn busy(&self) -> usize {
        self.phase.iter().filter(|&&p| p != SlotPhase::Free).count()
    }

    /// Slots currently decoding.
    pub fn busy_decode(&self) -> usize {
        self.phase.iter().filter(|&&p| p == SlotPhase::Decode).count()
    }

    /// Decode tasks not yet assigned to a slot.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Drafts not yet assigned to a slot.
    pub fn pending_verify(&self) -> usize {
        self.pending_verify.len()
    }

    /// Slots currently free.
    pub fn free(&self) -> usize {
        self.batch - self.busy()
    }

    /// Nothing running, nothing queued in either phase.
    pub fn is_done(&self) -> bool {
        self.busy() == 0 && self.pending.is_empty() && self.pending_verify.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::cache::CacheEntry;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![1],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    fn draft(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![1],
            entry: CacheEntry {
                response: vec![7; len],
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn initial_fill_orders_longest_remaining_first() {
        let mut s = SlotScheduler::new(2, vec![task(0, 1), task(1, 5), task(2, 3)]);
        let fills = s.fill();
        let got: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(got, vec![0, 2], "shortest prefixes (longest remaining) go first");
        assert_eq!(fills[0].0, 0);
        assert_eq!(fills[1].0, 1);
        assert_eq!(s.pending(), 1);
        assert_eq!(s.busy(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut s = SlotScheduler::new(4, vec![task(3, 2), task(1, 2), task(2, 2)]);
        let ids: Vec<usize> = s.fill().into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn release_then_fill_reuses_the_slot() {
        let mut s = SlotScheduler::new(2, (0..5).map(|i| task(i, 0)).collect());
        s.fill();
        s.release(1);
        let fills = s.fill();
        assert_eq!(fills.len(), 1);
        assert_eq!(fills[0].0, 1);
        assert_eq!(fills[0].1.id, 2);
        assert_eq!(s.pending(), 2);
    }

    #[test]
    fn multiple_frees_batch_into_one_fill() {
        let mut s = SlotScheduler::new(3, (0..6).map(|i| task(i, 0)).collect());
        s.fill();
        s.release(0);
        s.release(2);
        let fills = s.fill();
        let slots: Vec<usize> = fills.iter().map(|(sl, _)| *sl).collect();
        let ids: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(slots, vec![0, 2], "ascending slot order");
        assert_eq!(ids, vec![3, 4], "queue order");
    }

    #[test]
    fn drains_to_done() {
        let mut s = SlotScheduler::new(2, (0..3).map(|i| task(i, 0)).collect());
        assert!(!s.is_done());
        s.fill();
        s.release(0);
        s.release(1);
        s.fill();
        assert_eq!(s.busy(), 1);
        s.release(0);
        assert!(s.is_done());
        assert!(s.fill().is_empty());
    }

    #[test]
    fn fill_with_no_pending_is_empty() {
        let mut s = SlotScheduler::new(2, vec![task(0, 0)]);
        s.fill();
        assert!(s.fill().is_empty());
        assert_eq!(s.free(), 1);
    }

    #[test]
    fn decode_fill_takes_priority_then_drafts_pack_the_rest() {
        let mut s = SlotScheduler::with_drafts(
            3,
            vec![task(0, 0)],
            vec![draft(10, 4), draft(11, 2)],
        );
        let d = s.fill();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, 0);
        let v = s.fill_verify();
        // shortest draft first (longest expected remainder), ascending slots
        assert_eq!(v.len(), 2);
        assert_eq!((v[0].0, v[0].1.id), (1, 11));
        assert_eq!((v[1].0, v[1].1.id), (2, 10));
        assert_eq!(s.busy(), 3);
        assert_eq!(s.busy_decode(), 1);
        assert!(!s.is_done());
    }

    #[test]
    fn verify_transitions_to_decode_or_releases() {
        let mut s = SlotScheduler::with_drafts(2, Vec::new(), vec![draft(0, 3), draft(1, 3)]);
        let v = s.fill_verify();
        assert_eq!(v.len(), 2);
        assert_eq!(s.busy_decode(), 0);
        s.to_decode(0); // non-terminal accepted prefix
        s.release(1); // terminal accepted prefix
        assert_eq!(s.busy_decode(), 1);
        assert_eq!(s.busy(), 1);
        assert_eq!(s.free(), 1);
        s.release(0);
        assert!(s.is_done());
    }

    #[test]
    fn pending_verify_counts_drain() {
        let mut s = SlotScheduler::with_drafts(1, Vec::new(), vec![draft(0, 1), draft(1, 5)]);
        assert_eq!(s.pending_verify(), 2);
        assert!(!s.is_done());
        let v = s.fill_verify();
        assert_eq!(v[0].1.id, 0, "shortest draft first");
        assert_eq!(s.pending_verify(), 1);
        assert!(s.fill_verify().is_empty(), "no free slot left");
    }
}
