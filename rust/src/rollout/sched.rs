//! Phase-aware continuous-batching slot scheduler + the shared work queue.
//!
//! The rollout engine owns `B` physical rows ("slots") of the static-shape
//! AOT executables. The old wave loop bound a *set* of tasks to the slots
//! for the lifetime of the longest member: one slow row pinned the whole
//! wave while finished rows idled as inert filler. [`SlotScheduler`] keeps
//! the binding dynamic instead — the moment a slot's occupant finishes
//! (EOS or length cap), the slot is released and the next pending item is
//! seated in it, so all `B` rows stay busy until the queues drain.
//!
//! ## The work queue (PR 4: the steal-queue)
//!
//! Unstarted work lives in a [`WorkQueue`]: decode-ready tasks and
//! to-verify drafts, each LPT-ordered (see below). The scheduler owns **no
//! queues of its own** — every fill pass pulls from a caller-supplied
//! `WorkQueue`, which is what makes mid-step work stealing possible: a
//! single-engine run hands the scheduler a private queue, while
//! [`super::pool::EnginePool`] hands *every* shard the same shared queue,
//! so any engine with a free slot after its refill pass pulls the next
//! item — wherever the step's remaining work happens to be. Only
//! never-seated work moves between engines this way; a row, once seated,
//! never migrates (the lifecycle-pinning invariant, `ARCHITECTURE.md` §7).
//! Pops made after [`WorkQueue::mark_started`] count as steals.
//!
//! ## Sequence lifecycle (`Draft -> Verify -> Decode -> Done`)
//!
//! Since PR 2 the scheduler runs **two phases over one slot pool**:
//!
//! - *Decode-ready* tasks (fresh prompts, or drafts whose acceptance was
//!   resolved host-side by the Random/Full reuse variants) queue in the
//!   task lane and seat via `prefill`/`refill` as before.
//! - *Drafted* sequences ([`VerifyTask`]s) queue in the draft lane and
//!   seat into free slots via the `verify_seat` AOT entry, which scores
//!   the draft, finds its first rejection, **and** writes the accepted
//!   prefix's KV/valid/probs into the generation blob in the same call —
//!   the slot transitions `Verify -> Decode` ([`SlotScheduler::to_decode`])
//!   the moment its rejection offset is read back, with no separate
//!   refill forward and no global verify barrier.
//!
//! Free slots are offered to the decode lane first (those rows can sample
//! immediately), then to the draft lane; both fills proceed in ascending
//! slot order, so scheduling stays deterministic. Draft seating is
//! **adaptive** (PR 4): [`SlotScheduler::fill_verify`] seats a packed
//! `verify_seat` sub-batch only when at least `seat_min` slots are free
//! (`spec.verify_seat_min`, clamped into `[1, B]`; 1 = seat eagerly, the
//! pre-PR 4 behavior), trading verify latency for sub-batch packing.
//!
//! Refilled rows re-enter via the `refill` AOT entry (see the decode-entry
//! contract below): a *batched per-row prefill* that recomputes the KV
//! cache, device-side valid mask, and next-token probs for exactly the
//! rows named by a `[B]` row mask, blending them into the persistent
//! generation blob without disturbing live neighbours. Several slots
//! freeing in the same step refill (or verify-seat) in one call.
//!
//! ## Decode-entry contract (shared with `python/compile`)
//!
//! The generation blob is `[cache_k | cache_v | valid | probs | aux]` — the
//! `[B, T]` valid mask lives *device-side* and is maintained incrementally:
//!
//! - `prefill(blob, tokens, valid, last, temp)` uploads the mask once,
//!   seeds the blob, and zeroes the `[B]` aux lane;
//! - `decode(blob, gen, token, slot, lpos, temp)` extends the mask on
//!   device via a one-hot write at `slot` (out-of-range slot == inert row,
//!   no write) — the per-step host→device traffic is three `[B]` i32
//!   vectors, never the `[B, T]` mask;
//! - `refill(blob, gen, tokens, valid, rowmask, last, temp)` replaces the
//!   mask (and cache/probs) for masked rows only;
//! - `verify_seat(blob, gen, tokens, valid, logp_prev, uniforms,
//!   draft_valid, rowmask, loglen, temp)` runs the teacher-forced verify
//!   forward for masked rows, truncates their masks at the first rejection,
//!   seats the accepted prefix's KV/probs, and reports the accepted length
//!   in the aux lane;
//! - `read_gen(gen)` returns `[probs | aux]` (`B*V + B` floats), so
//!   acceptance results ride the read the decode loop already performs —
//!   on the host-sampling path; the device-sampling hot path (PR 6,
//!   `ARCHITECTURE.md` §12) ends each round with `sample` + the fused
//!   `read_step(gen)` readback (`3*B` floats: token, probability, aux)
//!   instead.
//!
//! Queue order is deterministic LPT: decode tasks sort by **ascending
//! verified-prefix length** (then ascending id) — i.e. longest *remaining*
//! generation first — and drafts sort by ascending draft length (a draft
//! can reuse at most its own length, so short drafts have the longest
//! expected remainder). When per-task length estimates are loaded
//! ([`WorkQueue::with_estimates`], `ARCHITECTURE.md` §14) both lanes order
//! by **expected remaining work** instead, with the raw keys as the
//! no-estimate fallback. Sampling uses per-task RNG streams and
//! verification uses per-task uniform streams, making results invariant to
//! slot assignment, sub-batch packing, scheduling order, **and which
//! engine pops an item from the shared queue** — byte-identical to the
//! lockstep engine and the two-phase verify-then-decode oracle.
//!
//! One `SlotScheduler` spans one engine's `B` physical rows. The
//! cross-engine layer — N slot pools pulling from one shared `WorkQueue`,
//! with every row's lifecycle pinned to the engine that seated it — is
//! [`super::pool::EnginePool`]. The full contract set (gen-blob layout,
//! inert slots, RNG streams, placement and stealing) lives in
//! `ARCHITECTURE.md` §§2-7.

use std::collections::VecDeque;

use super::batch::SeqTask;
use super::predict::LenEstimates;
use crate::spec::verifier::VerifyTask;

/// One step's unstarted work: decode-ready tasks and to-verify drafts in
/// LPT order. Private to a single engine run, or shared across an
/// [`super::pool::EnginePool`]'s shards as the mid-step steal-queue (only
/// never-seated work lives here, so pulling from it can never migrate a
/// row between engines).
pub struct WorkQueue {
    tasks: VecDeque<SeqTask>,
    drafts: VecDeque<VerifyTask>,
    /// Frozen length estimates supplying both lanes' sort keys
    /// (`ARCHITECTURE.md` §14). The empty table reproduces the raw
    /// prefix-length / draft-length LPT keys exactly, so a queue built
    /// with [`WorkQueue::new`] behaves as it always has.
    est: LenEstimates,
    /// Set once every shard's initial seating pass is done; later pops are
    /// counted as steals.
    started: bool,
    steals: usize,
}

impl WorkQueue {
    /// LPT-order both lanes with the raw keys: tasks by ascending
    /// verified-prefix length (longest remaining generation first),
    /// drafts by ascending draft length (longest expected remainder
    /// first); ties by id. Terminal full-reuse tasks must be split out by
    /// the caller first — every queued item is assumed to need a slot.
    pub fn new(tasks: Vec<SeqTask>, drafts: Vec<VerifyTask>) -> Self {
        Self::with_estimates(tasks, drafts, LenEstimates::off())
    }

    /// LPT-order both lanes by **expected remaining work** under `est`
    /// (`ARCHITECTURE.md` §14): ascending [`LenEstimates::task_rank`] /
    /// [`LenEstimates::draft_rank`], ties by id. Items without an
    /// estimate rank exactly as [`WorkQueue::new`] would rank them.
    pub fn with_estimates(
        mut tasks: Vec<SeqTask>,
        mut drafts: Vec<VerifyTask>,
        est: LenEstimates,
    ) -> Self {
        tasks.sort_by(|a, b| est.task_rank(a).cmp(&est.task_rank(b)).then(a.id.cmp(&b.id)));
        drafts.sort_by(|a, b| est.draft_rank(a).cmp(&est.draft_rank(b)).then(a.id.cmp(&b.id)));
        WorkQueue { tasks: tasks.into(), drafts: drafts.into(), est, started: false, steals: 0 }
    }

    /// A decode-only queue (no drafts).
    pub fn tasks_only(tasks: Vec<SeqTask>) -> Self {
        Self::new(tasks, Vec::new())
    }

    fn pop_task(&mut self) -> Option<SeqTask> {
        let t = self.tasks.pop_front();
        self.steals += (t.is_some() && self.started) as usize;
        t
    }

    fn pop_draft(&mut self) -> Option<VerifyTask> {
        let d = self.drafts.pop_front();
        self.steals += (d.is_some() && self.started) as usize;
        d
    }

    /// Decode-ready tasks not yet seated anywhere.
    pub fn pending(&self) -> usize {
        self.tasks.len()
    }

    /// Drafts not yet seated anywhere.
    pub fn pending_drafts(&self) -> usize {
        self.drafts.len()
    }

    /// Nothing left to hand out.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty() && self.drafts.is_empty()
    }

    /// Flip into the stealing regime: the pool calls this after every
    /// shard's initial seating pass, so later pops — work an engine picks
    /// up mid-step that one-pass placement would have pinned elsewhere —
    /// are counted in [`WorkQueue::steals`].
    pub fn mark_started(&mut self) {
        self.started = true;
    }

    /// Items popped after [`WorkQueue::mark_started`].
    pub fn steals(&self) -> usize {
        self.steals
    }

    /// Return a dead shard's recovered work to the queue, restoring the
    /// global LPT order of both lanes (`ARCHITECTURE.md` §13): the merged
    /// lanes re-sort with this queue's own estimate-aware comparators
    /// ([`WorkQueue::with_estimates`]), so a survivor's next pull sees the
    /// same deterministic order a fresh queue over the combined work
    /// would. Keeps the `started` flag —
    /// requeued items popped mid-step count as steals, like any other
    /// mid-step pull. Returns the number of items re-entered.
    pub fn requeue(&mut self, tasks: Vec<SeqTask>, drafts: Vec<VerifyTask>) -> usize {
        let n = tasks.len() + drafts.len();
        let est = &self.est;
        let mut t: Vec<SeqTask> = std::mem::take(&mut self.tasks).into();
        t.extend(tasks);
        t.sort_by(|a, b| est.task_rank(a).cmp(&est.task_rank(b)).then(a.id.cmp(&b.id)));
        self.tasks = t.into();
        let mut d: Vec<VerifyTask> = std::mem::take(&mut self.drafts).into();
        d.extend(drafts);
        d.sort_by(|a, b| est.draft_rank(a).cmp(&est.draft_rank(b)).then(a.id.cmp(&b.id)));
        self.drafts = d.into();
        n
    }

    /// Empty both lanes, returning everything still unstarted (in current
    /// queue order). The static-placement recovery path drains a dead
    /// shard's private queue into the survivor spill; pops here are not
    /// steals (the items were never handed to an engine).
    pub fn drain(&mut self) -> (Vec<SeqTask>, Vec<VerifyTask>) {
        (std::mem::take(&mut self.tasks).into(), std::mem::take(&mut self.drafts).into())
    }
}

/// What currently occupies a slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotPhase {
    Free,
    /// Seated by `verify_seat`, rejection offset not yet read back.
    Verify,
    /// Actively decoding (fresh, refilled, or transitioned from Verify).
    Decode,
}

/// Dynamic item→slot binding over one engine's `batch` physical rows, both
/// phases. Owns no work of its own: every fill pulls from the caller's
/// [`WorkQueue`] (private or shared — the scheduler cannot tell).
pub struct SlotScheduler {
    batch: usize,
    phase: Vec<SlotPhase>,
}

impl SlotScheduler {
    /// `batch` initially-free slots.
    pub fn new(batch: usize) -> Self {
        SlotScheduler { batch, phase: vec![SlotPhase::Free; batch] }
    }

    /// Assign queued decode tasks to every free slot, in ascending slot
    /// order. Returns the (slot, task) assignments made; empty when no
    /// slot is free or the task lane is drained.
    pub fn fill(&mut self, queue: &mut WorkQueue) -> Vec<(usize, SeqTask)> {
        let mut out = Vec::new();
        for slot in 0..self.batch {
            if self.phase[slot] != SlotPhase::Free {
                continue;
            }
            let Some(task) = queue.pop_task() else { break };
            self.phase[slot] = SlotPhase::Decode;
            out.push((slot, task));
        }
        out
    }

    /// Assign queued drafts to the remaining free slots (after a decode
    /// fill), in ascending slot order; the caller packs them into one
    /// `verify_seat` sub-batch. Adaptive seating: seats nothing unless at
    /// least `seat_min` slots are free (clamped into `[1, batch]`, so a
    /// draft-only run can never deadlock — when every slot is free,
    /// `free() == batch >= seat_min` always holds).
    pub fn fill_verify(
        &mut self,
        queue: &mut WorkQueue,
        seat_min: usize,
    ) -> Vec<(usize, VerifyTask)> {
        if self.free() < seat_min.clamp(1, self.batch) {
            return Vec::new();
        }
        let mut out = Vec::new();
        for slot in 0..self.batch {
            if self.phase[slot] != SlotPhase::Free {
                continue;
            }
            let Some(task) = queue.pop_draft() else { break };
            self.phase[slot] = SlotPhase::Verify;
            out.push((slot, task));
        }
        out
    }

    /// Transition a verified occupant to decoding (its accepted prefix was
    /// read back and is not terminal).
    pub fn to_decode(&mut self, slot: usize) {
        debug_assert_eq!(self.phase[slot], SlotPhase::Verify, "to_decode on non-verify slot");
        self.phase[slot] = SlotPhase::Decode;
    }

    /// Release a slot whose occupant finished (or verified terminal).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(self.phase[slot] != SlotPhase::Free, "releasing a free slot");
        self.phase[slot] = SlotPhase::Free;
    }

    /// Occupied slot count (either phase).
    pub fn busy(&self) -> usize {
        self.phase.iter().filter(|&&p| p != SlotPhase::Free).count()
    }

    /// Slots currently decoding.
    pub fn busy_decode(&self) -> usize {
        self.phase.iter().filter(|&&p| p == SlotPhase::Decode).count()
    }

    /// Slots currently free.
    pub fn free(&self) -> usize {
        self.batch - self.busy()
    }

    /// Nothing running here, nothing left in the queue. With a shared
    /// queue this is per-engine: another shard may still be decoding rows
    /// of its own, but it can no longer hand work to this one.
    pub fn is_done(&self, queue: &WorkQueue) -> bool {
        self.busy() == 0 && queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::cache::CacheEntry;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![1],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    fn draft(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![1],
            entry: CacheEntry {
                response: vec![7; len],
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn initial_fill_orders_longest_remaining_first() {
        let mut q = WorkQueue::tasks_only(vec![task(0, 1), task(1, 5), task(2, 3)]);
        let mut s = SlotScheduler::new(2);
        let fills = s.fill(&mut q);
        let got: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(got, vec![0, 2], "shortest prefixes (longest remaining) go first");
        assert_eq!(fills[0].0, 0);
        assert_eq!(fills[1].0, 1);
        assert_eq!(q.pending(), 1);
        assert_eq!(s.busy(), 2);
    }

    #[test]
    fn ties_break_by_id() {
        let mut q = WorkQueue::tasks_only(vec![task(3, 2), task(1, 2), task(2, 2)]);
        let mut s = SlotScheduler::new(4);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn release_then_fill_reuses_the_slot() {
        let mut q = WorkQueue::tasks_only((0..5).map(|i| task(i, 0)).collect());
        let mut s = SlotScheduler::new(2);
        s.fill(&mut q);
        s.release(1);
        let fills = s.fill(&mut q);
        assert_eq!(fills.len(), 1);
        assert_eq!(fills[0].0, 1);
        assert_eq!(fills[0].1.id, 2);
        assert_eq!(q.pending(), 2);
    }

    #[test]
    fn multiple_frees_batch_into_one_fill() {
        let mut q = WorkQueue::tasks_only((0..6).map(|i| task(i, 0)).collect());
        let mut s = SlotScheduler::new(3);
        s.fill(&mut q);
        s.release(0);
        s.release(2);
        let fills = s.fill(&mut q);
        let slots: Vec<usize> = fills.iter().map(|(sl, _)| *sl).collect();
        let ids: Vec<usize> = fills.iter().map(|(_, t)| t.id).collect();
        assert_eq!(slots, vec![0, 2], "ascending slot order");
        assert_eq!(ids, vec![3, 4], "queue order");
    }

    #[test]
    fn drains_to_done() {
        let mut q = WorkQueue::tasks_only((0..3).map(|i| task(i, 0)).collect());
        let mut s = SlotScheduler::new(2);
        assert!(!s.is_done(&q));
        s.fill(&mut q);
        s.release(0);
        s.release(1);
        s.fill(&mut q);
        assert_eq!(s.busy(), 1);
        s.release(0);
        assert!(s.is_done(&q));
        assert!(s.fill(&mut q).is_empty());
    }

    #[test]
    fn fill_with_no_pending_is_empty() {
        let mut q = WorkQueue::tasks_only(vec![task(0, 0)]);
        let mut s = SlotScheduler::new(2);
        s.fill(&mut q);
        assert!(s.fill(&mut q).is_empty());
        assert_eq!(s.free(), 1);
    }

    #[test]
    fn decode_fill_takes_priority_then_drafts_pack_the_rest() {
        let mut q = WorkQueue::new(vec![task(0, 0)], vec![draft(10, 4), draft(11, 2)]);
        let mut s = SlotScheduler::new(3);
        let d = s.fill(&mut q);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, 0);
        let v = s.fill_verify(&mut q, 1);
        // shortest draft first (longest expected remainder), ascending slots
        assert_eq!(v.len(), 2);
        assert_eq!((v[0].0, v[0].1.id), (1, 11));
        assert_eq!((v[1].0, v[1].1.id), (2, 10));
        assert_eq!(s.busy(), 3);
        assert_eq!(s.busy_decode(), 1);
        assert!(!s.is_done(&q));
    }

    #[test]
    fn verify_transitions_to_decode_or_releases() {
        let mut q = WorkQueue::new(Vec::new(), vec![draft(0, 3), draft(1, 3)]);
        let mut s = SlotScheduler::new(2);
        let v = s.fill_verify(&mut q, 1);
        assert_eq!(v.len(), 2);
        assert_eq!(s.busy_decode(), 0);
        s.to_decode(0); // non-terminal accepted prefix
        s.release(1); // terminal accepted prefix
        assert_eq!(s.busy_decode(), 1);
        assert_eq!(s.busy(), 1);
        assert_eq!(s.free(), 1);
        s.release(0);
        assert!(s.is_done(&q));
    }

    #[test]
    fn pending_draft_counts_drain() {
        let mut q = WorkQueue::new(Vec::new(), vec![draft(0, 1), draft(1, 5)]);
        let mut s = SlotScheduler::new(1);
        assert_eq!(q.pending_drafts(), 2);
        assert!(!s.is_done(&q));
        let v = s.fill_verify(&mut q, 1);
        assert_eq!(v[0].1.id, 0, "shortest draft first");
        assert_eq!(q.pending_drafts(), 1);
        assert!(s.fill_verify(&mut q, 1).is_empty(), "no free slot left");
    }

    #[test]
    fn fill_verify_waits_for_seat_min_free_slots() {
        let mut q = WorkQueue::new((0..2).map(|i| task(i, 0)).collect(), vec![draft(10, 2)]);
        let mut s = SlotScheduler::new(4);
        s.fill(&mut q); // 2 decode rows seated, 2 slots free
        assert!(s.fill_verify(&mut q, 3).is_empty(), "2 free < seat_min 3: hold the draft");
        assert_eq!(q.pending_drafts(), 1, "held drafts stay in the queue");
        s.release(0);
        let v = s.fill_verify(&mut q, 3);
        assert_eq!(v.len(), 1, "3 free >= seat_min 3: seat");
        assert_eq!(v[0].1.id, 10);
    }

    #[test]
    fn seat_min_clamps_to_batch_so_draft_only_runs_cannot_deadlock() {
        let mut q = WorkQueue::new(Vec::new(), vec![draft(0, 2), draft(1, 3)]);
        let mut s = SlotScheduler::new(2);
        // seat_min far above batch still seats once every slot is free
        let v = s.fill_verify(&mut q, 64);
        assert_eq!(v.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn draft_lane_lpt_ties_break_by_id() {
        // Equal-length drafts must pop in ascending id order regardless
        // of insertion order — the determinism the overlapped driver's
        // submit-pass pull sequence (and every placement oracle) relies
        // on.
        let mut q = WorkQueue::new(Vec::new(), vec![draft(5, 3), draft(1, 3), draft(3, 3)]);
        let mut s = SlotScheduler::new(3);
        let ids: Vec<usize> =
            s.fill_verify(&mut q, 1).into_iter().map(|(_, d)| d.id).collect();
        assert_eq!(ids, vec![1, 3, 5]);
    }

    #[test]
    fn zero_draft_queue_behaves_like_tasks_only() {
        // A step with no drafts at all: the draft lane must hand out
        // nothing (whatever seat_min says), never count a steal for it,
        // and never block completion.
        let mut q = WorkQueue::new(vec![task(0, 0), task(1, 2)], Vec::new());
        let mut s = SlotScheduler::new(4);
        assert_eq!(q.pending_drafts(), 0);
        assert_eq!(s.fill(&mut q).len(), 2);
        assert!(s.fill_verify(&mut q, 1).is_empty(), "no draft lane to pull from");
        assert!(s.fill_verify(&mut q, 64).is_empty(), "seat_min cannot conjure drafts");
        s.release(0);
        s.release(1);
        assert!(s.is_done(&q), "an empty draft lane must not block completion");
        assert_eq!(q.steals(), 0);
    }

    #[test]
    fn oversized_pool_leaves_trailing_schedulers_empty() {
        // More shards than work items: schedulers that reach the queue
        // after it drained seat nothing and are immediately done — the
        // engine layer turns that into zero submits (pinned end-to-end by
        // the pool's idle-shard tests).
        let mut q = WorkQueue::new(vec![task(0, 0)], vec![draft(9, 2)]);
        let mut shards: Vec<SlotScheduler> = (0..4).map(|_| SlotScheduler::new(2)).collect();
        let mut seated = 0;
        for s in shards.iter_mut() {
            seated += s.fill(&mut q).len() + s.fill_verify(&mut q, 1).len();
        }
        assert_eq!(seated, 2, "both items seat exactly once, on the first shard");
        assert!(q.is_empty());
        for (i, s) in shards.iter().enumerate().skip(1) {
            assert_eq!(s.busy(), 0, "shard {i} should have nothing seated");
            assert!(s.is_done(&q), "an empty shard over a drained queue is done");
        }
    }

    #[test]
    fn requeue_restores_global_lpt_order_in_both_lanes() {
        let mut q = WorkQueue::new(vec![task(0, 4)], vec![draft(10, 5)]);
        let n = q.requeue(vec![task(1, 1), task(2, 4)], vec![draft(11, 2)]);
        assert_eq!(n, 3);
        let mut s = SlotScheduler::new(4);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 0, 2], "shortest prefix first, ties by id");
        let dids: Vec<usize> = s.fill_verify(&mut q, 1).into_iter().map(|(_, d)| d.id).collect();
        assert_eq!(dids, vec![11], "one free slot left: shortest draft first");
        assert_eq!(q.pending_drafts(), 1);
    }

    #[test]
    fn requeued_items_popped_mid_step_count_as_steals() {
        let mut q = WorkQueue::new(Vec::new(), Vec::new());
        q.mark_started();
        assert_eq!(q.requeue(vec![task(0, 0)], vec![draft(1, 2)]), 2);
        let mut s = SlotScheduler::new(2);
        assert_eq!(s.fill(&mut q).len() + s.fill_verify(&mut q, 1).len(), 2);
        assert_eq!(q.steals(), 2);
    }

    #[test]
    fn drain_empties_both_lanes_without_counting_steals() {
        let mut q = WorkQueue::new(vec![task(0, 0), task(1, 2)], vec![draft(9, 3)]);
        q.mark_started();
        let (t, d) = q.drain();
        assert_eq!(t.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(d.len(), 1);
        assert!(q.is_empty());
        assert_eq!(q.steals(), 0, "drained items were never handed to an engine");
    }

    #[test]
    fn with_estimates_reorders_by_expected_remaining() {
        // Equal raw keys, different predicted totals: the predicted
        // straggler (longest expected remaining) must pop first (§14).
        let mut est = LenEstimates::off();
        est.set_total(0, 10);
        est.set_total(1, 40);
        est.set_total(2, 20);
        let mut q =
            WorkQueue::with_estimates(vec![task(0, 2), task(1, 2), task(2, 2)], Vec::new(), est);
        let mut s = SlotScheduler::new(3);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 2, 0], "longest expected remaining first");
    }

    #[test]
    fn estimate_ties_fall_back_to_id_tiebreak() {
        // Identical estimates must preserve the documented id tie-break
        // in both lanes, exactly like identical raw keys.
        let mut est = LenEstimates::off();
        for id in [1, 3, 5] {
            est.set_total(id, 30);
        }
        for id in [2, 4] {
            est.set_total(id, 30);
            est.set_settled(id, 3);
        }
        let mut q = WorkQueue::with_estimates(
            vec![task(5, 2), task(1, 2), task(3, 2)],
            vec![draft(4, 6), draft(2, 6)],
            est,
        );
        let mut s = SlotScheduler::new(5);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 3, 5], "tied task estimates break by id");
        let dids: Vec<usize> =
            s.fill_verify(&mut q, 1).into_iter().map(|(_, d)| d.id).collect();
        assert_eq!(dids, vec![2, 4], "tied draft estimates break by id");
    }

    #[test]
    fn empty_estimates_queue_matches_raw_lpt_order() {
        // `with_estimates(.., off())` is the same queue `new` builds: the
        // empty table collapses every rank to the raw key.
        let tasks = || vec![task(3, 1), task(0, 5), task(2, 1), task(1, 0)];
        let drafts = || vec![draft(10, 4), draft(11, 2), draft(12, 4)];
        let mut raw = WorkQueue::new(tasks(), drafts());
        let mut off = WorkQueue::with_estimates(tasks(), drafts(), LenEstimates::off());
        while !raw.is_empty() || !off.is_empty() {
            let a = raw.pop_task().map(|t| t.id).or_else(|| raw.pop_draft().map(|d| d.id));
            let b = off.pop_task().map(|t| t.id).or_else(|| off.pop_draft().map(|d| d.id));
            assert_eq!(a, b, "off-estimates order must be bit-identical to raw");
        }
    }

    #[test]
    fn zero_history_prompts_rank_by_suite_priors() {
        // Fresh prompts have no EWMA history; the predictor's suite prior
        // must still separate them in the queue.
        use super::super::predict::LenPredictor;
        let mut p = LenPredictor::new(true);
        p.set_prior(0, 8.0); // short-answer family
        p.set_prior(1, 40.0); // long-answer family
        p.set_prior(2, 16.0);
        let tasks = vec![task(0, 0), task(1, 0), task(2, 0)];
        let est = p.estimates(&tasks, &[]);
        let mut q = WorkQueue::with_estimates(tasks, Vec::new(), est);
        let mut s = SlotScheduler::new(3);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 2, 0], "longest prior first among zero-history prompts");
    }

    #[test]
    fn adversarial_inverse_estimates_lose_no_item() {
        // A deliberately wrong predictor (shortest-first) may wreck the
        // makespan, but the queue must still hand every item out exactly
        // once — correctness never depends on estimate quality.
        let mut est = LenEstimates::off();
        for id in 0..6 {
            // Inverse: claim the longest-prefix (shortest-remaining) tasks
            // have the most remaining work.
            est.set_total(id, 100 + id);
        }
        let tasks: Vec<SeqTask> = (0..6).map(|i| task(i, 6 - i)).collect();
        let drafts: Vec<VerifyTask> = (0..3).map(|i| draft(10 + i, 2 + i)).collect();
        let mut q = WorkQueue::with_estimates(tasks, drafts, est);
        let mut popped = Vec::new();
        while let Some(t) = q.pop_task() {
            popped.push(t.id);
        }
        while let Some(d) = q.pop_draft() {
            popped.push(d.id);
        }
        let mut sorted = popped.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, (0..6).chain(10..13).collect::<Vec<_>>());
        assert_eq!(popped.len(), 9, "no item lost or duplicated");
        assert!(q.is_empty());
    }

    #[test]
    fn requeue_resorts_with_the_queue_estimates() {
        // Fault-recovery requeue must use the queue's own estimate table,
        // not the raw keys — otherwise a survivor's pull order would
        // diverge from a fresh estimate-aware queue over the same work.
        let mut est = LenEstimates::off();
        est.set_total(0, 10);
        est.set_total(1, 50);
        let mut q = WorkQueue::with_estimates(vec![task(0, 2)], Vec::new(), est);
        q.requeue(vec![task(1, 2)], Vec::new());
        let mut s = SlotScheduler::new(2);
        let ids: Vec<usize> = s.fill(&mut q).into_iter().map(|(_, t)| t.id).collect();
        assert_eq!(ids, vec![1, 0], "requeued straggler jumps ahead per its estimate");
    }

    #[test]
    fn shared_queue_pops_after_start_count_as_steals() {
        let mut q = WorkQueue::new((0..3).map(|i| task(i, 0)).collect(), vec![draft(9, 2)]);
        let mut a = SlotScheduler::new(1);
        let mut b = SlotScheduler::new(1);
        a.fill(&mut q);
        b.fill(&mut q);
        assert_eq!(q.steals(), 0, "initial placement pops are not steals");
        q.mark_started();
        a.release(0);
        let f = a.fill(&mut q);
        assert_eq!(f[0].1.id, 2);
        b.release(0);
        let v = b.fill_verify(&mut q, 1);
        assert_eq!(v[0].1.id, 9);
        assert_eq!(q.steals(), 2, "mid-step pops from the shared queue are steals");
        assert!(q.is_empty());
    }
}
