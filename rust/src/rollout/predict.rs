//! Predicted-length scheduling (`ARCHITECTURE.md` §14).
//!
//! The LPT [`super::sched::WorkQueue`] has always ordered by what it can
//! *see*: a task's verified-prefix length, a draft's materialized length.
//! Both are proxies for the quantity LPT actually wants — the **remaining
//! decode work** — and both go blind exactly where stragglers live: a
//! fresh prompt carries no estimate at all, and a stale draft's length
//! says nothing about how much of it will survive verification ("Beat the
//! Long Tail", PAPERS.md).
//!
//! [`LenPredictor`] closes that gap with the cheapest signal available:
//! a per-task EWMA of realized total response lengths, seeded from the
//! prior epoch's accepted rollouts already resident in the prefix-trie
//! cache (`spec/cache.rs` — the leaf length is O(1), no materialization),
//! falling back to per-suite priors (`tasks/suites.rs`) for prompts with
//! no history, plus a per-task acceptance-rate EWMA that discounts a
//! draft's length by how much of it is actually expected to settle.
//!
//! [`LenEstimates`] is the per-step snapshot handed to the queue: an
//! id → predicted-total map plus an id → expected-settled map for drafts.
//! The encoding is chosen so that a **missing estimate degrades to the
//! raw key exactly**: ranks are `usize::MAX - expected_remaining`, and an
//! absent total is treated as `usize::MAX`, which algebraically collapses
//! the rank back to `prefix.len()` / `draft_len()`. An empty
//! [`LenEstimates`] therefore reproduces the historical LPT order
//! bit-for-bit — predictor-off is not a separate code path, it is the
//! empty estimate table.
//!
//! Prediction only ever reorders the queue. It never touches the per-task
//! RNG streams of `ARCHITECTURE.md` §6, so outputs are byte-identical for
//! every predictor/placement/shard combination — even under an
//! adversarially wrong predictor, which can only cost makespan
//! (`rust/tests/prop_invariants.rs` pins both properties).

use std::collections::HashMap;

use super::batch::SeqTask;
use crate::spec::cache::RolloutCache;
use crate::spec::verifier::VerifyTask;

/// EWMA smoothing factor for both the length and acceptance trackers:
/// `new = alpha * observed + (1 - alpha) * old`.
pub const EWMA_ALPHA: f64 = 0.5;

/// Default prior for a task with no history and no suite prior: "assume
/// the longest remainder" — encoded as no estimate at all, so the queue
/// falls back to the raw LPT key for that item. This constant is the
/// *numeric* fallback used only when a caller asks [`LenPredictor::predict`]
/// for a number.
pub const DEFAULT_PRIOR: f64 = 0.0;

/// Per-task length predictor: total-response-length EWMA + acceptance
/// EWMA, with suite-prior and cache-seed fallbacks for fresh ids.
#[derive(Clone, Debug)]
pub struct LenPredictor {
    enabled: bool,
    alpha: f64,
    /// Per-id EWMA of realized total response length.
    ewma: HashMap<usize, f64>,
    /// Per-id EWMA of draft acceptance ratio (accepted / offered).
    acc: HashMap<usize, f64>,
    /// Per-id prior (suite-level mean length) for zero-history prompts.
    priors: HashMap<usize, f64>,
}

impl Default for LenPredictor {
    fn default() -> Self {
        LenPredictor {
            enabled: false,
            alpha: EWMA_ALPHA,
            ewma: HashMap::new(),
            acc: HashMap::new(),
            priors: HashMap::new(),
        }
    }
}

impl LenPredictor {
    /// A predictor that is on (`enabled = true`) or off. A disabled
    /// predictor produces only empty [`LenEstimates`] — the queue then
    /// orders by the raw keys, exactly the pre-§14 behavior.
    pub fn new(enabled: bool) -> Self {
        LenPredictor { enabled, ..Self::default() }
    }

    /// Whether estimates are produced at all.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Set the suite prior for one id (mean expected total length of its
    /// task family — `tasks::suites::family_length_priors`). Used only
    /// while the id has no observed history.
    pub fn set_prior(&mut self, id: usize, len: f64) {
        self.priors.insert(id, len);
    }

    /// Predicted total response length for `id`: observed EWMA, else the
    /// suite prior, else [`DEFAULT_PRIOR`]. `None` when disabled.
    pub fn predict(&self, id: usize) -> Option<f64> {
        if !self.enabled {
            return None;
        }
        Some(
            self.ewma
                .get(&id)
                .or_else(|| self.priors.get(&id))
                .copied()
                .unwrap_or(DEFAULT_PRIOR),
        )
    }

    /// Expected fraction of an offered draft that settles (is accepted).
    /// 1.0 until observed — the optimistic default matches the raw LPT
    /// key's implicit assumption that a draft reuses its whole length.
    pub fn acceptance(&self, id: usize) -> f64 {
        self.acc.get(&id).copied().unwrap_or(1.0)
    }

    /// Fold one realized total response length into the id's EWMA.
    pub fn observe_len(&mut self, id: usize, len: usize) {
        let a = self.alpha;
        self.ewma
            .entry(id)
            .and_modify(|e| *e = a * len as f64 + (1.0 - a) * *e)
            .or_insert(len as f64);
    }

    /// Fold one step's acceptance outcome (`accepted` of `offered` draft
    /// tokens settled) into the id's acceptance EWMA.
    pub fn observe_acceptance(&mut self, id: usize, accepted: usize, offered: usize) {
        if offered == 0 {
            return;
        }
        let r = (accepted as f64 / offered as f64).clamp(0.0, 1.0);
        let a = self.alpha;
        self.acc
            .entry(id)
            .and_modify(|e| *e = a * r + (1.0 - a) * *e)
            .or_insert(r);
    }

    /// Seed a zero-history id from the prior epoch's accepted rollout
    /// resident in the prefix trie (`cache.cached_len` reads the leaf
    /// length in O(1)). A no-op once the id has observed history.
    pub fn seed_from_cache(&mut self, cache: &RolloutCache, id: usize) {
        if !self.enabled || self.ewma.contains_key(&id) {
            return;
        }
        if let Some(len) = cache.cached_len(id) {
            self.ewma.insert(id, len as f64);
        }
    }

    /// Seed a zero-history id's acceptance EWMA from the trie's divergence
    /// signal: a sibling-spine fallback draft is expected to settle about
    /// `branch_depth / offered` of its tokens (the provably-shared prefix),
    /// a far better opening guess than the optimistic 1.0 for a draft that
    /// is, by construction, someone else's continuation. A no-op once the
    /// id has observed acceptance history, and when disabled — seeding
    /// never touches RNG, so outputs stay invariant (`ARCHITECTURE.md` §6).
    pub fn seed_acceptance(&mut self, id: usize, frac: f64) {
        if !self.enabled || self.acc.contains_key(&id) {
            return;
        }
        self.acc.insert(id, frac.clamp(0.0, 1.0));
    }

    /// Snapshot this step's estimates for the given work: predicted
    /// totals for every item, plus expected-settled lengths for drafts
    /// (`acceptance * offered`, rounded). Empty when disabled.
    pub fn estimates(&self, tasks: &[SeqTask], drafts: &[VerifyTask]) -> LenEstimates {
        if !self.enabled {
            return LenEstimates::off();
        }
        let mut est = LenEstimates::default();
        for t in tasks {
            if let Some(p) = self.predict(t.id) {
                est.set_total(t.id, p.round().max(0.0) as usize);
            }
        }
        for d in drafts {
            if let Some(p) = self.predict(d.id) {
                est.set_total(d.id, p.round().max(0.0) as usize);
            }
            let settled = (self.acceptance(d.id) * d.draft_len() as f64).round() as usize;
            est.set_settled(d.id, settled.min(d.draft_len()));
        }
        est
    }
}

/// One step's frozen length estimates, owned by the
/// [`super::sched::WorkQueue`] (and consulted by the static placement's
/// cost model). Cloneable and cheap; an **empty** table reproduces the
/// raw LPT keys exactly (see the module docs), so `off()` is just
/// `default()`.
#[derive(Clone, Debug, Default)]
pub struct LenEstimates {
    /// id → predicted total response length.
    totals: HashMap<usize, usize>,
    /// id → expected settled (accepted) draft tokens.
    settled: HashMap<usize, usize>,
}

impl LenEstimates {
    /// The no-predictor table: every rank falls back to the raw LPT key.
    pub fn off() -> Self {
        Self::default()
    }

    /// True when no estimate is loaded (raw-key ordering).
    pub fn is_empty(&self) -> bool {
        self.totals.is_empty() && self.settled.is_empty()
    }

    /// Load a predicted total length for `id`.
    pub fn set_total(&mut self, id: usize, total: usize) {
        self.totals.insert(id, total);
    }

    /// Load an expected settled draft length for `id`.
    pub fn set_settled(&mut self, id: usize, settled: usize) {
        self.settled.insert(id, settled);
    }

    /// Predicted total length, if loaded.
    pub fn total(&self, id: usize) -> Option<usize> {
        self.totals.get(&id).copied()
    }

    /// Expected settled draft tokens, if loaded.
    pub fn settled_of(&self, id: usize) -> Option<usize> {
        self.settled.get(&id).copied()
    }

    /// Ascending sort key for the decode-task lane: longest expected
    /// remaining generation first. With no estimate the key collapses to
    /// the raw `prefix.len()` (the historical LPT key):
    /// `MAX - (MAX - prefix_len) == prefix_len`.
    pub fn task_rank(&self, t: &SeqTask) -> usize {
        let total = self.total(t.id).unwrap_or(usize::MAX);
        usize::MAX - total.saturating_sub(t.prefix.len())
    }

    /// Ascending sort key for the draft lane: longest expected remainder
    /// first, where the remainder is the predicted total minus the
    /// expected settled prefix. With no estimate it collapses to the raw
    /// `draft_len()` key.
    pub fn draft_rank(&self, d: &VerifyTask) -> usize {
        let total = self.total(d.id).unwrap_or(usize::MAX);
        let settled = self.settled_of(d.id).unwrap_or_else(|| d.draft_len());
        usize::MAX - total.saturating_sub(settled)
    }

    /// Expected decode cost of a task for the static placement's
    /// load-balance model (`gen_len` caps the prediction; with no
    /// estimate this is exactly the historical `gen_len - prefix_len`).
    pub fn task_cost(&self, t: &SeqTask, gen_len: usize) -> usize {
        self.total(t.id).unwrap_or(usize::MAX).min(gen_len).saturating_sub(t.prefix.len())
    }

    /// Expected decode cost of a draft for the static placement (with no
    /// estimate: exactly the historical `gen_len - draft_len`).
    pub fn draft_cost(&self, d: &VerifyTask, gen_len: usize) -> usize {
        let settled = self.settled_of(d.id).unwrap_or_else(|| d.draft_len());
        self.total(d.id).unwrap_or(usize::MAX).min(gen_len).saturating_sub(settled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::cache::CacheEntry;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![1],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    fn draft(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![1],
            entry: CacheEntry {
                response: vec![7; len],
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn empty_estimates_collapse_to_raw_lpt_keys() {
        let est = LenEstimates::off();
        assert_eq!(est.task_rank(&task(0, 5)), 5);
        assert_eq!(est.task_rank(&task(1, 0)), 0);
        assert_eq!(est.draft_rank(&draft(2, 7)), 7);
        assert_eq!(est.task_cost(&task(0, 5), 48), 43);
        assert_eq!(est.draft_cost(&draft(2, 7), 48), 41);
        assert!(est.is_empty());
    }

    #[test]
    fn loaded_estimates_rank_by_expected_remaining() {
        let mut est = LenEstimates::off();
        // id 0: total 40, prefix 5 -> remaining 35
        // id 1: total 10, prefix 5 -> remaining 5
        est.set_total(0, 40);
        est.set_total(1, 10);
        assert!(est.task_rank(&task(0, 5)) < est.task_rank(&task(1, 5)));
        // drafts: same length, different expected settle
        est.set_total(2, 48);
        est.set_total(3, 48);
        est.set_settled(2, 2); // stale: almost nothing settles -> 46 remain
        est.set_settled(3, 40); // fresh: most settles -> 8 remain
        assert!(est.draft_rank(&draft(2, 48)) < est.draft_rank(&draft(3, 48)));
    }

    #[test]
    fn cost_caps_at_gen_len_and_floors_at_zero() {
        let mut est = LenEstimates::off();
        est.set_total(0, 500);
        assert_eq!(est.task_cost(&task(0, 5), 48), 43, "total caps at gen_len");
        est.set_total(1, 3);
        assert_eq!(est.task_cost(&task(1, 5), 48), 0, "overshot prefix floors at 0");
    }

    #[test]
    fn predictor_ewma_tracks_observations() {
        let mut p = LenPredictor::new(true);
        p.observe_len(3, 10);
        assert_eq!(p.predict(3), Some(10.0), "first observation seeds the EWMA");
        p.observe_len(3, 20);
        assert_eq!(p.predict(3), Some(15.0), "alpha 0.5 blend");
        assert_eq!(p.predict(99), Some(DEFAULT_PRIOR), "no history, no prior");
    }

    #[test]
    fn zero_history_ids_fall_back_to_suite_priors() {
        let mut p = LenPredictor::new(true);
        p.set_prior(5, 12.5);
        assert_eq!(p.predict(5), Some(12.5), "prior answers before any history");
        p.observe_len(5, 40);
        assert_eq!(p.predict(5), Some(40.0), "history beats the prior");
    }

    #[test]
    fn disabled_predictor_emits_empty_estimates() {
        let mut p = LenPredictor::new(false);
        p.observe_len(0, 10);
        p.set_prior(1, 5.0);
        assert_eq!(p.predict(0), None);
        let est = p.estimates(&[task(0, 0)], &[draft(1, 4)]);
        assert!(est.is_empty(), "off-mode estimates must be the empty table");
    }

    #[test]
    fn acceptance_ewma_discounts_settled_length() {
        let mut p = LenPredictor::new(true);
        assert_eq!(p.acceptance(7), 1.0, "optimistic until observed");
        p.observe_acceptance(7, 0, 10);
        assert_eq!(p.acceptance(7), 0.0);
        p.observe_acceptance(7, 10, 10);
        assert_eq!(p.acceptance(7), 0.5);
        p.observe_len(7, 48);
        let est = p.estimates(&[], &[draft(7, 40)]);
        assert_eq!(est.settled_of(7), Some(20), "0.5 * 40 offered");
        assert_eq!(est.total(7), Some(48));
    }

    #[test]
    fn acceptance_seed_fills_only_zero_history_ids() {
        let mut p = LenPredictor::new(true);
        p.seed_acceptance(3, 0.25);
        assert_eq!(p.acceptance(3), 0.25, "divergence seed answers first");
        p.seed_acceptance(3, 0.9);
        assert_eq!(p.acceptance(3), 0.25, "seed never overwrites a seed");
        p.observe_acceptance(3, 3, 4);
        assert_eq!(p.acceptance(3), 0.5, "EWMA blends seed with observation");
        p.seed_acceptance(4, 7.0);
        assert_eq!(p.acceptance(4), 1.0, "seed fraction is clamped to [0, 1]");
        let mut off = LenPredictor::new(false);
        off.seed_acceptance(5, 0.2);
        assert_eq!(off.acceptance(5), 1.0, "disabled predictor stays untouched");
    }

    #[test]
    fn cache_seed_fills_only_zero_history_ids() {
        let mut cache = RolloutCache::new();
        cache.insert(
            4,
            CacheEntry { response: vec![7; 9], logps: vec![-1.0; 9], version: 0, finished: true },
        );
        let mut p = LenPredictor::new(true);
        p.seed_from_cache(&cache, 4);
        assert_eq!(p.predict(4), Some(9.0), "seeded from the trie leaf length");
        p.observe_len(4, 19);
        p.seed_from_cache(&cache, 4);
        assert_eq!(p.predict(4), Some(14.0), "seed never overwrites history");
        p.seed_from_cache(&cache, 12);
        assert_eq!(p.predict(12), Some(DEFAULT_PRIOR), "no cache entry, no seed");
    }
}
