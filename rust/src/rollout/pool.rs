//! Sharded multi-engine rollout: one slot pool per backend, one pool of
//! pools.
//!
//! [`EnginePool`] owns `N` [`RolloutEngine`]s, one per [`Backend`]
//! instance (N [`crate::testing::mock::MockEngine`]s in tests, N AOT
//! engines in production), and places one step's work across their
//! per-engine slot pools. It is the layer the ROADMAP's "shard the slot
//! pool across multiple engines" lever lands in, and the prerequisite for
//! multi-host pools (see `ARCHITECTURE.md`, "Sharding and placement").
//!
//! ## Placement rules
//!
//! - **A row's entire lifecycle is pinned to one engine.** Draft →
//!   Verify → Decode → Done all happen inside the shard the task was
//!   placed on, so KV never migrates between generation blobs. Placement
//!   therefore happens once per step, before any engine call.
//! - **LPT across pools.** The shared pending queue (decode tasks *and*
//!   drafts) is ordered longest-expected-remainder first — the same
//!   proxies [`SlotScheduler`](super::SlotScheduler) sorts by within a
//!   shard: a decode task still needs `gen_len - prefix` tokens, and a
//!   draft can reuse at most its own length, so short drafts carry the
//!   longest expected remainder. Each item then spills into the
//!   least-loaded pool (ties go to the lowest shard index), keeping every
//!   engine busy until the tail drains instead of letting one shard idle
//!   on the decode tail.
//! - **Replicas must be interchangeable.** Every backend must serve the
//!   same bundle geometry (checked at construction) and hold the same
//!   policy weights (the caller passes one blob per shard); per-row
//!   independence of probs — the contract every backend already
//!   guarantees — makes outputs placement-invariant.
//!
//! ## Determinism
//!
//! Sampling uses per-task streams (`task_rng(rnonce, id)`) and
//! verification uses per-task uniform streams (`verify_rng(vnonce, id)`),
//! so a task's tokens depend only on the step nonces and its id — never on
//! which shard, slot, or verify sub-batch it lands in. Results are
//! byte-identical for any shard count, pinned by
//! `rust/tests/sched_continuous.rs` (`shards ∈ {1, 2, 4}` vs the
//! `run_two_phase` oracle across all `ReuseVariant`s) and measured by
//! `bench_shards` (`BENCH_shards.json`).

use anyhow::{ensure, Result};

use super::batch::{SeqResult, SeqTask};
use super::engine::{PipelineStats, RolloutEngine, SampleCfg};
use crate::runtime::{Backend, Engine};
use crate::spec::verifier::VerifyTask;
use crate::util::StageTimer;

/// A pool of per-backend rollout engines behind one placement front-end.
///
/// Construct it from any iterator of backend references (all serving the
/// same bundle geometry); [`crate::spec::SpecRollout::collect`] drives it.
///
/// ```
/// use spec_rl::rollout::{EnginePool, SampleCfg};
/// use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
/// use spec_rl::testing::mock::MockEngine;
/// use spec_rl::tokenizer::BOS;
/// use spec_rl::util::{Rng, StageTimer};
///
/// // Two mock replicas stand in for two identically-provisioned engines.
/// let shards = MockEngine::replicas(2, 4, 8, 16, 16);
/// let blobs: Vec<_> = shards.iter().map(|m| m.blob()).collect();
/// let blob_refs: Vec<_> = blobs.iter().collect();
/// let mut pool = EnginePool::new(shards.iter(), "mock").unwrap();
///
/// let reqs: Vec<RolloutRequest> = (0..6)
///     .map(|i| RolloutRequest { id: i, prompt: vec![BOS, 3 + i as i32] })
///     .collect();
/// let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
/// let mut rng = Rng::new(7);
/// let mut timer = StageTimer::new();
/// let (results, stats) = spec
///     .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
///     .unwrap();
/// assert_eq!(results.len(), 6);
/// assert_eq!(stats.shard_device_calls.len(), 2, "one device-call total per shard");
/// ```
pub struct EnginePool<'e, B: Backend = Engine> {
    shards: Vec<RolloutEngine<'e, B>>,
}

/// One shard's placed work: (decode-ready tasks, drafts to verify).
type ShardWork = (Vec<SeqTask>, Vec<VerifyTask>);

impl<'e, B: Backend> EnginePool<'e, B> {
    /// Bind one [`RolloutEngine`] per backend, all serving `bundle`.
    /// Fails when the pool is empty or the shard geometries differ (the
    /// placement rules assume interchangeable replicas).
    pub fn new<I>(backends: I, bundle: &str) -> Result<Self>
    where
        I: IntoIterator<Item = &'e B>,
    {
        let mut shards = Vec::new();
        for eng in backends {
            shards.push(RolloutEngine::new(eng, bundle)?);
        }
        ensure!(!shards.is_empty(), "EnginePool needs at least one backend");
        let first = &shards[0];
        let (b0, p0, t0, v0) = (first.batch, first.prompt_len, first.total_len, first.vocab);
        for (i, s) in shards.iter().enumerate().skip(1) {
            ensure!(
                s.batch == b0 && s.prompt_len == p0 && s.total_len == t0 && s.vocab == v0,
                "EnginePool shard {i} geometry (B={}, P={}, T={}, V={}) differs from shard 0 \
                 (B={b0}, P={p0}, T={t0}, V={v0})",
                s.batch,
                s.prompt_len,
                s.total_len,
                s.vocab
            );
        }
        Ok(EnginePool { shards })
    }

    /// A one-shard pool (the single-engine pipeline, unchanged).
    pub fn single(backend: &'e B, bundle: &str) -> Result<Self> {
        Self::new(std::iter::once(backend), bundle)
    }

    /// Number of engines in the pool.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Borrow one shard's engine. Shard 0 doubles as the "primary" engine
    /// for decode-only consumers (evaluation, the scheduler benches).
    pub fn shard_mut(&mut self, i: usize) -> &mut RolloutEngine<'e, B> {
        &mut self.shards[i]
    }

    /// LPT placement across pools: order the shared queue by descending
    /// expected remainder (ties by id, so placement is deterministic) and
    /// spill each item into the least-loaded shard. Terminal drafts cost
    /// zero — they never occupy a slot wherever they land.
    fn place(&self, tasks: Vec<SeqTask>, drafts: Vec<VerifyTask>) -> Vec<ShardWork> {
        enum Item {
            Task(SeqTask),
            Draft(VerifyTask),
        }
        let n = self.shards.len();
        let gen_len = self.shards[0].gen_len();
        let mut work: Vec<(usize, usize, Item)> =
            Vec::with_capacity(tasks.len() + drafts.len());
        for t in tasks {
            // Terminal full-reuse prefixes never occupy a slot (the engine
            // routes them straight to results), so they carry zero load.
            let cost = if t.prefix_is_terminal(gen_len) {
                0
            } else {
                gen_len.saturating_sub(t.prefix.len())
            };
            work.push((cost, t.id, Item::Task(t)));
        }
        for d in drafts {
            work.push((gen_len.saturating_sub(d.draft_len()), d.id, Item::Draft(d)));
        }
        work.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));

        let mut out: Vec<ShardWork> = (0..n).map(|_| (Vec::new(), Vec::new())).collect();
        let mut load = vec![0usize; n];
        for (cost, _, item) in work {
            let shard = (0..n).min_by_key(|&i| load[i]).expect("pool has shards");
            load[shard] += cost;
            match item {
                Item::Task(t) => out[shard].0.push(t),
                Item::Draft(d) => out[shard].1.push(d),
            }
        }
        out
    }

    /// Run one step's decode-ready `tasks` and to-verify `drafts` across
    /// the pool: place (LPT across pools), run each shard's phase-aware
    /// pipeline with the *same* step nonces, and merge id-sorted results.
    ///
    /// `blobs` carries one policy blob per shard (the same buffer repeated
    /// when the shards share a device, one device-resident copy each when
    /// they do not). The merged [`PipelineStats`] sums the raw counters
    /// and records each shard's `device_calls()` in `shard_device_calls`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipeline(
        &mut self,
        blobs: &[&B::Buf],
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        ensure!(
            blobs.len() == self.shards.len(),
            "EnginePool: {} blobs for {} shards (one policy blob per engine)",
            blobs.len(),
            self.shards.len()
        );
        if self.shards.len() == 1 {
            let (results, mut stats) = self.shards[0]
                .run_pipeline(blobs[0], tasks, drafts, loglen, cfg, vnonce, rnonce, timer)?;
            stats.shard_device_calls = vec![stats.device_calls()];
            return Ok((results, stats));
        }

        let placed = self.place(tasks, drafts);
        let mut results: Vec<SeqResult> = Vec::new();
        let mut agg = PipelineStats::default();
        for (shard, (t, d)) in placed.into_iter().enumerate() {
            let (r, s) = self.shards[shard]
                .run_pipeline(blobs[shard], t, d, loglen, cfg, vnonce, rnonce, timer)?;
            agg.absorb(&s);
            agg.shard_device_calls.push(s.device_calls());
            results.extend(r);
        }
        results.sort_by_key(|r| r.id);
        Ok((results, agg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::cache::CacheEntry;
    use crate::testing::mock::MockEngine;
    use crate::tokenizer::BOS;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![BOS, 5],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    fn draft(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![BOS, 5],
            entry: CacheEntry {
                response: vec![7; len],
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn placement_is_lpt_and_deterministic() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        // remainders (gen_len = 8): id0 -> 8, id1 -> 6, id2 -> 5, id3 -> 1
        let tasks = vec![task(0, 0), task(1, 2), task(2, 3), task(3, 7)];
        let placed = pool.place(tasks, Vec::new());
        // LPT greedy: 8 -> shard0, 6 -> shard1, 5 -> shard1 (6 < 8),
        // 1 -> shard0 (8 < 11)
        let ids = |s: usize| placed[s].0.iter().map(|t| t.id).collect::<Vec<_>>();
        assert_eq!(ids(0), vec![0, 3]);
        assert_eq!(ids(1), vec![1, 2]);
    }

    #[test]
    fn drafts_and_tasks_share_one_spill_queue() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        // expected remainders: task2 -> 8, draft0 -> 7, draft1 -> 6,
        // task3 -> 5; greedy LPT lands the tasks on shard 0 and both
        // drafts on shard 1 (loads 13 / 13).
        let placed =
            pool.place(vec![task(2, 0), task(3, 3)], vec![draft(0, 1), draft(1, 2)]);
        assert_eq!(placed[0].0.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(placed[0].1.is_empty());
        assert_eq!(placed[1].1.iter().map(|d| d.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(placed[1].0.is_empty());
    }

    #[test]
    fn empty_pool_is_rejected() {
        let mocks: Vec<MockEngine> = Vec::new();
        assert!(EnginePool::new(mocks.iter(), "mock").is_err());
    }

    #[test]
    fn mismatched_geometry_is_rejected() {
        let a = MockEngine::new(2, 8, 16, 16);
        let b = MockEngine::new(4, 8, 16, 16);
        assert!(EnginePool::new([&a, &b], "mock").is_err());
    }

    #[test]
    fn blob_count_must_match_shards() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let blob = mocks[0].blob();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut timer = StageTimer::new();
        let err = pool.run_pipeline(
            &[&blob],
            vec![task(0, 0)],
            Vec::new(),
            0.0,
            SampleCfg::default(),
            1,
            2,
            &mut timer,
        );
        assert!(err.is_err());
    }
}
