//! Sharded multi-engine rollout: one slot pool per backend, one shared
//! steal-queue across all of them.
//!
//! [`EnginePool`] owns `N` [`RolloutEngine`]s, one per [`Backend`]
//! instance (N [`crate::testing::mock::MockEngine`]s in tests, N AOT
//! engines in production), and drives one step's work across their
//! per-engine slot pools. Since PR 4 the pool no longer places work once
//! at step start: unstarted items stay in one shared [`WorkQueue`] (the
//! **steal-queue**) and every engine pulls from it — at its initial
//! seating pass and again whenever a refill pass finds free slots
//! mid-step. The slowest shard can no longer sit on a private backlog
//! while its neighbours idle; `bench_steal` pins the busiest engine's
//! device-call total strictly below one-pass placement on the adversarial
//! stale-draft workload.
//!
//! ## Placement rules
//!
//! - **Only never-seated work moves.** The steal-queue holds tasks and
//!   drafts whose lifecycle has not begun: no KV, no slot, no uniforms
//!   consumed anywhere. The moment an engine seats an item (prefill,
//!   `refill`, or `verify_seat`), the row's entire remaining
//!   `Draft -> Verify -> Decode -> Done` lifecycle is pinned to that
//!   engine — KV never migrates between generation blobs. Stealing moves
//!   queue entries, never rows.
//! - **LPT-first pulls.** The queue keeps decode tasks sorted by
//!   ascending verified-prefix length and drafts by ascending draft
//!   length (longest expected remainder first, ties by id — the same
//!   proxies [`SlotScheduler`](super::SlotScheduler) has always used), so
//!   every pull — initial or stolen — takes the longest-remaining work
//!   first. Decode tasks are offered before drafts: those rows can sample
//!   immediately.
//! - **Deterministic interleave.** Shards start in index order and then
//!   step round-robin (shard 0, 1, …, N-1, repeat), so which engine pulls
//!   which item is a pure function of the inputs — placement is
//!   reproducible even though it is decided mid-step.
//! - **Overlapped stepping (PR 5).** Each round-robin round runs in two
//!   passes over the shards: a *submit* pass
//!   ([`RolloutEngine::step_submit`]) issues every live shard's whole
//!   device chain for the round, then a *complete* pass
//!   ([`RolloutEngine::step_complete`]) blocks on the readbacks in the
//!   same order. Queue pulls all happen in the submit pass, in shard
//!   index order — exactly the sequence the old host-serialized driver
//!   produced — so placement, steal counts, and outputs are unchanged
//!   while engines on distinct devices run their forwards concurrently.
//!   Since PR 6 the *opening* pass is overlapped the same way
//!   ([`RolloutEngine::start_submit`] across all shards, then
//!   [`RolloutEngine::start_complete`]), so first-step prefills no longer
//!   host-serialize either. `PipelineStats::overlap_makespan` vs
//!   `serial_makespan` measures the win on the mock's virtual clock
//!   (`ARCHITECTURE.md` §11, `bench_overlap`).
//! - **Replicas must be interchangeable.** Every backend must serve the
//!   same bundle geometry (checked at construction) and hold the same
//!   policy weights (the caller passes one blob per shard); per-row
//!   independence of probs — the contract every backend already
//!   guarantees — makes outputs placement-invariant.
//!
//! [`Placement::Static`] keeps PR 3's one-pass discipline (estimate
//! expected remainders, spill LPT-greedy into per-engine queues, never
//! rebalance) as the measurable baseline and second placement oracle:
//! outputs must be byte-identical either way, only the device-call split
//! may differ.
//!
//! ## Shard failure recovery (`ARCHITECTURE.md` §13)
//!
//! A backend/transport error (a [`crate::runtime::remote::RemoteBackend`]
//! losing its peer, an injected
//! [`crate::testing::mock::FaultPlan`] fault) marks the shard **dead** for
//! the rest of the step instead of failing it: never-seated work stays in
//! the queue, the dead shard's seated rows are harvested back into
//! queueable items (verified prefixes as drafts, anything else as tasks —
//! [`RolloutEngine::harvest_requeue`]) and the step completes on the
//! survivors. Because sampling and verification use stateless per-task
//! streams, a re-executed row reproduces its tokens exactly: outputs stay
//! byte-identical to the no-failure run, every task finishes exactly
//! once, and only `PipelineStats::{shard_failures, requeued_tasks}` and
//! the device-call split betray that anything happened. A step only
//! errors when *every* shard dies with work still pending.
//!
//! ## Determinism
//!
//! Sampling uses per-task streams (`task_rng(rnonce, id)`) and
//! verification uses per-task uniform streams (`verify_rng(vnonce, id)`),
//! so a task's tokens depend only on the step nonces and its id — never on
//! which shard, slot, or verify sub-batch it lands in, and never on *when*
//! a shard stole it. Draft *selection* is equally shard-blind: the
//! coordinator's prepare pass — including the sibling-spine fallback of
//! `spec.sibling_drafts` (`ARCHITECTURE.md` §8) — resolves every row's
//! draft against the shared cache before any work enters the queue, so a
//! fallback draft's content is fixed before placement and its tokens are
//! verified under the *requesting* id's streams wherever it seats.
//! Results are byte-identical for any shard count and
//! either placement, pinned by `rust/tests/sched_continuous.rs`
//! (`shards ∈ {1, 2, 4}` vs the `run_two_phase` oracle across all
//! `ReuseVariant`s, plus the steal-vs-static and `verify_seat_min` sweeps)
//! and measured by `bench_shards` / `bench_steal`.
//!
//! The pool returns one id-sorted result set per step; the caller's
//! single shared prefix-trie rollout cache (`ARCHITECTURE.md` §8)
//! refreshes from it once, so trie structure, dedup gauges, and
//! `spec.cache_budget` eviction evolve identically for every shard count
//! and the token budget binds globally — N shards never hold N budgets.

use anyhow::{ensure, Result};

use super::batch::{SeqResult, SeqTask};
use super::engine::{PipelineRun, PipelineStats, RolloutEngine, SampleCfg, StepTicket};
use super::predict::LenEstimates;
use super::sched::WorkQueue;
use crate::runtime::{Backend, Engine};
use crate::spec::verifier::VerifyTask;
use crate::util::StageTimer;

/// How a pool spreads one step's work across its shards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Placement {
    /// Shared steal-queue (the default since PR 4): every engine pulls
    /// LPT-first whenever it has free slots, so realized load balances
    /// mid-step. `PipelineStats::steal_count` reports the pulls made
    /// after the initial seating pass.
    #[default]
    Steal,
    /// PR 3's one-pass placement: spill the queue LPT-greedy by
    /// *estimated* remainder into per-engine private queues at step
    /// start, never rebalance. Kept as the baseline `bench_steal`
    /// measures against and as a second placement oracle.
    Static,
}

/// A pool of per-backend rollout engines behind one steal-queue front-end.
///
/// Construct it from any iterator of backend references (all serving the
/// same bundle geometry); [`crate::spec::SpecRollout::collect`] drives it.
///
/// ```
/// use spec_rl::rollout::{EnginePool, SampleCfg};
/// use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
/// use spec_rl::testing::mock::MockEngine;
/// use spec_rl::tokenizer::BOS;
/// use spec_rl::util::{Rng, StageTimer};
///
/// // Two mock replicas stand in for two identically-provisioned engines.
/// let shards = MockEngine::replicas(2, 4, 8, 16, 16);
/// let blobs: Vec<_> = shards.iter().map(|m| m.blob()).collect();
/// let blob_refs: Vec<_> = blobs.iter().collect();
/// let mut pool = EnginePool::new(shards.iter(), "mock").unwrap();
///
/// // 12 prompts over 2x4 slots: the tail beyond the 8 initial seats
/// // stays in the shared steal-queue and goes to whichever engine's
/// // slots free up first.
/// let reqs: Vec<RolloutRequest> = (0..12)
///     .map(|i| RolloutRequest { id: i, prompt: vec![BOS, 3 + (i as i32 % 9)] })
///     .collect();
/// let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
/// let mut rng = Rng::new(7);
/// let mut timer = StageTimer::new();
/// let (results, stats) = spec
///     .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
///     .unwrap();
/// assert_eq!(results.len(), 12);
/// assert_eq!(stats.shard_device_calls.len(), 2, "one device-call total per shard");
/// assert!(stats.steal_count > 0, "the 4-task tail is stolen mid-step");
/// ```
pub struct EnginePool<'e, B: Backend = Engine> {
    shards: Vec<RolloutEngine<'e, B>>,
}

/// One shard's statically-placed work: (decode-ready tasks, drafts).
type ShardWork = (Vec<SeqTask>, Vec<VerifyTask>);

/// Dead-shard bookkeeping for one recovering step (`ARCHITECTURE.md`
/// §13): which shards are still drivable, plus the errors that killed
/// the rest (surfaced only if every shard dies with work pending —
/// a completed step never re-raises a recovered failure).
struct Recovery {
    alive: Vec<bool>,
    errors: Vec<anyhow::Error>,
}

impl Recovery {
    fn new(n: usize) -> Self {
        Recovery { alive: vec![true; n], errors: Vec::new() }
    }

    fn ensure_survivor(&self) -> Result<()> {
        ensure!(
            self.alive.iter().any(|&a| a),
            "EnginePool: every shard failed with work still pending: {:?}",
            self.errors
        );
        Ok(())
    }
}

impl<'e, B: Backend> EnginePool<'e, B> {
    /// Bind one [`RolloutEngine`] per backend, all serving `bundle`.
    /// Fails when the pool is empty or the shard geometries differ (the
    /// placement rules assume interchangeable replicas).
    pub fn new<I>(backends: I, bundle: &str) -> Result<Self>
    where
        I: IntoIterator<Item = &'e B>,
    {
        let mut shards = Vec::new();
        for eng in backends {
            shards.push(RolloutEngine::new(eng, bundle)?);
        }
        ensure!(!shards.is_empty(), "EnginePool needs at least one backend");
        let first = &shards[0];
        let (b0, p0, t0, v0) = (first.batch, first.prompt_len, first.total_len, first.vocab);
        for (i, s) in shards.iter().enumerate().skip(1) {
            ensure!(
                s.batch == b0 && s.prompt_len == p0 && s.total_len == t0 && s.vocab == v0,
                "EnginePool shard {i} geometry (B={}, P={}, T={}, V={}) differs from shard 0 \
                 (B={b0}, P={p0}, T={t0}, V={v0})",
                s.batch,
                s.prompt_len,
                s.total_len,
                s.vocab
            );
        }
        Ok(EnginePool { shards })
    }

    /// A one-shard pool (the single-engine pipeline, unchanged).
    pub fn single(backend: &'e B, bundle: &str) -> Result<Self> {
        Self::new(std::iter::once(backend), bundle)
    }

    /// Number of engines in the pool.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Borrow one shard's engine. Shard 0 doubles as the "primary" engine
    /// for decode-only consumers (evaluation, the scheduler benches).
    pub fn shard_mut(&mut self, i: usize) -> &mut RolloutEngine<'e, B> {
        &mut self.shards[i]
    }

    /// Force (or un-force) the host sampling path on every shard — the
    /// `bench_readback` baseline and the §12 byte-identity sweeps. See
    /// [`RolloutEngine::set_host_sampling`].
    pub fn set_host_sampling(&mut self, force: bool) {
        for s in &mut self.shards {
            s.set_host_sampling(force);
        }
    }

    /// PR 3's one-pass LPT placement: order the work by descending
    /// *estimated* remainder (ties by id, so placement is deterministic)
    /// and spill each item into the least-loaded shard. Terminal drafts
    /// cost zero — they never occupy a slot wherever they land. The
    /// estimate is all this pass ever sees: a draft whose acceptance
    /// collapses at verify time still pays its full re-decode on the
    /// engine it was pinned to, which is exactly the imbalance the
    /// steal-queue exists to drain.
    fn place(&self, tasks: Vec<SeqTask>, drafts: Vec<VerifyTask>) -> Vec<ShardWork> {
        self.place_on(tasks, drafts, &vec![true; self.shards.len()], &LenEstimates::off())
    }

    /// [`EnginePool::place`] restricted to the shards still alive: the
    /// static-placement recovery path re-places a dead shard's recovered
    /// work over the survivors only (`ARCHITECTURE.md` §13). Dead shards
    /// get empty work lists. At least one entry of `alive` must be true.
    /// Per-item costs come from `est` (`ARCHITECTURE.md` §14); the empty
    /// table reproduces the raw `gen_len - known_len` estimates exactly.
    fn place_on(
        &self,
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        alive: &[bool],
        est: &LenEstimates,
    ) -> Vec<ShardWork> {
        enum Item {
            Task(SeqTask),
            Draft(VerifyTask),
        }
        let n = self.shards.len();
        let gen_len = self.shards[0].gen_len();
        let mut work: Vec<(usize, usize, Item)> =
            Vec::with_capacity(tasks.len() + drafts.len());
        for t in tasks {
            // Terminal full-reuse prefixes never occupy a slot (the engine
            // routes them straight to results), so they carry zero load.
            let cost =
                if t.prefix_is_terminal(gen_len) { 0 } else { est.task_cost(&t, gen_len) };
            work.push((cost, t.id, Item::Task(t)));
        }
        for d in drafts {
            work.push((est.draft_cost(&d, gen_len), d.id, Item::Draft(d)));
        }
        work.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));

        let mut out: Vec<ShardWork> = (0..n).map(|_| (Vec::new(), Vec::new())).collect();
        let mut load = vec![0usize; n];
        for (cost, _, item) in work {
            let shard = (0..n)
                .filter(|&i| alive[i])
                .min_by_key(|&i| load[i])
                .expect("place_on needs a live shard");
            load[shard] += cost;
            match item {
                Item::Task(t) => out[shard].0.push(t),
                Item::Draft(d) => out[shard].1.push(d),
            }
        }
        out
    }

    /// Mark shard `i` dead after a backend/transport error
    /// (`ARCHITECTURE.md` §13): record the failure, harvest the shard's
    /// unfinished seated rows back into queueable work
    /// ([`RolloutEngine::harvest_requeue`]), and return every recovered
    /// item to `queue`. The run is left done — its finished rows stay in
    /// it for the normal absorb pass — and the shard is never driven
    /// again this step. Only harvested (once-seated) rows count in
    /// `requeued_tasks`; never-seated work simply stays wherever it was
    /// queued.
    fn fail_shard(
        &mut self,
        i: usize,
        err: anyhow::Error,
        rec: &mut Recovery,
        run: &mut PipelineRun<B>,
        queue: &mut WorkQueue,
        agg: &mut PipelineStats,
    ) {
        rec.alive[i] = false;
        agg.shard_failures += 1;
        let (t, d) = self.shards[i].harvest_requeue(run);
        agg.requeued_tasks += queue.requeue(t, d);
        rec.errors.push(err.context(format!("shard {i} marked dead")));
    }

    /// Snapshot the backends' virtual clock for overlap accounting: the
    /// shared host reading plus each shard's device-busy total. A `None`
    /// host reading (any real device backend) disables the accounting.
    fn clock_begin(&self) -> (Option<f64>, Vec<f64>) {
        let t0 = self.shards[0].backend().virtual_now();
        let busy0 = self.shards.iter().map(|s| s.backend().device_busy_secs()).collect();
        (t0, busy0)
    }

    /// Fill the makespan telemetry from a [`EnginePool::clock_begin`]
    /// snapshot: `overlap_makespan` is the realized host-clock delta of
    /// this run under the driver actually used; `serial_makespan` is the
    /// summed device-busy deltas — what a driver that never lets two
    /// forwards overlap would have realized (`ARCHITECTURE.md` §11).
    fn clock_end(&self, stats: &mut PipelineStats, t0: Option<f64>, busy0: &[f64]) {
        let Some(t0) = t0 else { return };
        let now = self.shards[0].backend().virtual_now().unwrap_or(t0);
        stats.overlap_makespan = now - t0;
        stats.serial_makespan = self
            .shards
            .iter()
            .zip(busy0)
            .map(|(s, b0)| s.backend().device_busy_secs() - b0)
            .sum();
    }

    /// Run one step's decode-ready `tasks` and to-verify `drafts` across
    /// the pool under the default [`Placement::Steal`] discipline. See
    /// [`EnginePool::run_pipeline_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipeline(
        &mut self,
        blobs: &[&B::Buf],
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        self.run_pipeline_with(
            Placement::Steal,
            blobs,
            tasks,
            drafts,
            loglen,
            cfg,
            vnonce,
            rnonce,
            &LenEstimates::off(),
            timer,
        )
    }

    /// Run one step across the pool under an explicit [`Placement`]:
    /// every shard runs the phase-aware pipeline with the *same* step
    /// nonces, and the id-sorted merged results are byte-identical for
    /// either discipline and any shard count.
    ///
    /// `blobs` carries one policy blob per shard (the same buffer repeated
    /// when the shards share a device, one device-resident copy each when
    /// they do not). The merged [`PipelineStats`] sums the raw counters,
    /// records each shard's `device_calls()` in `shard_device_calls`, and
    /// (under `Steal`) reports mid-step pulls in `steal_count`.
    ///
    /// `est` carries the step's frozen length estimates (`ARCHITECTURE.md`
    /// §14): the queue's LPT keys and the static placement's cost model
    /// both consult it. Estimates only reorder work, so the merged
    /// results are byte-identical for *any* estimate table, including an
    /// adversarially wrong one — pass [`LenEstimates::off`] for the raw
    /// keys.
    #[allow(clippy::too_many_arguments)]
    pub fn run_pipeline_with(
        &mut self,
        placement: Placement,
        blobs: &[&B::Buf],
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        est: &LenEstimates,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        ensure!(
            blobs.len() == self.shards.len(),
            "EnginePool: {} blobs for {} shards (one policy blob per engine)",
            blobs.len(),
            self.shards.len()
        );
        if self.shards.len() == 1 {
            let (t0, busy0) = self.clock_begin();
            let (results, mut stats) = self.shards[0].run_pipeline_est(
                blobs[0],
                tasks,
                drafts,
                loglen,
                cfg,
                vnonce,
                rnonce,
                est.clone(),
                timer,
            )?;
            stats.shard_device_calls = vec![stats.device_calls()];
            self.clock_end(&mut stats, t0, &busy0);
            return Ok((results, stats));
        }
        match placement {
            Placement::Static => {
                self.run_static(blobs, tasks, drafts, loglen, cfg, vnonce, rnonce, est, timer)
            }
            Placement::Steal => {
                self.run_steal(blobs, tasks, drafts, loglen, cfg, vnonce, rnonce, est, timer)
            }
        }
    }

    /// The PR 3 discipline: one-pass placement, then each shard's
    /// pipeline runs to completion on its private queue.
    ///
    /// Failure recovery (`ARCHITECTURE.md` §13): a shard that errors
    /// mid-drive is marked dead, its seated rows are harvested back into
    /// queueable work, its private queue is drained, and everything
    /// recovered spills into the next placement pass — re-placed
    /// LPT-greedy over the survivors only. With no failures the spill
    /// stays empty and the loop body runs exactly once, placing and
    /// driving precisely as PR 3 did.
    #[allow(clippy::too_many_arguments)]
    fn run_static(
        &mut self,
        blobs: &[&B::Buf],
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        est: &LenEstimates,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let n = self.shards.len();
        let mut results: Vec<SeqResult> = Vec::new();
        let mut agg = PipelineStats::default();
        let mut per_shard = vec![0usize; n];
        let mut rec = Recovery::new(n);
        let (t0, busy0) = self.clock_begin();
        let (mut work_t, mut work_d) = (tasks, drafts);
        loop {
            let placed = self.place_on(work_t, work_d, &rec.alive, est);
            let mut spill_t: Vec<SeqTask> = Vec::new();
            let mut spill_d: Vec<VerifyTask> = Vec::new();
            for (i, (t, d)) in placed.into_iter().enumerate() {
                let pending = self.shards[i].split_terminal(t, &mut results, &mut agg);
                if pending.is_empty() && d.is_empty() {
                    continue;
                }
                let mut queue = WorkQueue::with_estimates(pending, d, est.clone());
                let mut failed = false;
                let (mut run, ticket) = self.shards[i].start_submit(
                    blobs[i], &mut queue, loglen, cfg, vnonce, rnonce, timer,
                );
                let started = match ticket {
                    Ok(tk) => self.shards[i].start_complete(&mut run, tk, &queue, timer),
                    Err(e) => Err(e),
                };
                if let Err(e) = started {
                    self.fail_shard(i, e, &mut rec, &mut run, &mut queue, &mut agg);
                    failed = true;
                }
                while !failed && !run.done() {
                    if let Err(e) =
                        self.shards[i].pipeline_step(&mut run, blobs[i], &mut queue, timer)
                    {
                        self.fail_shard(i, e, &mut rec, &mut run, &mut queue, &mut agg);
                        failed = true;
                    }
                }
                if failed {
                    // Never-seated items (harvested rows included — they
                    // re-entered via the requeue above) spill to the next
                    // placement pass over the survivors.
                    let (qt, qd) = queue.drain();
                    spill_t.extend(qt);
                    spill_d.extend(qd);
                }
                let (r, s) = run.into_parts();
                agg.absorb(&s);
                per_shard[i] += s.device_calls();
                results.extend(r);
            }
            if spill_t.is_empty() && spill_d.is_empty() {
                break;
            }
            rec.ensure_survivor()?;
            work_t = spill_t;
            work_d = spill_d;
        }
        agg.shard_device_calls = per_shard;
        self.clock_end(&mut agg, t0, &busy0);
        results.sort_by_key(|r| r.id);
        Ok((results, agg))
    }

    /// The steal discipline: all shards pull from one shared steal-queue
    /// (PR 4), and since PR 5 the drive loop is **overlapped** — each
    /// round submits every live shard's device chain before completing
    /// any of them, so shard *i+1*'s forward no longer waits for shard
    /// *i*'s readback. Shards start in index order, then step
    /// round-robin; a shard whose refill pass finds free slots pulls the
    /// queue's longest-remaining item, so the step's tail drains to
    /// whichever engine has capacity instead of queueing behind one
    /// shard's backlog. Because every queue pull happens in the submit
    /// pass, in shard index order, the pull sequence — and therefore
    /// placement, steal counts, and outputs — is identical to the old
    /// host-serialized round-robin; only the realized makespan changes
    /// (`overlap_makespan` < `serial_makespan` on the virtual clock).
    #[allow(clippy::too_many_arguments)]
    fn run_steal(
        &mut self,
        blobs: &[&B::Buf],
        tasks: Vec<SeqTask>,
        drafts: Vec<VerifyTask>,
        loglen: f32,
        cfg: SampleCfg,
        vnonce: u64,
        rnonce: u64,
        est: &LenEstimates,
        timer: &mut StageTimer,
    ) -> Result<(Vec<SeqResult>, PipelineStats)> {
        let n = self.shards.len();
        let mut results: Vec<SeqResult> = Vec::new();
        let mut agg = PipelineStats::default();
        // Terminal full-reuse drafts never need a slot: fold them straight
        // into the merged results, exactly as the engine driver would.
        let pending = self.shards[0].split_terminal(tasks, &mut results, &mut agg);

        let (t0, busy0) = self.clock_begin();
        let mut queue = WorkQueue::with_estimates(pending, drafts, est.clone());
        let mut rec = Recovery::new(n);
        let mut per_shard = vec![0usize; n];
        // Recovery cycles (`ARCHITECTURE.md` §13): a failure-free cycle
        // always drains the queue (a run is only done once the queue is
        // empty), so with no failures the loop body runs exactly once and
        // this is byte-for-byte the PR 5 overlapped driver. A shard
        // failure requeues its recovered work; if every survivor had
        // already gone done by then, the leftover queue forces one more
        // cycle over the survivors — at most n cycles total.
        loop {
            // Overlapped start (ARCHITECTURE.md §12): submit every shard's
            // opening prefill/seat chain before completing any, so
            // first-step forwards run concurrently exactly like
            // steady-state rounds. All queue pulls still happen in the
            // submit pass, in shard index order, so placement is unchanged
            // from the old serial start; a shard that finds the queue
            // empty still makes zero device calls. Dead shards park on an
            // idle run and are never driven again.
            let mut runs: Vec<PipelineRun<B>> = Vec::with_capacity(n);
            let mut starts: Vec<Option<StepTicket<B>>> = Vec::with_capacity(n);
            for i in 0..n {
                if !rec.alive[i] {
                    runs.push(self.shards[i].idle_run(cfg, vnonce, rnonce));
                    starts.push(None);
                    continue;
                }
                let (mut run, ticket) = self.shards[i].start_submit(
                    blobs[i], &mut queue, loglen, cfg, vnonce, rnonce, timer,
                );
                match ticket {
                    Ok(tk) => starts.push(Some(tk)),
                    Err(e) => {
                        self.fail_shard(i, e, &mut rec, &mut run, &mut queue, &mut agg);
                        starts.push(None);
                    }
                }
                runs.push(run);
            }
            for (i, start) in starts.into_iter().enumerate() {
                let Some(ticket) = start else { continue };
                if let Err(e) = self.shards[i].start_complete(&mut runs[i], ticket, &queue, timer)
                {
                    self.fail_shard(i, e, &mut rec, &mut runs[i], &mut queue, &mut agg);
                }
            }
            // Everything popped from here on is work the one-pass
            // placement would have pinned to a single engine up front.
            queue.mark_started();
            let mut tickets: Vec<Option<StepTicket<B>>> = (0..n).map(|_| None).collect();
            while runs.iter().any(|r| !r.done()) {
                // Submit pass: issue every live shard's chain for this
                // round. All queue pulls happen here, in shard index
                // order.
                for i in 0..n {
                    if runs[i].done() {
                        continue;
                    }
                    match self.shards[i].step_submit(&mut runs[i], blobs[i], &mut queue, timer) {
                        Ok(tk) => tickets[i] = Some(tk),
                        Err(e) => {
                            self.fail_shard(i, e, &mut rec, &mut runs[i], &mut queue, &mut agg)
                        }
                    }
                }
                // Complete pass: now block on the readbacks, same order.
                // On devices this is where the overlap is realized —
                // shard i's wait runs concurrently with shards i+1..n's
                // forwards.
                for i in 0..n {
                    if let Some(ticket) = tickets[i].take() {
                        if let Err(e) =
                            self.shards[i].step_complete(&mut runs[i], ticket, &queue, timer)
                        {
                            self.fail_shard(i, e, &mut rec, &mut runs[i], &mut queue, &mut agg);
                        }
                    }
                }
            }
            for (i, run) in runs.into_iter().enumerate() {
                let (r, s) = run.into_parts();
                agg.absorb(&s);
                per_shard[i] += s.device_calls();
                results.extend(r);
            }
            if queue.is_empty() {
                break;
            }
            rec.ensure_survivor()?;
        }
        agg.steal_count = queue.steals();
        agg.shard_device_calls = per_shard;
        self.clock_end(&mut agg, t0, &busy0);
        results.sort_by_key(|r| r.id);
        Ok((results, agg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::cache::CacheEntry;
    use crate::testing::mock::MockEngine;
    use crate::tokenizer::BOS;

    fn task(id: usize, prefix_len: usize) -> SeqTask {
        SeqTask {
            id,
            prompt: vec![BOS, 5],
            prefix: vec![7; prefix_len],
            prefix_logps: vec![-1.0; prefix_len],
        }
    }

    fn draft(id: usize, len: usize) -> VerifyTask {
        VerifyTask {
            id,
            prompt: vec![BOS, 5],
            entry: CacheEntry {
                response: vec![7; len],
                logps: vec![-1.0; len],
                version: 0,
                finished: false,
            },
        }
    }

    #[test]
    fn static_placement_is_lpt_and_deterministic() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        // remainders (gen_len = 8): id0 -> 8, id1 -> 6, id2 -> 5, id3 -> 1
        let tasks = vec![task(0, 0), task(1, 2), task(2, 3), task(3, 7)];
        let placed = pool.place(tasks, Vec::new());
        // LPT greedy: 8 -> shard0, 6 -> shard1, 5 -> shard1 (6 < 8),
        // 1 -> shard0 (8 < 11)
        let ids = |s: usize| placed[s].0.iter().map(|t| t.id).collect::<Vec<_>>();
        assert_eq!(ids(0), vec![0, 3]);
        assert_eq!(ids(1), vec![1, 2]);
    }

    #[test]
    fn static_drafts_and_tasks_share_one_spill_queue() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        // expected remainders: task2 -> 8, draft0 -> 7, draft1 -> 6,
        // task3 -> 5; greedy LPT lands the tasks on shard 0 and both
        // drafts on shard 1 (loads 13 / 13).
        let placed =
            pool.place(vec![task(2, 0), task(3, 3)], vec![draft(0, 1), draft(1, 2)]);
        assert_eq!(placed[0].0.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(placed[0].1.is_empty());
        assert_eq!(placed[1].1.iter().map(|d| d.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(placed[1].0.is_empty());
    }

    #[test]
    fn empty_pool_is_rejected() {
        let mocks: Vec<MockEngine> = Vec::new();
        assert!(EnginePool::new(mocks.iter(), "mock").is_err());
    }

    #[test]
    fn mismatched_geometry_is_rejected() {
        let a = MockEngine::new(2, 8, 16, 16);
        let b = MockEngine::new(4, 8, 16, 16);
        assert!(EnginePool::new([&a, &b], "mock").is_err());
    }

    #[test]
    fn blob_count_must_match_shards() {
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let blob = mocks[0].blob();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut timer = StageTimer::new();
        let err = pool.run_pipeline(
            &[&blob],
            vec![task(0, 0)],
            Vec::new(),
            0.0,
            SampleCfg::default(),
            1,
            2,
            &mut timer,
        );
        assert!(err.is_err());
    }

    #[test]
    fn steal_tail_goes_to_free_engines_and_results_match_static() {
        // 2 shards x 2 slots, 7 fresh tasks with skewed remainders: the
        // 3-task tail beyond the 4 initial seats is stolen mid-step, and
        // both disciplines produce identical id-sorted results.
        let mocks = MockEngine::replicas(2, 2, 8, 16, 16);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut timer = StageTimer::new();
        let tasks = || (0..7).map(|i| task(i, i)).collect::<Vec<_>>();

        let (steal_res, steal_stats) = pool
            .run_pipeline_with(
                Placement::Steal,
                &blob_refs,
                tasks(),
                Vec::new(),
                0.0,
                SampleCfg::default(),
                11,
                12,
                &LenEstimates::off(),
                &mut timer,
            )
            .unwrap();
        let (static_res, static_stats) = pool
            .run_pipeline_with(
                Placement::Static,
                &blob_refs,
                tasks(),
                Vec::new(),
                0.0,
                SampleCfg::default(),
                11,
                12,
                &LenEstimates::off(),
                &mut timer,
            )
            .unwrap();

        assert_eq!(steal_res.len(), 7);
        for (a, b) in steal_res.iter().zip(&static_res) {
            assert_eq!((a.id, &a.response, &a.logps), (b.id, &b.response, &b.logps));
        }
        assert!(steal_stats.steal_count > 0, "{steal_stats:?}");
        assert_eq!(static_stats.steal_count, 0, "static placement never steals");
        assert_eq!(steal_stats.shard_device_calls.len(), 2);
        assert_eq!(
            steal_stats.new_tokens, static_stats.new_tokens,
            "same tokens either way"
        );
    }

    #[test]
    fn idle_shards_of_an_oversized_pool_cost_nothing() {
        // 4 shards x 2 slots but only one 1-token task: shards that find
        // the queue empty at start must make zero device calls.
        let mocks = MockEngine::replicas(4, 2, 8, 16, 16);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut timer = StageTimer::new();
        let (res, stats) = pool
            .run_pipeline(
                &blob_refs,
                vec![task(0, 7)],
                Vec::new(),
                0.0,
                SampleCfg::default(),
                3,
                4,
                &mut timer,
            )
            .unwrap();
        assert_eq!(res.len(), 1);
        assert_eq!(stats.shard_device_calls.len(), 4);
        assert!(!mocks[0].counters().calls.is_empty(), "shard 0 ran the task");
        for (i, m) in mocks.iter().enumerate().skip(1) {
            assert_eq!(m.counters().calls.len(), 0, "shard {i} should be idle");
            assert_eq!(stats.shard_device_calls[i], 0);
        }
    }
}
