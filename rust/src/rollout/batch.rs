//! Sequence tasks, results, and canonical [B, T] batch packing.

use crate::tokenizer::{EOS, PAD};

/// One sequence to produce: a prompt plus an optional verified prefix to
/// resume from (SPEC-RL reuse). `prefix` tokens count as response tokens.
#[derive(Clone, Debug)]
pub struct SeqTask {
    /// Caller-chosen id (cache key); results carry it back.
    pub id: usize,
    /// BOS + prompt token ids (≤ prompt_len).
    pub prompt: Vec<i32>,
    /// Already-accepted response prefix (possibly empty; may end in EOS).
    pub prefix: Vec<i32>,
    /// Current-policy log-probs of the prefix tokens (from verification).
    pub prefix_logps: Vec<f32>,
}

impl SeqTask {
    pub fn fresh(id: usize, prompt: Vec<i32>) -> Self {
        SeqTask { id, prompt, prefix: Vec::new(), prefix_logps: Vec::new() }
    }

    /// Prefix already terminates the sequence (fully reused finished draft).
    pub fn prefix_is_terminal(&self, gen_len: usize) -> bool {
        self.prefix.last() == Some(&EOS) || self.prefix.len() >= gen_len
    }
}

/// A finished sequence.
#[derive(Clone, Debug)]
pub struct SeqResult {
    pub id: usize,
    /// Full response (reused prefix + newly decoded), incl. EOS if emitted.
    pub response: Vec<i32>,
    /// Per-response-token log-probs under the *current* policy.
    pub logps: Vec<f32>,
    /// How many leading tokens were reused from the draft.
    pub reused: usize,
    /// Newly decoded tokens (== response.len() - reused).
    pub new_tokens: usize,
    /// EOS emitted (vs length cap).
    pub finished: bool,
}

/// Canonical [B, T] packing for one batch of rows.
///
/// Logical length is tracked incrementally per row (`prompt_tokens` +
/// `resp_len`), so [`BatchLayout::n_valid`] is O(1) — the decode loop calls
/// it per row per step and must not rescan the `[B, T]` mask. Rows are
/// individually resettable ([`BatchLayout::set_row`]) so the continuous
/// scheduler can refill one slot without disturbing its neighbours.
pub struct BatchLayout {
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub tokens: Vec<i32>,
    pub valid: Vec<f32>,
    /// Per-row last real slot (P + resp_len - 1, or P-1 when resp empty).
    pub last: Vec<i32>,
    /// Per-row current response length.
    pub resp_len: Vec<usize>,
    /// Per-row prompt token count (logical prompt length).
    pub prompt_tokens: Vec<usize>,
    /// Per-row active flag (false for filler rows of a partial batch).
    pub active: Vec<bool>,
}

impl BatchLayout {
    /// All-inert layout (every row filler).
    pub fn new(batch: usize, prompt_len: usize, total_len: usize) -> Self {
        BatchLayout {
            batch,
            prompt_len,
            total_len,
            tokens: vec![PAD; batch * total_len],
            valid: vec![0.0; batch * total_len],
            last: vec![(prompt_len - 1) as i32; batch],
            resp_len: vec![0; batch],
            prompt_tokens: vec![0; batch],
            active: vec![false; batch],
        }
    }

    /// Pack up to `batch` tasks. Rows beyond `tasks.len()` are inert
    /// filler (all-invalid; never sampled).
    pub fn pack(tasks: &[SeqTask], batch: usize, prompt_len: usize, total_len: usize) -> Self {
        assert!(tasks.len() <= batch);
        let mut l = BatchLayout::new(batch, prompt_len, total_len);
        for (r, task) in tasks.iter().enumerate() {
            l.set_row(r, &task.prompt, &task.prefix);
        }
        l
    }

    /// Reset every row to inert filler, keeping the allocations.
    pub fn clear(&mut self) {
        self.tokens.fill(PAD);
        self.valid.fill(0.0);
        self.last.fill((self.prompt_len - 1) as i32);
        self.resp_len.fill(0);
        self.prompt_tokens.fill(0);
        self.active.fill(false);
    }

    /// Reset row `r` to inert filler.
    pub fn clear_row(&mut self, r: usize) {
        let row = r * self.total_len;
        self.tokens[row..row + self.total_len].fill(PAD);
        self.valid[row..row + self.total_len].fill(0.0);
        self.last[r] = (self.prompt_len - 1) as i32;
        self.resp_len[r] = 0;
        self.prompt_tokens[r] = 0;
        self.active[r] = false;
    }

    /// (Re)pack row `r` from a prompt + response prefix, replacing whatever
    /// occupied it. The per-row reset path behind slot refills.
    pub fn set_row(&mut self, r: usize, prompt: &[i32], prefix: &[i32]) {
        assert!(
            prompt.len() <= self.prompt_len,
            "prompt {} tokens > prompt_len {}",
            prompt.len(),
            self.prompt_len
        );
        let gen_len = self.total_len - self.prompt_len;
        assert!(prefix.len() <= gen_len);
        self.clear_row(r);
        let row = r * self.total_len;
        let start = self.prompt_len - prompt.len();
        for (i, &t) in prompt.iter().enumerate() {
            self.tokens[row + start + i] = t;
            self.valid[row + start + i] = 1.0;
        }
        for (i, &t) in prefix.iter().enumerate() {
            self.tokens[row + self.prompt_len + i] = t;
            self.valid[row + self.prompt_len + i] = 1.0;
        }
        self.prompt_tokens[r] = prompt.len();
        self.resp_len[r] = prefix.len();
        self.last[r] = (self.prompt_len + prefix.len()) as i32 - 1;
        self.active[r] = true;
    }

    /// Append a sampled token to row `r` (updates tokens/valid/last).
    /// Returns the physical slot written.
    pub fn push_token(&mut self, r: usize, token: i32) -> usize {
        let slot = self.prompt_len + self.resp_len[r];
        assert!(slot < self.total_len, "row {r} overflow");
        self.tokens[r * self.total_len + slot] = token;
        self.valid[r * self.total_len + slot] = 1.0;
        self.resp_len[r] += 1;
        self.last[r] = slot as i32;
        slot
    }

    /// Number of valid tokens in row `r` (logical length). O(1): tracked
    /// incrementally, never rescanned from the mask.
    pub fn n_valid(&self, r: usize) -> usize {
        self.prompt_tokens[r] + self.resp_len[r]
    }

    /// Extract row `r`'s response tokens.
    pub fn response(&self, r: usize) -> Vec<i32> {
        let row = r * self.total_len;
        (0..self.resp_len[r]).map(|i| self.tokens[row + self.prompt_len + i]).collect()
    }

    /// Extract row `r`'s prompt tokens (right-aligned region, in logical
    /// order). The dead-shard requeue path rebuilds a seated row's
    /// original task from this plus [`BatchLayout::response`].
    pub fn prompt(&self, r: usize) -> Vec<i32> {
        let row = r * self.total_len;
        let start = self.prompt_len - self.prompt_tokens[r];
        (0..self.prompt_tokens[r]).map(|i| self.tokens[row + start + i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BOS;

    fn task(id: usize, p: &[i32], pre: &[i32]) -> SeqTask {
        SeqTask {
            id,
            prompt: p.to_vec(),
            prefix: pre.to_vec(),
            prefix_logps: vec![-1.0; pre.len()],
        }
    }

    #[test]
    fn pack_right_aligns_prompts() {
        let t = task(0, &[BOS, 10, 11], &[]);
        let l = BatchLayout::pack(&[t], 2, 8, 16);
        // slots 5,6,7 hold the prompt
        assert_eq!(&l.tokens[5..8], &[BOS, 10, 11]);
        assert_eq!(&l.valid[..5], &[0.0; 5]);
        assert_eq!(l.last[0], 7);
        // filler row inert
        assert!(!l.active[1]);
        assert!(l.valid[16..32].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pack_places_prefix_in_response_region() {
        let t = task(0, &[BOS, 10], &[20, 21, 22]);
        let l = BatchLayout::pack(&[t], 1, 8, 16);
        assert_eq!(&l.tokens[8..11], &[20, 21, 22]);
        assert_eq!(l.resp_len[0], 3);
        assert_eq!(l.last[0], 10);
        assert_eq!(l.response(0), vec![20, 21, 22]);
    }

    #[test]
    fn push_token_advances() {
        let t = task(0, &[BOS], &[]);
        let mut l = BatchLayout::pack(&[t], 1, 8, 16);
        let s1 = l.push_token(0, 30);
        let s2 = l.push_token(0, 31);
        assert_eq!((s1, s2), (8, 9));
        assert_eq!(l.response(0), vec![30, 31]);
        assert_eq!(l.n_valid(0), 3);
    }

    #[test]
    fn pack_then_unpack_is_identity() {
        // invariant 7 in DESIGN.md
        let tasks = vec![
            task(0, &[BOS, 5, 6, 7], &[40, 41]),
            task(1, &[BOS, 9], &[]),
        ];
        let l = BatchLayout::pack(&tasks, 4, 8, 20);
        for (r, t) in tasks.iter().enumerate() {
            assert_eq!(l.response(r), t.prefix);
            let row = r * 20;
            let start = 8 - t.prompt.len();
            let got: Vec<i32> = (0..t.prompt.len()).map(|i| l.tokens[row + start + i]).collect();
            assert_eq!(got, t.prompt);
        }
    }

    #[test]
    fn n_valid_matches_mask_scan() {
        let tasks = vec![
            task(0, &[BOS, 5, 6], &[40, 41, 42]),
            task(1, &[BOS], &[]),
        ];
        let mut l = BatchLayout::pack(&tasks, 3, 8, 20);
        l.push_token(0, 7);
        l.push_token(1, 9);
        for r in 0..3 {
            let scanned = l.valid[r * 20..(r + 1) * 20].iter().filter(|&&v| v > 0.5).count();
            assert_eq!(l.n_valid(r), scanned, "row {r}");
        }
    }

    #[test]
    fn set_row_replaces_occupant_completely() {
        let mut l = BatchLayout::pack(&[task(0, &[BOS, 4, 5], &[30, 31])], 2, 8, 16);
        l.push_token(0, 32);
        l.set_row(0, &[BOS, 9], &[]);
        assert_eq!(l.n_valid(0), 2);
        assert_eq!(l.resp_len[0], 0);
        assert_eq!(l.last[0], 7);
        assert_eq!(l.response(0), Vec::<i32>::new());
        // no stale tokens/valid anywhere in the row
        let scanned = l.valid[..16].iter().filter(|&&v| v > 0.5).count();
        assert_eq!(scanned, 2);
        assert_eq!(&l.tokens[6..8], &[BOS, 9]);
        assert!(l.tokens[8..16].iter().all(|&t| t == PAD));
    }

    #[test]
    fn clear_keeps_capacity_and_inerts_rows() {
        let mut l = BatchLayout::pack(&[task(0, &[BOS, 4], &[30])], 2, 8, 16);
        l.clear();
        assert!(!l.active[0]);
        assert_eq!(l.n_valid(0), 0);
        assert!(l.valid.iter().all(|&v| v == 0.0));
        assert_eq!(l.tokens.len(), 32);
    }

    #[test]
    fn terminal_prefix_detection() {
        let mut t = task(0, &[BOS], &[40, EOS]);
        assert!(t.prefix_is_terminal(48));
        t.prefix = vec![40, 41];
        assert!(!t.prefix_is_terminal(48));
        t.prefix = vec![7; 48];
        assert!(t.prefix_is_terminal(48));
    }

    #[test]
    #[should_panic]
    fn oversized_prompt_panics() {
        let t = task(0, &[1; 20], &[]);
        BatchLayout::pack(&[t], 1, 8, 16);
    }
}
