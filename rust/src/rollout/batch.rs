//! Sequence tasks, results, and canonical [B, T] batch packing.

use crate::tokenizer::{EOS, PAD};

/// One sequence to produce: a prompt plus an optional verified prefix to
/// resume from (SPEC-RL reuse). `prefix` tokens count as response tokens.
#[derive(Clone, Debug)]
pub struct SeqTask {
    /// Caller-chosen id (cache key); results carry it back.
    pub id: usize,
    /// BOS + prompt token ids (≤ prompt_len).
    pub prompt: Vec<i32>,
    /// Already-accepted response prefix (possibly empty; may end in EOS).
    pub prefix: Vec<i32>,
    /// Current-policy log-probs of the prefix tokens (from verification).
    pub prefix_logps: Vec<f32>,
}

impl SeqTask {
    pub fn fresh(id: usize, prompt: Vec<i32>) -> Self {
        SeqTask { id, prompt, prefix: Vec::new(), prefix_logps: Vec::new() }
    }

    /// Prefix already terminates the sequence (fully reused finished draft).
    pub fn prefix_is_terminal(&self, gen_len: usize) -> bool {
        self.prefix.last() == Some(&EOS) || self.prefix.len() >= gen_len
    }
}

/// A finished sequence.
#[derive(Clone, Debug)]
pub struct SeqResult {
    pub id: usize,
    /// Full response (reused prefix + newly decoded), incl. EOS if emitted.
    pub response: Vec<i32>,
    /// Per-response-token log-probs under the *current* policy.
    pub logps: Vec<f32>,
    /// How many leading tokens were reused from the draft.
    pub reused: usize,
    /// Newly decoded tokens (== response.len() - reused).
    pub new_tokens: usize,
    /// EOS emitted (vs length cap).
    pub finished: bool,
}

/// Canonical [B, T] packing for one wave.
pub struct BatchLayout {
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub tokens: Vec<i32>,
    pub valid: Vec<f32>,
    /// Per-row last real slot (P + resp_len - 1, or P-1 when resp empty).
    pub last: Vec<i32>,
    /// Per-row current response length.
    pub resp_len: Vec<usize>,
    /// Per-row active flag (false for filler rows of a partial wave).
    pub active: Vec<bool>,
}

impl BatchLayout {
    /// Pack up to `batch` tasks. Rows beyond `tasks.len()` are inert
    /// filler (all-invalid; never sampled).
    pub fn pack(tasks: &[SeqTask], batch: usize, prompt_len: usize, total_len: usize) -> Self {
        assert!(tasks.len() <= batch);
        let mut l = BatchLayout {
            batch,
            prompt_len,
            total_len,
            tokens: vec![PAD; batch * total_len],
            valid: vec![0.0; batch * total_len],
            last: vec![(prompt_len - 1) as i32; batch],
            resp_len: vec![0; batch],
            active: vec![false; batch],
        };
        for (r, task) in tasks.iter().enumerate() {
            assert!(
                task.prompt.len() <= prompt_len,
                "prompt {} tokens > prompt_len {}",
                task.prompt.len(),
                prompt_len
            );
            let gen_len = total_len - prompt_len;
            assert!(task.prefix.len() <= gen_len);
            let row = r * total_len;
            let start = prompt_len - task.prompt.len();
            for (i, &t) in task.prompt.iter().enumerate() {
                l.tokens[row + start + i] = t;
                l.valid[row + start + i] = 1.0;
            }
            for (i, &t) in task.prefix.iter().enumerate() {
                l.tokens[row + prompt_len + i] = t;
                l.valid[row + prompt_len + i] = 1.0;
            }
            l.resp_len[r] = task.prefix.len();
            l.last[r] = (prompt_len + task.prefix.len()) as i32 - 1;
            l.active[r] = true;
        }
        l
    }

    /// Append a sampled token to row `r` (updates tokens/valid/last).
    /// Returns the physical slot written.
    pub fn push_token(&mut self, r: usize, token: i32) -> usize {
        let slot = self.prompt_len + self.resp_len[r];
        assert!(slot < self.total_len, "row {r} overflow");
        self.tokens[r * self.total_len + slot] = token;
        self.valid[r * self.total_len + slot] = 1.0;
        self.resp_len[r] += 1;
        self.last[r] = slot as i32;
        slot
    }

    /// Number of valid tokens in row `r` (logical length).
    pub fn n_valid(&self, r: usize) -> usize {
        let row = &self.valid[r * self.total_len..(r + 1) * self.total_len];
        row.iter().filter(|&&v| v > 0.5).count() as usize
    }

    /// Extract row `r`'s response tokens.
    pub fn response(&self, r: usize) -> Vec<i32> {
        let row = r * self.total_len;
        (0..self.resp_len[r]).map(|i| self.tokens[row + self.prompt_len + i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BOS;

    fn task(id: usize, p: &[i32], pre: &[i32]) -> SeqTask {
        SeqTask {
            id,
            prompt: p.to_vec(),
            prefix: pre.to_vec(),
            prefix_logps: vec![-1.0; pre.len()],
        }
    }

    #[test]
    fn pack_right_aligns_prompts() {
        let t = task(0, &[BOS, 10, 11], &[]);
        let l = BatchLayout::pack(&[t], 2, 8, 16);
        // slots 5,6,7 hold the prompt
        assert_eq!(&l.tokens[5..8], &[BOS, 10, 11]);
        assert_eq!(&l.valid[..5], &[0.0; 5]);
        assert_eq!(l.last[0], 7);
        // filler row inert
        assert!(!l.active[1]);
        assert!(l.valid[16..32].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pack_places_prefix_in_response_region() {
        let t = task(0, &[BOS, 10], &[20, 21, 22]);
        let l = BatchLayout::pack(&[t], 1, 8, 16);
        assert_eq!(&l.tokens[8..11], &[20, 21, 22]);
        assert_eq!(l.resp_len[0], 3);
        assert_eq!(l.last[0], 10);
        assert_eq!(l.response(0), vec![20, 21, 22]);
    }

    #[test]
    fn push_token_advances() {
        let t = task(0, &[BOS], &[]);
        let mut l = BatchLayout::pack(&[t], 1, 8, 16);
        let s1 = l.push_token(0, 30);
        let s2 = l.push_token(0, 31);
        assert_eq!((s1, s2), (8, 9));
        assert_eq!(l.response(0), vec![30, 31]);
        assert_eq!(l.n_valid(0), 3);
    }

    #[test]
    fn pack_then_unpack_is_identity() {
        // invariant 7 in DESIGN.md
        let tasks = vec![
            task(0, &[BOS, 5, 6, 7], &[40, 41]),
            task(1, &[BOS, 9], &[]),
        ];
        let l = BatchLayout::pack(&tasks, 4, 8, 20);
        for (r, t) in tasks.iter().enumerate() {
            assert_eq!(l.response(r), t.prefix);
            let row = r * 20;
            let start = 8 - t.prompt.len();
            let got: Vec<i32> = (0..t.prompt.len()).map(|i| l.tokens[row + start + i]).collect();
            assert_eq!(got, t.prompt);
        }
    }

    #[test]
    fn terminal_prefix_detection() {
        let mut t = task(0, &[BOS], &[40, EOS]);
        assert!(t.prefix_is_terminal(48));
        t.prefix = vec![40, 41];
        assert!(!t.prefix_is_terminal(48));
        t.prefix = vec![7; 48];
        assert!(t.prefix_is_terminal(48));
    }

    #[test]
    #[should_panic]
    fn oversized_prompt_panics() {
        let t = task(0, &[1; 20], &[]);
        BatchLayout::pack(&[t], 1, 8, 16);
    }
}
