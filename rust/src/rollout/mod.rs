//! The phase-aware batched rollout engine — the vLLM substitute.
//!
//! Processes one step's sequences through the explicit lifecycle
//! `Draft -> Verify -> Decode -> Done` over a **single continuous-batching
//! slot pool** ([`sched`]): all `batch` physical rows stay busy, a
//! finished row's slot is refilled with the next pending decode task via
//! the masked `refill` entry *or* seated with the next pending draft via
//! the `verify_seat` entry, which verifies the draft and reuses its
//! teacher-forced forward's KV as the continuation's cache in the same
//! call. Fresh prompts decode from the first step while drafts verify in
//! packed sub-batches beside them — there is no global verify barrier, and
//! a verified row pays no refill forward.
//!
//! Per-decode-step host→device traffic is three `[B]` vectors plus the
//! sampler ctrl block (the `[B, T]` valid mask is maintained device-side
//! inside the generation blob — full contract in `sched.rs`); the
//! per-step readback is the fused `[B tok | B ptok | B aux]` of the
//! `read_step` entry — sampling happens on device (`ARCHITECTURE.md`
//! §12), so the `[B*V probs | B aux]` payload of `read_gen` is read only
//! on the host-sampling oracle/fallback path.
//!
//! Two oracles are retained, both byte-identical to the pipeline thanks to
//! per-task sampling and verification RNG streams:
//! [`engine::RolloutEngine::run_lockstep`] (the pre-scheduler wave
//! discipline) pins down decode scheduling, and
//! [`crate::spec::SpecRollout::run_two_phase`] (blocking verify wave, then
//! decode) pins down phase interleaving.
//!
//! Fully-reused terminal drafts (SPEC-RL full reuse) never occupy a slot —
//! they bypass decode entirely, which is what makes the paper's wall-clock
//! speedups reachable.
//!
//! Above the single-engine pipeline sits [`pool::EnginePool`]: `N`
//! backends, one slot pool each, all pulling from one shared
//! [`sched::WorkQueue`] (the steal-queue): unstarted work drains LPT-first
//! to whichever engine has free slots, mid-step included, while a row's
//! whole lifecycle stays pinned to the engine that seated it so KV never
//! migrates. The pool drives each round in two phases
//! ([`engine::RolloutEngine::step_submit`] /
//! [`engine::RolloutEngine::step_complete`]): every live shard's device
//! chain is submitted before any shard's readback blocks the host, so
//! engine forwards run concurrently instead of host-serialized —
//! `PipelineStats::overlap_makespan` vs `serial_makespan` quantifies the
//! win on the mock's virtual clock (`bench_overlap`). Per-task sampling
//! and verification RNG streams make results byte-identical for any shard
//! count, either placement discipline, and any `verify_seat_min` — see
//! `ARCHITECTURE.md` for the full contract set.
//!
//! Canonical layout (shared with L2): prompts right-aligned into slots
//! `[0, P)`, responses in `[P, T)`; positional embeddings are logical
//! (mask-cumsum) so physical padding is invisible to the model.

pub mod batch;
pub mod engine;
pub mod pool;
pub mod predict;
pub mod sched;

pub use batch::{BatchLayout, SeqResult, SeqTask};
pub use engine::{
    PipelineRun, PipelineStats, RolloutEngine, RolloutStats, SampleCfg, StepTicket,
};
pub use pool::{EnginePool, Placement};
pub use predict::{LenEstimates, LenPredictor};
pub use sched::{SlotPhase, SlotScheduler, WorkQueue};
