//! The batched rollout engine — the vLLM substitute.
//!
//! Processes a queue of sequence tasks (prompt + optional reused prefix) in
//! *waves* of at most `batch` rows. Within a wave all rows decode in
//! lockstep on the static-shape AOT executables; rows finish independently
//! (EOS or length cap) and finished rows become inert (their K/V writes
//! vanish into masked slots).
//!
//! Wave scheduling: tasks are sorted by descending prefix length before
//! being split into waves, so rows with similar *remaining* generation
//! lengths share a wave. This is what makes wall-clock track generated
//! tokens the way a continuous-batching engine does — a wave of
//! fully-reused drafts costs zero decode steps. (Without it, one
//! zero-prefix row would pin every wave at `gen_len` steps and the paper's
//! wall-clock speedups would be structurally unreachable on a lockstep
//! engine; see DESIGN.md.)
//!
//! Canonical layout (shared with L2): prompts right-aligned into slots
//! `[0, P)`, responses in `[P, T)`; positional embeddings are logical
//! (mask-cumsum) so physical padding is invisible to the model.

pub mod batch;
pub mod engine;

pub use batch::{BatchLayout, SeqResult, SeqTask};
pub use engine::{RolloutEngine, RolloutStats, SampleCfg};
