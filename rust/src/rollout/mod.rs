//! The batched rollout engine — the vLLM substitute.
//!
//! Processes a queue of sequence tasks (prompt + optional reused prefix)
//! with a **continuous-batching slot scheduler** ([`sched`]): all `batch`
//! physical rows stay busy, a finished row's slot is refilled with the
//! next pending task via the masked `refill` entry, and per-decode-step
//! host→device traffic is three `[B]` vectors (the `[B, T]` valid mask is
//! maintained device-side inside the generation blob — contract in
//! `sched.rs`). A wave-lockstep path ([`engine::RolloutEngine::run_lockstep`])
//! is retained as the equivalence oracle and scheduler baseline; per-task
//! RNG streams make the two produce byte-identical results.
//!
//! Fully-reused terminal drafts (SPEC-RL full reuse) never occupy a slot —
//! they bypass decode entirely, which is what makes the paper's wall-clock
//! speedups reachable.
//!
//! Canonical layout (shared with L2): prompts right-aligned into slots
//! `[0, P)`, responses in `[P, T)`; positional embeddings are logical
//! (mask-cumsum) so physical padding is invisible to the model.

pub mod batch;
pub mod engine;
pub mod sched;

pub use batch::{BatchLayout, SeqResult, SeqTask};
pub use engine::{RolloutEngine, RolloutStats, SampleCfg};
pub use sched::SlotScheduler;
