//! Typed view of `artifacts/manifest.json` (written by `python -m
//! compile.aot`). The manifest is the single contract between the python
//! compile path and this runtime: shapes, blob sizes, output field offsets,
//! file names, vocabulary.
//!
//! Decode-entry contract (since the continuous-batching scheduler): the
//! generation blob is `[cache_k | cache_v | valid | probs]` — the `[B, T]`
//! valid mask is device-resident state. `prefill` seeds it, `decode`
//! extends it from the per-step `slot` vector (no mask upload per step),
//! and the `refill` entry re-seats a masked subset of rows. The full
//! contract is documented in `rollout/sched.rs`; bundles lowered before
//! this contract lack the `refill` entry and must be re-exported.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

/// Transformer hyperparameters of a lowered bundle.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

/// One input argument of an entry point.
#[derive(Clone, Debug)]
pub struct ArgInfo {
    pub name: String,
    /// "f32" or "i32"
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One named field inside an entry's flat output.
#[derive(Clone, Debug)]
pub struct FieldInfo {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl FieldInfo {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub file: String,
    pub inputs: Vec<ArgInfo>,
    pub output_size: usize,
    pub output_fields: Vec<FieldInfo>,
}

impl EntryInfo {
    pub fn field(&self, name: &str) -> &FieldInfo {
        self.output_fields
            .iter()
            .find(|f| f.name == name)
            .unwrap_or_else(|| panic!("entry has no output field '{name}'"))
    }
}

/// One (model, batch) bundle.
#[derive(Clone, Debug)]
pub struct BundleInfo {
    pub model: ModelInfo,
    pub batch: usize,
    pub value_head: bool,
    pub n_params: usize,
    pub blob_size: usize,
    pub gen_blob_size: usize,
    pub init_blob: String,
    pub entries: BTreeMap<String, EntryInfo>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub charset: String,
    pub vocab: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub hp_names: Vec<String>,
    pub metric_slots: Vec<String>,
    pub use_pallas: bool,
    pub bundles: BTreeMap<String, BundleInfo>,
}

impl Manifest {
    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }

    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let geo = j.req("geometry");
        let mut bundles = BTreeMap::new();
        for (bname, bj) in j.req("bundles").as_obj().context("bundles")? {
            bundles.insert(bname.clone(), parse_bundle(bj)?);
        }
        Ok(Manifest {
            dir,
            charset: j.req("charset").as_str().unwrap_or_default().to_string(),
            vocab: j.req("vocab").as_usize().context("vocab")?,
            prompt_len: geo.req("prompt_len").as_usize().context("prompt_len")?,
            total_len: geo.req("total_len").as_usize().context("total_len")?,
            hp_names: str_arr(j.req("hp_names")),
            metric_slots: str_arr(j.req("metric_slots")),
            use_pallas: j.req("use_pallas").as_bool().unwrap_or(true),
            bundles,
        })
    }

    /// Bundle by name, e.g. "tiny_b32".
    pub fn bundle(&self, name: &str) -> Result<&BundleInfo> {
        self.bundles.get(name).with_context(|| {
            format!(
                "bundle '{name}' not in manifest (have: {:?}); re-run `make artifacts MODELS=...`",
                self.bundles.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Index of a metric slot by name (e.g. "loss").
    pub fn metric_index(&self, name: &str) -> usize {
        self.metric_slots
            .iter()
            .position(|s| s == name)
            .unwrap_or_else(|| panic!("unknown metric slot '{name}'"))
    }
}

fn str_arr(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|v| v.iter().filter_map(|x| x.as_str().map(|s| s.to_string())).collect())
        .unwrap_or_default()
}

fn parse_bundle(bj: &Json) -> Result<BundleInfo> {
    let mj = bj.req("model");
    let model = ModelInfo {
        name: mj.req("name").as_str().unwrap_or_default().to_string(),
        n_layers: mj.req("n_layers").as_usize().context("n_layers")?,
        d_model: mj.req("d_model").as_usize().context("d_model")?,
        n_heads: mj.req("n_heads").as_usize().context("n_heads")?,
        d_ff: mj.req("d_ff").as_usize().context("d_ff")?,
        vocab: mj.req("vocab").as_usize().context("vocab")?,
    };
    let mut entries = BTreeMap::new();
    for (ename, ej) in bj.req("entries").as_obj().context("entries")? {
        let inputs = ej
            .req("inputs")
            .as_arr()
            .context("inputs")?
            .iter()
            .map(|a| ArgInfo {
                name: a.req("name").as_str().unwrap_or_default().to_string(),
                dtype: a.req("dtype").as_str().unwrap_or_default().to_string(),
                shape: a.req("shape").usize_arr(),
            })
            .collect();
        let output_fields = ej
            .req("output_fields")
            .as_arr()
            .context("output_fields")?
            .iter()
            .map(|f| FieldInfo {
                name: f.req("name").as_str().unwrap_or_default().to_string(),
                offset: f.req("offset").as_usize().unwrap_or(0),
                shape: f.req("shape").usize_arr(),
            })
            .collect();
        entries.insert(
            ename.clone(),
            EntryInfo {
                file: ej.req("file").as_str().unwrap_or_default().to_string(),
                inputs,
                output_size: ej.req("output_size").as_usize().unwrap_or(0),
                output_fields,
            },
        );
    }
    Ok(BundleInfo {
        model,
        batch: bj.req("batch").as_usize().context("batch")?,
        value_head: bj.req("value_head").as_bool().unwrap_or(false),
        n_params: bj.req("n_params").as_usize().context("n_params")?,
        blob_size: bj.req("blob_size").as_usize().context("blob_size")?,
        gen_blob_size: bj.req("gen_blob_size").as_usize().unwrap_or(0),
        init_blob: bj.req("init_blob").as_str().unwrap_or_default().to_string(),
        entries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !manifest_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.vocab, 51);
        assert!(m.total_len > m.prompt_len);
        let b = m.bundle("tiny_b32").unwrap();
        assert_eq!(b.batch, 32);
        assert!(b.entries.contains_key("verify"));
        let v = &b.entries["verify"];
        assert_eq!(v.field("reject_off").offset, 0);
        assert_eq!(v.field("logp").offset, b.batch);
    }

    #[test]
    fn unknown_bundle_is_error() {
        if !manifest_available() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.bundle("no_such").is_err());
    }
}
