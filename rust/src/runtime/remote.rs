//! Remote backends: the [`Backend`] contract over a wire
//! (`ARCHITECTURE.md` §13).
//!
//! [`RemoteBackend`] is a [`Backend`] whose entry calls ship over a
//! pluggable [`Transport`]. The design rule is **handles cross the wire,
//! buffers do not**: every device buffer lives on the remote side and is
//! named here by an opaque `u64` handle ([`RemoteBuf`]), so the
//! generation blob — the big `[ck | cv | valid | probs | aux]` state the
//! decode loop chains through every round — never round-trips per call.
//! The host only ever moves the small things it always moved: token
//! vectors up, `O(B)` readbacks down.
//!
//! ## The transport contract
//!
//! A [`Transport`] is five data-plane operations (`upload_f32` /
//! `upload_i32` / `submit` / `complete` / `read_f32`) plus two
//! control-plane lookups (`resolve`, `shape`). The semantics that make
//! retries safe:
//!
//! - **Caller-assigned tickets.** `submit` takes a caller-chosen ticket
//!   id and is **idempotent**: if the transport has already executed a
//!   submit under that ticket (the classic dropped-ack failure — work
//!   applied, acknowledgement lost), resubmitting returns the recorded
//!   output handle *without re-running the forward*. This is what makes
//!   [`RemoteBackend`]'s retry loop safe: a retried submit can never
//!   double-apply a forward.
//! - **Idempotent completes.** `complete(ticket)` blocks until that
//!   ticket's forward is finished remotely; completing an
//!   already-complete ticket is a no-op `Ok`. A timed-out complete is
//!   therefore always retryable.
//! - **Submit is cheap, complete blocks.** `submit` only enqueues (the
//!   returned handle may name a still-executing forward, usable as an
//!   argument to further submits — device-side chaining exactly like
//!   [`Backend::pending_buf`]); `complete` is the one host-blocking
//!   point. This preserves the pool's overlapped shard stepping
//!   (`ARCHITECTURE.md` §11) across the wire.
//!
//! Retry policy lives in [`RemoteBackend`], not the transport: ticketed
//! operations (`submit`, `complete`) retry up to
//! `rollout.max_retries` times with `rollout.rpc_timeout_ms` per
//! complete; uploads and reads are *not* retried (they carry no ticket —
//! a failed upload just errors out to the pool, which handles it as a
//! shard failure, `ARCHITECTURE.md` §13).
//!
//! ## Loopback: offline testability
//!
//! [`Loopback`] is an in-process [`Transport`] wrapping any existing
//! [`Backend`] (the [`crate::testing::mock::MockEngine`] in tests): a
//! handle table maps `u64`s to inner buffers or in-flight pendings, and
//! the ticket table provides the idempotency the contract demands. It
//! also carries [`TransportFaults`] — dropped submit-acks, complete
//! timeouts, and a dead-peer cutoff — so the retry loop and the pool's
//! dead-shard recovery run as plain unit tests with zero network
//! dependencies. `RemoteBackend<Loopback<MockEngine>>` over any workload
//! is byte-identical to driving the `MockEngine` directly (pinned by
//! `rust/tests/remote_loopback.rs`), including the virtual-clock overlap
//! accounting, which the loopback forwards verbatim.
//!
//! Handles are never garbage-collected: the table grows for the lifetime
//! of the transport, like an arena. Real transports would add an
//! explicit release op; the rollout layer's buffer lifetimes are step-
//! scoped and small (handles are `u64`s — the *payloads* stay remote),
//! so the bookkeeping cost here is negligible for tests and benches.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::backend::{Backend, BatchShape};

/// Wire-level operations a [`RemoteBackend`] ships its calls over. See
/// the module docs for the contract (ticket idempotency, cheap submits,
/// blocking completes).
pub trait Transport {
    /// Resolve `bundle/entry` remotely; the returned token names the
    /// entry in subsequent [`Transport::submit`] calls.
    fn resolve(&self, bundle: &str, entry: &str) -> Result<String>;

    /// Remote bundle geometry.
    fn shape(&self, bundle: &str) -> Result<BatchShape>;

    /// Ship host floats to the remote side; returns the buffer handle.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<u64>;

    /// Ship host ints to the remote side; returns the buffer handle.
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<u64>;

    /// Enqueue one forward under a caller-assigned `ticket`; returns the
    /// output buffer handle (usable as an argument to further submits
    /// before completion). **Idempotent**: resubmitting a ticket the
    /// transport already executed returns the recorded handle without
    /// re-running the forward.
    fn submit(&self, ticket: u64, entry: &str, args: &[u64]) -> Result<u64>;

    /// Block until `ticket`'s forward finishes remotely (up to
    /// `timeout_ms`). **Idempotent**: completing a finished ticket is a
    /// no-op `Ok`.
    fn complete(&self, ticket: u64, timeout_ms: u64) -> Result<()>;

    /// Read a completed buffer's floats back into caller scratch.
    fn read_f32(&self, handle: u64, out: &mut Vec<f32>) -> Result<()>;

    /// Remote virtual clock, if the far side models one (the loopback
    /// forwards the wrapped backend's — overlap accounting keeps working
    /// through the wire).
    fn virtual_now(&self) -> Option<f64> {
        None
    }

    /// Remote cumulative forward time (see [`Backend::device_busy_secs`]).
    fn device_busy_secs(&self) -> f64 {
        0.0
    }
}

/// A remote buffer: just its handle. The payload never leaves the far
/// side; cloning a handle is free and aliases the same remote buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RemoteBuf {
    /// Transport-scoped buffer id.
    pub handle: u64,
}

/// An in-flight remote forward: the RPC ticket plus the output handle
/// the transport assigned at submit time — which is what lets
/// [`Backend::pending_buf`] hand out a chaining argument without any
/// round-trip.
#[derive(Debug)]
pub struct RemoteTicket {
    /// Caller-assigned submit ticket (the idempotency key).
    pub ticket: u64,
    buf: RemoteBuf,
}

/// Default RPC completion timeout (ms) — `rollout.rpc_timeout_ms`.
pub const DEFAULT_RPC_TIMEOUT_MS: u64 = 5_000;
/// Default retry budget per ticketed op — `rollout.max_retries`.
pub const DEFAULT_MAX_RETRIES: u64 = 2;

/// A [`Backend`] whose `Pending` is an RPC ticket and whose buffers are
/// remote handles. Generic over the [`Transport`]; the rollout layer
/// above cannot tell it from an in-process backend (byte-identical
/// outputs, pinned by `rust/tests/remote_loopback.rs`).
pub struct RemoteBackend<T: Transport> {
    transport: T,
    /// Monotone ticket source — ticket ids never repeat, so a transport
    /// can key its executed-submit table by them forever.
    next_ticket: Cell<u64>,
    timeout_ms: u64,
    max_retries: u64,
}

impl<T: Transport> RemoteBackend<T> {
    /// Wrap a transport with the default RPC knobs.
    pub fn new(transport: T) -> Self {
        RemoteBackend {
            transport,
            next_ticket: Cell::new(0),
            timeout_ms: DEFAULT_RPC_TIMEOUT_MS,
            max_retries: DEFAULT_MAX_RETRIES,
        }
    }

    /// Override the RPC knobs (`rollout.rpc_timeout_ms`,
    /// `rollout.max_retries`).
    pub fn with_rpc(mut self, timeout_ms: u64, max_retries: u64) -> Self {
        self.timeout_ms = timeout_ms;
        self.max_retries = max_retries;
        self
    }

    /// Borrow the transport (tests reach through to the loopback's fault
    /// and telemetry state).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    fn alloc_ticket(&self) -> u64 {
        let t = self.next_ticket.get();
        self.next_ticket.set(t + 1);
        t
    }
}

impl<T: Transport> Backend for RemoteBackend<T> {
    type Buf = RemoteBuf;
    type Entry = String;
    type Pending = RemoteTicket;

    fn resolve(&self, bundle: &str, entry: &str) -> Result<String> {
        self.transport.resolve(bundle, entry)
    }

    fn call_entry(&self, entry: &String, args: &[&RemoteBuf]) -> Result<RemoteBuf> {
        let pending = self.submit_entry(entry, args)?;
        self.complete(pending)
    }

    /// Submit with retry: the same ticket id is resubmitted on every
    /// attempt, so a transport that executed the forward but lost the
    /// ack returns the recorded handle instead of running it twice.
    fn submit_entry(&self, entry: &String, args: &[&RemoteBuf]) -> Result<RemoteTicket> {
        let handles: Vec<u64> = args.iter().map(|b| b.handle).collect();
        let ticket = self.alloc_ticket();
        let mut last: Option<anyhow::Error> = None;
        for _ in 0..=self.max_retries {
            match self.transport.submit(ticket, entry, &handles) {
                Ok(handle) => {
                    return Ok(RemoteTicket { ticket, buf: RemoteBuf { handle } })
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .expect("at least one submit attempt ran")
            .context(format!(
                "remote submit of '{entry}' (ticket {ticket}) failed after {} attempts",
                self.max_retries + 1
            )))
    }

    /// Complete with retry: completes are idempotent, so a timed-out
    /// attempt is safely reissued until the retry budget runs out.
    fn complete(&self, pending: RemoteTicket) -> Result<RemoteBuf> {
        let mut last: Option<anyhow::Error> = None;
        for _ in 0..=self.max_retries {
            match self.transport.complete(pending.ticket, self.timeout_ms) {
                Ok(()) => return Ok(pending.buf),
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .expect("at least one complete attempt ran")
            .context(format!(
                "remote complete of ticket {} failed after {} attempts",
                pending.ticket,
                self.max_retries + 1
            )))
    }

    fn pending_buf<'a>(&self, pending: &'a RemoteTicket) -> &'a RemoteBuf {
        &pending.buf
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<RemoteBuf> {
        Ok(RemoteBuf { handle: self.transport.upload_f32(data, dims)? })
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<RemoteBuf> {
        Ok(RemoteBuf { handle: self.transport.upload_i32(data, dims)? })
    }

    fn read_f32(&self, buf: &RemoteBuf) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.transport.read_f32(buf.handle, &mut out)?;
        Ok(out)
    }

    fn read_f32_into(&self, buf: &RemoteBuf, out: &mut Vec<f32>) -> Result<()> {
        self.transport.read_f32(buf.handle, out)
    }

    fn virtual_now(&self) -> Option<f64> {
        self.transport.virtual_now()
    }

    fn device_busy_secs(&self) -> f64 {
        self.transport.device_busy_secs()
    }

    fn shape(&self, bundle: &str) -> Result<BatchShape> {
        self.transport.shape(bundle)
    }
}

/// Injected transport failures for the chaos tests (the wire-level
/// counterpart of [`crate::testing::mock::FaultPlan`], which kills the
/// *backend* under the transport). Indices are 0-based op counts over
/// this transport's lifetime.
#[derive(Clone, Debug, Default)]
pub struct TransportFaults {
    /// The n-th `submit` executes and records its ticket, but the ack is
    /// dropped: the caller sees `Err` and must resubmit the same ticket —
    /// the idempotency case [`RemoteBackend::submit_entry`]'s retry loop
    /// exists for. One-shot.
    pub drop_submit_ack_at: Option<usize>,
    /// The n-th `complete` times out (transient — a retry succeeds).
    /// One-shot.
    pub timeout_complete_at: Option<usize>,
    /// Every data-plane op (upload/submit/complete/read) from this
    /// global op index on fails: the dead-peer model that exhausts the
    /// retry budget and surfaces to the pool as a shard failure.
    pub dead_from_op: Option<usize>,
}

/// A remote buffer's loopback-side backing: materialized, or still the
/// wrapped backend's in-flight pending (resolvable as a chaining arg via
/// [`Backend::pending_buf`], like the real thing).
enum Slot<B: Backend> {
    Ready(B::Buf),
    InFlight(B::Pending),
}

/// Executed-submit record: the output handle, and whether the inner
/// forward has been completed.
struct TicketState {
    out: u64,
    done: bool,
}

/// In-process [`Transport`] over any wrapped [`Backend`] — the offline
/// stand-in for a real RPC peer. See the module docs.
pub struct Loopback<'b, B: Backend> {
    inner: &'b B,
    entries: RefCell<HashMap<String, B::Entry>>,
    bufs: RefCell<HashMap<u64, Slot<B>>>,
    tickets: RefCell<HashMap<u64, TicketState>>,
    next_handle: Cell<u64>,
    faults: RefCell<TransportFaults>,
    /// Data-plane ops seen (uploads + submits + completes + reads).
    ops_seen: Cell<usize>,
    submits_seen: Cell<usize>,
    completes_seen: Cell<usize>,
}

impl<'b, B: Backend> Loopback<'b, B> {
    pub fn new(inner: &'b B) -> Self {
        Loopback {
            inner,
            entries: RefCell::new(HashMap::new()),
            bufs: RefCell::new(HashMap::new()),
            tickets: RefCell::new(HashMap::new()),
            next_handle: Cell::new(0),
            faults: RefCell::new(TransportFaults::default()),
            ops_seen: Cell::new(0),
            submits_seen: Cell::new(0),
            completes_seen: Cell::new(0),
        }
    }

    /// Arm injected transport failures (replaces any previous plan).
    pub fn set_faults(&self, faults: TransportFaults) {
        *self.faults.borrow_mut() = faults;
    }

    /// Builder form of [`Loopback::set_faults`].
    pub fn with_faults(self, faults: TransportFaults) -> Self {
        self.set_faults(faults);
        self
    }

    /// Live remote-side buffer count (tests pin the no-GC arena model).
    pub fn handles(&self) -> usize {
        self.bufs.borrow().len()
    }

    /// Executed submits recorded in the ticket table.
    pub fn tickets(&self) -> usize {
        self.tickets.borrow().len()
    }

    fn alloc_handle(&self) -> u64 {
        let h = self.next_handle.get();
        self.next_handle.set(h + 1);
        h
    }

    /// Count one data-plane op; fail it if the dead-peer cutoff passed.
    fn op_check(&self, what: &str) -> Result<()> {
        let idx = self.ops_seen.get();
        self.ops_seen.set(idx + 1);
        if let Some(dead) = self.faults.borrow().dead_from_op {
            if idx >= dead {
                bail!("loopback transport: peer dead, {what} op {idx} refused");
            }
        }
        Ok(())
    }

    fn insert_buf(&self, slot: Slot<B>) -> u64 {
        let h = self.alloc_handle();
        self.bufs.borrow_mut().insert(h, slot);
        h
    }
}

impl<B: Backend> Transport for Loopback<'_, B> {
    fn resolve(&self, bundle: &str, entry: &str) -> Result<String> {
        let handle = self.inner.resolve(bundle, entry)?;
        let token = format!("{bundle}/{entry}");
        self.entries.borrow_mut().insert(token.clone(), handle);
        Ok(token)
    }

    fn shape(&self, bundle: &str) -> Result<BatchShape> {
        self.inner.shape(bundle)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<u64> {
        self.op_check("upload_f32")?;
        let buf = self.inner.upload_f32(data, dims)?;
        Ok(self.insert_buf(Slot::Ready(buf)))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<u64> {
        self.op_check("upload_i32")?;
        let buf = self.inner.upload_i32(data, dims)?;
        Ok(self.insert_buf(Slot::Ready(buf)))
    }

    fn submit(&self, ticket: u64, entry: &str, args: &[u64]) -> Result<u64> {
        self.op_check("submit")?;
        let sub_idx = self.submits_seen.get();
        self.submits_seen.set(sub_idx + 1);
        // Idempotency: a ticket this transport already executed returns
        // its recorded output handle — the forward must not run again.
        if let Some(st) = self.tickets.borrow().get(&ticket) {
            return Ok(st.out);
        }
        let handle = self
            .entries
            .borrow()
            .get(entry)
            .cloned()
            .ok_or_else(|| anyhow!("loopback transport: unresolved entry '{entry}'"))?;
        let pending = {
            let bufs = self.bufs.borrow();
            let arg_refs: Vec<&B::Buf> = args
                .iter()
                .map(|h| match bufs.get(h) {
                    Some(Slot::Ready(b)) => Ok(b),
                    Some(Slot::InFlight(p)) => Ok(self.inner.pending_buf(p)),
                    None => Err(anyhow!("loopback transport: unknown buffer handle {h}")),
                })
                .collect::<Result<_>>()?;
            self.inner.submit_entry(&handle, &arg_refs)?
        };
        let out = self.insert_buf(Slot::InFlight(pending));
        self.tickets.borrow_mut().insert(ticket, TicketState { out, done: false });
        // Dropped ack (after the work is applied and recorded): the
        // caller never learns the handle and must retry the ticket.
        let drop_ack = {
            let mut f = self.faults.borrow_mut();
            if f.drop_submit_ack_at == Some(sub_idx) {
                f.drop_submit_ack_at = None;
                true
            } else {
                false
            }
        };
        if drop_ack {
            bail!("loopback transport: submit ack dropped (ticket {ticket})");
        }
        Ok(out)
    }

    fn complete(&self, ticket: u64, timeout_ms: u64) -> Result<()> {
        self.op_check("complete")?;
        let cpl_idx = self.completes_seen.get();
        self.completes_seen.set(cpl_idx + 1);
        let timeout = {
            let mut f = self.faults.borrow_mut();
            if f.timeout_complete_at == Some(cpl_idx) {
                f.timeout_complete_at = None;
                true
            } else {
                false
            }
        };
        if timeout {
            bail!(
                "loopback transport: complete of ticket {ticket} timed out after {timeout_ms} ms"
            );
        }
        let out = {
            let tickets = self.tickets.borrow();
            let st = tickets
                .get(&ticket)
                .ok_or_else(|| anyhow!("loopback transport: unknown ticket {ticket}"))?;
            if st.done {
                return Ok(()); // idempotent: already completed
            }
            st.out
        };
        let slot = self
            .bufs
            .borrow_mut()
            .remove(&out)
            .ok_or_else(|| anyhow!("loopback transport: ticket {ticket} lost its buffer"))?;
        let ready = match slot {
            Slot::InFlight(p) => self.inner.complete(p)?,
            Slot::Ready(b) => b,
        };
        self.bufs.borrow_mut().insert(out, Slot::Ready(ready));
        self.tickets.borrow_mut().get_mut(&ticket).expect("ticket recorded above").done = true;
        Ok(())
    }

    fn read_f32(&self, handle: u64, out: &mut Vec<f32>) -> Result<()> {
        self.op_check("read_f32")?;
        let bufs = self.bufs.borrow();
        match bufs.get(&handle) {
            Some(Slot::Ready(b)) => self.inner.read_f32_into(b, out),
            Some(Slot::InFlight(_)) => {
                bail!("loopback transport: read of handle {handle} before its complete")
            }
            None => bail!("loopback transport: unknown buffer handle {handle}"),
        }
    }

    fn virtual_now(&self) -> Option<f64> {
        self.inner.virtual_now()
    }

    fn device_busy_secs(&self) -> f64 {
        self.inner.device_busy_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::mock::MockEngine;
    use crate::tokenizer::BOS;

    fn remote_over(
        mock: &MockEngine,
    ) -> RemoteBackend<Loopback<'_, MockEngine>> {
        RemoteBackend::new(Loopback::new(mock))
    }

    /// Upload a 1-row prompt layout and run `prefill` through `backend`,
    /// returning the read-back `read_gen` payload.
    fn prefill_read<B: Backend>(backend: &B) -> Vec<f32> {
        let hp = backend.resolve("mock", "prefill").unwrap();
        let hr = backend.resolve("mock", "read_gen").unwrap();
        let blob = backend.upload_f32(&[0.0], &[1]).unwrap();
        let tok = backend.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = backend.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = backend.upload_i32(&[1], &[1]).unwrap();
        let temp = backend.upload_f32(&[1.0], &[1]).unwrap();
        let gen = backend.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        let out = backend.call_entry(&hr, &[&gen]).unwrap();
        backend.read_f32(&out).unwrap()
    }

    #[test]
    fn loopback_prefill_readback_matches_the_wrapped_mock() {
        let direct = MockEngine::new(1, 2, 4, 8);
        let wrapped = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&wrapped);
        assert_eq!(prefill_read(&direct), prefill_read(&remote));
        // shape passes through too
        let s = Backend::shape(&remote, "mock").unwrap();
        assert_eq!((s.batch, s.prompt_len, s.total_len, s.vocab), (1, 2, 4, 8));
    }

    #[test]
    fn dropped_submit_ack_retries_without_double_applying() {
        let mock = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&mock);
        remote
            .transport()
            .set_faults(TransportFaults { drop_submit_ack_at: Some(0), ..Default::default() });
        let hp = remote.resolve("mock", "prefill").unwrap();
        let blob = remote.upload_f32(&[0.0], &[1]).unwrap();
        let tok = remote.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = remote.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = remote.upload_i32(&[1], &[1]).unwrap();
        let temp = remote.upload_f32(&[1.0], &[1]).unwrap();
        remote.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        // the forward ran exactly once: the retried submit hit the ticket
        // table, not the engine
        assert_eq!(mock.calls_of("prefill"), 1);
        assert_eq!(remote.transport().tickets(), 1);
    }

    #[test]
    fn dropped_ack_without_retry_budget_is_an_error() {
        let mock = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&mock).with_rpc(1_000, 0);
        remote
            .transport()
            .set_faults(TransportFaults { drop_submit_ack_at: Some(0), ..Default::default() });
        let hp = remote.resolve("mock", "prefill").unwrap();
        let blob = remote.upload_f32(&[0.0], &[1]).unwrap();
        let tok = remote.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = remote.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = remote.upload_i32(&[1], &[1]).unwrap();
        let temp = remote.upload_f32(&[1.0], &[1]).unwrap();
        let err =
            remote.call_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap_err();
        assert!(format!("{err:#}").contains("after 1 attempts"), "{err:#}");
        // the work itself was applied remotely (ack lost, not the work)
        assert_eq!(mock.calls_of("prefill"), 1);
    }

    #[test]
    fn complete_timeout_is_retried_idempotently() {
        let mock = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&mock);
        remote
            .transport()
            .set_faults(TransportFaults { timeout_complete_at: Some(0), ..Default::default() });
        assert_eq!(prefill_read(&remote), prefill_read(&MockEngine::new(1, 2, 4, 8)));
        assert_eq!(mock.calls_of("prefill"), 1);
        assert_eq!(mock.calls_of("read_gen"), 1);
    }

    #[test]
    fn dead_peer_exhausts_retries_and_errors() {
        let mock = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&mock);
        remote.resolve("mock", "prefill").unwrap();
        remote
            .transport()
            .set_faults(TransportFaults { dead_from_op: Some(0), ..Default::default() });
        let err = remote.upload_f32(&[0.0], &[1]).unwrap_err();
        assert!(format!("{err:#}").contains("peer dead"), "{err:#}");
        assert_eq!(mock.counters().uploads.len(), 0, "nothing reached the engine");
    }

    #[test]
    fn chained_submits_resolve_inflight_handles() {
        // decode(submit) consuming prefill's still-in-flight output via
        // pending_buf, exactly like the engine's device chains.
        let mock = MockEngine::new(1, 2, 4, 8);
        let remote = remote_over(&mock);
        let hp = remote.resolve("mock", "prefill").unwrap();
        let hd = remote.resolve("mock", "decode").unwrap();
        let blob = remote.upload_f32(&[0.0], &[1]).unwrap();
        let tok = remote.upload_i32(&[BOS, 5, 0, 0], &[1, 4]).unwrap();
        let val = remote.upload_f32(&[1.0, 1.0, 0.0, 0.0], &[1, 4]).unwrap();
        let last = remote.upload_i32(&[1], &[1]).unwrap();
        let temp = remote.upload_f32(&[1.0], &[1]).unwrap();
        let p_gen = remote.submit_entry(&hp, &[&blob, &tok, &val, &last, &temp]).unwrap();
        let tok1 = remote.upload_i32(&[7], &[1]).unwrap();
        let slot = remote.upload_i32(&[2], &[1]).unwrap();
        let lpos = remote.upload_i32(&[2], &[1]).unwrap();
        let p_dec = {
            let gen = remote.pending_buf(&p_gen);
            remote.submit_entry(&hd, &[&blob, gen, &tok1, &slot, &lpos, &temp]).unwrap()
        };
        remote.complete(p_dec).unwrap();
        assert_eq!(mock.calls_of("prefill"), 1);
        assert_eq!(mock.calls_of("decode"), 1);
        // handle table is an arena: every upload + 2 outputs stay live
        assert_eq!(remote.transport().handles(), 8 + 2);
    }
}
