//! PJRT runtime: load `artifacts/` once, execute forever.
//!
//! - [`manifest`] — typed view of `artifacts/manifest.json`.
//! - [`engine`] — the PJRT CPU client, lazily-compiled executables, typed
//!   upload/execute/read helpers, and per-entry timing stats.
//! - [`remote`] — the [`backend::Backend`] contract over a wire: RPC
//!   tickets as `Pending`, remote buffer handles as `Buf`, and the
//!   in-process [`remote::Loopback`] transport for offline testing
//!   (`ARCHITECTURE.md` §13).
//!
//! Design constraint discovered by probing this image's plugin (see
//! DESIGN.md): multi-output executables return a *single tuple buffer* and
//! `CopyRawToHost` is unimplemented, so every entry point is lowered with
//! one flat array output, large state chains device-side buffer-to-buffer,
//! and tiny `read_*` extraction executables service the host's need for
//! probs/metrics.

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod remote;

pub use backend::{Backend, BatchShape};
pub use engine::{Engine, EntryHandle, EntryStats};
pub use manifest::{ArgInfo, BundleInfo, EntryInfo, FieldInfo, Manifest, ModelInfo};
pub use remote::{Loopback, RemoteBackend, Transport, TransportFaults};
