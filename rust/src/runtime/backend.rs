//! The device abstraction the rollout layer is generic over.
//!
//! [`Backend`] is the narrow waist between the coordinator's hot loops
//! (rollout scheduler, spec verifier) and whatever executes the AOT
//! entries: the real PJRT [`super::Engine`], or the in-tree mock
//! ([`crate::testing::mock::MockEngine`]) that lets scheduler invariants,
//! decode-traffic budgets, and lockstep-vs-continuous equivalence run as
//! plain unit tests with no built `artifacts/`.
//!
//! Entry points are pre-resolved to [`Backend::Entry`] handles once at
//! engine construction, so the per-decode-step path does no string
//! formatting, no map lookups, and (for the PJRT engine) no lock
//! acquisitions.
//!
//! ## The submit/complete protocol (overlapped shard stepping)
//!
//! Every entry call exists in two forms:
//!
//! - [`Backend::call_entry`] — the synchronous form: issue the forward
//!   and block until its result is usable. Single-engine drivers and the
//!   oracles use it exclusively.
//! - [`Backend::submit_entry`] / [`Backend::complete`] — the two-phase
//!   form: `submit_entry` *issues* the forward and returns a
//!   [`Backend::Pending`] handle without waiting; `complete` blocks until
//!   the forward's output is host-usable and hands the buffer back.
//!   [`Backend::pending_buf`] borrows the device buffer behind a pending
//!   forward so a *further* submit on the same backend can consume it as
//!   an argument — device-side chaining with no host wait in between.
//!
//! The two-phase form is what lets
//! [`crate::rollout::pool::EnginePool`]'s overlapped driver issue every
//! shard's forward chain for a round before blocking on any shard's
//! readback, so engines on distinct devices run concurrently instead of
//! host-serialized (`ARCHITECTURE.md` §11). A purely synchronous backend
//! implements the protocol as its degenerate case — `Pending = Buf`,
//! `submit_entry = call_entry`, `complete = identity` — which is exactly
//! what the PJRT [`super::Engine`] does (PJRT buffers are futures the
//! runtime resolves on first host read, so the degenerate submit is
//! still a real asynchronous dispatch there). [`super::remote::RemoteBackend`]
//! is the other extreme: its `Pending` is an RPC ticket and its `Buf` a
//! remote buffer handle, shipped over a [`super::remote::Transport`] —
//! and nothing in the scheduler layer changes (`ARCHITECTURE.md` §13).

use anyhow::Result;

/// Static geometry of one bundle (from the manifest).
#[derive(Clone, Copy, Debug)]
pub struct BatchShape {
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub vocab: usize,
}

impl BatchShape {
    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }
}

/// Executes AOT entries over opaque device buffers.
pub trait Backend {
    /// Device buffer handle.
    type Buf;
    /// Pre-resolved entry-point handle (cheap to clone, lock-free to call).
    type Entry: Clone;
    /// Handle to an in-flight forward issued by [`Backend::submit_entry`].
    /// Synchronous backends use `Pending = Buf` (the forward completes at
    /// submit time and the handle merely carries the result to
    /// [`Backend::complete`]); asynchronous backends carry their transport
    /// ticket here.
    type Pending;

    /// Resolve `bundle/entry` once; the returned handle is used for every
    /// subsequent call.
    fn resolve(&self, bundle: &str, entry: &str) -> Result<Self::Entry>;

    /// Execute a pre-resolved entry synchronously (submit + complete in
    /// one blocking step).
    fn call_entry(&self, entry: &Self::Entry, args: &[&Self::Buf]) -> Result<Self::Buf>;

    /// Issue a forward without blocking the host on its result. The
    /// returned [`Backend::Pending`] must eventually be passed to
    /// [`Backend::complete`] (or dropped, abandoning the result).
    fn submit_entry(&self, entry: &Self::Entry, args: &[&Self::Buf]) -> Result<Self::Pending>;

    /// Block until a pending forward's output is host-usable and return
    /// it. This is the only host-blocking point of the two-phase form.
    fn complete(&self, pending: Self::Pending) -> Result<Self::Buf>;

    /// Borrow the device buffer behind a pending forward for use as an
    /// argument to a further submit on the *same* backend. This is
    /// device-side chaining: the dependency is resolved on the device's
    /// own timeline, so the host never waits. Reading the buffer back to
    /// host without [`Backend::complete`] is outside the contract.
    fn pending_buf<'a>(&self, pending: &'a Self::Pending) -> &'a Self::Buf;

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Self::Buf>;

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Self::Buf>;

    fn read_f32(&self, buf: &Self::Buf) -> Result<Vec<f32>>;

    /// Read into a caller-owned scratch vec (decode hot loop: no per-step
    /// allocation beyond what the transport itself requires).
    ///
    /// This default is the documented *fallback only*: it round-trips
    /// through the `Vec` that [`Backend::read_f32`] allocates, paying one
    /// extra copy per readback. Backends with a host-visible view of
    /// their buffers should override it to copy straight into `out`
    /// (both in-tree backends do — see [`super::Engine`] and
    /// [`crate::testing::mock::MockEngine`]).
    fn read_f32_into(&self, buf: &Self::Buf, out: &mut Vec<f32>) -> Result<()> {
        let v = self.read_f32(buf)?;
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }

    /// Current reading of the backend's **virtual clock**, if it models
    /// one ([`crate::testing::mock::MockEngine`] with an attached
    /// [`crate::testing::mock::VirtualClock`]). The pool's overlap
    /// accounting (`PipelineStats::overlap_makespan` /
    /// `serial_makespan`, `ARCHITECTURE.md` §11) is driven entirely by
    /// this hook; real device backends keep the default `None` and the
    /// makespan telemetry stays zero.
    fn virtual_now(&self) -> Option<f64> {
        None
    }

    /// Total virtual seconds this backend has spent executing forwards
    /// (monotonic; meaningful only when [`Backend::virtual_now`] is
    /// `Some`). Summed across shards this is what a host-serialized
    /// driver would realize as its makespan, since it never lets two
    /// forwards overlap.
    fn device_busy_secs(&self) -> f64 {
        0.0
    }

    /// Bundle geometry (batch rows, sequence slots, vocabulary).
    fn shape(&self, bundle: &str) -> Result<BatchShape>;
}
