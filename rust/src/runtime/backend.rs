//! The device abstraction the rollout layer is generic over.
//!
//! [`Backend`] is the narrow waist between the coordinator's hot loops
//! (rollout scheduler, spec verifier) and whatever executes the AOT
//! entries: the real PJRT [`super::Engine`], or the in-tree mock
//! ([`crate::testing::mock::MockEngine`]) that lets scheduler invariants,
//! decode-traffic budgets, and lockstep-vs-continuous equivalence run as
//! plain unit tests with no built `artifacts/`.
//!
//! Entry points are pre-resolved to [`Backend::Entry`] handles once at
//! engine construction, so the per-decode-step path does no string
//! formatting, no map lookups, and (for the PJRT engine) no lock
//! acquisitions.

use anyhow::Result;

/// Static geometry of one bundle (from the manifest).
#[derive(Clone, Copy, Debug)]
pub struct BatchShape {
    pub batch: usize,
    pub prompt_len: usize,
    pub total_len: usize,
    pub vocab: usize,
}

impl BatchShape {
    pub fn gen_len(&self) -> usize {
        self.total_len - self.prompt_len
    }
}

/// Executes AOT entries over opaque device buffers.
pub trait Backend {
    /// Device buffer handle.
    type Buf;
    /// Pre-resolved entry-point handle (cheap to clone, lock-free to call).
    type Entry: Clone;

    /// Resolve `bundle/entry` once; the returned handle is used for every
    /// subsequent call.
    fn resolve(&self, bundle: &str, entry: &str) -> Result<Self::Entry>;

    /// Execute a pre-resolved entry.
    fn call_entry(&self, entry: &Self::Entry, args: &[&Self::Buf]) -> Result<Self::Buf>;

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Self::Buf>;

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Self::Buf>;

    fn read_f32(&self, buf: &Self::Buf) -> Result<Vec<f32>>;

    /// Read into a caller-owned scratch vec (decode hot loop: no per-step
    /// allocation beyond what the transport itself requires).
    fn read_f32_into(&self, buf: &Self::Buf, out: &mut Vec<f32>) -> Result<()> {
        let v = self.read_f32(buf)?;
        out.clear();
        out.extend_from_slice(&v);
        Ok(())
    }

    /// Bundle geometry (batch rows, sequence slots, vocabulary).
    fn shape(&self, bundle: &str) -> Result<BatchShape>;
}
