//! The PJRT engine: compile-once executables + typed buffer helpers.
//!
//! One [`Engine`] wraps one `PjRtClient` and the artifact manifest. Entry
//! points are interned into [`EntryHandle`]s: resolving takes the registry
//! lock once and clones an `Arc`; *calling* through a handle takes no lock
//! at all (executable via `OnceLock`, stats via atomics). The string-keyed
//! [`Engine::call`] remains as a convenience wrapper that resolves and
//! calls — exactly one lock per call — but hot loops (rollout decode, spec
//! verify) hold pre-resolved handles instead.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use super::backend::{Backend, BatchShape};
use super::manifest::{BundleInfo, EntryInfo, Manifest};

/// Cumulative per-entry execution statistics.
#[derive(Clone, Debug, Default)]
pub struct EntryStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Interned per-entry state: manifest signature, lazily-compiled
/// executable, and lock-free counters.
struct EntryState {
    /// "bundle/entry", for reporting.
    key: String,
    info: EntryInfo,
    file: PathBuf,
    exe: OnceLock<Arc<xla::PjRtLoadedExecutable>>,
    calls: AtomicU64,
    exec_nanos: AtomicU64,
    compile_nanos: AtomicU64,
}

/// A pre-resolved entry point. Cloning is an `Arc` bump; calling through it
/// takes no locks.
#[derive(Clone)]
pub struct EntryHandle(Arc<EntryState>);

impl EntryHandle {
    /// "bundle/entry".
    pub fn key(&self) -> &str {
        &self.0.key
    }

    /// Manifest signature of this entry.
    pub fn info(&self) -> &EntryInfo {
        &self.0.info
    }
}

/// Compile-once, execute-many PJRT wrapper.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    entries: Mutex<HashMap<String, Arc<EntryState>>>,
    upload_calls: AtomicU64,
    upload_elems: AtomicU64,
}

impl Engine {
    /// Load the manifest and create the CPU client. Executables compile on
    /// first call (`warmup` forces them eagerly).
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            entries: Mutex::new(HashMap::new()),
            upload_calls: AtomicU64::new(0),
            upload_elems: AtomicU64::new(0),
        })
    }

    pub fn bundle(&self, name: &str) -> Result<&BundleInfo> {
        self.manifest.bundle(name)
    }

    fn entry_info<'a>(&'a self, bundle: &str, entry: &str) -> Result<&'a EntryInfo> {
        let b = self.manifest.bundle(bundle)?;
        b.entries
            .get(entry)
            .with_context(|| format!("bundle '{bundle}' has no entry '{entry}'"))
    }

    /// Intern `bundle/entry` into a reusable handle (one registry lock).
    pub fn handle(&self, bundle: &str, entry: &str) -> Result<EntryHandle> {
        let key = format!("{bundle}/{entry}");
        let mut map = self.entries.lock().unwrap();
        if let Some(st) = map.get(&key) {
            return Ok(EntryHandle(st.clone()));
        }
        let info = self.entry_info(bundle, entry)?.clone();
        let file = self.manifest.dir.join(&info.file);
        let st = Arc::new(EntryState {
            key: key.clone(),
            info,
            file,
            exe: OnceLock::new(),
            calls: AtomicU64::new(0),
            exec_nanos: AtomicU64::new(0),
            compile_nanos: AtomicU64::new(0),
        });
        map.insert(key, st.clone());
        Ok(EntryHandle(st))
    }

    /// The executable behind a handle, compiling on first use (racing
    /// resolvers may compile twice; the first `set` wins).
    fn ensure_exe(&self, st: &EntryState) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = st.exe.get() {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&st.file)
            .with_context(|| format!("parsing HLO text {:?}", st.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {}", st.key))?,
        );
        let nanos = t0.elapsed().as_nanos() as u64;
        st.compile_nanos.fetch_add(nanos, Ordering::Relaxed);
        log::debug!("compiled {} in {:.2}s", st.key, nanos as f64 / 1e9);
        let _ = st.exe.set(exe);
        Ok(st.exe.get().expect("exe just set").clone())
    }

    /// Force-compile every entry of a bundle (so run timings exclude JIT).
    pub fn warmup(&self, bundle: &str) -> Result<()> {
        let names: Vec<String> =
            self.manifest.bundle(bundle)?.entries.keys().cloned().collect();
        for e in names {
            let h = self.handle(bundle, &e)?;
            self.ensure_exe(&h.0)?;
        }
        Ok(())
    }

    // -- uploads -------------------------------------------------------------
    fn count_upload(&self, elems: usize) {
        self.upload_calls.fetch_add(1, Ordering::Relaxed);
        self.upload_elems.fetch_add(elems as u64, Ordering::Relaxed);
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.count_upload(data.len());
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.count_upload(data.len());
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an f32 .npy file (the initial blobs written by aot.py).
    pub fn upload_npy(&self, rel_path: &str) -> Result<xla::PjRtBuffer> {
        let path = self.manifest.dir.join(rel_path);
        let lit = xla::Literal::read_npy(&path, &())
            .with_context(|| format!("reading npy {path:?}"))?;
        let host = lit.to_vec::<f32>()?;
        self.upload_f32(&host, &[host.len()])
    }

    /// (host→device transfer count, total elements) since the last reset —
    /// the raw data behind the decode-traffic acceptance tests.
    pub fn upload_stats(&self) -> (u64, u64) {
        (
            self.upload_calls.load(Ordering::Relaxed),
            self.upload_elems.load(Ordering::Relaxed),
        )
    }

    // -- execute -------------------------------------------------------------
    /// Execute through a pre-resolved handle: zero locks, zero string
    /// formatting. Returns the single flat output buffer (device-resident).
    pub fn call_handle(
        &self,
        h: &EntryHandle,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let st = &*h.0;
        if args.len() != st.info.inputs.len() {
            bail!(
                "{}: expected {} args ({:?}), got {}",
                st.key,
                st.info.inputs.len(),
                st.info.inputs.iter().map(|a| a.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        let exe = self.ensure_exe(st)?;
        let t0 = Instant::now();
        let mut outs = exe.execute_b(args)?;
        st.calls.fetch_add(1, Ordering::Relaxed);
        st.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut replica = outs.pop().context("no replica output")?;
        if replica.len() != 1 {
            bail!("{}: expected 1 output buffer, got {}", st.key, replica.len());
        }
        Ok(replica.pop().unwrap())
    }

    /// Execute `bundle/entry` with buffer args (resolve + call: exactly one
    /// registry lock). Hot loops should pre-resolve via [`Engine::handle`].
    pub fn call(
        &self,
        bundle: &str,
        entry: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let h = self.handle(bundle, entry)?;
        self.call_handle(&h, args)
    }

    /// Copy a whole device buffer to host as f32.
    pub fn read_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Snapshot per-entry stats (sorted by total time desc).
    pub fn stats(&self) -> Vec<(String, EntryStats)> {
        let map = self.entries.lock().unwrap();
        let mut v: Vec<(String, EntryStats)> = map
            .values()
            .map(|st| {
                (
                    st.key.clone(),
                    EntryStats {
                        calls: st.calls.load(Ordering::Relaxed),
                        total_secs: st.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9,
                        compile_secs: st.compile_nanos.load(Ordering::Relaxed) as f64 / 1e9,
                    },
                )
            })
            .collect();
        v.sort_by(|a, b| b.1.total_secs.total_cmp(&a.1.total_secs));
        v
    }

    pub fn reset_stats(&self) {
        for st in self.entries.lock().unwrap().values() {
            st.calls.store(0, Ordering::Relaxed);
            st.exec_nanos.store(0, Ordering::Relaxed);
            st.compile_nanos.store(0, Ordering::Relaxed);
        }
        self.upload_calls.store(0, Ordering::Relaxed);
        self.upload_elems.store(0, Ordering::Relaxed);
    }
}

impl Backend for Engine {
    type Buf = xla::PjRtBuffer;
    type Entry = EntryHandle;
    // The synchronous degenerate of the submit/complete protocol
    // (ARCHITECTURE.md §11): PJRT's execute returns buffer futures the
    // runtime resolves on first host read, so "submit" is already a real
    // asynchronous dispatch and the pending handle is the buffer itself.
    type Pending = xla::PjRtBuffer;

    fn resolve(&self, bundle: &str, entry: &str) -> Result<EntryHandle> {
        self.handle(bundle, entry)
    }

    fn call_entry(&self, entry: &EntryHandle, args: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        self.call_handle(entry, args)
    }

    fn submit_entry(
        &self,
        entry: &EntryHandle,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        self.call_handle(entry, args)
    }

    fn complete(&self, pending: xla::PjRtBuffer) -> Result<xla::PjRtBuffer> {
        Ok(pending)
    }

    fn pending_buf<'a>(&self, pending: &'a xla::PjRtBuffer) -> &'a xla::PjRtBuffer {
        pending
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Engine::upload_f32(self, data, dims)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Engine::upload_i32(self, data, dims)
    }

    fn read_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        Engine::read_f32(self, buf)
    }

    fn read_f32_into(&self, buf: &xla::PjRtBuffer, out: &mut Vec<f32>) -> Result<()> {
        // The device→host transfer lands in one literal; copying out of
        // its borrowed view into the caller's scratch reuses `out`'s
        // capacity, so the steady-state decode loop allocates nothing
        // here (the trait default would pay a fresh `to_vec` allocation
        // per readback — it remains only as the documented fallback).
        let lit = buf.to_literal_sync()?;
        out.clear();
        out.extend_from_slice(lit.as_slice::<f32>()?);
        Ok(())
    }

    fn shape(&self, bundle: &str) -> Result<BatchShape> {
        let info = self.manifest.bundle(bundle)?;
        Ok(BatchShape {
            batch: info.batch,
            prompt_len: self.manifest.prompt_len,
            total_len: self.manifest.total_len,
            vocab: info.model.vocab,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::load("artifacts").unwrap())
    }

    #[test]
    fn score_roundtrip_uniform_policy() {
        let Some(eng) = engine() else { return };
        let b = eng.bundle("tiny_b32").unwrap().clone();
        let (bt, t, g, v) = (b.batch, eng.manifest.total_len, eng.manifest.gen_len(), b.model.vocab);
        let blob = eng.upload_npy(&b.init_blob).unwrap();
        let tokens: Vec<i32> = vec![5; bt * t];
        let valid: Vec<f32> = vec![1.0; bt * t];
        let temp: Vec<f32> = vec![1.0];
        let tb = eng.upload_i32(&tokens, &[bt, t]).unwrap();
        let vb = eng.upload_f32(&valid, &[bt, t]).unwrap();
        let tp = eng.upload_f32(&temp, &[1]).unwrap();
        let out = eng.call("tiny_b32", "score", &[&blob, &tb, &vb, &tp]).unwrap();
        let host = eng.read_f32(&out).unwrap();
        assert_eq!(host.len(), 2 * bt * g);
        // init head is zero => uniform distribution => logp == -ln(V)
        let expect = -(v as f32).ln();
        assert!((host[0] - expect).abs() < 1e-4, "{} vs {expect}", host[0]);
        // entropy == ln(V)
        assert!((host[bt * g] + expect).abs() < 1e-4);
        // stats recorded
        let stats = eng.stats();
        assert!(stats.iter().any(|(k, s)| k == "tiny_b32/score" && s.calls == 1));
    }

    #[test]
    fn bad_arg_count_is_error() {
        let Some(eng) = engine() else { return };
        let b = eng.bundle("tiny_b32").unwrap().clone();
        let blob = eng.upload_npy(&b.init_blob).unwrap();
        assert!(eng.call("tiny_b32", "score", &[&blob]).is_err());
    }

    #[test]
    fn handles_are_interned() {
        let Some(eng) = engine() else { return };
        let h1 = eng.handle("tiny_b32", "score").unwrap();
        let h2 = eng.handle("tiny_b32", "score").unwrap();
        assert_eq!(h1.key(), "tiny_b32/score");
        assert!(Arc::ptr_eq(&h1.0, &h2.0), "same entry must intern to one state");
    }

    #[test]
    fn unknown_entry_handle_is_error() {
        let Some(eng) = engine() else { return };
        assert!(eng.handle("tiny_b32", "no_such_entry").is_err());
    }

    #[test]
    fn upload_stats_count_calls_and_elems() {
        let Some(eng) = engine() else { return };
        eng.reset_stats();
        let _ = eng.upload_f32(&[0.0; 8], &[8]).unwrap();
        let _ = eng.upload_i32(&[0; 4], &[4]).unwrap();
        assert_eq!(eng.upload_stats(), (2, 12));
    }
}
