//! The PJRT engine: compile-once executables + typed buffer helpers.
//!
//! One [`Engine`] wraps one `PjRtClient` and the artifact manifest.
//! Executables compile lazily on first use and are cached for the process
//! lifetime. All `call`s validate argument count/shape against the
//! manifest, execute buffer-to-buffer (`execute_b`), and account wall-clock
//! into per-entry [`EntryStats`] (the raw data behind EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use super::manifest::{BundleInfo, EntryInfo, Manifest};

/// Cumulative per-entry execution statistics.
#[derive(Clone, Debug, Default)]
pub struct EntryStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// Compile-once, execute-many PJRT wrapper.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<HashMap<String, EntryStats>>,
}

impl Engine {
    /// Load the manifest and create the CPU client. Executables compile on
    /// first call (`warmup` forces them eagerly).
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn bundle(&self, name: &str) -> Result<&BundleInfo> {
        self.manifest.bundle(name)
    }

    fn entry<'a>(&'a self, bundle: &str, entry: &str) -> Result<&'a EntryInfo> {
        let b = self.manifest.bundle(bundle)?;
        b.entries
            .get(entry)
            .with_context(|| format!("bundle '{bundle}' has no entry '{entry}'"))
    }

    fn executable(
        &self,
        bundle: &str,
        entry: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{bundle}/{entry}");
        if let Some(exe) = self.exes.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let info = self.entry(bundle, entry)?;
        let path = self.manifest.dir.join(&info.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {key}"))?,
        );
        let secs = t0.elapsed().as_secs_f64();
        self.stats.lock().unwrap().entry(key.clone()).or_default().compile_secs += secs;
        log::debug!("compiled {key} in {secs:.2}s");
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Force-compile every entry of a bundle (so run timings exclude JIT).
    pub fn warmup(&self, bundle: &str) -> Result<()> {
        let names: Vec<String> =
            self.manifest.bundle(bundle)?.entries.keys().cloned().collect();
        for e in names {
            self.executable(bundle, &e)?;
        }
        Ok(())
    }

    // -- uploads -------------------------------------------------------------
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an f32 .npy file (the initial blobs written by aot.py).
    pub fn upload_npy(&self, rel_path: &str) -> Result<xla::PjRtBuffer> {
        let path = self.manifest.dir.join(rel_path);
        let lit = xla::Literal::read_npy(&path, &())
            .with_context(|| format!("reading npy {path:?}"))?;
        let host = lit.to_vec::<f32>()?;
        self.upload_f32(&host, &[host.len()])
    }

    // -- execute -------------------------------------------------------------
    /// Execute `bundle/entry` with buffer args; returns the single flat
    /// output buffer (device-resident).
    pub fn call(
        &self,
        bundle: &str,
        entry: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let info = self.entry(bundle, entry)?;
        if args.len() != info.inputs.len() {
            bail!(
                "{bundle}/{entry}: expected {} args ({:?}), got {}",
                info.inputs.len(),
                info.inputs.iter().map(|a| a.name.as_str()).collect::<Vec<_>>(),
                args.len()
            );
        }
        let exe = self.executable(bundle, entry)?;
        let t0 = Instant::now();
        let mut outs = exe.execute_b(args)?;
        let secs = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            let s = stats.entry(format!("{bundle}/{entry}")).or_default();
            s.calls += 1;
            s.total_secs += secs;
        }
        let mut replica = outs.pop().context("no replica output")?;
        if replica.len() != 1 {
            bail!("{bundle}/{entry}: expected 1 output buffer, got {}", replica.len());
        }
        Ok(replica.pop().unwrap())
    }

    /// Copy a whole device buffer to host as f32.
    pub fn read_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Snapshot per-entry stats (sorted by total time desc).
    pub fn stats(&self) -> Vec<(String, EntryStats)> {
        let mut v: Vec<(String, EntryStats)> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| b.1.total_secs.total_cmp(&a.1.total_secs));
        v
    }

    pub fn reset_stats(&self) {
        self.stats.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::load("artifacts").unwrap())
    }

    #[test]
    fn score_roundtrip_uniform_policy() {
        let Some(eng) = engine() else { return };
        let b = eng.bundle("tiny_b32").unwrap().clone();
        let (bt, t, g, v) = (b.batch, eng.manifest.total_len, eng.manifest.gen_len(), b.model.vocab);
        let blob = eng.upload_npy(&b.init_blob).unwrap();
        let tokens: Vec<i32> = vec![5; bt * t];
        let valid: Vec<f32> = vec![1.0; bt * t];
        let temp: Vec<f32> = vec![1.0];
        let tb = eng.upload_i32(&tokens, &[bt, t]).unwrap();
        let vb = eng.upload_f32(&valid, &[bt, t]).unwrap();
        let tp = eng.upload_f32(&temp, &[1]).unwrap();
        let out = eng.call("tiny_b32", "score", &[&blob, &tb, &vb, &tp]).unwrap();
        let host = eng.read_f32(&out).unwrap();
        assert_eq!(host.len(), 2 * bt * g);
        // init head is zero => uniform distribution => logp == -ln(V)
        let expect = -(v as f32).ln();
        assert!((host[0] - expect).abs() < 1e-4, "{} vs {expect}", host[0]);
        // entropy == ln(V)
        assert!((host[bt * g] + expect).abs() < 1e-4);
        // stats recorded
        let stats = eng.stats();
        assert!(stats.iter().any(|(k, s)| k == "tiny_b32/score" && s.calls == 1));
    }

    #[test]
    fn bad_arg_count_is_error() {
        let Some(eng) = engine() else { return };
        let b = eng.bundle("tiny_b32").unwrap().clone();
        let blob = eng.upload_npy(&b.init_blob).unwrap();
        assert!(eng.call("tiny_b32", "score", &[&blob]).is_err());
    }
}
