//! Property-based tests over the coordinator invariants (DESIGN.md list),
//! using the in-tree `testing` kit (proptest substitute).
//!
//! These run without artifacts — they exercise the pure L3 logic: packing,
//! cache, advantage computation, samplers, lenience, diversity metrics.

use spec_rl::algo;
use spec_rl::metrics;
use spec_rl::rollout::{BatchLayout, SeqTask};
use spec_rl::spec::{CacheEntry, Lenience, RolloutCache};
use spec_rl::testing::{forall, tokens};
use spec_rl::tokenizer::{Tokenizer, BOS, EOS};
use spec_rl::util::{sample_top_p, Rng};

const P: usize = 16;
const T: usize = 64;
const G: usize = T - P;

#[derive(Debug)]
struct PackCase {
    tasks: Vec<SeqTask>,
}

fn pack_case(rng: &mut Rng) -> PackCase {
    let n = 1 + rng.below(8);
    let tasks = (0..n)
        .map(|i| {
            let plen = 1 + rng.below(P - 1);
            let mut prompt = vec![BOS];
            prompt.extend((1..plen).map(|_| (3 + rng.below(48)) as i32));
            let prefix_len = rng.below(G);
            let prefix: Vec<i32> = (0..prefix_len).map(|_| (3 + rng.below(48)) as i32).collect();
            SeqTask {
                id: i,
                prompt,
                prefix_logps: vec![-1.0; prefix.len()],
                prefix,
            }
        })
        .collect();
    PackCase { tasks }
}

/// Invariant 7: packing then unpacking is the identity; pads never leak.
#[test]
fn prop_pack_unpack_identity() {
    forall(101, 300, pack_case, |case| {
        let l = BatchLayout::pack(&case.tasks, 8, P, T);
        case.tasks.iter().enumerate().all(|(r, t)| {
            let resp_ok = l.response(r) == t.prefix;
            let nvalid_ok = l.n_valid(r) == t.prompt.len() + t.prefix.len();
            let last_ok = l.last[r] == (P + t.prefix.len()) as i32 - 1;
            resp_ok && nvalid_ok && last_ok
        })
    });
}

/// Rows beyond the packed tasks are fully invalid (inert filler).
#[test]
fn prop_filler_rows_inert() {
    forall(102, 200, pack_case, |case| {
        let l = BatchLayout::pack(&case.tasks, 8, P, T);
        (case.tasks.len()..8).all(|r| l.n_valid(r) == 0 && !l.active[r])
    });
}

/// Invariant 9 (part): GRPO advantages sum to ~0 within each group and are
/// zero for zero-variance groups.
#[test]
fn prop_grpo_group_advantages() {
    #[derive(Debug)]
    struct Case {
        rewards: Vec<f32>,
        group: usize,
    }
    forall(
        103,
        500,
        |rng: &mut Rng| {
            let group = 2 + rng.below(4);
            let n_groups = 1 + rng.below(6);
            let rewards = (0..group * n_groups)
                .map(|_| if rng.f32() < 0.5 { 1.0 } else { 0.0 })
                .collect();
            Case { rewards, group }
        },
        |c| {
            let adv = algo::grpo_advantages(&c.rewards, c.group);
            adv.chunks(c.group).zip(c.rewards.chunks(c.group)).all(|(a, r)| {
                let sum: f32 = a.iter().sum();
                let uniform = r.iter().all(|&x| x == r[0]);
                let zeroed = a.iter().all(|&x| x.abs() < 1e-3);
                sum.abs() < 1e-3 && (!uniform || zeroed)
            })
        },
    );
}

/// GAE with gamma=lam=1 telescopes to reward - V(s_j) for every j.
#[test]
fn prop_gae_telescopes() {
    #[derive(Debug)]
    struct Case {
        values: Vec<f32>,
        reward: f32,
    }
    forall(
        104,
        300,
        |rng: &mut Rng| {
            let l = 2 + rng.below(20);
            Case {
                values: (0..=l).map(|_| rng.f32() * 2.0 - 1.0).collect(),
                reward: if rng.f32() < 0.5 { 1.0 } else { 0.0 },
            }
        },
        |c| {
            let (adv, tgt) = algo::gae(&c.values, c.reward, 1.0, 1.0);
            adv.iter().enumerate().all(|(j, a)| (a - (c.reward - c.values[j])).abs() < 1e-4)
                && tgt.iter().all(|t| (t - c.reward).abs() < 1e-4)
        },
    );
}

/// Whitening produces ~zero mean, ~unit variance on the mask.
#[test]
fn prop_whiten_moments() {
    forall(
        105,
        200,
        |rng: &mut Rng| {
            let n = 8 + rng.below(64);
            let adv: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let mask: Vec<f32> =
                (0..n).map(|i| if i < 4 || rng.f32() < 0.7 { 1.0 } else { 0.0 }).collect();
            (adv, mask)
        },
        |(adv, mask)| {
            let mut a = adv.clone();
            algo::whiten(&mut a, mask);
            let n: f32 = mask.iter().sum();
            let mean = a.iter().zip(mask).map(|(x, m)| x * m).sum::<f32>() / n;
            // distinct values => variance near 1 (allow slack for ties)
            mean.abs() < 1e-3 && a.iter().zip(mask).all(|(x, m)| *m > 0.5 || *x == 0.0)
        },
    );
}

/// Lenience is monotone: larger log-l never decreases at any step.
#[test]
fn prop_lenience_monotone_schedules() {
    forall(
        106,
        200,
        |rng: &mut Rng| {
            let from = rng.f32() * 2.0 - 1.0;
            let to = from + rng.f32() * 2.0;
            let steps = 1 + rng.below(100) as u64;
            (Lenience::Linear { from, to, steps }, rng.below(200) as u64)
        },
        |(l, step)| l.log_value(*step) <= l.log_value(step + 1) + 1e-6,
    );
}

/// Cache: after any insert sequence, `latest` is the last insert and
/// `previous` the one before.
#[test]
fn prop_cache_latest_previous() {
    forall(
        107,
        300,
        |rng: &mut Rng| {
            let n = 2 + rng.below(6);
            (0..n)
                .map(|v| CacheEntry {
                    response: vec![v as i32; 1 + rng.below(5)],
                    logps: vec![-1.0; 1 + rng.below(5)],
                    version: v as u64,
                    finished: true,
                })
                .map(|mut e| {
                    e.logps.resize(e.response.len(), -1.0);
                    e
                })
                .collect::<Vec<_>>()
        },
        |entries| {
            let mut c = RolloutCache::new();
            for e in entries {
                c.insert(9, e.clone());
            }
            let n = entries.len();
            c.latest(9).unwrap().version == (n - 1) as u64
                && c.previous(9).unwrap().version == (n - 2) as u64
        },
    );
}

/// Top-p sampling never returns an index whose probability is zero.
#[test]
fn prop_top_p_never_samples_zero_mass() {
    forall(
        108,
        300,
        |rng: &mut Rng| {
            let v = 4 + rng.below(48);
            let mut probs: Vec<f32> = (0..v)
                .map(|_| if rng.f32() < 0.3 { 0.0 } else { rng.f32() })
                .collect();
            probs[0] = probs[0].max(0.1); // ensure some mass
            let top_p = 0.5 + rng.f32() * 0.5;
            let seed = rng.next_u64();
            (probs, top_p, seed)
        },
        |(probs, top_p, seed)| {
            let mut r = Rng::new(*seed);
            (0..50).all(|_| probs[sample_top_p(probs, *top_p, &mut r)] > 0.0)
        },
    );
}

/// Tokenizer: encode/decode roundtrip over random charset strings.
#[test]
fn prop_tokenizer_roundtrip() {
    let tok = Tokenizer::default_charset();
    forall(109, 300, tokens(30, 51), |ids| {
        // skip specials (not produced by encode)
        if ids.iter().any(|&t| t < 3) {
            return true;
        }
        let text = tok.decode(ids);
        tok.encode(&text) == *ids
    });
}

/// Diversity: distinct-1 is in [0, 1]; self-BLEU in [0, 1].
#[test]
fn prop_diversity_bounds() {
    forall(
        110,
        150,
        |rng: &mut Rng| {
            let n = 2 + rng.below(6);
            (0..n)
                .map(|_| {
                    let len = 1 + rng.below(20);
                    (0..len).map(|_| (3 + rng.below(20)) as i32).collect::<Vec<i32>>()
                })
                .collect::<Vec<_>>()
        },
        |seqs| {
            let d = metrics::distinct_1(seqs);
            let s = metrics::self_bleu(seqs);
            (0.0..=1.0).contains(&d) && (0.0..=1.0 + 1e-9).contains(&s)
        },
    );
}

/// ROUGE-1 is symmetric and bounded.
#[test]
fn prop_rouge_symmetric() {
    forall(
        111,
        300,
        |rng: &mut Rng| {
            let a: Vec<i32> = (0..1 + rng.below(20)).map(|_| (3 + rng.below(10)) as i32).collect();
            let b: Vec<i32> = (0..1 + rng.below(20)).map(|_| (3 + rng.below(10)) as i32).collect();
            (a, b)
        },
        |(a, b)| {
            let f = metrics::rouge1_f1(a, b);
            let g = metrics::rouge1_f1(b, a);
            (f - g).abs() < 1e-12 && (0.0..=1.0 + 1e-12).contains(&f)
        },
    );
}

/// Terminal prefixes (EOS-ended or full-length) never enter decoding.
#[test]
fn prop_terminal_prefix_detection() {
    forall(
        112,
        300,
        |rng: &mut Rng| {
            let len = rng.below(G + 1);
            let mut prefix: Vec<i32> = (0..len).map(|_| (3 + rng.below(40)) as i32).collect();
            let terminal = rng.f32() < 0.5 && !prefix.is_empty();
            if terminal {
                let l = prefix.len();
                prefix[l - 1] = EOS;
            }
            prefix
        },
        |prefix| {
            let t = SeqTask {
                id: 0,
                prompt: vec![BOS],
                prefix: prefix.clone(),
                prefix_logps: vec![-1.0; prefix.len()],
            };
            let expect = prefix.last() == Some(&EOS) || prefix.len() >= G;
            t.prefix_is_terminal(G) == expect
        },
    );
}
