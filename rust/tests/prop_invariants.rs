//! Property-based tests over the coordinator invariants (DESIGN.md list),
//! using the in-tree `testing` kit (proptest substitute).
//!
//! These run without artifacts — they exercise the pure L3 logic: packing,
//! cache, advantage computation, samplers, lenience, diversity metrics.

use spec_rl::algo;
use spec_rl::benchkit::stale;
use spec_rl::metrics;
use spec_rl::rollout::{
    BatchLayout, EnginePool, LenEstimates, PipelineStats, Placement, RolloutEngine, SampleCfg,
    SeqResult, SeqTask, WorkQueue,
};
use spec_rl::spec::{CacheEntry, Lenience, RolloutCache, VerifyTask};
use spec_rl::testing::mock::{FaultPlan, MockEngine};
use spec_rl::testing::{forall, forall_ok, tokens};
use spec_rl::tokenizer::{Tokenizer, BOS, EOS};
use spec_rl::util::{sample_top_p, Rng, StageTimer};

const P: usize = 16;
const T: usize = 64;
const G: usize = T - P;

#[derive(Debug)]
struct PackCase {
    tasks: Vec<SeqTask>,
}

fn pack_case(rng: &mut Rng) -> PackCase {
    let n = 1 + rng.below(8);
    let tasks = (0..n)
        .map(|i| {
            let plen = 1 + rng.below(P - 1);
            let mut prompt = vec![BOS];
            prompt.extend((1..plen).map(|_| (3 + rng.below(48)) as i32));
            let prefix_len = rng.below(G);
            let prefix: Vec<i32> = (0..prefix_len).map(|_| (3 + rng.below(48)) as i32).collect();
            SeqTask {
                id: i,
                prompt,
                prefix_logps: vec![-1.0; prefix.len()],
                prefix,
            }
        })
        .collect();
    PackCase { tasks }
}

/// Invariant 7: packing then unpacking is the identity; pads never leak.
#[test]
fn prop_pack_unpack_identity() {
    forall(101, 300, pack_case, |case| {
        let l = BatchLayout::pack(&case.tasks, 8, P, T);
        case.tasks.iter().enumerate().all(|(r, t)| {
            let resp_ok = l.response(r) == t.prefix;
            let nvalid_ok = l.n_valid(r) == t.prompt.len() + t.prefix.len();
            let last_ok = l.last[r] == (P + t.prefix.len()) as i32 - 1;
            resp_ok && nvalid_ok && last_ok
        })
    });
}

/// Rows beyond the packed tasks are fully invalid (inert filler).
#[test]
fn prop_filler_rows_inert() {
    forall(102, 200, pack_case, |case| {
        let l = BatchLayout::pack(&case.tasks, 8, P, T);
        (case.tasks.len()..8).all(|r| l.n_valid(r) == 0 && !l.active[r])
    });
}

/// Invariant 9 (part): GRPO advantages sum to ~0 within each group and are
/// zero for zero-variance groups.
#[test]
fn prop_grpo_group_advantages() {
    #[derive(Debug)]
    struct Case {
        rewards: Vec<f32>,
        group: usize,
    }
    forall(
        103,
        500,
        |rng: &mut Rng| {
            let group = 2 + rng.below(4);
            let n_groups = 1 + rng.below(6);
            let rewards = (0..group * n_groups)
                .map(|_| if rng.f32() < 0.5 { 1.0 } else { 0.0 })
                .collect();
            Case { rewards, group }
        },
        |c| {
            let adv = algo::grpo_advantages(&c.rewards, c.group);
            adv.chunks(c.group).zip(c.rewards.chunks(c.group)).all(|(a, r)| {
                let sum: f32 = a.iter().sum();
                let uniform = r.iter().all(|&x| x == r[0]);
                let zeroed = a.iter().all(|&x| x.abs() < 1e-3);
                sum.abs() < 1e-3 && (!uniform || zeroed)
            })
        },
    );
}

/// GAE with gamma=lam=1 telescopes to reward - V(s_j) for every j.
#[test]
fn prop_gae_telescopes() {
    #[derive(Debug)]
    struct Case {
        values: Vec<f32>,
        reward: f32,
    }
    forall(
        104,
        300,
        |rng: &mut Rng| {
            let l = 2 + rng.below(20);
            Case {
                values: (0..=l).map(|_| rng.f32() * 2.0 - 1.0).collect(),
                reward: if rng.f32() < 0.5 { 1.0 } else { 0.0 },
            }
        },
        |c| {
            let (adv, tgt) = algo::gae(&c.values, c.reward, 1.0, 1.0);
            adv.iter().enumerate().all(|(j, a)| (a - (c.reward - c.values[j])).abs() < 1e-4)
                && tgt.iter().all(|t| (t - c.reward).abs() < 1e-4)
        },
    );
}

/// Whitening produces ~zero mean, ~unit variance on the mask.
#[test]
fn prop_whiten_moments() {
    forall(
        105,
        200,
        |rng: &mut Rng| {
            let n = 8 + rng.below(64);
            let adv: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0 - 5.0).collect();
            let mask: Vec<f32> =
                (0..n).map(|i| if i < 4 || rng.f32() < 0.7 { 1.0 } else { 0.0 }).collect();
            (adv, mask)
        },
        |(adv, mask)| {
            let mut a = adv.clone();
            algo::whiten(&mut a, mask);
            let n: f32 = mask.iter().sum();
            let mean = a.iter().zip(mask).map(|(x, m)| x * m).sum::<f32>() / n;
            // distinct values => variance near 1 (allow slack for ties)
            mean.abs() < 1e-3 && a.iter().zip(mask).all(|(x, m)| *m > 0.5 || *x == 0.0)
        },
    );
}

/// Lenience is monotone: larger log-l never decreases at any step.
#[test]
fn prop_lenience_monotone_schedules() {
    forall(
        106,
        200,
        |rng: &mut Rng| {
            let from = rng.f32() * 2.0 - 1.0;
            let to = from + rng.f32() * 2.0;
            let steps = 1 + rng.below(100) as u64;
            (Lenience::Linear { from, to, steps }, rng.below(200) as u64)
        },
        |(l, step)| l.log_value(*step) <= l.log_value(step + 1) + 1e-6,
    );
}

/// Cache: after any insert sequence, `latest` is the last insert and
/// `previous` the one before.
#[test]
fn prop_cache_latest_previous() {
    forall(
        107,
        300,
        |rng: &mut Rng| {
            let n = 2 + rng.below(6);
            (0..n)
                .map(|v| CacheEntry {
                    response: vec![v as i32; 1 + rng.below(5)],
                    logps: vec![-1.0; 1 + rng.below(5)],
                    version: v as u64,
                    finished: true,
                })
                .map(|mut e| {
                    e.logps.resize(e.response.len(), -1.0);
                    e
                })
                .collect::<Vec<_>>()
        },
        |entries| {
            let mut c = RolloutCache::new();
            for e in entries {
                c.insert(9, e.clone());
            }
            let n = entries.len();
            c.latest(9).unwrap().version == (n - 1) as u64
                && c.previous(9).unwrap().version == (n - 2) as u64
        },
    );
}

/// Top-p sampling never returns an index whose probability is zero.
#[test]
fn prop_top_p_never_samples_zero_mass() {
    forall(
        108,
        300,
        |rng: &mut Rng| {
            let v = 4 + rng.below(48);
            let mut probs: Vec<f32> = (0..v)
                .map(|_| if rng.f32() < 0.3 { 0.0 } else { rng.f32() })
                .collect();
            probs[0] = probs[0].max(0.1); // ensure some mass
            let top_p = 0.5 + rng.f32() * 0.5;
            let seed = rng.next_u64();
            (probs, top_p, seed)
        },
        |(probs, top_p, seed)| {
            let mut r = Rng::new(*seed);
            (0..50).all(|_| probs[sample_top_p(probs, *top_p, &mut r)] > 0.0)
        },
    );
}

/// Tokenizer: encode/decode roundtrip over random charset strings.
#[test]
fn prop_tokenizer_roundtrip() {
    let tok = Tokenizer::default_charset();
    forall(109, 300, tokens(30, 51), |ids| {
        // skip specials (not produced by encode)
        if ids.iter().any(|&t| t < 3) {
            return true;
        }
        let text = tok.decode(ids);
        tok.encode(&text) == *ids
    });
}

/// Diversity: distinct-1 is in [0, 1]; self-BLEU in [0, 1].
#[test]
fn prop_diversity_bounds() {
    forall(
        110,
        150,
        |rng: &mut Rng| {
            let n = 2 + rng.below(6);
            (0..n)
                .map(|_| {
                    let len = 1 + rng.below(20);
                    (0..len).map(|_| (3 + rng.below(20)) as i32).collect::<Vec<i32>>()
                })
                .collect::<Vec<_>>()
        },
        |seqs| {
            let d = metrics::distinct_1(seqs);
            let s = metrics::self_bleu(seqs);
            (0.0..=1.0).contains(&d) && (0.0..=1.0 + 1e-9).contains(&s)
        },
    );
}

/// ROUGE-1 is symmetric and bounded.
#[test]
fn prop_rouge_symmetric() {
    forall(
        111,
        300,
        |rng: &mut Rng| {
            let a: Vec<i32> = (0..1 + rng.below(20)).map(|_| (3 + rng.below(10)) as i32).collect();
            let b: Vec<i32> = (0..1 + rng.below(20)).map(|_| (3 + rng.below(10)) as i32).collect();
            (a, b)
        },
        |(a, b)| {
            let f = metrics::rouge1_f1(a, b);
            let g = metrics::rouge1_f1(b, a);
            (f - g).abs() < 1e-12 && (0.0..=1.0 + 1e-12).contains(&f)
        },
    );
}

// ---------------------------------------------------------------------------
// chaos schedules: shard failure under random fault plans (ARCHITECTURE.md §13)
// ---------------------------------------------------------------------------

/// Geometry for the chaos property: 4 slots per shard over the small
/// sched-test bundle shape, `eos_bias = 0` so rejected rows decode to the
/// cap (maximum seated lifetime for a fault to interrupt).
const CB: usize = 4;
const CP: usize = 8;
const CT: usize = 16;
const CV: usize = 16;
/// Step RNG seed shared by the chaos run and its single-shard oracle: the
/// §6 contract keys every stream off (nonce, task id), so byte-identity
/// across pool shapes only needs the same step seed.
const CHAOS_STEP_SEED: u64 = 23;

#[derive(Debug)]
struct ChaosCase {
    shards: usize,
    placement: Placement,
    fault_shard: usize,
    plan: FaultPlan,
    n_tasks: usize,
    draft_len: usize,
    lenience: f32,
}

fn chaos_case(rng: &mut Rng) -> ChaosCase {
    let shards = 2 + rng.below(3); // 2..=4
    let placement = if rng.f32() < 0.5 { Placement::Steal } else { Placement::Static };
    // Any entry the pool path can issue. A plan that never trips (entry
    // unused on the armed shard, or call index past its traffic) is a
    // healthy run and must satisfy the same invariants with zero failures.
    const ENTRIES: [&str; 6] =
        ["prefill", "refill", "verify_seat", "decode", "sample", "read_step"];
    let plan = match rng.below(3) {
        0 => FaultPlan::at_call(rng.below(120)).sticky(),
        1 => FaultPlan::at_entry(ENTRIES[rng.below(ENTRIES.len())]).sticky(),
        // Transient: trips once, then the host heals — the pool still
        // declares the shard dead (fail-fast policy, §13) and recovery
        // must behave identically.
        _ => FaultPlan::at_call(rng.below(120)),
    };
    ChaosCase {
        shards,
        placement,
        fault_shard: rng.below(shards),
        plan,
        n_tasks: 6 + rng.below(31),  // 6..=36: stale prompts stay per-id unique
        draft_len: 2 + rng.below(5), // 2..=6 at gen_len 8
        lenience: -0.8 * rng.f32(),
    }
}

/// One drafted pool step with the case's fault armed on one shard.
fn chaos_run(c: &ChaosCase) -> (Vec<SeqResult>, PipelineStats, Vec<MockEngine>) {
    let mut mocks = MockEngine::replicas(c.shards, CB, CP, CT, CV);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    mocks[c.fault_shard].arm_faults(c.plan.clone());
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec =
        stale::warmed(c.n_tasks, c.draft_len, CV, c.lenience).with_placement(c.placement);
    let mut rng = Rng::new(CHAOS_STEP_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(c.n_tasks, CV);
    let (res, stats) = spec
        .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    (res, stats, mocks)
}

/// The blocking single-shard two-phase oracle on the same workload.
fn chaos_oracle(c: &ChaosCase) -> Vec<SeqResult> {
    let mut mocks = MockEngine::replicas(1, CB, CP, CT, CV);
    mocks[0].eos_bias = 0.0;
    let blob = mocks[0].blob();
    let mut eng = RolloutEngine::new(&mocks[0], "mock").unwrap();
    let mut spec = stale::warmed(c.n_tasks, c.draft_len, CV, c.lenience);
    let mut rng = Rng::new(CHAOS_STEP_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(c.n_tasks, CV);
    let (res, _) = spec
        .run_two_phase(&mut eng, &blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    res
}

/// Chaos schedules (ARCHITECTURE.md §13): under a random [`FaultPlan`] on
/// a random shard — any phase, sticky or transient, sometimes never
/// tripping at all — the step still completes byte-identical to the
/// single-shard two-phase oracle (so no task is lost and none completes
/// twice), and no row is ever seated on two engines that both survived.
#[test]
fn prop_chaos_faults_lose_nothing_and_never_double_seat() {
    // CI's chaos smoke job sweeps this seed (CHAOS_SEED=n); the default
    // keeps local runs deterministic.
    let seed = std::env::var("CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(114);
    forall_ok(seed, 24, chaos_case, |c| {
        let (res, stats, mocks) = chaos_run(c);
        let oracle = chaos_oracle(c);

        // exactly-once completion with pinned outputs: byte-identical to
        // the no-pool oracle whether or not the fault tripped
        if res.len() != oracle.len() {
            return Err(format!("{} results, oracle has {}", res.len(), oracle.len()));
        }
        for (x, y) in res.iter().zip(&oracle) {
            let same = x.id == y.id
                && x.response == y.response
                && x.logps == y.logps
                && (x.reused, x.new_tokens, x.finished)
                    == (y.reused, y.new_tokens, y.finished);
            if !same {
                return Err(format!("id {} diverged from the oracle", x.id));
            }
        }

        // only the armed shard can die; a healthy step requeues nothing
        if stats.shard_failures > 1 {
            return Err(format!("{} shard failures from one armed plan", stats.shard_failures));
        }
        if stats.shard_failures == 0 && stats.requeued_tasks != 0 {
            return Err(format!("{} tasks requeued with no failure", stats.requeued_tasks));
        }

        // seat attribution (MockCounters::seats): among surviving engines
        // every row signature is unique — a requeued row may re-seat on a
        // survivor only because its first seat was on the engine that died
        let dead = (stats.shard_failures > 0).then_some(c.fault_shard);
        let mut live_seats: Vec<Vec<i32>> = mocks
            .iter()
            .enumerate()
            .filter(|(i, _)| Some(*i) != dead)
            .flat_map(|(_, m)| m.seated_rows())
            .collect();
        live_seats.sort();
        if let Some(w) = live_seats.windows(2).find(|w| w[0] == w[1]) {
            return Err(format!("row {:?} seated on two live engines", w[0]));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// predicted-length scheduling (ARCHITECTURE.md §14)
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct PredCase {
    n_tasks: usize,
    draft_len: usize,
    lenience: f32,
    /// Per-id predictor seeding: (prior, observed len, accepted, offered).
    obs: Vec<(f64, usize, usize, usize)>,
}

fn pred_case(rng: &mut Rng) -> PredCase {
    let n_tasks = 6 + rng.below(31); // 6..=36: stale prompts stay per-id unique
    PredCase {
        n_tasks,
        draft_len: 2 + rng.below(5), // 2..=6 at gen_len 8
        lenience: -0.8 * rng.f32(),
        // Arbitrary — even adversarial — predictor state: identity may
        // not depend on the estimates being any good.
        obs: (0..n_tasks)
            .map(|_| {
                (rng.f64() * 20.0, rng.below(CT + 1), rng.below(7), 1 + rng.below(6))
            })
            .collect(),
    }
}

/// One drafted pool step of the case's workload, predictor on or off.
fn pred_run(
    c: &PredCase,
    shards: usize,
    placement: Placement,
    predict: bool,
) -> Vec<SeqResult> {
    let mocks = MockEngine::replicas(shards, CB, CP, CT, CV);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec = stale::warmed(c.n_tasks, c.draft_len, CV, c.lenience)
        .with_placement(placement)
        .with_predict(predict);
    if predict {
        for (id, &(prior, len, acc, off)) in c.obs.iter().enumerate() {
            spec.set_len_prior(id, prior);
            spec.predictor.observe_len(id, len);
            spec.predictor.observe_acceptance(id, acc, off);
        }
    }
    let mut rng = Rng::new(CHAOS_STEP_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(c.n_tasks, CV);
    let (res, _) = spec
        .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    res
}

/// §14 identity: whatever the predictor believes — including random
/// nonsense — estimates only reorder seating, so outputs are
/// byte-identical to the predictor-off run for every shard count and
/// placement discipline.
#[test]
fn prop_predictor_identity_across_shards_and_placements() {
    forall_ok(113, 12, pred_case, |c| {
        let baseline = pred_run(c, 1, Placement::Steal, false);
        for shards in [1usize, 2, 4] {
            for placement in [Placement::Steal, Placement::Static] {
                for predict in [false, true] {
                    let res = pred_run(c, shards, placement, predict);
                    if res.len() != baseline.len() {
                        return Err(format!(
                            "{shards} shards {placement:?} predict={predict}: \
                             {} results, baseline has {}",
                            res.len(),
                            baseline.len()
                        ));
                    }
                    for (x, y) in res.iter().zip(&baseline) {
                        let same = x.id == y.id
                            && x.response == y.response
                            && x.logps == y.logps
                            && (x.reused, x.new_tokens, x.finished)
                                == (y.reused, y.new_tokens, y.finished);
                        if !same {
                            return Err(format!(
                                "{shards} shards {placement:?} predict={predict}: \
                                 id {} diverged from baseline",
                                x.id
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[derive(Debug)]
struct QueueCase {
    tasks: Vec<SeqTask>,
    drafts: Vec<VerifyTask>,
    est: LenEstimates,
}

fn queue_case(rng: &mut Rng) -> QueueCase {
    let nt = rng.below(12);
    let nd = rng.below(12);
    let tasks: Vec<SeqTask> = (0..nt)
        .map(|id| {
            let plen = rng.below(G);
            SeqTask {
                id,
                prompt: vec![BOS],
                prefix: vec![7; plen],
                prefix_logps: vec![-1.0; plen],
            }
        })
        .collect();
    let drafts: Vec<VerifyTask> = (0..nd)
        .map(|id| {
            let dlen = 1 + rng.below(G);
            VerifyTask {
                id: nt + id,
                prompt: vec![BOS],
                entry: CacheEntry {
                    response: vec![5; dlen],
                    logps: vec![-1.0; dlen],
                    version: 0,
                    finished: false,
                },
            }
        })
        .collect();
    // Partial, arbitrary estimates: some ids predicted, some not, some
    // settled-only — every mix must still yield a lossless queue.
    let mut est = LenEstimates::off();
    for t in &tasks {
        if rng.f32() < 0.6 {
            est.set_total(t.id, rng.below(2 * G));
        }
    }
    for d in &drafts {
        if rng.f32() < 0.6 {
            est.set_total(d.id, rng.below(2 * G));
        }
        if rng.f32() < 0.5 {
            est.set_settled(d.id, rng.below(G));
        }
    }
    QueueCase { tasks, drafts, est }
}

/// §14 queue soundness: under any (even partial or adversarial) estimate
/// table, the queue's pop order is a permutation of its input — no item
/// lost, none duplicated — and follows the estimate-aware LPT comparator
/// with the id tie-break.
#[test]
fn prop_workqueue_pop_order_is_a_lossless_permutation() {
    forall_ok(115, 300, queue_case, |c| {
        let mut q =
            WorkQueue::with_estimates(c.tasks.clone(), c.drafts.clone(), c.est.clone());
        let (tasks, drafts) = q.drain();

        let mut got: Vec<usize> = tasks.iter().map(|t| t.id).collect();
        got.extend(drafts.iter().map(|d| d.id));
        got.sort_unstable();
        let mut want: Vec<usize> = c.tasks.iter().map(|t| t.id).collect();
        want.extend(c.drafts.iter().map(|d| d.id));
        want.sort_unstable();
        if got != want {
            return Err(format!("queue lost or duplicated items: {got:?} != {want:?}"));
        }

        for w in tasks.windows(2) {
            let ka = (c.est.task_rank(&w[0]), w[0].id);
            let kb = (c.est.task_rank(&w[1]), w[1].id);
            if ka > kb {
                return Err(format!("task lane out of LPT order at ids {}/{}", w[0].id, w[1].id));
            }
        }
        for w in drafts.windows(2) {
            let ka = (c.est.draft_rank(&w[0]), w[0].id);
            let kb = (c.est.draft_rank(&w[1]), w[1].id);
            if ka > kb {
                return Err(format!("draft lane out of LPT order at ids {}/{}", w[0].id, w[1].id));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// sibling-spine fallback under eviction pressure (ARCHITECTURE.md §8)
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct EvictionCase {
    group: usize,
    n_ids: usize,
    /// One insert batch per epoch (ids randomly dropped — fresh prompts).
    batches: Vec<Vec<(usize, CacheEntry)>>,
    /// Budget tightened after each batch (`None` keeps the previous one).
    budgets: Vec<Option<usize>>,
}

fn eviction_case(rng: &mut Rng) -> EvictionCase {
    let group = 2 + rng.below(4); // 2..=5
    let keys = 2 + rng.below(3); // 2..=4 prompt roots
    let n_ids = group * keys;
    // Per-key spine shared by the whole group. Log-probs derive from the
    // token so equal tokens always carry bitwise-equal log-probs — the
    // trie's sharing precondition.
    let spines: Vec<Vec<i32>> = (0..keys)
        .map(|k| (0..1 + rng.below(6)).map(|j| (3 + (k * 7 + j) % 40) as i32).collect())
        .collect();
    let logp = |t: i32| -0.01 * t as f32;
    let mut batches = Vec::new();
    let mut budgets = Vec::new();
    for e in 0..1 + rng.below(3) as u64 {
        let mut batch = Vec::new();
        for id in 0..n_ids {
            if rng.f32() < 0.8 {
                let mut response = spines[id / group].clone();
                response.extend(
                    (0..rng.below(5)).map(|j| (10 + (id * 3 + j + e as usize) % 30) as i32),
                );
                batch.push((
                    id,
                    CacheEntry {
                        logps: response.iter().map(|&t| logp(t)).collect(),
                        response,
                        version: e,
                        finished: rng.f32() < 0.5,
                    },
                ));
            }
        }
        batches.push(batch);
        // 0..=59 spans everything from evict-all to no pressure at all
        budgets.push((rng.f32() < 0.7).then(|| rng.below(60)));
    }
    EvictionCase { group, n_ids, batches, budgets }
}

/// §8 fallback soundness under random churn: after any mix of partial
/// refreshes and budget tightenings, a sibling-spine fallback is always
/// byte-identical to a *surviving* leaf of the requesting id's own prompt
/// root (so it can never resurrect an evicted run or borrow across
/// prompts), exists whenever any group leaf survives, reports a branch
/// depth consistent with the survivors, and the trie invariants hold
/// throughout.
#[test]
fn prop_sibling_fallback_survives_eviction_pressure() {
    forall_ok(116, 150, eviction_case, |c| {
        let mut cache = RolloutCache::new().with_group(c.group);
        for (batch, budget) in c.batches.iter().zip(&c.budgets) {
            cache.insert_batch(batch.clone());
            if let Some(b) = budget {
                cache.set_token_budget(Some(*b));
            }
            cache.check_invariants().map_err(|e| format!("invariants: {e}"))?;
            for id in 0..c.n_ids {
                let key = id / c.group;
                let survivors: Vec<CacheEntry> = (key * c.group..(key + 1) * c.group)
                    .flat_map(|sid| [cache.latest(sid), cache.previous(sid)])
                    .flatten()
                    .filter(|e| !e.response.is_empty())
                    .collect();
                match cache.sibling_spine(id) {
                    Some(s) => {
                        let alive = survivors.iter().any(|e| {
                            e.response == s.response
                                && e.logps == s.logps
                                && e.version == s.version
                                && e.finished == s.finished
                        });
                        if !alive {
                            return Err(format!(
                                "id {id}: fallback is not a surviving leaf of its root"
                            ));
                        }
                    }
                    None => {
                        if !survivors.is_empty() {
                            return Err(format!(
                                "id {id}: no fallback despite {} surviving siblings",
                                survivors.len()
                            ));
                        }
                    }
                }
                let depth = cache.branch_depth(id);
                if depth.is_some() != !survivors.is_empty() {
                    return Err(format!(
                        "id {id}: branch depth {depth:?} vs {} survivors",
                        survivors.len()
                    ));
                }
                if let Some(d) = depth {
                    let longest = survivors.iter().map(|e| e.response.len()).max().unwrap();
                    if d > longest {
                        return Err(format!(
                            "id {id}: branch depth {d} exceeds longest survivor {longest}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Terminal prefixes (EOS-ended or full-length) never enter decoding.
#[test]
fn prop_terminal_prefix_detection() {
    forall(
        112,
        300,
        |rng: &mut Rng| {
            let len = rng.below(G + 1);
            let mut prefix: Vec<i32> = (0..len).map(|_| (3 + rng.below(40)) as i32).collect();
            let terminal = rng.f32() < 0.5 && !prefix.is_empty();
            if terminal {
                let l = prefix.len();
                prefix[l - 1] = EOS;
            }
            prefix
        },
        |prefix| {
            let t = SeqTask {
                id: 0,
                prompt: vec![BOS],
                prefix: prefix.clone(),
                prefix_logps: vec![-1.0; prefix.len()],
            };
            let expect = prefix.last() == Some(&EOS) || prefix.len() >= G;
            t.prefix_is_terminal(G) == expect
        },
    );
}
