//! Remote-backend conformance over the loopback transport
//! (`ARCHITECTURE.md` §13): `RemoteBackend<Loopback<MockEngine>>` must be
//! indistinguishable from driving the wrapped `MockEngine` directly —
//! byte-identical rollout outputs across every reuse variant and shard
//! count, an identical call/upload trace on the wrapped engine, and the
//! virtual-clock overlap accounting preserved through the wire. Injected
//! transport faults (dropped acks, timeouts, a dead peer) must either be
//! absorbed invisibly by the retry loop or surface as a clean shard
//! failure the pool recovers from with every task finished exactly once.

use spec_rl::benchkit::stale;
use spec_rl::rollout::{EnginePool, PipelineStats, Placement, RolloutEngine, SampleCfg, SeqResult};
use spec_rl::runtime::{Backend, Loopback, RemoteBackend, TransportFaults};
use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
use spec_rl::testing::mock::MockEngine;
use spec_rl::tokenizer::BOS;
use spec_rl::util::{Rng, StageTimer};

/// Pool geometry shared with the sched tests: 4 slots per shard over the
/// small bundle shape.
const B: usize = 4;
const P: usize = 8;
const T: usize = 16;
const V: usize = 16;

const STALE_LEN: usize = 5;
const STALE_LENIENCE: f32 = -0.4;
const STALE_SEED: u64 = 13;

/// 11 requests over 4 slots (the sched-test workload): more tasks than
/// slots forces mid-stream refills/seats.
fn pipe_requests() -> Vec<RolloutRequest> {
    (0..11)
        .map(|i| RolloutRequest {
            id: i,
            prompt: vec![BOS, 3 + (i as i32 % 9), 4 + (i as i32 % 7)],
        })
        .collect()
}

/// Wrap each mock in its own loopback `RemoteBackend`.
fn remotes_over(mocks: &[MockEngine]) -> Vec<RemoteBackend<Loopback<'_, MockEngine>>> {
    mocks.iter().map(|m| RemoteBackend::new(Loopback::new(m))).collect()
}

/// The blocking two-phase oracle, driven on the backend directly.
fn drive_oracle(variant: ReuseVariant, epochs: usize, seed: u64) -> Vec<Vec<SeqResult>> {
    let mocks = MockEngine::replicas(1, B, P, T, V);
    let blob = mocks[0].blob();
    let mut eng = RolloutEngine::new(&mocks[0], "mock").unwrap();
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4));
    let mut rng = Rng::new(seed);
    let mut timer = StageTimer::new();
    (0..epochs)
        .map(|_| {
            spec.run_two_phase(
                &mut eng,
                &blob,
                &pipe_requests(),
                SampleCfg::default(),
                &mut rng,
                &mut timer,
            )
            .unwrap()
            .0
        })
        .collect()
}

/// The interleaved pipeline over `shards` loopback remotes.
fn drive_remote(
    variant: ReuseVariant,
    shards: usize,
    epochs: usize,
    seed: u64,
) -> Vec<Vec<SeqResult>> {
    let mocks = MockEngine::replicas(shards, B, P, T, V);
    let remotes = remotes_over(&mocks);
    // the policy blob lives remotely too: upload once per shard, chain by
    // handle from then on
    let blobs: Vec<_> = remotes.iter().map(|r| r.upload_f32(&[0.0], &[1]).unwrap()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(remotes.iter(), "mock").unwrap();
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4));
    let mut rng = Rng::new(seed);
    let mut timer = StageTimer::new();
    (0..epochs)
        .map(|_| {
            spec.collect(
                &mut pool,
                &blob_refs,
                &pipe_requests(),
                SampleCfg::default(),
                &mut rng,
                &mut timer,
            )
            .unwrap()
            .0
        })
        .collect()
}

fn assert_same_results(a: &[SeqResult], b: &[SeqResult], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.response, y.response, "{tag} id {}", x.id);
        assert_eq!(x.logps, y.logps, "{tag} id {}", x.id);
        assert_eq!(
            (x.reused, x.new_tokens, x.finished),
            (y.reused, y.new_tokens, y.finished),
            "{tag} id {}",
            x.id
        );
    }
}

/// Acceptance criterion: with zero faults, the remote pool is
/// byte-identical to the in-process two-phase oracle across all reuse
/// variants × shards {1, 2, 4}. Epoch 0 fills the cache, epoch 1 drafts,
/// epoch 2 exercises the Delayed variant's `previous` slot.
#[test]
fn remote_pool_matches_the_oracle_across_variants_and_shards() {
    for variant in [
        ReuseVariant::Off,
        ReuseVariant::Spec,
        ReuseVariant::Random,
        ReuseVariant::Delayed,
        ReuseVariant::Full,
    ] {
        let oracle = drive_oracle(variant, 3, 77);
        for shards in [1usize, 2, 4] {
            let remote = drive_remote(variant, shards, 3, 77);
            for (epoch, (r, o)) in remote.iter().zip(&oracle).enumerate() {
                assert_same_results(r, o, &format!("{variant:?} shards {shards} epoch {epoch}"));
            }
        }
    }
}

/// One adversarial drafted step over `shards` loopback remotes, with
/// optional transport faults armed on one shard after the blob uploads.
fn remote_stale_run(
    shards: usize,
    placement: Placement,
    faults: Option<(usize, TransportFaults)>,
) -> (Vec<SeqResult>, PipelineStats, Vec<MockEngine>) {
    let mut mocks = MockEngine::replicas(shards, B, P, T, V);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    let remotes = remotes_over(&mocks);
    let blobs: Vec<_> = remotes.iter().map(|r| r.upload_f32(&[0.0], &[1]).unwrap()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    if let Some((shard, f)) = &faults {
        remotes[*shard].transport().set_faults(f.clone());
    }
    let mut pool = EnginePool::new(remotes.iter(), "mock").unwrap();
    let mut spec =
        stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE).with_placement(placement);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(stale::N_TASKS, V);
    let (res, stats) = spec
        .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    drop(pool);
    drop(remotes);
    (res, stats, mocks)
}

/// The same step on a single in-process engine (continuous path), for
/// call-trace comparison.
fn direct_stale_run(placement: Placement) -> (Vec<SeqResult>, MockEngine) {
    let mut mocks = MockEngine::replicas(1, B, P, T, V);
    mocks[0].eos_bias = 0.0;
    let blob = mocks[0].blob();
    let mut pool = EnginePool::single(&mocks[0], "mock").unwrap();
    let mut spec =
        stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE).with_placement(placement);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(stale::N_TASKS, V);
    let (res, _) = spec
        .collect(&mut pool, &[&blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    drop(pool);
    (res, mocks.remove(0))
}

/// Every entry the rollout layer issues round-trips through the wire
/// without the wrapped engine being able to tell: the *exact* call and
/// upload traces match the in-process run, op for op.
#[test]
fn wrapped_engine_sees_an_identical_trace_through_the_wire() {
    let (direct_res, direct_mock) = direct_stale_run(Placement::Steal);
    let (remote_res, _, remote_mocks) = remote_stale_run(1, Placement::Steal, None);
    assert_same_results(&remote_res, &direct_res, "remote vs direct, 1 shard");

    let d = direct_mock.counters();
    let r = remote_mocks[0].counters();
    // the remote side uploads the blob itself (the direct run reuses
    // `blob()` without an upload); everything after is identical
    assert_eq!(r.uploads[0], vec![1], "first remote upload is the blob");
    assert_eq!(r.uploads[1..], d.uploads[..], "upload dims trace diverged");
    assert_eq!(r.calls, d.calls, "entry call trace diverged");
    assert_eq!(r.seated, d.seated, "seat trace diverged");

    // the trace actually covers the decode contract's entries
    for entry in ["verify_seat", "decode", "sample", "read_step"] {
        assert!(
            d.calls.iter().any(|c| c == entry),
            "workload never exercised '{entry}' — the trace comparison is vacuous"
        );
    }
}

/// Submit/complete overlap survives the wire: on shared-virtual-clock
/// replicas the remote pool realizes the same makespans as the in-process
/// pool — overlapped strictly below serialized — because loopback submits
/// only enqueue on the wrapped backend and forward its clock verbatim.
#[test]
fn virtual_clock_overlap_accounting_survives_the_wire() {
    fn clocked(shards: usize, remote: bool) -> PipelineStats {
        let mut mocks = MockEngine::clocked_replicas(shards, B, P, T, V);
        for m in &mut mocks {
            m.eos_bias = 0.0;
        }
        let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE)
            .with_placement(Placement::Steal);
        let mut rng = Rng::new(STALE_SEED);
        let mut timer = StageTimer::new();
        let reqs = stale::requests(stale::N_TASKS, V);
        if remote {
            let remotes = remotes_over(&mocks);
            let blobs: Vec<_> =
                remotes.iter().map(|r| r.upload_f32(&[0.0], &[1]).unwrap()).collect();
            let blob_refs: Vec<_> = blobs.iter().collect();
            let mut pool = EnginePool::new(remotes.iter(), "mock").unwrap();
            spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
                .unwrap()
                .1
        } else {
            let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
            let blob_refs: Vec<_> = blobs.iter().collect();
            let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
            spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
                .unwrap()
                .1
        }
    }
    for shards in [2usize, 4] {
        let wire = clocked(shards, true);
        let direct = clocked(shards, false);
        assert!(
            wire.overlap_makespan > 0.0 && wire.overlap_makespan < wire.serial_makespan,
            "{shards} shards: remote pool lost the overlap ({wire:?})"
        );
        assert!(
            (wire.overlap_makespan - direct.overlap_makespan).abs() < 1e-9
                && (wire.serial_makespan - direct.serial_makespan).abs() < 1e-9,
            "{shards} shards: makespans diverged through the wire \
             (wire {}/{}, direct {}/{})",
            wire.overlap_makespan,
            wire.serial_makespan,
            direct.overlap_makespan,
            direct.serial_makespan
        );
    }
}

/// Transient wire trouble — a dropped submit ack and a timed-out
/// complete — is absorbed by the ticketed retry loop: outputs stay
/// byte-identical, no shard failure is declared, and the wrapped engines
/// execute exactly the same forwards (nothing double-applied).
#[test]
fn transient_transport_faults_are_invisible_end_to_end() {
    let (clean_res, clean_stats, clean_mocks) = remote_stale_run(2, Placement::Steal, None);
    assert_eq!(clean_stats.shard_failures, 0);
    let faults = TransportFaults {
        drop_submit_ack_at: Some(6),
        timeout_complete_at: Some(4),
        ..Default::default()
    };
    let (res, stats, mocks) = remote_stale_run(2, Placement::Steal, Some((0, faults)));
    assert_same_results(&res, &clean_res, "transient faults vs clean");
    assert_eq!(stats.shard_failures, 0, "retries must absorb transient faults");
    assert_eq!(stats.requeued_tasks, 0);
    for (i, (m, c)) in mocks.iter().zip(&clean_mocks).enumerate() {
        assert_eq!(
            m.counters().calls,
            c.counters().calls,
            "shard {i}: retried ops must not double-apply forwards"
        );
    }
}

/// A dead remote peer exhausts the retry budget, surfaces as a shard
/// failure, and the pool recovers on the survivor: every task finishes
/// exactly once, byte-identical to the in-process run.
#[test]
fn dead_remote_peer_recovers_with_every_task_exactly_once() {
    let (clean_res, _, _) = remote_stale_run(2, Placement::Steal, None);
    // cut the peer at two depths: immediately after the blob upload
    // (death during seating) and mid-step (death with seated rows)
    for dead_from in [0usize, 37] {
        let faults = TransportFaults { dead_from_op: Some(dead_from), ..Default::default() };
        let (res, stats, _) = remote_stale_run(2, Placement::Steal, Some((1, faults.clone())));
        assert_same_results(&res, &clean_res, &format!("dead peer at op {dead_from}"));
        assert_eq!(stats.shard_failures, 1, "dead_from={dead_from}: {stats:?}");
        let ids: Vec<usize> = res.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..stale::N_TASKS).collect::<Vec<_>>(), "dead_from={dead_from}");

        // static placement recovers identically
        let (sres, sstats, _) = remote_stale_run(2, Placement::Static, Some((1, faults)));
        assert_same_results(&sres, &clean_res, &format!("static, dead peer at op {dead_from}"));
        assert_eq!(sstats.shard_failures, 1);
    }
}
