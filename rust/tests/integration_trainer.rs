//! Integration tests of the full training pipeline (short runs on the
//! `nano` bundle to stay fast). Skipped without artifacts.

use spec_rl::algo::Algo;
use spec_rl::config::RunConfig;
use spec_rl::model::Policy;
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant};
use spec_rl::trainer::Trainer;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

fn tiny_cfg(algo: Algo, variant: ReuseVariant) -> RunConfig {
    let mut cfg = RunConfig {
        bundle: "nano_b32".into(),
        algo,
        params: algo.default_params(),
        n_prompts: 16,
        prompts_per_step: 8,
        group: 4,
        steps: 5, // 2 epochs at 16/8 = 2 steps/epoch
        variant,
        lenience: Lenience::Fixed(0.5),
        eval_n: 4,
        eval_samples_hard: 1,
        ..RunConfig::default()
    };
    cfg.params.lr = 1e-3;
    cfg
}

#[test]
fn grpo_vanilla_runs_and_records_stages() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut tr = Trainer::new(&eng, tiny_cfg(Algo::Grpo, ReuseVariant::Off), base).unwrap();
    let rec = tr.step(0).unwrap();
    assert!(rec["rollout_s"] > 0.0);
    assert_eq!(rec["verification_s"], 0.0);
    assert!(rec["update_actor_s"] > 0.0);
    assert!(rec["ref_s"] > 0.0, "GRPO scores the reference policy");
    assert_eq!(rec["values_s"], 0.0, "GRPO has no critic");
    assert!(rec["loss"].is_finite());
    assert!(rec["entropy"] > 0.0);
}

#[test]
fn grpo_spec_reuses_after_one_epoch() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut tr = Trainer::new(&eng, tiny_cfg(Algo::Grpo, ReuseVariant::Spec), base).unwrap();
    // epoch 1: steps 0,1 — no drafts
    let r0 = tr.step(0).unwrap();
    assert_eq!(r0["drafts"], 0.0);
    let _ = tr.step(1).unwrap();
    // epoch 2: step 2 revisits step-0 prompts — drafts must appear
    let r2 = tr.step(2).unwrap();
    assert_eq!(r2["drafts"], 32.0);
    assert!(r2["verification_s"] > 0.0);
    // policy barely moved (tiny lr, 2 steps): most drafts accepted
    assert!(r2["prefix_len"] > 0.0, "{r2:?}");
}

#[test]
fn ppo_uses_critic_stages() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut cfg = tiny_cfg(Algo::Ppo, ReuseVariant::Off);
    cfg.group = 4;
    let mut tr = Trainer::new(&eng, cfg, base).unwrap();
    let rec = tr.step(0).unwrap();
    assert!(rec["values_s"] > 0.0, "PPO runs value_fwd");
    assert!(rec["update_critic_s"] > 0.0, "PPO trains the critic");
    assert_eq!(rec["ref_s"], 0.0, "PPO has no KL reference");
}

#[test]
fn dapo_dynamic_sampling_may_use_extra_rounds() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut tr = Trainer::new(&eng, tiny_cfg(Algo::Dapo, ReuseVariant::Off), base).unwrap();
    let rec = tr.step(0).unwrap();
    // uniform-policy rewards are all zero -> every group degenerate ->
    // DAPO must exhaust its reroll budget and report >1 gen rounds.
    assert!(rec["gen_rounds"] >= 2.0, "{rec:?}");
    assert!(rec["loss"].is_finite());
}

#[test]
fn full_run_produces_summary_and_csv() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut cfg = tiny_cfg(Algo::Grpo, ReuseVariant::Spec);
    cfg.out_dir = std::env::temp_dir().join("specrl_itest_out").to_string_lossy().into_owned();
    let mut tr = Trainer::new(&eng, cfg.clone(), base).unwrap();
    let summary = tr.run("itest").unwrap();
    assert_eq!(summary.steps, 5);
    assert!(summary.total_new_tokens > 0);
    assert_eq!(summary.final_eval.len(), 7);
    assert!(summary.stage_means.contains_key("rollout"));
    // CSV written
    let csv = format!("{}/grpo_spec_nano_b32.csv", cfg.out_dir);
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.lines().count() >= 6, "header + 5 steps");
    assert!(text.starts_with("step,"));
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
}

#[test]
fn trainer_rejects_mismatched_batch() {
    let Some(eng) = engine() else { return };
    let base = Policy::from_init(&eng, "nano_b32").unwrap();
    let mut cfg = tiny_cfg(Algo::Grpo, ReuseVariant::Off);
    cfg.prompts_per_step = 4; // 4*4=16 != 32
    assert!(Trainer::new(&eng, cfg, base).is_err());
}

#[test]
fn deterministic_given_seed() {
    let Some(eng) = engine() else { return };
    let run = |seed: u64| {
        let base = Policy::from_init(&eng, "nano_b32").unwrap();
        let mut cfg = tiny_cfg(Algo::Grpo, ReuseVariant::Spec);
        cfg.seed = seed;
        cfg.steps = 3;
        let mut tr = Trainer::new(&eng, cfg, base).unwrap();
        let mut rewards = Vec::new();
        for s in 0..3 {
            rewards.push(tr.step(s).unwrap()["tokens_new"]);
        }
        rewards
    };
    assert_eq!(run(11), run(11));
    assert_ne!(run(11), run(12));
}
