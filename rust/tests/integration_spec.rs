//! Integration tests of the SPEC-RL mechanism against the real engine:
//! the spec-consistency invariants (1-4 in DESIGN.md).

use spec_rl::model::Policy;
use spec_rl::rollout::{EnginePool, RolloutEngine, SampleCfg};
use spec_rl::runtime::Engine;
use spec_rl::spec::{Lenience, ReuseVariant, RolloutRequest, SpecRollout};
use spec_rl::tokenizer::Tokenizer;
use spec_rl::util::{Rng, StageTimer};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

fn requests(tok: &Tokenizer, prompts: &[&str]) -> Vec<RolloutRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| RolloutRequest { id: i, prompt: tok.encode_prompt(p) })
        .collect()
}

const PROMPTS: [&str; 4] = ["1+1=", "17+25=", "9*9=", "50-8="];

fn collect_once(
    spec: &mut SpecRollout,
    pool: &mut EnginePool<'_>,
    policy: &Policy,
    tok: &Tokenizer,
    rng: &mut Rng,
) -> (Vec<spec_rl::rollout::SeqResult>, spec_rl::spec::SpecStepStats) {
    let reqs = requests(tok, &PROMPTS);
    let mut timer = StageTimer::new();
    spec.collect(pool, &[&policy.blob], &reqs, SampleCfg::default(), rng, &mut timer)
        .unwrap()
}

/// Invariant 1: identical policy + lenience just above 1 => every draft
/// token is accepted, rollouts are bit-identical to the cache.
#[test]
fn identical_policy_full_acceptance() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(21);
    // small epsilon absorbs decode-vs-score float noise (~1e-6)
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.01));

    let (first, s0) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s0.drafts, 0, "epoch 1 has no drafts");
    let (second, s1) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s1.drafts, 4);
    assert!(s1.full_reuse_ratio > 0.99, "{s1:?}");
    assert_eq!(s1.new_tokens, 0);
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.response, b.response, "reused rollouts must be identical");
    }
}

/// Invariant 2: lenience zero => rejection at offset 0 (vanilla).
#[test]
fn zero_lenience_is_vanilla() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(22);
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Zero);

    collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    let (_, s1) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s1.drafts, 4);
    assert_eq!(s1.mean_prefix_len, 0.0, "{s1:?}");
    assert_eq!(s1.reused_tokens, 0);
    assert!(s1.new_tokens > 0);
}

/// Invariant 3: full-reuse variant decodes nothing after epoch 1.
#[test]
fn full_variant_reuses_everything() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(23);
    let mut spec = SpecRollout::new(ReuseVariant::Full, Lenience::Infinite);

    let (first, _) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    let (second, s1) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s1.verify_calls, 0, "full reuse skips verification");
    // drafts that ended with EOS are terminal -> zero new tokens for them;
    // length-capped drafts resume (prefix == gen cap is terminal too).
    for (a, b) in first.iter().zip(&second) {
        assert!(b.response.starts_with(&a.response) || b.reused == a.response.len());
    }
}

/// Invariant 6: the cache refreshes immediately — after a collect, every
/// request id's latest entry is the new rollout at this step's version.
#[test]
fn cache_refreshes_to_current_step() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(24);
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));

    let (r0, _) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    for r in &r0 {
        let e = spec.cache.latest(r.id).unwrap();
        assert_eq!(e.version, 0);
        assert_eq!(e.response, r.response);
        assert_eq!(e.logps.len(), e.response.len());
    }
    let (r1, _) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    for r in &r1 {
        assert_eq!(spec.cache.latest(r.id).unwrap().version, 1);
        // previous slot holds the step-0 rollout (delayed-reuse source)
        assert_eq!(spec.cache.previous(r.id).unwrap().version, 0);
    }
}

/// Random reuse never calls the verifier and reuses some prefix lengths
/// spread over [0, len].
#[test]
fn random_variant_skips_verifier() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(25);
    let mut spec = SpecRollout::new(ReuseVariant::Random, Lenience::Fixed(0.5));

    collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    let (_, s1) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s1.verify_calls, 0);
    assert_eq!(s1.drafts, 4);
}

/// Off variant: cache shadow-updates but drafts never form.
#[test]
fn off_variant_never_drafts_but_tracks_cache() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(26);
    let mut spec = SpecRollout::vanilla();

    collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(spec.cache.len(), 4, "shadow cache fills");
    let (_, s1) = collect_once(&mut spec, &mut pool, &policy, &tok, &mut rng);
    assert_eq!(s1.drafts, 0);
    assert_eq!(s1.reused_tokens, 0);
}

/// The two-phase oracle packs verification into ceil(n/batch) full-batch
/// calls (paper: one packed call per batch); the interleaved pipeline
/// verifies the same drafts in opportunistic sub-batches and must agree
/// byte-for-byte.
#[test]
fn verification_is_packed() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let mut pool = EnginePool::single(&eng, "tiny_b32").unwrap();
    let b = rollout.batch;
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));
    let mut spec_p = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.5));

    // n = batch + 2 requests -> 2 verify calls on the second pass
    let prompts: Vec<String> = (0..b + 2).map(|i| format!("{}+{}=", i % 90, (i * 7) % 90)).collect();
    let reqs: Vec<RolloutRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| RolloutRequest { id: i, prompt: tok.encode_prompt(p) })
        .collect();
    let mut timer = StageTimer::new();
    let mut rng = Rng::new(27);
    spec.run_two_phase(&mut rollout, &policy.blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    let (two, s1) = spec
        .run_two_phase(&mut rollout, &policy.blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(s1.drafts, b + 2);
    assert_eq!(s1.verify_calls, 2);
    assert!(timer.get("verification") > 0.0);

    // interleaved pipeline: same seed, same results, byte for byte
    let mut rng = Rng::new(27);
    spec_p
        .collect(&mut pool, &[&policy.blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    let (pipe, sp) = spec_p
        .collect(&mut pool, &[&policy.blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(sp.drafts, b + 2);
    assert_eq!(two.len(), pipe.len());
    // token-level agreement on the real engine (bitwise equality is pinned
    // down by the MockEngine tests; XLA may fuse verify_seat and refill
    // differently, so float logps are not compared here)
    for (a, c) in two.iter().zip(&pipe) {
        assert_eq!((a.id, &a.response), (c.id, &c.response));
    }
}
