//! The doc-link CI gate: every numbered section anchor (`§N`, `§§A-B`)
//! referenced from ROADMAP.md or from rustdoc comments in this crate's
//! sources, benches, tests, and examples must name a real `## N.` heading
//! in ARCHITECTURE.md. The book is normative — module docs point into it
//! by section number — so a renumbering that orphans a reference has to
//! fail CI instead of silently rotting.

use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // tests run with CARGO_MANIFEST_DIR = <repo>/rust
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ has a parent").to_path_buf()
}

/// Section numbers of ARCHITECTURE.md's `## N. Title` headings.
fn headings(book: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for line in book.lines() {
        let Some(rest) = line.strip_prefix("## ") else { continue };
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        if !digits.is_empty() && rest[digits.len()..].starts_with('.') {
            out.push(digits.parse().expect("ascii digits parse"));
        }
    }
    out
}

/// Every section number referenced as `§N` (or the endpoints of a
/// `§§A-B` range) in `text`. A `§` not followed by digits is prose, not
/// an anchor, and is ignored.
fn section_refs(text: &str) -> Vec<usize> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] != '§' {
            i += 1;
            continue;
        }
        while i < chars.len() && chars[i] == '§' {
            i += 1;
        }
        let mut read_num = |i: &mut usize| -> Option<usize> {
            let start = *i;
            while *i < chars.len() && chars[*i].is_ascii_digit() {
                *i += 1;
            }
            (*i > start).then(|| chars[start..*i].iter().collect::<String>().parse().unwrap())
        };
        let Some(first) = read_num(&mut i) else { continue };
        out.push(first);
        if i < chars.len() && chars[i] == '-' {
            let mut j = i + 1;
            if let Some(second) = read_num(&mut j) {
                out.push(second);
                i = j;
            }
        }
    }
    out
}

/// All .rs files under `dir`, recursively.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn architecture_section_anchors_resolve() {
    let root = repo_root();
    let book = fs::read_to_string(root.join("ARCHITECTURE.md"))
        .expect("ARCHITECTURE.md at the repo root");
    let sections = headings(&book);
    assert!(
        sections.len() >= 14,
        "ARCHITECTURE.md lost its numbered headings? found {sections:?}"
    );

    // The book's own internal cross-references are scanned too, so a
    // renumbering cannot orphan an in-book "§N" while CI stays green.
    let mut sources: Vec<PathBuf> =
        vec![root.join("ROADMAP.md"), root.join("ARCHITECTURE.md")];
    for dir in ["rust/src", "rust/benches", "rust/tests", "examples"] {
        rust_files(&root.join(dir), &mut sources);
    }

    let mut checked = 0usize;
    for path in sources {
        let Ok(text) = fs::read_to_string(&path) else { continue };
        for n in section_refs(&text) {
            assert!(
                sections.contains(&n),
                "{} references ARCHITECTURE.md §{n}, but the book has sections {sections:?}",
                path.display()
            );
            checked += 1;
        }
    }
    assert!(
        checked > 0,
        "no § anchors found anywhere — the reference scan is broken"
    );
}

#[test]
fn roadmap_quick_index_points_at_real_sections() {
    let root = repo_root();
    let roadmap =
        fs::read_to_string(root.join("ROADMAP.md")).expect("ROADMAP.md at the repo root");
    let book = fs::read_to_string(root.join("ARCHITECTURE.md"))
        .expect("ARCHITECTURE.md at the repo root");
    let sections = headings(&book);
    let refs = section_refs(&roadmap);
    assert!(
        !refs.is_empty(),
        "ROADMAP.md's quick index should reference ARCHITECTURE.md by § number"
    );
    for n in refs {
        assert!(sections.contains(&n), "ROADMAP.md §{n} is not a section of ARCHITECTURE.md");
    }
}
