//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a note) when
//! artifacts are missing so `cargo test` stays green in a fresh checkout.

use spec_rl::model::Policy;
use spec_rl::rollout::{RolloutEngine, SampleCfg, SeqTask};
use spec_rl::runtime::Engine;
use spec_rl::tokenizer::{Tokenizer, EOS};
use spec_rl::util::{Rng, StageTimer};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load("artifacts").unwrap())
}

#[test]
fn uniform_policy_decode_probs_are_uniform() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(5);
    let mut timer = StageTimer::new();
    // zero-init head => uniform next-token distribution => responses are
    // (almost surely) unfinished noise; just check mechanics.
    let tasks: Vec<SeqTask> = (0..4)
        .map(|i| SeqTask::fresh(i, tok.encode_prompt("1+1=")))
        .collect();
    let (results, stats) = rollout
        .run(&policy.blob, tasks, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(results.len(), 4);
    for r in &results {
        assert_eq!(r.reused, 0);
        assert_eq!(r.new_tokens, r.response.len());
        assert_eq!(r.logps.len(), r.response.len());
        // uniform logp ~= -ln(51) for every sampled token
        for lp in &r.logps {
            assert!((lp + (51f32).ln()).abs() < 0.05, "{lp}");
        }
    }
    assert!(stats.new_tokens > 0);
    assert!(timer.get("rollout") > 0.0);
}

#[test]
fn rollout_respects_gen_cap_and_eos() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let g = rollout.gen_len();
    let mut rng = Rng::new(6);
    let mut timer = StageTimer::new();
    let tasks: Vec<SeqTask> =
        (0..8).map(|i| SeqTask::fresh(i, tok.encode_prompt("9*9="))).collect();
    let (results, _) = rollout
        .run(&policy.blob, tasks, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    for r in &results {
        assert!(r.response.len() <= g);
        if r.finished {
            assert_eq!(*r.response.last().unwrap(), EOS);
        } else {
            assert_eq!(r.response.len(), g, "unfinished row must hit the cap");
        }
    }
}

#[test]
fn prefix_resume_counts_reused_tokens() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(7);
    let mut timer = StageTimer::new();
    let prefix = tok.encode("12345");
    let task = SeqTask {
        id: 0,
        prompt: tok.encode_prompt("1+1="),
        prefix_logps: vec![-1.0; prefix.len()],
        prefix: prefix.clone(),
    };
    let (results, stats) = rollout
        .run(&policy.blob, vec![task], SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(results[0].reused, 5);
    assert_eq!(&results[0].response[..5], &prefix[..]);
    assert_eq!(stats.reused_tokens, 5);
    assert_eq!(results[0].response.len(), 5 + results[0].new_tokens);
}

#[test]
fn terminal_prefix_skips_decoding_entirely() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let mut rng = Rng::new(8);
    let mut timer = StageTimer::new();
    let mut prefix = tok.encode("42");
    prefix.push(EOS);
    let task = SeqTask {
        id: 3,
        prompt: tok.encode_prompt("6*7="),
        prefix_logps: vec![-0.5; prefix.len()],
        prefix: prefix.clone(),
    };
    let (results, stats) = rollout
        .run(&policy.blob, vec![task], SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(stats.decode_steps, 0);
    assert_eq!(stats.new_tokens, 0);
    assert_eq!(results[0].response, prefix);
    assert!(results[0].finished);
    assert_eq!(results[0].logps, vec![-0.5; 3]);
}

#[test]
fn more_tasks_than_batch_refills_slots() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let b = rollout.batch;
    let mut rng = Rng::new(9);
    let mut timer = StageTimer::new();
    let tasks: Vec<SeqTask> =
        (0..b + 3).map(|i| SeqTask::fresh(i, tok.encode_prompt("2+2="))).collect();
    let (results, stats) = rollout
        .run(&policy.blob, tasks, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(results.len(), b + 3);
    // continuous batching: one prefill, overflow enters via slot refills
    assert_eq!(stats.waves, 1);
    assert!(stats.refills >= 1, "{stats:?}");
    // ids come back sorted
    let ids: Vec<usize> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..b + 3).collect::<Vec<_>>());
}

#[test]
fn lockstep_and_continuous_agree_on_real_engine() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "tiny_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "tiny_b32").unwrap();
    let b = rollout.batch;
    let mut timer = StageTimer::new();
    let mk_tasks = || -> Vec<SeqTask> {
        (0..b + 5).map(|i| SeqTask::fresh(i, tok.encode_prompt("7*6="))).collect()
    };
    let mut rng_a = Rng::new(77);
    let (cont, _) = rollout
        .run(&policy.blob, mk_tasks(), SampleCfg::default(), &mut rng_a, &mut timer)
        .unwrap();
    let mut rng_b = Rng::new(77);
    let (lock, _) = rollout
        .run_lockstep(&policy.blob, mk_tasks(), SampleCfg::default(), &mut rng_b, &mut timer)
        .unwrap();
    assert_eq!(cont.len(), lock.len());
    for (c, l) in cont.iter().zip(&lock) {
        assert_eq!(c.id, l.id);
        assert_eq!(c.response, l.response, "id {}", c.id);
        assert_eq!(c.logps, l.logps, "id {}", c.id);
    }
}

#[test]
fn engine_stats_accumulate() {
    let Some(eng) = engine() else { return };
    let policy = Policy::from_init(&eng, "nano_b32").unwrap();
    let tok = Tokenizer::new(&eng.manifest.charset);
    let mut rollout = RolloutEngine::new(&eng, "nano_b32").unwrap();
    let mut rng = Rng::new(10);
    let mut timer = StageTimer::new();
    let tasks = vec![SeqTask::fresh(0, tok.encode_prompt("1+2="))];
    rollout.run(&policy.blob, tasks, SampleCfg::default(), &mut rng, &mut timer).unwrap();
    let stats = eng.stats();
    assert!(stats.iter().any(|(k, s)| k == "nano_b32/prefill" && s.calls >= 1));
    assert!(stats.iter().any(|(k, _)| k == "nano_b32/read_gen"));
}
