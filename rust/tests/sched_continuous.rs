//! Continuous-batching scheduler invariants, runnable without artifacts:
//! the mock backend (testing::mock) implements the decode-entry contract
//! (including `verify`/`verify_seat`) with a deterministic content-hashed
//! model, so lockstep-vs-continuous equivalence, interleaved-pipeline vs
//! two-phase equivalence, sharded-pool vs single-engine equivalence,
//! upload-traffic budgets, and slot accounting are all plain unit tests.

use std::collections::HashSet;

use spec_rl::benchkit::{grouped, stale};
use spec_rl::rollout::{
    EnginePool, PipelineStats, Placement, RolloutEngine, SampleCfg, SeqResult, SeqTask,
};
use spec_rl::spec::{CacheEntry, Lenience, ReuseVariant, RolloutRequest, SpecRollout};
use spec_rl::testing::mock::{FaultPlan, MockEngine};
use spec_rl::tokenizer::{BOS, EOS};
use spec_rl::util::{Rng, StageTimer};

/// Geometry used by the deterministic-count tests.
const B: usize = 2;
const P: usize = 8;
const T: usize = 16;
const V: usize = 16;

fn fresh(id: usize, seed: i32) -> SeqTask {
    SeqTask::fresh(id, vec![BOS, 3 + (seed % 9), 4 + (seed % 7)])
}

fn with_prefix(id: usize, prefix_len: usize) -> SeqTask {
    SeqTask {
        id,
        prompt: vec![BOS, 5, 6],
        prefix: (0..prefix_len).map(|j| 3 + (j as i32 % 9)).collect(),
        prefix_logps: vec![-1.0; prefix_len],
    }
}

/// Deterministic skewed workload: remaining lengths 1, 4 and 8 over 2
/// slots (eos_bias = 0 => every row runs exactly to the cap).
fn skewed_tasks() -> Vec<SeqTask> {
    vec![with_prefix(0, 7), with_prefix(1, 4), with_prefix(2, 0)]
}

fn no_eos_engine() -> MockEngine {
    let mut m = MockEngine::new(B, P, T, V);
    m.eos_bias = 0.0;
    m
}

#[test]
fn continuous_strictly_reduces_decode_steps_on_skew() {
    let m = no_eos_engine();
    let blob = m.blob();
    let mut timer = StageTimer::new();

    let mut rng = Rng::new(11);
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let (cont, cstats) = eng
        .run(&blob, skewed_tasks(), SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();

    let mut rng = Rng::new(11);
    let (lock, lstats) = eng
        .run_lockstep(&blob, skewed_tasks(), SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();

    // Hand-derived schedule: chains of 1/4/8 samples over 2 slots, LPT
    // order (id2 rem=8 and id1 rem=4 start; id0 rem=1 refills id1's slot).
    assert_eq!(cstats.decode_steps, 7, "{cstats:?}");
    assert_eq!(lstats.decode_steps, 10, "{lstats:?}");
    assert!(cstats.decode_steps < lstats.decode_steps);
    assert_eq!(cstats.waves, 1);
    assert_eq!(cstats.refills, 1);
    assert_eq!(lstats.waves, 2);
    assert_eq!(lstats.refills, 0);
    // Slot-idle accounting: continuous wastes 4 row-steps, lockstep 10.
    assert_eq!(cstats.slot_idle_steps, 4);
    assert_eq!(lstats.slot_idle_steps, 10);
    assert!(
        cstats.slot_idle_fraction(B) < lstats.slot_idle_fraction(B),
        "{} vs {}",
        cstats.slot_idle_fraction(B),
        lstats.slot_idle_fraction(B)
    );

    // Equal outputs at equal seeds: same tokens, same logps, same flags.
    assert_eq!(cont.len(), lock.len());
    for (c, l) in cont.iter().zip(&lock) {
        assert_eq!(c.id, l.id);
        assert_eq!(c.response, l.response, "id {}", c.id);
        assert_eq!(c.logps, l.logps, "id {}", c.id);
        assert_eq!(c.reused, l.reused);
        assert_eq!(c.new_tokens, l.new_tokens);
        assert_eq!(c.finished, l.finished);
    }
    // token accounting identical
    assert_eq!(cstats.new_tokens, lstats.new_tokens);
    assert_eq!(cstats.new_tokens, 13); // 1 + 4 + 8
    assert_eq!(cstats.reused_tokens, 11); // 7 + 4 + 0
}

#[test]
fn no_per_step_bt_mask_traffic() {
    let m = no_eos_engine();
    let blob = m.blob();
    m.reset_counters();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();
    let mut rng = Rng::new(3);
    let (_, stats) = eng
        .run(&blob, skewed_tasks(), SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();

    // [B,T]-shaped uploads happen only at prefill (tokens+valid) and at
    // each refill (tokens+valid) — never per decode step.
    let bt_uploads = m.uploads_with_dims(&[B, T]);
    assert_eq!(bt_uploads, 2 * (stats.waves + stats.refills), "{stats:?}");
    assert!(
        stats.decode_steps > bt_uploads,
        "decode steps ({}) must dominate [B,T] uploads ({bt_uploads})",
        stats.decode_steps
    );
    // decode itself ships exactly three [B] i32 vectors per step (plus one
    // [B] last + one [B] f32 rowmask per refill, one [B] last at prefill).
    let b_uploads = m.uploads_with_dims(&[B]);
    assert_eq!(b_uploads, 3 * stats.decode_steps + stats.waves + 2 * stats.refills);
    // temperature is cached: a single [1] upload for the whole run.
    assert_eq!(m.uploads_with_dims(&[1]), 1);
}

#[test]
fn equivalence_holds_with_content_dependent_lengths() {
    // EOS pressure on: lengths vary by sampled content, scheduling is
    // irregular, outputs must still match lockstep byte-for-byte.
    let m = MockEngine::new(4, P, T, V);
    let blob = m.blob();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();
    let tasks = || -> Vec<SeqTask> {
        (0..11)
            .map(|i| if i % 3 == 0 { with_prefix(i, (i * 2) % 7) } else { fresh(i, i as i32) })
            .collect()
    };

    let mut rng = Rng::new(42);
    let (cont, cstats) = eng.run(&blob, tasks(), SampleCfg::default(), &mut rng, &mut timer).unwrap();
    let mut rng = Rng::new(42);
    let (lock, lstats) =
        eng.run_lockstep(&blob, tasks(), SampleCfg::default(), &mut rng, &mut timer).unwrap();

    let ids: Vec<usize> = cont.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..11).collect::<Vec<_>>(), "results id-sorted");
    for (c, l) in cont.iter().zip(&lock) {
        assert_eq!((c.id, &c.response, &c.logps), (l.id, &l.response, &l.logps));
        assert_eq!(c.finished, l.finished);
    }
    assert!(cstats.decode_steps <= lstats.decode_steps, "{cstats:?} vs {lstats:?}");
    for r in &cont {
        assert!(r.response.len() <= T - P);
        if r.finished {
            assert_eq!(*r.response.last().unwrap(), EOS);
        }
    }
}

#[test]
fn same_seed_same_schedule_same_results() {
    let m = MockEngine::new(3, P, T, V);
    let blob = m.blob();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();
    let tasks = || (0..8).map(|i| fresh(i, i as i32 * 5)).collect::<Vec<_>>();

    let mut rng = Rng::new(9);
    let (a, astats) = eng.run(&blob, tasks(), SampleCfg::default(), &mut rng, &mut timer).unwrap();
    let mut rng = Rng::new(9);
    let (b, bstats) = eng.run(&blob, tasks(), SampleCfg::default(), &mut rng, &mut timer).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!((x.id, &x.response, &x.logps), (y.id, &y.response, &y.logps));
    }
    assert_eq!(astats.decode_steps, bstats.decode_steps);
    assert_eq!(astats.refills, bstats.refills);
    assert_eq!(astats.slot_idle_steps, bstats.slot_idle_steps);
}

#[test]
fn terminal_drafts_bypass_the_device_entirely() {
    let m = MockEngine::new(B, P, T, V);
    let blob = m.blob();
    m.reset_counters();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();
    let gen_len = T - P;

    let mut eos_prefix = vec![4, 5, 6];
    eos_prefix.push(EOS);
    let tasks = vec![
        SeqTask {
            id: 0,
            prompt: vec![BOS, 7],
            prefix_logps: vec![-0.5; eos_prefix.len()],
            prefix: eos_prefix.clone(),
        },
        SeqTask {
            id: 1,
            prompt: vec![BOS, 8],
            prefix_logps: vec![-0.25; gen_len],
            prefix: vec![9; gen_len],
        },
    ];
    let mut rng = Rng::new(1);
    let (results, stats) = eng.run(&blob, tasks, SampleCfg::default(), &mut rng, &mut timer).unwrap();

    assert_eq!(stats.decode_steps, 0);
    assert_eq!(stats.new_tokens, 0);
    assert_eq!(stats.reused_tokens, eos_prefix.len() + gen_len);
    assert_eq!(m.calls_of("prefill"), 0, "terminal drafts must not prefill");
    assert_eq!(m.calls_of("decode"), 0);
    assert_eq!(results[0].response, eos_prefix);
    assert!(results[0].finished);
    assert_eq!(results[0].logps, vec![-0.5; eos_prefix.len()]);
    assert_eq!(results[1].response, vec![9; gen_len]);
    assert!(!results[1].finished, "cap-length prefix without EOS is unfinished");
}

// ---------------------------------------------------------------------------
// interleaved pipeline vs two-phase oracle
// ---------------------------------------------------------------------------

/// 11 requests over 4 slots: more tasks than slots forces mid-stream
/// refills/seats; prompt variety gives content-dependent (skewed) lengths.
fn pipe_requests() -> Vec<RolloutRequest> {
    (0..11)
        .map(|i| RolloutRequest {
            id: i,
            prompt: vec![BOS, 3 + (i as i32 % 9), 4 + (i as i32 % 7)],
        })
        .collect()
}

/// Drive `epochs` steps of one path against a fresh engine pool + cache.
/// `shards == 0` selects the blocking two-phase oracle (single engine);
/// `shards >= 1` runs the interleaved pipeline over that many mock
/// replicas under `placement` (the overlapped steal driver or PR 3's
/// static spill). Negative log-lenience stands in for policy drift: with
/// the mock's frozen policy, `p_curr == p_prev` exactly, so `log l < 0`
/// yields varied mid-draft rejections (the skew the pipeline must handle).
fn drive_placed(
    variant: ReuseVariant,
    shards: usize,
    epochs: usize,
    seed: u64,
    placement: Placement,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>) {
    let mocks = MockEngine::replicas(shards.max(1), 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool =
        (shards > 0).then(|| EnginePool::new(mocks.iter(), "mock").unwrap());
    let mut eng =
        (shards == 0).then(|| RolloutEngine::new(&mocks[0], "mock").unwrap());
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4)).with_placement(placement);
    let mut rng = Rng::new(seed);
    let mut timer = StageTimer::new();
    let mut all_results = Vec::new();
    let mut all_stats = Vec::new();
    for _ in 0..epochs {
        let (r, s) = if let Some(eng) = eng.as_mut() {
            spec.run_two_phase(eng, &blobs[0], &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
        } else {
            spec.collect(pool.as_mut().unwrap(), &blob_refs, &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
        }
        .unwrap();
        all_results.push(r);
        all_stats.push(s);
    }
    (all_results, all_stats)
}

fn drive(
    variant: ReuseVariant,
    shards: usize,
    epochs: usize,
    seed: u64,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>) {
    drive_placed(variant, shards, epochs, seed, Placement::Steal)
}

#[test]
fn pipeline_matches_two_phase_across_all_variants_and_shard_counts() {
    // 3 epochs: epoch 0 fills the cache, epoch 1 drafts from `latest`,
    // epoch 2 additionally exercises the Delayed variant's `previous`
    // slot. shards ∈ {1, 2, 4} must all match the two-phase oracle
    // byte-for-byte under BOTH placement disciplines — the overlapped
    // steal driver (the default) and the static one-pass spill: per-task
    // RNG streams make results invariant to placement and to how the
    // drive loop interleaves submits and completes, so neither the shard
    // count nor the driver can show up in the outputs.
    for variant in [
        ReuseVariant::Off,
        ReuseVariant::Spec,
        ReuseVariant::Random,
        ReuseVariant::Delayed,
        ReuseVariant::Full,
    ] {
        let (two, ts) = drive(variant, 0, 3, 77);
        for shards in [2usize, 4] {
            let (stat, _) = drive_placed(variant, shards, 3, 77, Placement::Static);
            for (epoch, (ra, rb)) in stat.iter().zip(&two).enumerate() {
                assert_eq!(ra.len(), rb.len(), "{variant:?} static {shards} epoch {epoch}");
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(
                        (x.id, &x.response, &x.logps),
                        (y.id, &y.response, &y.logps),
                        "{variant:?} static {shards} epoch {epoch}"
                    );
                }
            }
        }
        let mut ps1: Vec<PipelineStats> = Vec::new();
        for shards in [1usize, 2, 4] {
            let (pipe, ps) = drive(variant, shards, 3, 77);
            for (epoch, (ra, rb)) in pipe.iter().zip(&two).enumerate() {
                assert_eq!(ra.len(), rb.len(), "{variant:?} shards {shards} epoch {epoch}");
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(x.id, y.id, "{variant:?} shards {shards} epoch {epoch}");
                    assert_eq!(
                        x.response, y.response,
                        "{variant:?} shards {shards} epoch {epoch} id {}",
                        x.id
                    );
                    assert_eq!(
                        x.logps, y.logps,
                        "{variant:?} shards {shards} epoch {epoch} id {}",
                        x.id
                    );
                    assert_eq!(
                        (x.reused, x.new_tokens, x.finished),
                        (y.reused, y.new_tokens, y.finished),
                        "{variant:?} shards {shards} epoch {epoch} id {}",
                        x.id
                    );
                }
            }
            for (epoch, (a, b)) in ps.iter().zip(&ts).enumerate() {
                let tag = format!("{variant:?} shards {shards} epoch {epoch}");
                assert_eq!(a.new_tokens, b.new_tokens, "{tag}");
                assert_eq!(a.reused_tokens, b.reused_tokens, "{tag}");
                assert_eq!(a.drafts, b.drafts, "{tag}");
                assert_eq!(a.prefix_tokens, b.prefix_tokens, "{tag}");
                assert_eq!(a.full_reuses, b.full_reuses, "{tag}");
                assert_eq!(a.shard_device_calls.len(), shards, "{tag}");
            }
            if shards == 1 {
                ps1 = ps;
            }
        }
        // sanity on the single-shard run: draft-bearing variants actually
        // drafted once warm (Delayed needs two cache generations before
        // `previous` exists)
        let ps = ps1;
        match variant {
            ReuseVariant::Off => assert_eq!(ps[1].drafts + ps[2].drafts, 0),
            ReuseVariant::Delayed => {
                assert_eq!(ps[1].drafts, 0, "no `previous` entry yet");
                assert_eq!(ps[2].drafts, 11, "epoch 2 drafts from `previous`");
            }
            _ => assert_eq!(ps[1].drafts, 11, "{variant:?} epoch 1 must draft everything"),
        }
    }
}

#[test]
fn pipeline_matches_two_phase_at_full_acceptance_boundary() {
    // log l = 0 with a frozen policy accepts every draft token: epoch 2
    // is pure reuse (terminal drafts) on both paths.
    let m = MockEngine::new(3, P, T, V);
    let blob = m.blob();
    let mut pool = EnginePool::single(&m, "mock").unwrap();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut a = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.0));
    let mut b = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.0));
    let mut timer = StageTimer::new();
    let mut rng_a = Rng::new(5);
    let mut rng_b = Rng::new(5);
    for epoch in 0..2 {
        let (ra, sa) = a
            .collect(&mut pool, &[&blob], &pipe_requests(), SampleCfg::default(), &mut rng_a, &mut timer)
            .unwrap();
        let (rb, sb) = b
            .run_two_phase(&mut eng, &blob, &pipe_requests(), SampleCfg::default(), &mut rng_b, &mut timer)
            .unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!((x.id, &x.response, &x.logps), (y.id, &y.response, &y.logps));
        }
        if epoch == 1 {
            assert!(sa.full_reuse_ratio > 0.99, "{sa:?}");
            assert_eq!(sa.new_tokens, 0, "pure reuse decodes nothing");
            assert_eq!(sb.new_tokens, 0);
        }
    }
}

#[test]
fn pipeline_uses_fewer_device_calls_than_two_phase() {
    // Heavily drafted skewed workload: every request carries a draft with
    // a content-dependent accepted prefix. The pipeline folds verification
    // into the seat (no blocking verify wave, no refill forward for
    // verified rows), so verify+decode+refill must come out strictly lower.
    let m = MockEngine::new(4, P, T, V);
    let blob = m.blob();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let reqs: Vec<RolloutRequest> = (0..40)
        .map(|i| RolloutRequest {
            id: i,
            prompt: vec![BOS, 3 + (i as i32 % 9), 4 + (i as i32 % 7)],
        })
        .collect();
    let mut timer = StageTimer::new();

    let count = |m: &MockEngine, entries: &[&str]| -> usize {
        entries.iter().map(|e| m.calls_of(e)).sum()
    };

    // pipeline path: epoch 0 (cold) then drafted epoch 1 under counters
    let mut pool = EnginePool::single(&m, "mock").unwrap();
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(-0.4));
    let mut rng = Rng::new(13);
    spec.collect(&mut pool, &[&blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    m.reset_counters();
    let (pipe_res, pipe_stats) = spec
        .collect(&mut pool, &[&blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    let pipe_calls = count(&m, &["verify", "verify_seat", "decode", "refill"]);
    assert_eq!(pipe_calls, pipe_stats.device_calls(), "{pipe_stats:?}");
    assert_eq!(m.calls_of("verify"), 0, "pipeline never uses the blocking entry");

    // two-phase oracle: identical seed and cache history
    let mut spec2 = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(-0.4));
    let mut rng = Rng::new(13);
    spec2
        .run_two_phase(&mut eng, &blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    m.reset_counters();
    let (two_res, two_stats) = spec2
        .run_two_phase(&mut eng, &blob, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    let two_calls = count(&m, &["verify", "verify_seat", "decode", "refill"]);
    assert_eq!(two_calls, two_stats.device_calls(), "{two_stats:?}");
    assert_eq!(m.calls_of("verify_seat"), 0, "oracle never seats");
    assert_eq!(m.calls_of("verify"), 10, "40 drafts / batch 4 = 10 packed waves");

    // same outputs, strictly fewer device calls
    for (x, y) in pipe_res.iter().zip(&two_res) {
        assert_eq!((x.id, &x.response, &x.logps), (y.id, &y.response, &y.logps));
    }
    assert!(
        pipe_calls < two_calls,
        "pipeline {pipe_calls} must beat two-phase {two_calls} ({pipe_stats:?} vs {two_stats:?})"
    );
}

#[test]
fn pipeline_without_drafts_matches_plain_run() {
    // Off-variant epoch 0 degenerates to the decode-only scheduler.
    let m = no_eos_engine();
    let blob = m.blob();
    let mut pool = EnginePool::single(&m, "mock").unwrap();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();

    let mut spec = SpecRollout::vanilla();
    let reqs: Vec<RolloutRequest> = (0..5)
        .map(|i| RolloutRequest { id: i, prompt: vec![BOS, 5 + i as i32, 6] })
        .collect();
    let mut rng = Rng::new(3);
    let (via_spec, s) = spec
        .collect(&mut pool, &[&blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    assert_eq!(s.verify_calls, 0);
    assert_eq!(s.drafts, 0);

    // same nonce consumption pattern: burn the verify nonce, then run
    let mut rng = Rng::new(3);
    let _vnonce = rng.next_u64();
    let tasks: Vec<SeqTask> =
        reqs.iter().map(|r| SeqTask::fresh(r.id, r.prompt.clone())).collect();
    let (plain, _) = eng.run(&blob, tasks, SampleCfg::default(), &mut rng, &mut timer).unwrap();
    for (x, y) in via_spec.iter().zip(&plain) {
        assert_eq!((x.id, &x.response, &x.logps), (y.id, &y.response, &y.logps));
    }
}

// ---------------------------------------------------------------------------
// sharded pool vs single engine
// ---------------------------------------------------------------------------

/// The skewed 40-draft acceptance workload (same shape as `bench_shards`).
fn sharded_requests() -> Vec<RolloutRequest> {
    (0..40)
        .map(|i| RolloutRequest {
            id: i,
            prompt: vec![BOS, 3 + (i as i32 % 9), 4 + (i as i32 % 7)],
        })
        .collect()
}

#[test]
fn sharding_strictly_reduces_per_engine_device_calls() {
    // 40 drafted tasks over B=4 slots per shard: as the pool grows, the
    // busiest engine's verify+decode+refill total (the critical path on
    // real hardware, where shards run concurrently) must strictly shrink,
    // while outputs stay byte-identical to the single-engine run.
    let reqs = sharded_requests();
    let mut baseline: Option<Vec<SeqResult>> = None;
    let mut prev_max = usize::MAX;
    for shards in [1usize, 2, 4] {
        let mocks = MockEngine::replicas(shards, 4, P, T, V);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(-0.4));
        let mut rng = Rng::new(13);
        let mut timer = StageTimer::new();

        // epoch 0 (cold) fills the cache; epoch 1 is the measured,
        // fully-drafted step
        spec.collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
            .unwrap();
        for m in &mocks {
            m.reset_counters();
        }
        let (res, stats) = spec
            .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
            .unwrap();

        // per-shard telemetry matches each engine's own counters
        let per_engine: Vec<usize> = mocks.iter().map(|m| m.device_calls()).collect();
        assert_eq!(stats.shard_device_calls, per_engine, "shards={shards}");
        assert_eq!(stats.device_calls(), per_engine.iter().sum::<usize>());
        assert!(
            per_engine.iter().all(|&c| c > 0),
            "idle shard on a 40-draft step: {per_engine:?}"
        );

        // byte-identical outputs regardless of shard count
        match &baseline {
            None => baseline = Some(res),
            Some(base) => {
                assert_eq!(base.len(), res.len());
                for (a, b) in base.iter().zip(&res) {
                    assert_eq!(
                        (a.id, &a.response, &a.logps),
                        (b.id, &b.response, &b.logps),
                        "shards={shards}"
                    );
                }
            }
        }

        let max = *per_engine.iter().max().unwrap();
        assert!(max < prev_max, "shards={shards}: busiest engine {max} !< {prev_max}");
        prev_max = max;
    }
}

/// Observable cache state after a budgeted run: (surviving latest ids,
/// surviving previous ids, cumulative eviction stats, total tokens,
/// summed per-step eviction counters from PipelineStats).
type CacheTrace = (Vec<usize>, Vec<usize>, (u64, u64), usize, (usize, usize));

/// Drive `epochs` budgeted steps under `shards` shards; the budget must
/// hold after every step.
fn drive_budgeted(shards: usize, budget: usize, epochs: usize) -> CacheTrace {
    let mocks = MockEngine::replicas(shards, 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(-0.4))
        .with_cache_budget(Some(budget));
    let mut rng = Rng::new(5);
    let mut timer = StageTimer::new();
    let mut step_evictions = 0usize;
    let mut step_evicted_tokens = 0usize;
    for _ in 0..epochs {
        let (_, s) = spec
            .collect(&mut pool, &blob_refs, &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
            .unwrap();
        step_evictions += s.cache_evictions;
        step_evicted_tokens += s.cache_evicted_tokens;
        assert!(
            spec.cache.total_tokens() <= budget,
            "budget violated under {shards} shards: {} > {budget}",
            spec.cache.total_tokens()
        );
    }
    let latest: Vec<usize> = (0..11).filter(|&id| spec.cache.latest(id).is_some()).collect();
    let previous: Vec<usize> =
        (0..11).filter(|&id| spec.cache.previous(id).is_some()).collect();
    (latest, previous, spec.cache.eviction_stats(), spec.cache.total_tokens(), (step_evictions, step_evicted_tokens))
}

#[test]
fn cache_budget_is_global_and_shard_count_invariant() {
    // The pool merges results before the single shared RolloutCache
    // refreshes, so the `spec.cache_budget` token budget binds globally —
    // N shards never hold N budgets — and the eviction sequence (and the
    // per-step counters surfaced through PipelineStats) must match the
    // single-engine run exactly.
    let budget = 48;
    let single = drive_budgeted(1, budget, 3);
    let sharded = drive_budgeted(2, budget, 3);
    assert_eq!(single, sharded, "cache evolution must be shard-count-invariant");

    let (latest, previous, (evictions, evicted_tokens), total, (se, st)) = single;
    assert!(evictions > 0, "budget {budget} must bind on this workload");
    assert_eq!(evictions as usize, se, "PipelineStats must aggregate every eviction");
    assert_eq!(evicted_tokens as usize, st);
    // Oldest-version-first: the `previous` tier drains before any `latest`
    // entry is touched, so surviving previous entries and evicted latest
    // entries cannot coexist.
    assert!(
        previous.is_empty() || latest.len() == 11,
        "previous {previous:?} survived while latest entries were evicted ({latest:?})"
    );
    assert!(total <= budget);
}

// ---------------------------------------------------------------------------
// prefix-trie cache on grouped workloads
// ---------------------------------------------------------------------------

/// Grouped request geometry fit to the test envelope (prompts stay
/// inside `V`; the crafted-entry knobs are unused by live runs).
fn grouped_cfg(prompts: usize, group: usize) -> grouped::GroupedCfg {
    grouped::GroupedCfg { prompts, group, vocab: V, ..grouped::GroupedCfg::default() }
}

/// Post-step cache observables: (resident tokens, shared tokens, live
/// nodes, cumulative eviction stats).
type TrieTrace = (usize, usize, usize, (u64, u64));

/// Drive `epochs` grouped steps with the group-keyed trie cache.
/// `shards == 0` selects the two-phase oracle; `shards >= 1` the
/// interleaved pipeline. After every step the trie's structural audit
/// must pass, the budget (if any) must hold, and the merged report's
/// gauges must agree with the cache itself.
fn drive_grouped(
    variant: ReuseVariant,
    shards: usize,
    cfg: &grouped::GroupedCfg,
    epochs: usize,
    budget: Option<usize>,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>, Vec<TrieTrace>) {
    let mocks = MockEngine::replicas(shards.max(1), 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = (shards > 0).then(|| EnginePool::new(mocks.iter(), "mock").unwrap());
    let mut eng = (shards == 0).then(|| RolloutEngine::new(&mocks[0], "mock").unwrap());
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4))
        .with_group(cfg.group)
        .with_cache_budget(budget);
    let reqs = grouped::requests(cfg);
    let mut rng = Rng::new(29);
    let mut timer = StageTimer::new();
    let mut results = Vec::new();
    let mut stats = Vec::new();
    let mut trace = Vec::new();
    for epoch in 0..epochs {
        let (r, s) = if let Some(eng) = eng.as_mut() {
            spec.run_two_phase(eng, &blobs[0], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        } else {
            let pool = pool.as_mut().unwrap();
            spec.collect(pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        }
        .unwrap();
        spec.cache
            .check_invariants()
            .unwrap_or_else(|e| panic!("{variant:?} shards {shards} epoch {epoch}: {e}"));
        if let Some(b) = budget {
            assert!(
                spec.cache.total_tokens() <= b,
                "{variant:?} shards {shards} epoch {epoch}: budget violated ({} > {b})",
                spec.cache.total_tokens()
            );
        }
        // the merged report's trie gauges are the cache's own numbers
        assert_eq!(s.cache_nodes, spec.cache.cache_nodes(), "shards {shards} epoch {epoch}");
        assert_eq!(
            s.cache_shared_tokens,
            spec.cache.shared_tokens(),
            "shards {shards} epoch {epoch}"
        );
        trace.push((
            spec.cache.total_tokens(),
            spec.cache.shared_tokens(),
            spec.cache.cache_nodes(),
            spec.cache.eviction_stats(),
        ));
        results.push(r);
        stats.push(s);
    }
    (results, stats, trace)
}

#[test]
fn grouped_pipeline_matches_two_phase_across_variants_and_shards() {
    // The trainer's grouped id layout (prompt × group + sample) with the
    // group-keyed trie cache: every variant × shards {1, 2, 4} must stay
    // byte-identical to the two-phase oracle over 3 epochs, with the
    // whole cache evolution (resident/shared/node counts, evictions)
    // shard-count-invariant — drafts materialized by the trie walk are
    // byte-exact, so acceptance cannot drift.
    for (prompts, group) in [(3usize, 4usize), (2, 8)] {
        let cfg = grouped_cfg(prompts, group);
        for variant in [
            ReuseVariant::Off,
            ReuseVariant::Spec,
            ReuseVariant::Random,
            ReuseVariant::Delayed,
            ReuseVariant::Full,
        ] {
            let (two, _, two_trace) = drive_grouped(variant, 0, &cfg, 3, None);
            for shards in [1usize, 2, 4] {
                let (pipe, ps, pipe_trace) = drive_grouped(variant, shards, &cfg, 3, None);
                for (epoch, (ra, rb)) in pipe.iter().zip(&two).enumerate() {
                    let tag = format!("{variant:?} g{group} shards {shards} epoch {epoch}");
                    assert_eq!(ra.len(), rb.len(), "{tag}");
                    for (x, y) in ra.iter().zip(rb) {
                        assert_eq!(x.id, y.id, "{tag}");
                        assert_eq!(x.response, y.response, "{tag} id {}", x.id);
                        assert_eq!(x.logps, y.logps, "{tag} id {}", x.id);
                    }
                }
                assert_eq!(
                    pipe_trace, two_trace,
                    "{variant:?} g{group} shards {shards}: cache evolution diverged"
                );
                assert_eq!(ps.len(), 3, "one merged report per epoch");
            }
            // Full reuse re-inserts the reused trajectory verbatim: from
            // epoch 1 on, latest and previous share their whole path, so
            // the dedup gauge must actually engage.
            if variant == ReuseVariant::Full {
                let (_, shared, _, _) = two_trace[1];
                assert!(shared > 0, "full reuse must share tokens across generations");
            }
        }
    }
}

#[test]
fn grouped_trie_budget_is_global_and_shard_count_invariant() {
    // The grouped extension of `cache_budget_is_global_and_shard_count_
    // invariant`: one shared group-keyed trie refreshes from the merged,
    // id-sorted results, so subtree-budget eviction must evolve
    // identically for every shard count, and the budget binds on resident
    // (deduplicated) tokens globally — never per shard.
    let cfg = grouped_cfg(3, 4);
    let budget = 32usize;
    let single = drive_grouped(ReuseVariant::Spec, 1, &cfg, 3, Some(budget));
    let double = drive_grouped(ReuseVariant::Spec, 2, &cfg, 3, Some(budget));
    let quad = drive_grouped(ReuseVariant::Spec, 4, &cfg, 3, Some(budget));
    assert_eq!(single.2, double.2, "cache evolution must be shard-count-invariant");
    assert_eq!(single.2, quad.2, "cache evolution must be shard-count-invariant");
    for (epoch, ((ra, rb), rc)) in
        single.0.iter().zip(&double.0).zip(&quad.0).enumerate()
    {
        for ((x, y), z) in ra.iter().zip(rb).zip(rc) {
            assert_eq!(
                (x.id, &x.response, &x.logps),
                (y.id, &y.response, &y.logps),
                "epoch {epoch}"
            );
            assert_eq!((x.id, &x.response), (z.id, &z.response), "epoch {epoch}");
        }
    }
    let (_, _, _, (evictions, _)) = *single.2.last().unwrap();
    assert!(evictions > 0, "budget {budget} must bind on this workload");
    // PipelineStats aggregates every eviction across steps
    let step_sum: usize = single.1.iter().map(|s| s.cache_evictions).sum();
    assert_eq!(evictions as usize, step_sum);
}

// ---------------------------------------------------------------------------
// sibling-spine fallback drafts (ARCHITECTURE.md §8, `spec.sibling_drafts`)
// ---------------------------------------------------------------------------

/// Pressure geometry scaled to this file's envelope (gen_len = 8): the
/// crafted 7-token spines fit inside the generation region and the
/// `pressure_budget` accounting lands exactly (warm epoch, partial
/// refresh, tighten — one stranded id per group, siblings intact).
fn sibling_cfg() -> grouped::GroupedCfg {
    grouped::GroupedCfg {
        prompts: 3,
        group: 4,
        divergence_depth: 4,
        epoch_overlap: 6,
        tail: 3,
        vocab: V,
    }
}

/// Drive `epochs` live grouped steps from a pre-stranded trie: every
/// group starts one leaf short under a binding budget, so the sibling
/// fallback (when enabled) has real work from the first step on.
/// `shards == 0` selects the two-phase oracle.
fn drive_sibling(
    sibling: bool,
    shards: usize,
    epochs: usize,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>) {
    let cfg = sibling_cfg();
    let mocks = MockEngine::replicas(shards.max(1), 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = (shards > 0).then(|| EnginePool::new(mocks.iter(), "mock").unwrap());
    let mut eng = (shards == 0).then(|| RolloutEngine::new(&mocks[0], "mock").unwrap());
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(-0.4))
        .with_group(cfg.group)
        .with_sibling_drafts(sibling);
    spec.cache.insert_batch(grouped::pressure_entries(&cfg, 0));
    spec.cache.insert_batch(grouped::pressure_refresh(&cfg, 1));
    spec.cache.set_token_budget(Some(grouped::pressure_budget(&cfg)));
    spec.step = 2;
    let reqs = grouped::requests(&cfg);
    let mut rng = Rng::new(29);
    let mut timer = StageTimer::new();
    let mut results = Vec::new();
    let mut stats = Vec::new();
    for epoch in 0..epochs {
        let (r, s) = if let Some(eng) = eng.as_mut() {
            spec.run_two_phase(eng, &blobs[0], &reqs, SampleCfg::default(), &mut rng, &mut timer)
        } else {
            let pool = pool.as_mut().unwrap();
            spec.collect(pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        }
        .unwrap();
        spec.cache
            .check_invariants()
            .unwrap_or_else(|e| panic!("sibling={sibling} shards {shards} epoch {epoch}: {e}"));
        results.push(r);
        stats.push(s);
    }
    (results, stats)
}

#[test]
fn sibling_fallback_sweep_is_deterministic_and_pinned_to_the_oracle() {
    // The §6 contract survives cross-slot drafts: sibling selection reads
    // only the shared trie before placement and the borrowed tokens are
    // verified under the *requesting* id's streams, so for either knob
    // setting the pipeline stays byte-identical to the two-phase oracle
    // at every shard count, and the fallback hit counts are
    // shard-count-invariant. Knob off takes zero fallbacks (own-leaf
    // behavior is exactly today's); knob on must actually exercise the
    // fallback from the stranded start.
    for sibling in [false, true] {
        let (oracle, ostats) = drive_sibling(sibling, 0, 3);
        let hits: usize = ostats.iter().map(|s| s.sibling_draft_hits).sum();
        if sibling {
            assert!(hits > 0, "stranded ids must ride sibling spines");
            assert!(
                ostats[0].branch_depth_rows > 0,
                "divergence gauge must see the stranded groups"
            );
        } else {
            assert_eq!(hits, 0, "knob off must never take a fallback");
            assert_eq!(ostats[0].branch_depth_rows, 0, "gauge is knob-gated");
        }
        for shards in [1usize, 2, 4] {
            let (pipe, pstats) = drive_sibling(sibling, shards, 3);
            assert_eq!(pipe.len(), oracle.len());
            for (epoch, (ra, rb)) in pipe.iter().zip(&oracle).enumerate() {
                let tag = format!("sibling={sibling} shards {shards} epoch {epoch}");
                assert_eq!(ra.len(), rb.len(), "{tag}");
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(x.id, y.id, "{tag}");
                    assert_eq!(x.response, y.response, "{tag} id {}", x.id);
                    assert_eq!(x.logps, y.logps, "{tag} id {}", x.id);
                    assert_eq!(
                        (x.reused, x.new_tokens, x.finished),
                        (y.reused, y.new_tokens, y.finished),
                        "{tag} id {}",
                        x.id
                    );
                }
            }
            let phits: usize = pstats.iter().map(|s| s.sibling_draft_hits).sum();
            assert_eq!(
                phits, hits,
                "sibling={sibling} shards {shards}: fallback count must be shard-invariant"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// mid-step work stealing + adaptive verify seating (PR 4)
// ---------------------------------------------------------------------------

/// Draft length of the adversarial stale-draft workload at the test
/// geometry (gen_len = 8): every draft the same length, every 4th stale.
const STALE_LEN: usize = 5;
const STALE_LENIENCE: f32 = -0.4;
const STALE_SEED: u64 = 13;

/// `eos_bias = 0` replicas: rejected rows decode exactly to the cap, so
/// the static-placement imbalance is structural, not sampled.
fn stale_mocks(shards: usize) -> Vec<MockEngine> {
    let mut mocks = MockEngine::replicas(shards, 4, P, T, V);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    mocks
}

/// One adversarial drafted step over `shards` engines; returns the
/// id-sorted results, merged stats, and the mocks (for counter/seat-trace
/// inspection — each holds exactly this one step's traffic).
fn stale_collect(
    shards: usize,
    placement: Placement,
    seat_min: usize,
) -> (Vec<SeqResult>, PipelineStats, Vec<MockEngine>) {
    let mocks = stale_mocks(shards);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE)
        .with_placement(placement);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let cfg = SampleCfg { verify_seat_min: seat_min, ..SampleCfg::default() };
    let reqs = stale::requests(stale::N_TASKS, V);
    let (res, stats) = spec
        .collect(&mut pool, &blob_refs, &reqs, cfg, &mut rng, &mut timer)
        .unwrap();
    (res, stats, mocks)
}

/// The blocking two-phase oracle on the same adversarial step.
fn stale_oracle() -> Vec<SeqResult> {
    let mocks = stale_mocks(1);
    let blob = mocks[0].blob();
    let mut eng = RolloutEngine::new(&mocks[0], "mock").unwrap();
    let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let (res, _) = spec
        .run_two_phase(
            &mut eng,
            &blob,
            &stale::requests(stale::N_TASKS, V),
            SampleCfg::default(),
            &mut rng,
            &mut timer,
        )
        .unwrap();
    res
}

fn assert_same_results(a: &[SeqResult], b: &[SeqResult], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{tag}");
        assert_eq!(x.response, y.response, "{tag} id {}", x.id);
        assert_eq!(x.logps, y.logps, "{tag} id {}", x.id);
        assert_eq!(
            (x.reused, x.new_tokens, x.finished),
            (y.reused, y.new_tokens, y.finished),
            "{tag} id {}",
            x.id
        );
    }
}

#[test]
fn stealing_matches_the_oracle_and_tightens_the_critical_path() {
    // Adversarially skewed: same-length drafts make the placement
    // estimate uninformative, and id-correlated staleness makes PR 3's
    // deterministic spill pin every expensive draft to shard 0. The
    // steal-queue must (a) stay byte-identical to the two-phase oracle,
    // (b) actually engage, and (c) strictly tighten the busiest engine's
    // device-call total vs static placement.
    let oracle = stale_oracle();
    for shards in [2usize, 4] {
        let (steal_res, steal_stats, steal_mocks) =
            stale_collect(shards, Placement::Steal, 1);
        let (static_res, static_stats, _) = stale_collect(shards, Placement::Static, 1);
        assert_same_results(&steal_res, &oracle, &format!("steal vs oracle, {shards} shards"));
        assert_same_results(&static_res, &oracle, &format!("static vs oracle, {shards} shards"));

        assert!(steal_stats.steal_count > 0, "{shards} shards: no steals ({steal_stats:?})");
        assert_eq!(static_stats.steal_count, 0, "static placement must never steal");

        // merged telemetry matches each engine's own counters
        let per_engine: Vec<usize> = steal_mocks.iter().map(|m| m.device_calls()).collect();
        assert_eq!(steal_stats.shard_device_calls, per_engine, "shards={shards}");

        let steal_max = *steal_stats.shard_device_calls.iter().max().unwrap();
        let static_max = *static_stats.shard_device_calls.iter().max().unwrap();
        assert!(
            steal_max < static_max,
            "{shards} shards: stealing must strictly tighten the critical path \
             ({steal_max} !< {static_max})"
        );
    }
}

#[test]
fn stolen_rows_never_seat_on_two_engines() {
    // Lifecycle pinning, observed from the device side: every task's row
    // is seated (prefill/refill/verify_seat) on exactly one engine, no
    // matter how the shared queue drained. Prompts are per-task-unique,
    // so the mock's seat trace attributes rows to engines exactly.
    for shards in [2usize, 4] {
        let (_, stats, mocks) = stale_collect(shards, Placement::Steal, 1);
        assert!(stats.steal_count > 0, "stealing must engage for the trace to mean much");
        let seats: Vec<HashSet<Vec<i32>>> =
            mocks.iter().map(|m| m.seated_rows().into_iter().collect()).collect();
        let total_seats: usize = mocks.iter().map(|m| m.seated_rows().len()).sum();
        assert_eq!(total_seats, stale::N_TASKS, "each drafted row seats exactly once");
        for i in 0..shards {
            for j in i + 1..shards {
                let both: Vec<_> = seats[i].intersection(&seats[j]).collect();
                assert!(
                    both.is_empty(),
                    "rows seated on engines {i} and {j}: {both:?} (KV would have migrated)"
                );
            }
        }
        let union: HashSet<_> = seats.iter().flatten().cloned().collect();
        assert_eq!(union.len(), stale::N_TASKS, "every drafted row seated somewhere");
    }
}

#[test]
fn verify_seat_min_sweep_is_byte_identical() {
    // Adaptive seating only reshapes verify_seat packing; per-task RNG
    // streams keep outputs byte-identical for every threshold (including
    // seat_min == batch, which must not deadlock) at any shard count.
    let oracle = stale_oracle();
    for shards in [1usize, 2] {
        for seat_min in [1usize, 2, 4] {
            let (res, stats, _) = stale_collect(shards, Placement::Steal, seat_min);
            assert_same_results(
                &res,
                &oracle,
                &format!("seat_min {seat_min}, {shards} shards"),
            );
            assert!(stats.verify_calls > 0, "drafted step must verify ({stats:?})");
        }
    }
}

// ---------------------------------------------------------------------------
// overlapped shard stepping (PR 5): submit/complete + the virtual clock
// ---------------------------------------------------------------------------

/// [`stale_collect`] on replicas sharing a virtual clock (eos_bias = 0),
/// so the run reports makespans.
fn stale_collect_clocked(
    shards: usize,
    placement: Placement,
) -> (Vec<SeqResult>, PipelineStats, Vec<MockEngine>) {
    let mut mocks = MockEngine::clocked_replicas(shards, 4, P, T, V);
    for m in &mut mocks {
        m.eos_bias = 0.0;
    }
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE)
        .with_placement(placement);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let reqs = stale::requests(stale::N_TASKS, V);
    let (res, stats) = spec
        .collect(&mut pool, &blob_refs, &reqs, SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    (res, stats, mocks)
}

#[test]
fn overlapped_driver_beats_the_serialized_makespan() {
    // The overlapped steal driver submits every live shard's chain before
    // completing any, so on the virtual clock the realized makespan must
    // come out strictly below the serialized baseline (the summed
    // device-busy time a host-serialized driver would realize) — while
    // results stay byte-identical to the two-phase oracle.
    let oracle = stale_oracle();
    for shards in [2usize, 4] {
        let (res, stats, mocks) = stale_collect_clocked(shards, Placement::Steal);
        assert_same_results(&res, &oracle, &format!("overlap vs oracle, {shards} shards"));
        assert!(
            stats.overlap_makespan > 0.0,
            "{shards} shards: the virtual clock never moved ({stats:?})"
        );
        assert!(
            stats.overlap_makespan < stats.serial_makespan,
            "{shards} shards: overlapped makespan {} must be strictly below serialized {}",
            stats.overlap_makespan,
            stats.serial_makespan
        );
        // The serialized column really is the summed device-busy time.
        let busy: f64 = mocks.iter().map(spec_rl::runtime::Backend::device_busy_secs).sum();
        assert!(
            (stats.serial_makespan - busy).abs() < 1e-6,
            "serial_makespan {} != summed busy {busy}",
            stats.serial_makespan
        );
    }
}

#[test]
fn serialized_disciplines_realize_the_serial_makespan() {
    // Static placement (and a one-shard pool) drive each chain through
    // the blocking composed step, never overlapping two forwards: their
    // realized makespan must equal the serialized column (up to f64
    // summation order). This is the degenerate end the overlap
    // accounting is calibrated against.
    for (shards, placement) in [(1usize, Placement::Steal), (2, Placement::Static)] {
        let (_, stats, _) = stale_collect_clocked(shards, placement);
        assert!(stats.serial_makespan > 0.0, "{stats:?}");
        assert!(
            (stats.overlap_makespan - stats.serial_makespan).abs() < 1e-6,
            "{shards} shards / {placement:?}: nothing overlapped, yet realized {} != serialized {}",
            stats.overlap_makespan,
            stats.serial_makespan
        );
    }
}

#[test]
fn idle_shards_of_an_overlapped_pool_submit_nothing() {
    // 4 clocked shards, one 1-token task: shards that find the queue
    // empty must make zero device calls AND consume zero virtual device
    // time — an idle shard is free under the overlapped driver too.
    let mocks = MockEngine::clocked_replicas(4, B, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut timer = StageTimer::new();
    let (res, stats) = pool
        .run_pipeline(
            &blob_refs,
            vec![with_prefix(0, 7)],
            Vec::new(),
            0.0,
            SampleCfg::default(),
            3,
            4,
            &mut timer,
        )
        .unwrap();
    assert_eq!(res.len(), 1);
    assert!(stats.overlap_makespan > 0.0, "shard 0 did run the task ({stats:?})");
    for (i, m) in mocks.iter().enumerate().skip(1) {
        assert_eq!(m.counters().calls.len(), 0, "shard {i} should submit nothing");
        assert_eq!(
            spec_rl::runtime::Backend::device_busy_secs(m),
            0.0,
            "shard {i} should consume no virtual device time"
        );
    }
}

// ---------------------------------------------------------------------------
// device-resident sampling vs the forced-host pipeline (PR 6)
// ---------------------------------------------------------------------------

/// Drive `epochs` pipeline steps over `shards` shards with sampling either
/// on-device (the default wherever the bundle exports `sample`/`read_step`)
/// or forced back onto the host `read_gen` + `TopPSampler` path.
fn drive_sampling(
    variant: ReuseVariant,
    shards: usize,
    epochs: usize,
    seed: u64,
    host: bool,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>) {
    let mocks = MockEngine::replicas(shards, 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    pool.set_host_sampling(host);
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4));
    let mut rng = Rng::new(seed);
    let mut timer = StageTimer::new();
    let mut all_results = Vec::new();
    let mut all_stats = Vec::new();
    for _ in 0..epochs {
        let (r, s) = spec
            .collect(&mut pool, &blob_refs, &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
            .unwrap();
        all_results.push(r);
        all_stats.push(s);
    }
    (all_results, all_stats)
}

#[test]
fn device_sampling_is_byte_identical_to_host_and_cuts_readback() {
    // ARCHITECTURE.md §12: the device replays each task's host RNG stream
    // statelessly (seed from the step nonce + task id, skip draws-so-far)
    // and mirrors TopPSampler bit-for-bit, so outputs must match the
    // forced-host pipeline byte-for-byte across every reuse variant, shard
    // count, and cache generation — while the per-round readback drops from
    // the [B*V probs | B aux] payload to the fused [B tok | B ptok | B aux].
    for variant in [
        ReuseVariant::Off,
        ReuseVariant::Spec,
        ReuseVariant::Random,
        ReuseVariant::Delayed,
        ReuseVariant::Full,
    ] {
        for shards in [1usize, 2, 4] {
            let (dev, ds) = drive_sampling(variant, shards, 3, 77, false);
            let (host, hs) = drive_sampling(variant, shards, 3, 77, true);
            for (epoch, (ra, rb)) in dev.iter().zip(&host).enumerate() {
                let tag = format!("{variant:?} shards {shards} epoch {epoch}");
                assert_eq!(ra.len(), rb.len(), "{tag}");
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(
                        (x.id, &x.response, &x.logps),
                        (y.id, &y.response, &y.logps),
                        "{tag}"
                    );
                    assert_eq!(
                        (x.reused, x.new_tokens, x.finished),
                        (y.reused, y.new_tokens, y.finished),
                        "{tag} id {}",
                        x.id
                    );
                }
            }
            for (epoch, (a, b)) in ds.iter().zip(&hs).enumerate() {
                let tag = format!("{variant:?} shards {shards} epoch {epoch}");
                assert_eq!(a.new_tokens, b.new_tokens, "{tag}");
                assert_eq!(a.decode_steps, b.decode_steps, "{tag}");
                // Every epoch with any device traffic must read strictly
                // less on the fused path (V = 16 already dwarfs the 3-lane
                // step readback; real vocabularies widen the gap).
                if b.readback_bytes > 0 {
                    assert!(
                        a.readback_bytes < b.readback_bytes,
                        "{tag}: device readback {} !< host readback {}",
                        a.readback_bytes,
                        b.readback_bytes
                    );
                }
                assert!(a.upload_bytes > 0, "{tag}: uploads must be accounted");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shard-failure recovery: the chaos matrix (ARCHITECTURE.md §13)
// ---------------------------------------------------------------------------

/// [`stale_collect`] with a [`FaultPlan`] armed on one shard before the
/// step runs. The pool must mark that shard dead at the injected error,
/// requeue its work, and finish on the survivors.
fn stale_collect_chaos(
    shards: usize,
    placement: Placement,
    fault_shard: usize,
    plan: FaultPlan,
) -> (Vec<SeqResult>, PipelineStats, Vec<MockEngine>) {
    let mocks = stale_mocks(shards);
    mocks[fault_shard].arm_faults(plan);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
    let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE)
        .with_placement(placement);
    let mut rng = Rng::new(STALE_SEED);
    let mut timer = StageTimer::new();
    let (res, stats) = spec
        .collect(
            &mut pool,
            &blob_refs,
            &stale::requests(stale::N_TASKS, V),
            SampleCfg::default(),
            &mut rng,
            &mut timer,
        )
        .unwrap();
    (res, stats, mocks)
}

#[test]
fn chaos_matrix_kills_every_phase_and_stays_pinned_to_the_oracle() {
    // Shard death at every lifecycle boundary × shards {2, 4} × both
    // placement disciplines, outputs pinned byte-identical to the
    // single-shard two-phase oracle. The phase knobs (a sticky plan
    // models a dead host — every call after the trip fails too):
    //   - at_call(0): the shard dies on its very first device call, before
    //     anything seats (the Draft-submission boundary);
    //   - verify_seat: dies inside the Verify wave;
    //   - decode: dies mid-Decode, with seated rows holding accepted
    //     prefixes AND partially-decoded tails that must be discarded and
    //     re-derived on a survivor (the §6 stream-replay case);
    //   - read_step: dies at the Done-boundary readback after the forwards
    //     ran.
    // Recovery is deterministic because a requeued draft carries the
    // original p_prev logps (`CacheEntry::requeue_draft`), so the
    // survivor's re-verification replays the same uniforms over the same
    // acceptance inputs, and the sample stream replays from draw 0.
    let oracle = stale_oracle();
    for shards in [2usize, 4] {
        for placement in [Placement::Steal, Placement::Static] {
            for (phase, plan) in [
                ("first-call", FaultPlan::at_call(0).sticky()),
                ("verify", FaultPlan::at_entry("verify_seat").sticky()),
                ("decode", FaultPlan::at_entry("decode").sticky()),
                ("readback", FaultPlan::at_entry("read_step").sticky()),
            ] {
                let (res, stats, mocks) =
                    stale_collect_chaos(shards, placement, 1, plan);
                let tag = format!("{placement:?} {shards} shards, kill at {phase}");
                assert_same_results(&res, &oracle, &tag);
                assert_eq!(
                    stats.shard_failures, 1,
                    "{tag}: exactly one shard death ({stats:?})"
                );
                // every result id appears exactly once (no task lost, none
                // duplicated) — the oracle pin already implies it, but spell
                // the invariant out
                let ids: Vec<usize> = res.iter().map(|r| r.id).collect();
                assert_eq!(ids, (0..stale::N_TASKS).collect::<Vec<_>>(), "{tag}");
                // the dead shard seated nothing after its trip: its call log
                // froze at the failure point
                let dead_calls = mocks[1].counters().calls.len();
                let (_, _, healthy_mocks) = stale_collect_chaos(
                    shards,
                    placement,
                    1,
                    FaultPlan::default(), // armed but trips nothing
                );
                assert!(
                    dead_calls < healthy_mocks[1].counters().calls.len(),
                    "{tag}: the fault never actually cut shard 1 short"
                );
            }
        }
    }
}

#[test]
fn chaos_with_zero_tripped_faults_is_byte_identical_to_the_healthy_run() {
    // An armed-but-never-tripped plan must leave the step bit-for-bit
    // equal to the unfaulted run: the fault check itself is free.
    for placement in [Placement::Steal, Placement::Static] {
        let (healthy, hs, _) = stale_collect(2, placement, 1);
        let (armed, armed_stats, _) =
            stale_collect_chaos(2, placement, 1, FaultPlan::at_call(usize::MAX));
        assert_same_results(&armed, &healthy, &format!("{placement:?} armed-idle"));
        assert_eq!(armed_stats.shard_failures, 0);
        assert_eq!(armed_stats.requeued_tasks, 0);
        assert_eq!(armed_stats.device_calls(), hs.device_calls(), "{placement:?}");
    }
}

#[test]
fn decode_phase_death_requeues_the_seated_rows() {
    // A shard killed mid-Decode holds once-seated rows; the recovery path
    // must requeue them (requeued_tasks > 0) and the survivors must seat
    // them again — so across the whole run those task rows legitimately
    // appear on two engines, but never on two LIVE engines (the property
    // suite drills this with the seat-entry attribution).
    for shards in [2usize, 4] {
        let (res, stats, _) = stale_collect_chaos(
            shards,
            Placement::Steal,
            1,
            FaultPlan::at_entry("decode").sticky(),
        );
        assert_eq!(res.len(), stale::N_TASKS);
        assert_eq!(stats.shard_failures, 1, "shards={shards}");
        assert!(
            stats.requeued_tasks > 0,
            "shards={shards}: a mid-decode death strands seated rows ({stats:?})"
        );
    }
}

#[test]
fn refill_preserves_live_neighbour_state() {
    // A long row must produce the same tokens whether or not its
    // neighbour slot gets refilled mid-flight — i.e. refills must not
    // disturb live rows' device state.
    let m = no_eos_engine();
    let blob = m.blob();
    let mut eng = RolloutEngine::new(&m, "mock").unwrap();
    let mut timer = StageTimer::new();

    // Run id2 (full-length) alone: no refills ever touch its neighbours.
    let mut rng = Rng::new(11);
    let (alone, _) = eng
        .run(&blob, vec![with_prefix(2, 0)], SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    // Same task inside the skewed workload: two refills happen around it.
    let mut rng = Rng::new(11);
    let (packed, _) = eng
        .run(&blob, skewed_tasks(), SampleCfg::default(), &mut rng, &mut timer)
        .unwrap();
    let packed_id2 = packed.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(alone[0].response, packed_id2.response);
    assert_eq!(alone[0].logps, packed_id2.logps);
}

// ---------------------------------------------------------------------------
// predicted-length scheduling + adaptive draft control (§14)
// ---------------------------------------------------------------------------

/// [`drive_placed`] with the §14 knobs on: predicted-length LPT seating
/// plus (optionally) adaptive per-row draft caps. The knobs are set
/// identically on both drive paths, so each pipeline run is compared to
/// an oracle driven with the very same settings.
fn drive_adaptive(
    variant: ReuseVariant,
    shards: usize,
    epochs: usize,
    seed: u64,
    placement: Placement,
    adapt: bool,
) -> (Vec<Vec<SeqResult>>, Vec<PipelineStats>) {
    let mocks = MockEngine::replicas(shards.max(1), 4, P, T, V);
    let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
    let blob_refs: Vec<_> = blobs.iter().collect();
    let mut pool = (shards > 0).then(|| EnginePool::new(mocks.iter(), "mock").unwrap());
    let mut eng = (shards == 0).then(|| RolloutEngine::new(&mocks[0], "mock").unwrap());
    let mut spec = SpecRollout::new(variant, Lenience::Fixed(-0.4))
        .with_placement(placement)
        .with_predict(true)
        .with_draft_control(1, 0, adapt);
    let mut rng = Rng::new(seed);
    let mut timer = StageTimer::new();
    let mut all_results = Vec::new();
    let mut all_stats = Vec::new();
    for _ in 0..epochs {
        let (r, s) = if let Some(eng) = eng.as_mut() {
            spec.run_two_phase(eng, &blobs[0], &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
        } else {
            spec.collect(pool.as_mut().unwrap(), &blob_refs, &pipe_requests(), SampleCfg::default(), &mut rng, &mut timer)
        }
        .unwrap();
        all_results.push(r);
        all_stats.push(s);
    }
    (all_results, all_stats)
}

#[test]
fn predicted_and_adaptive_knobs_stay_pinned_to_the_oracle() {
    // The §14 knobs change *which* draft lengths are offered (adaptive
    // caps) and the order rows seat (predicted LPT) — never the verified
    // outputs for a given setting: the predictor consumes no RNG, and
    // clipping happens in the shared `prepare` before either drive path
    // diverges. Every variant × adapt setting must match its own
    // two-phase oracle byte-for-byte at 1/2/4 shards under both
    // placement disciplines, across enough epochs for the caps to have
    // actually moved (the EWMA warms on epoch 1, clips from epoch 2 on).
    for adapt in [false, true] {
        for variant in [
            ReuseVariant::Off,
            ReuseVariant::Spec,
            ReuseVariant::Random,
            ReuseVariant::Delayed,
            ReuseVariant::Full,
        ] {
            let (two, _) = drive_adaptive(variant, 0, 3, 77, Placement::Steal, adapt);
            for shards in [1usize, 2, 4] {
                let (pipe, _) = drive_adaptive(variant, shards, 3, 77, Placement::Steal, adapt);
                for (epoch, (ra, rb)) in pipe.iter().zip(&two).enumerate() {
                    assert_same_results(
                        ra,
                        rb,
                        &format!("{variant:?} adapt={adapt} steal {shards} epoch {epoch}"),
                    );
                }
            }
            for shards in [2usize, 4] {
                let (pipe, _) = drive_adaptive(variant, shards, 3, 77, Placement::Static, adapt);
                for (epoch, (ra, rb)) in pipe.iter().zip(&two).enumerate() {
                    assert_same_results(
                        ra,
                        rb,
                        &format!("{variant:?} adapt={adapt} static {shards} epoch {epoch}"),
                    );
                }
            }
        }
    }
}

/// Crafted drafts with a deterministic acceptance boundary: the first
/// `accept` tokens record log-probs of -50 (the recomputed ratio
/// saturates at 1 — certainly kept), the rest +50 (ratio ~e^-50 —
/// certainly cut), so every row's `reused` comes out exactly `accept`.
fn boundary_entries(n: usize, len: usize, accept: usize) -> Vec<(usize, CacheEntry)> {
    (0..n)
        .map(|i| {
            let response: Vec<i32> = (0..len).map(|j| 3 + ((i + j) % (V - 3)) as i32).collect();
            let logps: Vec<f32> =
                (0..len).map(|j| if j < accept { -50.0 } else { 50.0 }).collect();
            (i, CacheEntry { response, logps, version: 0, finished: false })
        })
        .collect()
}

#[test]
fn adaptive_caps_shrink_on_stale_drafts_and_regrow_on_full_acceptance() {
    // Directed walk through the §14 feedback loop, every step derived by
    // hand. Epoch 1: uncapped 6-token drafts keep only 2 tokens (ratio
    // 1/3 < SHRINK_BELOW), so every row's cap halves to 3. Epochs 2-3:
    // the refreshed full-length drafts clip to the cap, the frozen mock
    // policy accepts everything offered (ratio 1 >= GROW_ABOVE), and the
    // cap doubles back 3 -> 6 -> 12. Epoch 4: the regrown cap clears the
    // full 8-token draft — no truncation, pure reuse.
    const N: usize = 4;
    let gen_len = T - P;
    let mut m = MockEngine::new(N, P, T, V);
    m.eos_bias = 0.0;
    let blob = m.blob();
    let mut pool = EnginePool::single(&m, "mock").unwrap();
    let mut spec = SpecRollout::new(ReuseVariant::Spec, Lenience::Fixed(0.0))
        .with_draft_control(1, 0, true);
    spec.cache.insert_batch(boundary_entries(N, 6, 2));
    spec.step = 1;
    let reqs = stale::requests(N, V);
    let mut rng = Rng::new(21);
    let mut timer = StageTimer::new();
    for epoch in 1..=4 {
        let (res, stats) = spec
            .collect(&mut pool, &[&blob], &reqs, SampleCfg::default(), &mut rng, &mut timer)
            .unwrap();
        assert_eq!(res.len(), N, "epoch {epoch}");
        assert_eq!(stats.drafts, N, "epoch {epoch}: every row drafts");
        match epoch {
            1 => {
                // No cap yet: the full crafted draft is offered, the +50
                // boundary cuts it at 2 of 6.
                assert_eq!(stats.draft_trunc, 0, "{stats:?}");
                assert_eq!((stats.draft_len_lo, stats.draft_len_hi), (6, 6));
                for r in &res {
                    assert_eq!(r.reused, 2, "id {}", r.id);
                }
                for id in 0..N {
                    assert_eq!(spec.draft_ctl.cap(id), 3, "id {id}: 6/2 floored at min=1");
                }
            }
            2 => {
                // Fresh 8-token drafts clip to the shrunken cap; full
                // acceptance of the clipped draft doubles it back.
                assert_eq!(stats.draft_trunc, N, "{stats:?}");
                assert_eq!((stats.draft_len_lo, stats.draft_len_hi), (3, 3));
                for r in &res {
                    assert_eq!(r.reused, 3, "id {}", r.id);
                }
                for id in 0..N {
                    assert_eq!(spec.draft_ctl.cap(id), 6, "id {id}: cap regrew 3 -> 6");
                }
            }
            3 => {
                assert_eq!(stats.draft_trunc, N, "{stats:?}");
                assert_eq!((stats.draft_len_lo, stats.draft_len_hi), (6, 6));
                for r in &res {
                    assert_eq!(r.reused, 6, "id {}", r.id);
                }
                for id in 0..N {
                    assert_eq!(spec.draft_ctl.cap(id), 12, "id {id}: cap regrew 6 -> 12");
                }
            }
            _ => {
                // Cap 12 no longer binds the 8-token draft: terminal
                // full reuse, nothing left to decode.
                assert_eq!(stats.draft_trunc, 0, "{stats:?}");
                assert_eq!((stats.draft_len_lo, stats.draft_len_hi), (gen_len, gen_len));
                assert_eq!(stats.full_reuses, N);
                assert_eq!(stats.new_tokens, 0, "pure reuse decodes nothing");
                for r in &res {
                    assert_eq!(r.reused, gen_len, "id {}", r.id);
                }
            }
        }
    }
}

#[test]
fn adversarial_length_estimates_never_change_outputs() {
    // Seed the predictor with *inverted* lengths — early (cheap) ids
    // claimed long, late ids claimed short — the worst case for the
    // predicted-LPT order. The schedule degrades toward shortest-first,
    // but §6 RNG streams keep the outputs byte-identical to the
    // unpredicted two-phase oracle; misprediction can only cost
    // makespan, never correctness.
    let oracle = stale_oracle();
    for shards in [1usize, 2, 4] {
        let mocks = stale_mocks(shards);
        let blobs: Vec<_> = mocks.iter().map(|m| m.blob()).collect();
        let blob_refs: Vec<_> = blobs.iter().collect();
        let mut pool = EnginePool::new(mocks.iter(), "mock").unwrap();
        let mut spec = stale::warmed(stale::N_TASKS, STALE_LEN, V, STALE_LENIENCE)
            .with_predict(true);
        for id in 0..stale::N_TASKS {
            spec.predictor.observe_len(id, 1 + (stale::N_TASKS - id) * 7);
            spec.predictor.observe_acceptance(id, 1, 2);
        }
        let mut rng = Rng::new(STALE_SEED);
        let mut timer = StageTimer::new();
        let (res, stats) = spec
            .collect(
                &mut pool,
                &blob_refs,
                &stale::requests(stale::N_TASKS, V),
                SampleCfg::default(),
                &mut rng,
                &mut timer,
            )
            .unwrap();
        assert_same_results(&res, &oracle, &format!("inverse estimates, {shards} shards"));
        assert_eq!(stats.predict_rows, stale::N_TASKS, "every row was scored");
        assert!(
            stats.mean_predict_err > 0.0,
            "inverted estimates must register as wrong ({stats:?})"
        );
    }
}
